//! Cluster scale-out: grow a heterogeneous deployment from one
//! (high-end, low-end) pair to a mixed fleet behind the cluster-level
//! router, and watch throughput scale while the per-pair utilization
//! stays visible.
//!
//! ```bash
//! cargo run --release --example cluster_scaleout
//! cargo run --release --example cluster_scaleout -- --max-pairs 8 --policy slo-aware
//! ```

use cronus::config::cli::Parser;
use cronus::cronus::router::RoutePolicy;
use cronus::launcher::{cluster_sweep, ExperimentOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parser = Parser::new("cluster_scaleout", "1→N pair cluster sweep")
        .opt("n", "requests per run", Some("300"))
        .opt("seed", "trace seed", Some("42"))
        .opt("max-pairs", "largest cluster size to sweep", Some("4"))
        .opt(
            "policy",
            "route policy (round-robin | least-outstanding | slo-aware)",
            Some("least-outstanding"),
        );
    let args = parser.parse(&args).unwrap_or_else(|e| {
        eprintln!("{e}\n{}", parser.usage());
        std::process::exit(2);
    });
    let opts = ExperimentOpts {
        n_requests: args.get_usize("n").unwrap(),
        seed: args.get_u64("seed").unwrap(),
    };
    let max_pairs = args.get_usize("max-pairs").unwrap();
    let policy_name = args.get("policy").unwrap();
    let policy = RoutePolicy::from_name(policy_name).unwrap_or_else(|| {
        eprintln!("unknown route policy {policy_name:?}");
        std::process::exit(2);
    });

    let (table, points) = cluster_sweep(&opts, policy, max_pairs, None);
    table.print();

    // Per-pair utilization of the largest cluster: every instance's busy
    // fraction of the cluster makespan, so capability imbalance is
    // visible pair by pair.
    let last = points.last().expect("sweep produced no points");
    let makespan = last.outcome.report.makespan_s.max(1e-12);
    println!(
        "\nper-pair utilization at {} pairs (makespan {:.2}s):",
        last.n_pairs, makespan
    );
    for inst in &last.outcome.instances {
        println!(
            "  {:<28} busy {:>5.1}%  iters {:>6}  prefill {:>9} tok  decode {:>9} tok",
            inst.name,
            100.0 * inst.busy_time_s / makespan,
            inst.n_iterations,
            inst.tokens_prefilled,
            inst.tokens_decoded
        );
    }

    let base = &points[0];
    println!(
        "\nthroughput scaling 1 → {} pairs: {:.2}x ({:.2} → {:.2} req/s, policy {})",
        last.n_pairs,
        last.scaling,
        base.outcome.report.throughput_rps,
        last.outcome.report.throughput_rps,
        policy.name()
    );
    println!(
        "cluster-wide tails at {} pairs: TTFT p99 {:.3}s, TBT p99 {:.4}s",
        last.n_pairs,
        last.outcome.report.ttft_p99_s,
        last.outcome.report.tbt_p99_s
    );

    // The scale-out contract this example exists to demonstrate.
    if policy == RoutePolicy::LeastOutstandingTokens && last.n_pairs >= 4 {
        assert!(
            last.scaling >= 3.0,
            "expected >= 3x throughput from 1 → {} pairs, got {:.2}x",
            last.n_pairs,
            last.scaling
        );
        println!("\n[ok] >= 3x scaling from 1 to {} pairs", last.n_pairs);
    }
}
