//! Sweep every (GPU pair, model, system) combination the paper evaluates
//! and print the resulting throughput/latency matrix — a compact view of
//! Table 2 + Fig. 4 at reduced request count.
//!
//! ```bash
//! cargo run --release --example heterogeneous_sweep [-- --n 300]
//! ```

use cronus::benchkit::Table;
use cronus::config::cli::Parser;
use cronus::config::{DeploymentConfig, SystemKind};
use cronus::launcher::{latency_at_rate, max_throughput, paper_trace, ExperimentOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parser = Parser::new("heterogeneous_sweep", "sweep GPU pairs × systems")
        .opt("n", "requests per run", Some("300"))
        .opt("seed", "trace seed", Some("42"))
        .opt("rate-frac", "fig4 rate as a fraction of the slowest system's capacity", Some("0.7"));
    let args = parser.parse(&args).unwrap_or_else(|e| {
        eprintln!("{e}\n{}", parser.usage());
        std::process::exit(2);
    });
    let opts = ExperimentOpts {
        n_requests: args.get_usize("n").unwrap(),
        seed: args.get_u64("seed").unwrap(),
    };
    let rate_frac = args.get_f64("rate-frac").unwrap();

    let trace = paper_trace(&opts);
    for (label, cfg) in DeploymentConfig::paper_matrix() {
        let mut table = Table::new(
            format!("{label} ({} requests)", opts.n_requests),
            &["Approach", "max thpt (req/s)", "TTFT p99 (s)", "TBT p99 (s)"],
        );
        // Common sub-saturation rate for the latency columns.
        let min_cap = SystemKind::ALL
            .iter()
            .map(|&k| max_throughput(k, &cfg, &trace).report.throughput_rps)
            .fold(f64::INFINITY, f64::min);
        let rate = (min_cap * rate_frac).max(0.1);
        for kind in SystemKind::ALL {
            let cap = max_throughput(kind, &cfg, &trace);
            let lat = latency_at_rate(kind, &cfg, &trace, rate);
            table.row(vec![
                kind.name().to_string(),
                format!("{:.2}", cap.report.throughput_rps),
                format!("{:.3}", lat.report.ttft_p99_s),
                format!("{:.4}", lat.report.tbt_p99_s),
            ]);
        }
        table.print();
        println!("(latency columns at {rate:.2} req/s fixed-interval arrivals)");
    }
}
