//! Quickstart: deploy Cronus on a simulated A100+A10 pair, serve a small
//! Azure-like trace, and compare against data parallelism.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cronus::config::{DeploymentConfig, SystemKind};
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::{build_system, replay_trace};
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    // 1. Describe the deployment: one high-end + one low-end GPU, the
    //    paper's engine defaults (512-token chunked prefill, 16-token KV
    //    blocks, 100 Gbps interconnect).
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    println!(
        "deployment: {} + {} serving {} ({} params)",
        cfg.high_gpu.name,
        cfg.low_gpu.name,
        cfg.model.name,
        cfg.model.param_count()
    );

    // 2. Generate a workload: 200 conversation requests with the Azure
    //    2023 trace statistics, all arriving at t=0 (max-throughput mode).
    let trace = generate(200, &AzureTraceConfig::default(), 42);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);

    // 3. Serve it with Cronus (partially disaggregated prefill) and with
    //    the DP+chunked baseline.  `replay_trace` feeds the recorded
    //    arrivals through the online submit/advance/drain lifecycle.
    for kind in [SystemKind::Cronus, SystemKind::DpChunked] {
        let mut sys = build_system(kind, &cfg);
        let out = replay_trace(sys.as_mut(), &trace);
        println!("{}", out.report.summary());
        for inst in &out.instances {
            println!(
                "    {:<18} busy {:>7.2}s  iters {:>6}  prefill {:>8} tok  decode {:>8} tok",
                inst.name,
                inst.busy_time_s,
                inst.n_iterations,
                inst.tokens_prefilled,
                inst.tokens_decoded
            );
        }
    }
}
