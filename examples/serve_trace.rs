//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled tiny-LLaMA artifacts (Pallas attention kernels
//! inside a JAX model, lowered to HLO text at build time), serves a
//! batch of Azure-shaped requests through the threaded Rust server via
//! the PJRT CPU client, and reports wall-clock latency/throughput.
//! Python is not involved at any point of this run.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace
//! ```

use cronus::runtime::artifacts_dir;
use cronus::server::{RealServer, ServeRequest};
use cronus::util::rng::Rng;
use cronus::util::stats;
use cronus::workload::azure::{generate, AzureTraceConfig};
use std::time::Instant;

fn main() -> cronus::util::error::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {dir:?} — run `make artifacts` first");
        std::process::exit(2);
    }

    let n_requests = std::env::var("SERVE_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24usize);

    // Azure-shaped workload, scaled to the tiny model's 512-token window:
    // inputs ~ mean 1014/16 ≈ 64 tokens, outputs ~ mean 247/16 ≈ 16.
    let cfg = AzureTraceConfig {
        mean_input: 64.0,
        mean_output: 16.0,
        sigma_input: 0.7,
        sigma_output: 0.6,
        min_input: 8,
        max_input: 320,
        min_output: 4,
        max_output: 64,
    };
    let trace = generate(n_requests, &cfg, 2024);
    let mut rng = Rng::new(7);

    println!("loading artifacts + compiling HLO entry points (one-time)...");
    let t0 = Instant::now();
    let server = RealServer::start(&dir)?;
    println!("server up in {:.2}s; serving {n_requests} requests", t0.elapsed().as_secs_f64());

    let t_serve = Instant::now();
    for r in &trace {
        let prompt: Vec<i32> =
            (0..r.input_len).map(|_| rng.range(1, 2047) as i32).collect();
        server.submit(ServeRequest {
            id: r.id,
            prompt,
            max_new_tokens: r.output_len,
        });
    }
    let responses = server.shutdown()?;
    let wall = t_serve.elapsed().as_secs_f64();

    assert_eq!(responses.len(), trace.len(), "all requests must complete");
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let ttfts: Vec<f64> = responses.iter().map(|r| r.ttft_s).collect();
    let tbts: Vec<f64> =
        responses.iter().flat_map(|r| r.tbt_s.iter().copied()).collect();

    println!("\n=== end-to-end results (real model, PJRT CPU, wall clock) ===");
    println!("requests            : {}", responses.len());
    println!("output tokens       : {total_tokens}");
    println!("makespan            : {wall:.2}s");
    println!("throughput          : {:.2} req/s, {:.1} tok/s",
        responses.len() as f64 / wall, total_tokens as f64 / wall);
    println!("TTFT   mean/p50/p99 : {:.3}s / {:.3}s / {:.3}s",
        stats::mean(&ttfts), stats::percentile(&ttfts, 50.0), stats::percentile(&ttfts, 99.0));
    println!("TBT    mean/p50/p99 : {:.4}s / {:.4}s / {:.4}s",
        stats::mean(&tbts), stats::percentile(&tbts, 50.0), stats::percentile(&tbts, 99.0));
    let sample = &responses[0];
    println!("sample completion (req {}): {:?}", sample.id, &sample.tokens);
    Ok(())
}
