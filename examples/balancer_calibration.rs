//! The Balancer up close: calibrate the paper's Eq. 2 / Eq. 3 predictors
//! by profiling (as §4.4 does on real GPUs), then watch Algorithm 1 pick
//! partial-prefill lengths as the chunked-prefill instance's load varies.
//!
//! ```bash
//! cargo run --release --example balancer_calibration
//! ```

use cronus::benchkit::Table;
use cronus::cronus::balancer::{Balancer, SplitPolicy};
use cronus::engine::instance::EngineStats;
use cronus::simgpu::fit::calibrate;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::{A10, A100};

fn main() {
    let ppi = PerfModel::new(A10, LLAMA3_8B);
    let cpi = PerfModel::new(A100, LLAMA3_8B);

    // Profile both instances with 1% measurement noise and fit the
    // paper's linear models.
    let (prefill, chunked) = calibrate(&ppi, &cpi, 512, 0.01, 7);
    println!("Eq. 2 (partial prefill on {}):", ppi.gpu.name);
    println!(
        "  T = {:.3} µs/token · L + {:.3} ms   (R² {:.4}, MAPE {:.2}%)",
        prefill.k_p * 1e6,
        prefill.b_p * 1e3,
        prefill.r2,
        prefill.mape * 100.0
    );
    println!("Eq. 3 (chunked prefill iteration on {}):", cpi.gpu.name);
    println!(
        "  t = {:.3} µs/ctx-tok · L_p2 + {:.1} ns/ctx-tok · ΣL_d + {:.3} ms   (R² {:.4}, MAPE {:.2}%)",
        chunked.k_ctxp * 1e6,
        chunked.k_ctxd * 1e9,
        chunked.b_c * 1e3,
        chunked.r2,
        chunked.mape * 100.0
    );

    let balancer = Balancer::new(SplitPolicy::Balanced, prefill, chunked, 512);
    let mut table = Table::new(
        "Algorithm 1 decisions (prompt 2048 tokens) vs CPI load",
        &["decode reqs", "Σ decode ctx", "L_p", "L_p/L_in", "T_ppi est", "T_cpi est"],
    );
    for n_decode in [0usize, 32, 64, 128, 256, 400] {
        let stats = EngineStats {
            n_decode,
            decode_ctx_sum: n_decode * 1300,
            n_prefilling: 0,
            waiting: 0,
            free_blocks: 25_000,
            block_size: 16,
            total_blocks: 30_000,
        };
        let d = balancer.split(2048, &stats);
        table.row(vec![
            n_decode.to_string(),
            (n_decode * 1300).to_string(),
            d.partial_len.to_string(),
            format!("{:.2}", d.partial_len as f64 / 2048.0),
            format!("{:.1} ms", d.t_prefill_est * 1e3),
            format!("{:.1} ms", d.t_chunked_est * 1e3),
        ]);
    }
    table.print();
    println!("\nThe busier the CPI, the more prefix Cronus pushes to the low-end GPU.");
}
