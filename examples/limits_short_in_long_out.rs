//! The paper's §6 limitation, demonstrated: with short inputs and long
//! outputs the workload is decode-dominated, the high-end GPU (which
//! Cronus dedicates to decode + chunked prefill) saturates, the low-end
//! partial-prefill instance idles, and Cronus's advantage over
//! disaggregated prefill shrinks — the load imbalance returns, now on
//! the other side.
//!
//! ```bash
//! cargo run --release --example limits_short_in_long_out
//! ```

use cronus::benchkit::Table;
use cronus::config::{DeploymentConfig, SystemKind};
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::{build_system, replay_trace};
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn run(cfg: &DeploymentConfig, trace_cfg: &AzureTraceConfig, label: &str) {
    let trace = generate(300, trace_cfg, 11);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
    let mut table = Table::new(
        label.to_string(),
        &["Approach", "thpt (req/s)", "PPI busy frac", "CPI busy frac"],
    );
    for kind in [
        SystemKind::Cronus,
        SystemKind::DpChunked,
        SystemKind::DisaggLowHigh,
    ] {
        let mut sys = build_system(kind, cfg);
        let out = replay_trace(sys.as_mut(), &trace);
        let makespan = out.report.makespan_s;
        let fracs: Vec<String> = out
            .instances
            .iter()
            .map(|i| format!("{:.0}%", 100.0 * i.busy_time_s / makespan))
            .collect();
        table.row(vec![
            kind.name().to_string(),
            format!("{:.2}", out.report.throughput_rps),
            fracs.first().cloned().unwrap_or_default(),
            fracs.get(1).cloned().unwrap_or_default(),
        ]);
    }
    table.print();
}

fn main() {
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    run(&cfg, &AzureTraceConfig::default(), "Conversation workload (mean in 1014 / out 247)");
    run(
        &cfg,
        &AzureTraceConfig::short_input_long_output(),
        "§6 limitation workload (mean in 128 / out 512): decode-bound",
    );
    println!(
        "\nIn the second table the first instance (PPI / DP-high / prefill side)\n\
         goes idle while the decode side saturates — the future-work case the\n\
         paper proposes offloading decode to the prefill node for."
    );
}
