//! Online, event-driven serving: drive an N-pair heterogeneous cluster
//! through the `submit` / `advance` / `drain` lifecycle directly —
//! requests enter one at a time at their arrival instants, the router
//! dispatches against the *live* per-pair backlog, and SLO admission
//! control sheds or defers load the cluster cannot serve in time.
//!
//! Prints a live admission/progress ledger as simulated time passes —
//! the open-loop view the batch benches never show.
//!
//! ```bash
//! cargo run --release --example online_serving
//! cargo run --release --example online_serving -- --pairs 4 --rate 12 --slo-ttft-ms 800
//! ```

use cronus::config::cli::Parser;
use cronus::config::ClusterConfig;
use cronus::cronus::router::RoutePolicy;
use cronus::simclock::SimTime;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::systems::{Admission, ClusterSystem, ServingSystem, SystemEvent};
use cronus::workload::arrival::at_rate;
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parser = Parser::new("online_serving", "open-loop online cluster serving")
        .opt("n", "number of requests", Some("120"))
        .opt("seed", "trace seed", Some("42"))
        .opt("pairs", "cluster pairs", Some("2"))
        .opt("rate", "arrival rate, requests/second", Some("8"))
        .opt(
            "slo-ttft-ms",
            "TTFT SLO for router admission control (0 = off)",
            Some("1500"),
        )
        .flag("help", "print usage");
    let args = parser.parse(&args).unwrap_or_else(|e| {
        eprintln!("{e}\n{}", parser.usage());
        std::process::exit(2);
    });
    if args.has_flag("help") {
        println!("{}", parser.usage());
        return;
    }
    // CI smoke mode reuses the bench knob to stay quick.
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| args.get_usize("n").unwrap());
    let seed = args.get_u64("seed").unwrap();
    let pairs = args.get_usize("pairs").unwrap();
    let rate = args.get_f64("rate").unwrap();
    let slo_ms = args.get_f64("slo-ttft-ms").unwrap();
    let slo = if slo_ms > 0.0 { Some(slo_ms / 1e3) } else { None };

    let trace = generate(n, &AzureTraceConfig::default(), seed);
    let trace = at_rate(&trace, rate);
    let cfg = ClusterConfig::mixed(pairs.max(1), LLAMA3_8B);
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::SloAware).with_slo_ttft(slo);

    println!(
        "online serving: {n} requests at {rate} req/s into {} pairs ({}), SLO {}",
        pairs,
        sys.label(),
        match slo {
            Some(s) => format!("TTFT <= {s:.2}s"),
            None => "off".to_string(),
        }
    );

    let mut admitted = 0usize;
    let mut rejected = 0usize;
    let mut deferred_drops = 0usize;
    let mut finished = 0usize;
    let mut shed_events = 0usize;
    let mut next_print_s = 5.0f64;

    for r in &trace {
        let t = SimTime(r.arrival_ns);
        // Submissions must be non-decreasing in time, so this strictly
        // open-loop client drops deferred requests on the spot; the
        // library's replay_trace harness interleaves timed retries
        // (up to 32 per request) instead.
        match sys.submit(t, *r) {
            Admission::Accepted => admitted += 1,
            Admission::Rejected { .. } => rejected += 1,
            Admission::Deferred { .. } => deferred_drops += 1,
        }
        for ev in sys.advance(t) {
            match ev {
                SystemEvent::Finished { .. } => finished += 1,
                SystemEvent::Shed { .. } => shed_events += 1,
                _ => {}
            }
        }
        let now_s = t.as_secs_f64();
        if now_s >= next_print_s {
            next_print_s = now_s + 5.0;
            println!(
                "  t={now_s:>7.2}s  admitted {admitted:>4}  finished {finished:>4}  \
                 rejected {rejected:>3}  deferred-drops {deferred_drops:>3}"
            );
        }
    }

    // Let the cluster run dry, counting the remaining completions live.
    while let Some(t) = sys.next_event_at() {
        for ev in sys.advance(t) {
            match ev {
                SystemEvent::Finished { .. } => finished += 1,
                SystemEvent::Shed { .. } => shed_events += 1,
                _ => {}
            }
        }
    }
    let out = sys.drain();

    println!("\n{}", out.report.summary());
    println!(
        "admitted {admitted}, finished {finished}, rejected {rejected}, \
         deferred-drops {deferred_drops}, shed events {shed_events}"
    );
    assert_eq!(
        admitted + rejected + deferred_drops,
        n,
        "every request must be admitted, rejected, or dropped"
    );
    assert_eq!(finished, admitted, "every admitted request must finish");
    assert_eq!(out.report.n_finished, finished);
    println!("[ok] conservation: admitted == finished, nothing lost");
}
