"""Layer-2 model tests: the chunked/incremental serving path must
reproduce the plain full-sequence forward, with Pallas or jnp kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

# A smaller-than-TINY config to keep interpret-mode Pallas fast in CI.
TEST_DIMS = M.ModelDims(
    name="test-llama",
    vocab=128,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    max_seq=64,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), TEST_DIMS)


def _empty_kv(dims, batch=None):
    shape = (dims.n_layers, dims.max_seq, dims.n_kv_heads, dims.head_dim)
    if batch is not None:
        shape = (batch,) + shape
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _run_chunked_prefill(params, dims, tokens, chunk, use_pallas):
    """Feed `tokens` through prefill_chunk in chunks; return last logits + kv."""
    kv_k, kv_v = _empty_kv(dims)
    n = tokens.shape[0]
    logits = None
    start = 0
    while start < n:
        n_valid = min(chunk, n - start)
        padded = jnp.zeros((chunk,), jnp.int32)
        padded = padded.at[:n_valid].set(tokens[start : start + n_valid])
        logits, kv_k, kv_v = M.prefill_chunk(
            params,
            dims,
            padded,
            jnp.int32(start),
            kv_k,
            kv_v,
            use_pallas=use_pallas,
        )
        last_row = logits[n_valid - 1]
        start += n_valid
    return last_row, kv_k, kv_v


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("n_tokens,chunk", [(7, 8), (16, 8), (21, 8)])
def test_chunked_prefill_matches_full_forward(params, use_pallas, n_tokens, chunk):
    rng = np.random.default_rng(42)
    tokens = jnp.asarray(
        rng.integers(0, TEST_DIMS.vocab, size=(n_tokens,)), jnp.int32
    )
    full = M.full_forward_ref(params, TEST_DIMS, tokens)
    last, _, _ = _run_chunked_prefill(params, TEST_DIMS, tokens, chunk, use_pallas)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[-1]), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_continues_prefill(params, use_pallas):
    """prefill(tokens[:k]) + decode_step over tokens[k:] == full forward."""
    rng = np.random.default_rng(7)
    n, k, chunk = 12, 8, 8
    tokens = jnp.asarray(rng.integers(0, TEST_DIMS.vocab, size=(n,)), jnp.int32)
    full = M.full_forward_ref(params, TEST_DIMS, tokens)

    _, kv_k, kv_v = _run_chunked_prefill(
        params, TEST_DIMS, tokens[:k], chunk, use_pallas
    )
    # Batch of 1 padded to 2 (exercises inactive-slot handling).
    b = 2
    kv_k_b = jnp.stack([kv_k, jnp.zeros_like(kv_k)])
    kv_v_b = jnp.stack([kv_v, jnp.zeros_like(kv_v)])
    logits = None
    for i in range(k, n):
        toks = jnp.asarray([tokens[i], 0], jnp.int32)
        pos = jnp.asarray([i, 0], jnp.int32)
        logits, kv_k_b, kv_v_b = M.decode_step(
            params, TEST_DIMS, toks, pos, kv_k_b, kv_v_b, use_pallas=use_pallas
        )
    np.testing.assert_allclose(
        np.asarray(logits[0]), np.asarray(full[-1]), rtol=2e-4, atol=2e-4
    )


def test_decode_batch_isolation(params):
    """Two requests decoded together == each decoded alone."""
    rng = np.random.default_rng(9)
    t0 = jnp.asarray(rng.integers(0, TEST_DIMS.vocab, size=(6,)), jnp.int32)
    t1 = jnp.asarray(rng.integers(0, TEST_DIMS.vocab, size=(9,)), jnp.int32)
    _, k0, v0 = _run_chunked_prefill(params, TEST_DIMS, t0, 8, False)
    _, k1, v1 = _run_chunked_prefill(params, TEST_DIMS, t1, 8, False)

    def solo(kv_k, kv_v, tok, pos):
        l, kk, vv = M.decode_step(
            params,
            TEST_DIMS,
            jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos], jnp.int32),
            kv_k[None],
            kv_v[None],
            use_pallas=False,
        )
        return l[0]

    l0 = solo(k0, v0, 5, 6)
    l1 = solo(k1, v1, 17, 9)
    batched, _, _ = M.decode_step(
        params,
        TEST_DIMS,
        jnp.asarray([5, 17], jnp.int32),
        jnp.asarray([6, 9], jnp.int32),
        jnp.stack([k0, k1]),
        jnp.stack([v0, v1]),
        use_pallas=False,
    )
    np.testing.assert_allclose(np.asarray(batched[0]), np.asarray(l0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(batched[1]), np.asarray(l1), rtol=1e-5, atol=1e-5)


def test_prefill_shapes(params):
    kv_k, kv_v = _empty_kv(TEST_DIMS)
    tokens = jnp.zeros((8,), jnp.int32)
    logits, k, v = M.prefill_chunk(
        params, TEST_DIMS, tokens, jnp.int32(0), kv_k, kv_v, use_pallas=False
    )
    assert logits.shape == (8, TEST_DIMS.vocab)
    assert logits.dtype == jnp.float32
    assert k.shape == kv_k.shape and v.shape == kv_v.shape


def test_decode_shapes(params):
    kv_k, kv_v = _empty_kv(TEST_DIMS, batch=3)
    logits, k, v = M.decode_step(
        params,
        TEST_DIMS,
        jnp.zeros((3,), jnp.int32),
        jnp.zeros((3,), jnp.int32),
        kv_k,
        kv_v,
        use_pallas=False,
    )
    assert logits.shape == (3, TEST_DIMS.vocab)
    assert k.shape == kv_k.shape


def test_param_count_formula():
    """param_count() must equal the sum of actual array sizes."""
    shapes = M.param_shapes(TEST_DIMS)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert TEST_DIMS.param_count() == total


def test_kv_bytes_per_token():
    # 2 (K,V) * L * kv_dim * dtype_bytes
    assert TEST_DIMS.kv_bytes_per_token(2) == 2 * 2 * 32 * 2
    assert M.LLAMA3_8B.kv_bytes_per_token(2) == 2 * 32 * 1024 * 2


def test_rope_position_zero_is_identity():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 2, 16)), jnp.float32)
    out = M.rope(x, jnp.zeros((4,), jnp.int32), 10000.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 2, 16)), jnp.float32)
    out = M.rope(x, jnp.asarray([0, 3, 100, 511], jnp.int32), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_paper_model_geometries():
    """The descriptor constants must match the published configs."""
    assert M.LLAMA3_8B.n_layers == 32 and M.LLAMA3_8B.d_model == 4096
    assert M.QWEN2_7B.n_layers == 28 and M.QWEN2_7B.d_model == 3584
    # ~8B / ~7.6B params
    assert 7.5e9 < M.LLAMA3_8B.param_count() < 8.5e9
    assert 7.0e9 < M.QWEN2_7B.param_count() < 8.2e9
