"""Paged decode attention vs the dense oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import paged as P
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _mk(rng, b, h_kv, group, d_h, block_size, max_blocks, n_extra, dtype):
    h_q = h_kv * group
    n_blocks = b * max_blocks + n_extra
    q = jnp.asarray(rng.normal(size=(b, h_q, d_h)).astype(np.float32), dtype)
    kp = jnp.asarray(
        rng.normal(size=(n_blocks, block_size, h_kv, d_h)).astype(np.float32), dtype
    )
    vp = jnp.asarray(
        rng.normal(size=(n_blocks, block_size, h_kv, d_h)).astype(np.float32), dtype
    )
    # Non-contiguous, shuffled block assignment (no aliasing across reqs).
    bt = jnp.asarray(
        rng.permutation(n_blocks)[: b * max_blocks].reshape(b, max_blocks),
        jnp.int32,
    )
    return q, kp, vp, bt


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    h_kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    d_h=st.sampled_from([8, 16, 32]),
    block_size=st.sampled_from([4, 8, 16]),
    max_blocks=st.integers(min_value=1, max_value=5),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_paged_matches_dense_oracle(
    b, h_kv, group, d_h, block_size, max_blocks, dtype, seed
):
    rng = np.random.default_rng(seed)
    q, kp, vp, bt = _mk(rng, b, h_kv, group, d_h, block_size, max_blocks, 3, dtype)
    t = block_size * max_blocks
    pos = jnp.asarray(rng.integers(0, t, size=(b,)), jnp.int32)
    out = P.paged_decode_attention(q, kp, vp, bt, pos)
    ref = R.decode_attention(
        q, P.gather_pages(kp, bt), P.gather_pages(vp, bt), pos
    )
    tol = dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


def test_unused_table_entries_are_masked():
    """Blocks past a request's position must not leak into its output."""
    rng = np.random.default_rng(1)
    q, kp, vp, bt = _mk(rng, 2, 1, 2, 8, 4, 4, 2, jnp.float32)
    pos = jnp.asarray([3, 7], jnp.int32)  # only block 0 (and 1) visible
    out1 = P.paged_decode_attention(q, kp, vp, bt, pos)
    # Poison the pool blocks referenced only by the masked tail.
    tail_blocks = np.asarray(bt)[:, 2:].reshape(-1)
    kp2 = kp.at[tail_blocks].set(1e9)
    vp2 = vp.at[tail_blocks].set(-1e9)
    out2 = P.paged_decode_attention(q, kp2, vp2, bt, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_request_isolation_under_shared_pool():
    """Two requests with disjoint block lists in one pool don't interact."""
    rng = np.random.default_rng(2)
    q, kp, vp, bt = _mk(rng, 2, 2, 2, 16, 8, 3, 0, jnp.float32)
    pos = jnp.asarray([23, 10], jnp.int32)
    base = P.paged_decode_attention(q, kp, vp, bt, pos)
    # Rewriting request 1's blocks leaves request 0's output unchanged.
    blocks1 = np.asarray(bt)[1]
    kp2 = kp.at[blocks1].set(rng.normal(size=(3, 8, 2, 16)).astype(np.float32))
    out = P.paged_decode_attention(q, kp2, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(base[0]), np.asarray(out[0]))
    assert not np.allclose(np.asarray(base[1]), np.asarray(out[1]))


def test_matches_contiguous_layout():
    """With an identity block table the paged kernel equals the dense one."""
    from compile.kernels import attention as A
    rng = np.random.default_rng(3)
    b, h_kv, group, d_h, bs, mb = 2, 2, 2, 16, 8, 4
    q, kp, vp, _ = _mk(rng, b, h_kv, group, d_h, bs, mb, 0, jnp.float32)
    bt = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    pos = jnp.asarray([31, 5], jnp.int32)
    paged = P.paged_decode_attention(q, kp, vp, bt, pos)
    dense = A.decode_attention(
        q, P.gather_pages(kp, bt), P.gather_pages(vp, bt), pos, kv_block=bs
    )
    np.testing.assert_allclose(
        np.asarray(paged), np.asarray(dense), rtol=2e-5, atol=2e-5
    )
