"""Kernel vs reference oracle — the CORE correctness signal.

Hypothesis sweeps shapes/dtypes of both Pallas kernels and asserts
allclose against the pure-jnp oracles in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return (
        dict(rtol=2e-5, atol=2e-5)
        if dtype == jnp.float32
        else dict(rtol=2e-2, atol=2e-2)
    )


# ---------------------------------------------------------------------------
# chunked_prefill_attention
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    c=st.sampled_from([1, 3, 8, 16]),
    h_kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    d_h=st.sampled_from([8, 16, 32]),
    t_blocks=st.integers(min_value=1, max_value=4),
    kv_block=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    q_start_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_chunked_prefill_matches_ref(
    c, h_kv, group, d_h, t_blocks, kv_block, dtype, q_start_frac, seed
):
    t = max(kv_block * t_blocks, c)
    h_q = h_kv * group
    rng = np.random.default_rng(seed)
    q = _rand(rng, (c, h_q, d_h), dtype)
    k = _rand(rng, (t, h_kv, d_h), dtype)
    v = _rand(rng, (t, h_kv, d_h), dtype)
    q_start = int(q_start_frac * (t - c))

    out = A.chunked_prefill_attention(q, k, v, q_start, kv_block=kv_block)
    ref = R.chunked_prefill_attention(q, k, v, q_start)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_chunked_prefill_q_start_zero_first_token():
    """First chunk, first token: attends only to itself."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (1, 2, 8), jnp.float32)
    k = _rand(rng, (16, 1, 8), jnp.float32)
    v = _rand(rng, (16, 1, 8), jnp.float32)
    out = A.chunked_prefill_attention(q, k, v, 0, kv_block=8)
    # Softmax over a single visible position == that position's V.
    expected = np.broadcast_to(np.asarray(v[0]), (1, 2, 8))
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-5, atol=1e-5)


def test_chunked_prefill_ignores_padding_beyond_context():
    """Garbage in cache positions the causal mask hides must not leak."""
    rng = np.random.default_rng(1)
    c, t = 4, 32
    q = _rand(rng, (c, 2, 8), jnp.float32)
    k = _rand(rng, (t, 1, 8), jnp.float32)
    v = _rand(rng, (t, 1, 8), jnp.float32)
    q_start = 10
    out1 = A.chunked_prefill_attention(q, k, v, q_start, kv_block=8)
    # Poison everything after the last visible position.
    vis = q_start + c
    k2 = k.at[vis:].set(1e9)
    v2 = v.at[vis:].set(-1e9)
    out2 = A.chunked_prefill_attention(q, k2, v2, q_start, kv_block=8)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_chunked_prefill_traced_q_start_jit():
    rng = np.random.default_rng(2)
    q = _rand(rng, (8, 4, 16), jnp.float32)
    k = _rand(rng, (64, 2, 16), jnp.float32)
    v = _rand(rng, (64, 2, 16), jnp.float32)
    f = jax.jit(
        lambda q, k, v, s: A.chunked_prefill_attention(q, k, v, s, kv_block=16)
    )
    for s in [0, 13, 56]:
        np.testing.assert_allclose(
            np.asarray(f(q, k, v, jnp.int32(s))),
            np.asarray(R.chunked_prefill_attention(q, k, v, s)),
            rtol=2e-5,
            atol=2e-5,
        )


def test_chunked_prefill_rejects_bad_heads():
    rng = np.random.default_rng(3)
    q = _rand(rng, (4, 3, 8), jnp.float32)  # 3 q heads
    k = _rand(rng, (16, 2, 8), jnp.float32)  # 2 kv heads -> not divisible
    v = k
    with pytest.raises(ValueError):
        A.chunked_prefill_attention(q, k, v, 0)


def test_kv_block_not_dividing_t():
    """kv_block is shrunk to a divisor of T automatically."""
    rng = np.random.default_rng(4)
    q = _rand(rng, (4, 2, 8), jnp.float32)
    k = _rand(rng, (48, 1, 8), jnp.float32)  # 48 not divisible by 32
    v = _rand(rng, (48, 1, 8), jnp.float32)
    out = A.chunked_prefill_attention(q, k, v, 5, kv_block=32)
    ref = R.chunked_prefill_attention(q, k, v, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    b=st.sampled_from([1, 2, 5, 8]),
    h_kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    d_h=st.sampled_from([8, 16, 32]),
    t_blocks=st.integers(min_value=1, max_value=4),
    kv_block=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_decode_matches_ref(b, h_kv, group, d_h, t_blocks, kv_block, dtype, seed):
    t = kv_block * t_blocks
    h_q = h_kv * group
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h_q, d_h), dtype)
    k = _rand(rng, (b, t, h_kv, d_h), dtype)
    v = _rand(rng, (b, t, h_kv, d_h), dtype)
    pos = jnp.asarray(rng.integers(0, t, size=(b,)), jnp.int32)

    out = A.decode_attention(q, k, v, pos, kv_block=kv_block)
    ref = R.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_decode_pos_zero_reads_only_slot_zero():
    rng = np.random.default_rng(5)
    q = _rand(rng, (2, 2, 8), jnp.float32)
    k = _rand(rng, (2, 16, 1, 8), jnp.float32)
    v = _rand(rng, (2, 16, 1, 8), jnp.float32)
    pos = jnp.zeros((2,), jnp.int32)
    out = A.decode_attention(q, k, v, pos, kv_block=8)
    expected = np.broadcast_to(np.asarray(v[:, 0]), (2, 2, 8))
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-5, atol=1e-5)


def test_decode_per_request_isolation():
    """Changing one request's cache must not affect another's output."""
    rng = np.random.default_rng(6)
    q = _rand(rng, (3, 4, 16), jnp.float32)
    k = _rand(rng, (3, 32, 2, 16), jnp.float32)
    v = _rand(rng, (3, 32, 2, 16), jnp.float32)
    pos = jnp.asarray([31, 7, 15], jnp.int32)
    out1 = A.decode_attention(q, k, v, pos, kv_block=16)
    k2 = k.at[1].set(rng.normal(size=(32, 2, 16)).astype(np.float32))
    out2 = A.decode_attention(q, k2, v, pos, kv_block=16)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]))
    np.testing.assert_allclose(np.asarray(out1[2]), np.asarray(out2[2]))
    assert not np.allclose(np.asarray(out1[1]), np.asarray(out2[1]))


def test_decode_traced_pos_jit():
    rng = np.random.default_rng(7)
    q = _rand(rng, (4, 4, 16), jnp.float32)
    k = _rand(rng, (4, 64, 2, 16), jnp.float32)
    v = _rand(rng, (4, 64, 2, 16), jnp.float32)
    f = jax.jit(lambda q, k, v, p: A.decode_attention(q, k, v, p, kv_block=16))
    pos = jnp.asarray([0, 63, 31, 12], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(f(q, k, v, pos)),
        np.asarray(R.decode_attention(q, k, v, pos)),
        rtol=2e-5,
        atol=2e-5,
    )
