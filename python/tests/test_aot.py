"""AOT pipeline tests: manifest consistency and HLO-text invariants."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART, "manifest.json"))


pytestmark = pytest.mark.skipif(
    not _have_artifacts(), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_matches_tiny_dims(manifest):
    m = manifest["model"]
    assert m["name"] == M.TINY.name
    assert m["n_layers"] == M.TINY.n_layers
    assert m["vocab"] == M.TINY.vocab
    assert m["param_count"] == M.TINY.param_count()


def test_param_table_is_contiguous_and_ordered(manifest):
    offset = 0
    names = []
    for entry in manifest["params"]:
        assert entry["offset_bytes"] == offset
        size = int(np.prod(entry["shape"])) * 4
        assert entry["size_bytes"] == size
        offset += size
        names.append(entry["name"])
    assert names == M.PARAM_ORDER
    bin_size = os.path.getsize(os.path.join(ART, manifest["weights_file"]))
    assert bin_size == offset


def test_weights_bin_matches_seeded_init(manifest):
    """weights.bin must be reproducible from the fixed seed."""
    params = M.init_params(jax.random.PRNGKey(aot.WEIGHT_SEED), M.TINY)
    raw = np.fromfile(os.path.join(ART, manifest["weights_file"]), dtype="<f4")
    offset = 0
    for name in M.PARAM_ORDER:
        arr = np.asarray(params[name], np.float32).ravel()
        np.testing.assert_array_equal(raw[offset : offset + arr.size], arr)
        offset += arr.size


@pytest.mark.parametrize("entry", ["prefill", "decode"])
def test_hlo_text_has_entry_computation(manifest, entry):
    path = os.path.join(ART, manifest["entries"][entry]["file"])
    with open(path) as f:
        text = f.read()
    assert "ENTRY" in text
    assert "HloModule" in text
    # Interchange is text: a serialized proto would not be valid UTF-8 here.
    assert text.isprintable() or "\n" in text


def test_hlo_parameter_count(manifest):
    """HLO entry must declare len(PARAM_ORDER) + 4 dynamic parameters."""
    for entry in ["prefill", "decode"]:
        path = os.path.join(ART, manifest["entries"][entry]["file"])
        with open(path) as f:
            text = f.read()
        entry_block = text[text.index("ENTRY") :]
        entry_block = entry_block[: entry_block.index("\n}")]
        n_params = entry_block.count("parameter(")
        assert n_params == len(M.PARAM_ORDER) + 4


def test_entry_output_shapes(manifest):
    pre = manifest["entries"]["prefill"]
    assert pre["outputs"][0]["shape"] == [pre["chunk"], M.TINY.vocab]
    dec = manifest["entries"]["decode"]
    assert dec["outputs"][0]["shape"] == [dec["batch"], M.TINY.vocab]
    kv_shape = [
        M.TINY.n_layers,
        M.TINY.max_seq,
        M.TINY.n_kv_heads,
        M.TINY.head_dim,
    ]
    assert pre["outputs"][1]["shape"] == kv_shape
    assert dec["outputs"][1]["shape"] == [dec["batch"]] + kv_shape


def test_lowering_is_deterministic():
    """Same dims -> byte-identical HLO text (reproducible artifacts)."""
    dims = M.ModelDims(
        name="t", vocab=64, d_model=32, n_layers=1, n_heads=2,
        n_kv_heads=1, head_dim=16, d_ff=48, max_seq=32,
    )
    a, _ = aot.lower_entries(dims, chunk=8, batch=2)
    b, _ = aot.lower_entries(dims, chunk=8, batch=2)
    assert a == b
