"""Paged decode attention — the vLLM-fidelity variant of the L1 kernel.

The serving engine's KV cache is *paged*: a request's context lives in
non-contiguous fixed-size blocks, addressed through a per-request block
table (`rust/src/kvcache` is the Rust side of this contract).  The dense
`decode_attention` kernel in `attention.py` assumes a contiguous cache;
this kernel implements the real layout:

  * the KV pool is one big array `[n_blocks, block_size, H_kv, D_h]`
    shared by all requests;
  * request ``b``'s context token ``t`` lives at
    ``pool[block_table[b, t // block_size], t % block_size]``;
  * the Pallas grid walks each request's block list, using the block
    table as a *scalar-prefetch* index map so the HBM→VMEM streaming of
    KV blocks is driven by the table exactly like vLLM's paged attention
    walks physical blocks — no gather materialization.

TPU adaptation notes (DESIGN.md §2): the CUDA paged-attention kernel
resolves the block table per warp; here the table lives in SMEM-like
scalar memory (`PrefetchScalarGridSpec`) and the index_map reads it to
pick which pool block the next grid step streams — the DMA engine does
the indirection, the MXU/VPU kernel body is identical to the dense case.

Oracle: ``ref.decode_attention`` after gathering the pages densely
(`gather_pages`).  interpret=True as always on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float("-inf")


def gather_pages(
    pool: jnp.ndarray,  # [n_blocks, block_size, H_kv, D_h]
    block_table: jnp.ndarray,  # [B, max_blocks] int32
) -> jnp.ndarray:
    """Densify a paged pool into per-request caches (test oracle only).

    Returns ``[B, max_blocks * block_size, H_kv, D_h]``.
    """
    b, max_blocks = block_table.shape
    _, block_size, h_kv, d_h = pool.shape
    gathered = pool[block_table.reshape(-1)]  # [B*max_blocks, bs, H, D]
    return gathered.reshape(b, max_blocks * block_size, h_kv, d_h)


def _paged_decode_kernel(
    # scalar-prefetch operands
    block_table_ref,  # [B, max_blocks] int32 (SMEM)
    pos_ref,  # [B] int32 (SMEM)
    # array operands
    q_ref,  # [1, 1, D]
    k_ref,  # [1, bs, 1, D]   (pool block selected via index_map)
    v_ref,  # [1, bs, 1, D]
    o_ref,  # [1, 1, D]
    # scratch
    m_ref,
    l_ref,
    acc_ref,
    *,
    block_size: int,
    max_blocks: int,
):
    j = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[2]
    pos = pos_ref[b]
    k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)

    # Blocks entirely beyond the query position are invisible; the
    # index_map already clamps their fetch, and we skip the math.
    @pl.when(j * block_size <= pos)
    def _body():
        q = q_ref[0, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        s = jax.lax.dot_general(
            k, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        s = jnp.where(k_pos <= pos, s, _NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        p = jnp.exp(s - m_new)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        m_ref[0] = m_new

    @pl.when(j == max_blocks - 1)
    def _finalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom)[None, None, :].astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,  # [B, H_q, D_h]
    k_pool: jnp.ndarray,  # [n_blocks, block_size, H_kv, D_h]
    v_pool: jnp.ndarray,  # [n_blocks, block_size, H_kv, D_h]
    block_table: jnp.ndarray,  # [B, max_blocks] int32 (entries past the
    #   context may be any valid block id; they are masked)
    pos: jnp.ndarray,  # [B] int32 — query's absolute position per request
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Flash-style decode attention over a paged KV pool.

    Equivalent to ``ref.decode_attention(q, gather_pages(k_pool, bt),
    gather_pages(v_pool, bt), pos)`` without materializing the gather.
    Returns ``[B, H_q, D_h]``.
    """
    b, h_q, d_h = q.shape
    n_blocks, block_size, h_kv, _ = k_pool.shape
    _, max_blocks = block_table.shape
    if h_q % h_kv != 0:
        raise ValueError(f"H_q={h_q} not a multiple of H_kv={h_kv}")
    group = h_q // h_kv

    block_table = jnp.asarray(block_table, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32).reshape((b,))

    kernel = functools.partial(
        _paged_decode_kernel, block_size=block_size, max_blocks=max_blocks
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, pos
        grid=(b, h_q, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, d_h), lambda i, h, j, bt, p: (i, h, 0)),  # q
            # KV pool blocks are selected *through the block table*: grid
            # step (i, ·, j) streams pool block block_table[i, j].
            pl.BlockSpec(
                (1, block_size, 1, d_h),
                lambda i, h, j, bt, p, g=group: (bt[i, j], 0, h // g, 0),
            ),
            pl.BlockSpec(
                (1, block_size, 1, d_h),
                lambda i, h, j, bt, p, g=group: (bt[i, j], 0, h // g, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, d_h), lambda i, h, j, bt, p: (i, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((d_h,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h_q, d_h), q.dtype),
        interpret=interpret,
    )(block_table, pos, q, k_pool, v_pool)
