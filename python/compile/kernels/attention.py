"""Layer-1 Pallas kernels: the serving hot path's attention cores.

Two kernels, mirroring the two iteration roles in a Cronus chunked-prefill
instance (CPI):

  * ``chunked_prefill_attention`` — a prefill *chunk* of C query tokens
    attends to the request's KV-cache prefix (flash-attention structure:
    the KV/context dimension streams through VMEM in blocks with an
    online softmax).  This is the term the paper models as
    ``k_ctxp * L(R_i^P2)`` in Eq. 3.
  * ``decode_attention`` — one query token per request in a decode batch
    attends to that request's cache (the ``k_ctxd * sum L(R_l^D)`` term;
    bandwidth-bound matrix-vector work).

Hardware adaptation (paper targets CUDA/vLLM; we target TPU — see
DESIGN.md §2):

  * The CUDA kernel's threadblock tiling over (query, context) becomes a
    Pallas ``grid`` over (head, context-block) with ``BlockSpec``-driven
    HBM→VMEM streaming; block sizes are chosen so Q, K, V tiles and the
    f32 accumulator fit comfortably in VMEM (≈16 MiB) with room for
    double buffering.
  * Warp-level online softmax becomes scratch refs (running max ``m``,
    denominator ``l``, accumulator ``acc``) carried across the innermost
    grid dimension — Pallas guarantees sequential iteration over the last
    grid axis, which is exactly the flash-attention recurrence.
  * Score and output matmuls use ``preferred_element_type=float32`` so
    the MXU accumulates in f32 even for bf16 inputs (tensor-core WMMA's
    f32 accumulate, in TPU terms).
  * Fully-masked context tiles are skipped with ``pl.when`` — the Pallas
    analogue of the CUDA kernel's early-exit warps.

Both kernels MUST be lowered with ``interpret=True``: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

Correctness oracle: ``kernels/ref.py`` (pytest sweeps shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default context-block size. 128 keeps a (C=128, BK=128) f32 score tile at
# 64 KiB and K/V tiles at 128*D_h*4 bytes — ~1 MiB total VMEM at D_h=128,
# leaving headroom for double buffering. Shrunk automatically for short
# caches in the wrappers below.
DEFAULT_KV_BLOCK = 128

_NEG_INF = float("-inf")


def _pick_kv_block(t: int, requested: int) -> int:
    """Largest divisor of ``t`` that is <= requested (>=1)."""
    bk = min(requested, t)
    while t % bk != 0:
        bk -= 1
    return bk


# ---------------------------------------------------------------------------
# Chunked-prefill attention
# ---------------------------------------------------------------------------


def _chunked_prefill_kernel(
    q_start_ref,  # [1] int32 (absolute position of q row 0)
    q_ref,  # [C, 1, D]
    k_ref,  # [BK, 1, D]
    v_ref,  # [BK, 1, D]
    o_ref,  # [C, 1, D]
    m_ref,  # scratch [C]   running max
    l_ref,  # scratch [C]   running denominator
    acc_ref,  # scratch [C, D] running numerator
    *,
    kv_block: int,
    n_kv_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = q_ref.shape[0]
    d = q_ref.shape[2]
    q_start = q_start_ref[0]
    q_pos = q_start + jax.lax.iota(jnp.int32, c)  # [C] absolute positions
    k_pos = j * kv_block + jax.lax.iota(jnp.int32, kv_block)  # [BK]

    # Early exit: if this context tile lies entirely beyond the last query's
    # position, it contributes nothing (causal) — skip the matmuls.
    tile_visible = (j * kv_block) <= (q_start + c - 1)

    @pl.when(tile_visible)
    def _body():
        q = q_ref[:, 0, :].astype(jnp.float32)  # [C, D]
        k = k_ref[:, 0, :].astype(jnp.float32)  # [BK, D]
        v = v_ref[:, 0, :].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [C, BK]
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Rows that have seen nothing yet and see nothing now keep m=-inf;
        # guard the rescale so exp(-inf - -inf) never produces NaN.
        alpha = jnp.where(
            m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_new)
        )
        p = jnp.where(
            m_new[:, None] == _NEG_INF, 0.0, jnp.exp(s - m_new[:, None])
        )  # [C, BK]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        # Padded / never-visible rows have l == 0: emit zeros, not NaN.
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom[:, None])[:, None, :].astype(
            o_ref.dtype
        )


def chunked_prefill_attention(
    q: jnp.ndarray,  # [C, H_q, D_h]
    k_cache: jnp.ndarray,  # [T, H_kv, D_h]
    v_cache: jnp.ndarray,  # [T, H_kv, D_h]
    q_start: jnp.ndarray | int,  # scalar int32
    *,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Flash-style chunked-prefill attention (see module docstring).

    Matches ``ref.chunked_prefill_attention`` exactly (up to float
    tolerance).  ``q_start`` may be a traced scalar — it is threaded into
    the kernel as a tiny int32 array so the same HLO serves every chunk
    of a request.
    """
    c, h_q, d_h = q.shape
    t, h_kv, _ = k_cache.shape
    if h_q % h_kv != 0:
        raise ValueError(f"H_q={h_q} not a multiple of H_kv={h_kv}")
    group = h_q // h_kv
    bk = _pick_kv_block(t, kv_block)
    n_kv_blocks = t // bk

    q_start_arr = jnp.asarray(q_start, dtype=jnp.int32).reshape((1,))

    kernel = functools.partial(
        _chunked_prefill_kernel, kv_block=bk, n_kv_blocks=n_kv_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(h_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda h, j: (0,)),  # q_start
            pl.BlockSpec((c, 1, d_h), lambda h, j: (0, h, 0)),  # q
            pl.BlockSpec(
                (bk, 1, d_h), lambda h, j, g=group: (j, h // g, 0)
            ),  # k
            pl.BlockSpec(
                (bk, 1, d_h), lambda h, j, g=group: (j, h // g, 0)
            ),  # v
        ],
        out_specs=pl.BlockSpec((c, 1, d_h), lambda h, j: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h_q, d_h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((c,), jnp.float32),  # m: running max
            pltpu.VMEM((c,), jnp.float32),  # l: running denominator
            pltpu.VMEM((c, d_h), jnp.float32),  # acc: running numerator
        ],
        interpret=interpret,
    )(q_start_arr, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


def _decode_kernel(
    pos_ref,  # [1] int32
    q_ref,  # [1, 1, D]
    k_ref,  # [1, BK, 1, D]
    v_ref,  # [1, BK, 1, D]
    o_ref,  # [1, 1, D]
    m_ref,  # scratch [1]
    l_ref,  # scratch [1]
    acc_ref,  # scratch [D]
    *,
    kv_block: int,
    n_kv_blocks: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[2]
    pos = pos_ref[0]
    k_pos = j * kv_block + jax.lax.iota(jnp.int32, kv_block)

    # Tiles entirely past the query position are invisible (causal).
    @pl.when(j * kv_block <= pos)
    def _body():
        q = q_ref[0, 0, :].astype(jnp.float32)  # [D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [BK, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        s = jax.lax.dot_general(
            k, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [BK]
        s = jnp.where(k_pos <= pos, s, _NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        p = jnp.exp(s - m_new)  # position 0 always visible -> m_new finite
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        m_ref[0] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom)[None, None, :].astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,  # [B, H_q, D_h]
    k_cache: jnp.ndarray,  # [B, T, H_kv, D_h]
    v_cache: jnp.ndarray,  # [B, T, H_kv, D_h]
    pos: jnp.ndarray,  # [B] int32
    *,
    kv_block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Flash-style decode (single-query) attention over per-request caches.

    The KV block default is larger than prefill's (512 vs 128): decode is
    bandwidth-bound, so we maximize the KV bytes resident per VMEM fill
    instead of tiling for the MXU.  Matches ``ref.decode_attention``.
    """
    b, h_q, d_h = q.shape
    _, t, h_kv, _ = k_cache.shape
    if h_q % h_kv != 0:
        raise ValueError(f"H_q={h_q} not a multiple of H_kv={h_kv}")
    group = h_q // h_kv
    bk = _pick_kv_block(t, kv_block)
    n_kv_blocks = t // bk

    pos_arr = jnp.asarray(pos, dtype=jnp.int32).reshape((b,))

    kernel = functools.partial(
        _decode_kernel, kv_block=bk, n_kv_blocks=n_kv_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, h, j: (i,)),  # pos
            pl.BlockSpec((1, 1, d_h), lambda i, h, j: (i, h, 0)),  # q
            pl.BlockSpec(
                (1, bk, 1, d_h), lambda i, h, j, g=group: (i, j, h // g, 0)
            ),
            pl.BlockSpec(
                (1, bk, 1, d_h), lambda i, h, j, g=group: (i, j, h // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, d_h), lambda i, h, j: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_q, d_h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),  # m
            pltpu.VMEM((1,), jnp.float32),  # l
            pltpu.VMEM((d_h,), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(pos_arr, q, k_cache, v_cache)
