"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package must match the corresponding function here to float tolerance
(see python/tests/test_kernel.py, which sweeps shapes/dtypes with
hypothesis).

Conventions (shared with kernels/*.py and model.py):
  * A request's KV cache is a pair of arrays ``k_cache, v_cache`` of shape
    ``[T, H_kv, D_h]`` (``T`` = max sequence length).  Positions
    ``[0, ctx_end)`` are valid; everything else is padding and must be
    masked out, never read.
  * Chunked prefill processes a chunk of ``C`` query tokens whose absolute
    positions are ``q_start .. q_start + C - 1``.  The chunk's own KV has
    already been written into the cache (functional update in the model),
    so query ``i`` attends to cache positions ``j <= q_start + i``.
  * Decode processes one query token per request at position ``pos``; it
    attends to cache positions ``j <= pos``.
  * Grouped-query attention: ``H_q`` query heads share ``H_kv`` KV heads,
    group size ``G = H_q // H_kv``.
"""

from __future__ import annotations

import jax.numpy as jnp


def _gqa_expand(x: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """Expand KV heads [T, H_kv, D] -> [T, H_q, D] by repetition (GQA)."""
    t, h_kv, d = x.shape
    group = n_q_heads // h_kv
    return jnp.repeat(x, group, axis=1)


def chunked_prefill_attention(
    q: jnp.ndarray,  # [C, H_q, D_h]
    k_cache: jnp.ndarray,  # [T, H_kv, D_h]
    v_cache: jnp.ndarray,  # [T, H_kv, D_h]
    q_start: jnp.ndarray | int,  # scalar: absolute position of q[0]
) -> jnp.ndarray:
    """Causal attention of a prefill chunk against the KV cache prefix.

    Query token ``i`` (absolute position ``q_start + i``) attends to cache
    positions ``j`` with ``j <= q_start + i``.  Returns ``[C, H_q, D_h]``.
    """
    c, h_q, d_h = q.shape
    t = k_cache.shape[0]
    k = _gqa_expand(k_cache, h_q)  # [T, H_q, D]
    v = _gqa_expand(v_cache, h_q)

    scale = 1.0 / jnp.sqrt(jnp.array(d_h, dtype=jnp.float32))
    # scores[i, h, j] in f32 regardless of input dtype.
    scores = jnp.einsum(
        "chd,thd->cht", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale

    q_pos = q_start + jnp.arange(c)[:, None, None]  # [C,1,1]
    k_pos = jnp.arange(t)[None, None, :]  # [1,1,T]
    mask = k_pos <= q_pos
    scores = jnp.where(mask, scores, -jnp.inf)

    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("cht,thd->chd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # [B, H_q, D_h]  (one query token per request)
    k_cache: jnp.ndarray,  # [B, T, H_kv, D_h]
    v_cache: jnp.ndarray,  # [B, T, H_kv, D_h]
    pos: jnp.ndarray,  # [B] int32: query's absolute position per request
) -> jnp.ndarray:
    """Single-token (decode) attention per request.  Returns [B, H_q, D_h].

    Request ``b``'s query attends to cache positions ``j <= pos[b]`` — the
    cache slot at ``pos[b]`` holds the query token's own KV.
    """
    b, h_q, d_h = q.shape
    t = k_cache.shape[1]
    group = h_q // k_cache.shape[2]
    k = jnp.repeat(k_cache, group, axis=2)  # [B, T, H_q, D]
    v = jnp.repeat(v_cache, group, axis=2)

    scale = 1.0 / jnp.sqrt(jnp.array(d_h, dtype=jnp.float32))
    scores = jnp.einsum(
        "bhd,bthd->bht", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(t)[None, None, :] <= pos[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bht,bthd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
