"""Layer-1 Pallas kernels for the Cronus serving stack (build-time only).

``attention`` holds the Pallas kernels; ``ref`` holds the pure-jnp oracles
they are tested against.
"""

from compile.kernels.attention import (  # noqa: F401
    chunked_prefill_attention,
    decode_attention,
)
from compile.kernels import ref  # noqa: F401
