"""Layer-2 JAX model: a LLaMA-style decoder-only transformer.

This is the *compute graph* the Rust coordinator serves.  It exposes the
two entry points a chunked-prefill serving engine needs — exactly the two
iteration roles of a Cronus chunked-prefill instance (CPI):

  * ``prefill_chunk`` — run one chunk of C prompt tokens for a single
    request against its KV cache (writes the chunk's KV, returns logits
    for every chunk position).  Repeated calls with advancing ``q_start``
    implement chunked prefill; the *first* call on the CPI side of Cronus
    starts from the ``q_start`` the low-end GPU's partial prefill reached,
    with the prefix KV arriving via the KV-transfer path.
  * ``decode_step`` — one autoregressive step for a batch of B requests,
    each with its own KV cache and position.

Both call the Layer-1 Pallas kernels for their attention cores (set
``use_pallas=False`` to swap in the jnp oracles; tests compare the two).

The model is deliberately parameterized (``ModelDims``) so the same code
describes LLaMA3-8B / Qwen2-7B geometries (used by the Rust performance
model via the artifact manifest) and the tiny configuration that is
actually AOT-compiled and executed end-to-end (``TINY``).

Build-time only: ``aot.py`` lowers ``jax.jit`` of these functions to HLO
text once; Python never runs on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import attention as kernels
from compile.kernels import ref as kernels_ref


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """Geometry of a decoder-only transformer (LLaMA family)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    max_seq: int  # KV-cache capacity per request (padded length)
    rope_theta: float = 10000.0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count (embeddings + blocks + head)."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        per_layer = (
            d * self.q_dim  # wq
            + 2 * d * self.kv_dim  # wk, wv
            + self.q_dim * d  # wo
            + 3 * d * f  # gate, up, down
            + 2 * d  # norms
        )
        return self.vocab * d * 2 + l * per_layer + d

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per context token (the paper's memory currency)."""
        return 2 * self.n_layers * self.kv_dim * dtype_bytes


# The tiny model that is actually AOT-compiled and executed end-to-end.
TINY = ModelDims(
    name="tiny-llama",
    vocab=2048,
    d_model=256,
    n_layers=4,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=704,
    max_seq=512,
)

# Geometry descriptors for the paper's evaluation models.  These are not
# compiled; they parameterize the Rust performance model (FLOPs / bytes).
LLAMA3_8B = ModelDims(
    name="llama3-8b",
    vocab=128256,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    max_seq=8192,
    rope_theta=500000.0,
)
QWEN2_7B = ModelDims(
    name="qwen2-7b",
    vocab=152064,
    d_model=3584,
    n_layers=28,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    max_seq=8192,
    rope_theta=1000000.0,
)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

# Flattened parameter order — this IS the wire format: aot.py writes
# weights.bin in this order and the Rust runtime feeds the HLO executable's
# inputs in this order.  Do not reorder without bumping the manifest.
PARAM_ORDER: List[str] = [
    "embed",  # [V, D]
    "attn_norm",  # [L, D]
    "wq",  # [L, D, Hq*Dh]
    "wk",  # [L, D, Hkv*Dh]
    "wv",  # [L, D, Hkv*Dh]
    "wo",  # [L, Hq*Dh, D]
    "mlp_norm",  # [L, D]
    "w_gate",  # [L, D, F]
    "w_up",  # [L, D, F]
    "w_down",  # [L, F, D]
    "final_norm",  # [D]
    "lm_head",  # [D, V]
]

Params = Dict[str, jnp.ndarray]


def param_shapes(dims: ModelDims) -> Dict[str, Tuple[int, ...]]:
    d, f, l, v = dims.d_model, dims.d_ff, dims.n_layers, dims.vocab
    return {
        "embed": (v, d),
        "attn_norm": (l, d),
        "wq": (l, d, dims.q_dim),
        "wk": (l, d, dims.kv_dim),
        "wv": (l, d, dims.kv_dim),
        "wo": (l, dims.q_dim, d),
        "mlp_norm": (l, d),
        "w_gate": (l, d, f),
        "w_up": (l, d, f),
        "w_down": (l, f, d),
        "final_norm": (d,),
        "lm_head": (d, v),
    }


def init_params(key: jax.Array, dims: ModelDims) -> Params:
    """Scaled-gaussian init (good enough for a synthetic serving model)."""
    shapes = param_shapes(dims)
    params: Params = {}
    for name in PARAM_ORDER:
        key, sub = jax.random.split(key)
        shape = shapes[name]
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * scale
            )
    return params


def params_as_tuple(params: Params) -> Tuple[jnp.ndarray, ...]:
    return tuple(params[name] for name in PARAM_ORDER)


def params_from_tuple(flat: Tuple[jnp.ndarray, ...]) -> Params:
    return dict(zip(PARAM_ORDER, flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary position embedding.  x: [..., n_heads, head_dim]; positions
    broadcastable to x.shape[:-2]."""
    head_dim = x.shape[-1]
    freqs = 1.0 / (
        theta
        ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )  # [Dh/2]
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [...,1,Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    rot1 = x1 * cos - x2 * sin
    rot2 = x1 * sin + x2 * cos
    out = jnp.stack([rot1, rot2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def _swiglu(h: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    return (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# Entry point 1: chunked prefill (single request)
# ---------------------------------------------------------------------------


def prefill_chunk(
    params: Params,
    dims: ModelDims,
    tokens: jnp.ndarray,  # [C] int32 (padded chunk)
    q_start: jnp.ndarray,  # scalar int32: absolute position of tokens[0]
    kv_k: jnp.ndarray,  # [L, T, H_kv, D_h]
    kv_v: jnp.ndarray,  # [L, T, H_kv, D_h]
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One chunked-prefill iteration for one request.

    Writes the chunk's KV into the cache at ``q_start`` and returns
    ``(logits [C, vocab] f32, kv_k', kv_v')``.  The caller (Rust engine)
    chains calls with advancing ``q_start`` and, on the final chunk,
    samples the request's first output token from the last valid row.
    """
    c = tokens.shape[0]
    x = params["embed"][tokens]  # [C, D]
    positions = q_start + jnp.arange(c, dtype=jnp.int32)

    def layer(carry, xs):
        x = carry
        (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down,
         k_cache, v_cache) = xs
        h = rmsnorm(x, attn_norm)
        q = (h @ wq).reshape(c, dims.n_heads, dims.head_dim)
        k = (h @ wk).reshape(c, dims.n_kv_heads, dims.head_dim)
        v = (h @ wv).reshape(c, dims.n_kv_heads, dims.head_dim)
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (q_start, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (q_start, 0, 0))
        if use_pallas:
            attn = kernels.chunked_prefill_attention(
                q, k_cache, v_cache, q_start, interpret=interpret
            )
        else:
            attn = kernels_ref.chunked_prefill_attention(
                q, k_cache, v_cache, q_start
            )
        x = x + attn.reshape(c, dims.q_dim) @ wo
        x = x + _swiglu(rmsnorm(x, mlp_norm), w_gate, w_up, w_down)
        return x, (k_cache, v_cache)

    xs = (
        params["attn_norm"], params["wq"], params["wk"], params["wv"],
        params["wo"], params["mlp_norm"], params["w_gate"], params["w_up"],
        params["w_down"], kv_k, kv_v,
    )
    x, (kv_k_new, kv_v_new) = jax.lax.scan(layer, x, xs)
    logits = (
        rmsnorm(x, params["final_norm"]) @ params["lm_head"]
    ).astype(jnp.float32)
    return logits, kv_k_new, kv_v_new


# ---------------------------------------------------------------------------
# Entry point 2: batched decode step
# ---------------------------------------------------------------------------


def decode_step(
    params: Params,
    dims: ModelDims,
    tokens: jnp.ndarray,  # [B] int32
    pos: jnp.ndarray,  # [B] int32: position each token is written at
    kv_k: jnp.ndarray,  # [B, L, T, H_kv, D_h]
    kv_v: jnp.ndarray,  # [B, L, T, H_kv, D_h]
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One autoregressive decode iteration for a batch of B requests.

    Request ``b`` contributes its previous output token ``tokens[b]`` at
    position ``pos[b]``; the step writes that token's KV and returns the
    logits for the *next* token: ``(logits [B, vocab] f32, kv')``.
    Inactive batch slots are handled by the caller (pos=0, output row
    ignored).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens]  # [B, D]

    def write_at(cache_layer, new, positions):
        # cache_layer [B, T, Hkv, Dh], new [B, Hkv, Dh]
        def one(cache_b, new_b, p):
            return jax.lax.dynamic_update_slice(
                cache_b, new_b[None, :, :], (p, 0, 0)
            )

        return jax.vmap(one)(cache_layer, new, positions)

    def layer(carry, xs):
        x = carry
        (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down,
         k_cache, v_cache) = xs  # caches [B, T, Hkv, Dh]
        h = rmsnorm(x, attn_norm)
        q = (h @ wq).reshape(b, dims.n_heads, dims.head_dim)
        k = (h @ wk).reshape(b, dims.n_kv_heads, dims.head_dim)
        v = (h @ wv).reshape(b, dims.n_kv_heads, dims.head_dim)
        q = rope(q, pos, dims.rope_theta)
        k = rope(k, pos, dims.rope_theta)
        k_cache = write_at(k_cache, k, pos)
        v_cache = write_at(v_cache, v, pos)
        if use_pallas:
            attn = kernels.decode_attention(
                q, k_cache, v_cache, pos, interpret=interpret
            )
        else:
            attn = kernels_ref.decode_attention(q, k_cache, v_cache, pos)
        x = x + attn.reshape(b, dims.q_dim) @ wo
        x = x + _swiglu(rmsnorm(x, mlp_norm), w_gate, w_up, w_down)
        return x, (k_cache, v_cache)

    # Scan over layers: move the per-request layer axis to the front.
    kv_k_l = jnp.moveaxis(kv_k, 1, 0)  # [L, B, T, Hkv, Dh]
    kv_v_l = jnp.moveaxis(kv_v, 1, 0)
    xs = (
        params["attn_norm"], params["wq"], params["wk"], params["wv"],
        params["wo"], params["mlp_norm"], params["w_gate"], params["w_up"],
        params["w_down"], kv_k_l, kv_v_l,
    )
    x, (kv_k_new, kv_v_new) = jax.lax.scan(layer, x, xs)
    logits = (
        rmsnorm(x, params["final_norm"]) @ params["lm_head"]
    ).astype(jnp.float32)
    return logits, jnp.moveaxis(kv_k_new, 0, 1), jnp.moveaxis(kv_v_new, 0, 1)


# ---------------------------------------------------------------------------
# Reference full-sequence forward (oracle for the chunked path)
# ---------------------------------------------------------------------------


def full_forward_ref(
    params: Params, dims: ModelDims, tokens: jnp.ndarray
) -> jnp.ndarray:
    """Plain (non-incremental) causal forward over a whole sequence.

    Oracle: running ``prefill_chunk`` over all chunks followed by
    ``decode_step`` per token must reproduce these logits.  Uses the jnp
    reference kernels and no KV cache at all.
    """
    n = tokens.shape[0]
    x = params["embed"][tokens]
    positions = jnp.arange(n, dtype=jnp.int32)
    for li in range(dims.n_layers):
        h = rmsnorm(x, params["attn_norm"][li])
        q = (h @ params["wq"][li]).reshape(n, dims.n_heads, dims.head_dim)
        k = (h @ params["wk"][li]).reshape(n, dims.n_kv_heads, dims.head_dim)
        v = (h @ params["wv"][li]).reshape(n, dims.n_kv_heads, dims.head_dim)
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
        attn = kernels_ref.chunked_prefill_attention(q, k, v, 0)
        x = x + attn.reshape(n, dims.q_dim) @ params["wo"][li]
        x = x + _swiglu(
            rmsnorm(x, params["mlp_norm"][li]),
            params["w_gate"][li],
            params["w_up"][li],
            params["w_down"][li],
        )
    return (
        rmsnorm(x, params["final_norm"]) @ params["lm_head"]
    ).astype(jnp.float32)
