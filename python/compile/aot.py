"""AOT pipeline: lower the Layer-2 model to HLO text + weight blobs.

Run once at build time (``make artifacts``); the Rust runtime
(`rust/src/runtime/`) loads the outputs and Python never appears on the
request path again.

Outputs (under ``artifacts/``):

  * ``prefill_c{C}.hlo.txt``  — chunked-prefill entry point (1 request)
  * ``decode_b{B}.hlo.txt``   — batched decode entry point
  * ``weights.bin``           — all parameters, f32 little-endian, in
                                ``model.PARAM_ORDER`` order
  * ``manifest.json``         — dims, parameter table (name/shape/offset),
                                entry-point input/output shape lists

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly.  Lowered with
``return_tuple=True`` and unwrapped with ``to_tuple()`` on the Rust side.

Pallas kernels are lowered with ``interpret=True`` so the resulting HLO is
plain ops the CPU PJRT client can execute (real-TPU lowering would emit a
Mosaic custom-call).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

DEFAULT_CHUNK = 64
DEFAULT_DECODE_BATCH = 8
WEIGHT_SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    CRITICAL: print with ``print_large_constants=True``.  The default
    printer elides big array constants as ``constant({...})`` and the
    consuming xla_extension 0.5.1 text parser silently reads those as
    zeros (we lost RoPE's frequency table to this once — the model
    degraded subtly instead of failing loudly).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The 0.5.1 text parser predates `source_end_line` etc.; metadata is
    # debug-only, drop it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def make_prefill_fn(dims: M.ModelDims, chunk: int):
    """Flat-arg wrapper so the HLO parameter order is exactly
    PARAM_ORDER + [tokens, q_start, kv_k, kv_v]."""

    n_params = len(M.PARAM_ORDER)

    def fn(*args):
        params = M.params_from_tuple(args[:n_params])
        tokens, q_start, kv_k, kv_v = args[n_params:]
        return M.prefill_chunk(
            params, dims, tokens, q_start[0], kv_k, kv_v, use_pallas=True
        )

    return fn


def make_decode_fn(dims: M.ModelDims, batch: int):
    n_params = len(M.PARAM_ORDER)

    def fn(*args):
        params = M.params_from_tuple(args[:n_params])
        tokens, pos, kv_k, kv_v = args[n_params:]
        return M.decode_step(
            params, dims, tokens, pos, kv_k, kv_v, use_pallas=True
        )

    return fn


def entry_specs(
    dims: M.ModelDims, chunk: int, batch: int
) -> Tuple[list, list]:
    """(prefill_dynamic_inputs, decode_dynamic_inputs) as ShapeDtypeStructs."""
    l, t = dims.n_layers, dims.max_seq
    hkv, dh = dims.n_kv_heads, dims.head_dim
    prefill = [
        jax.ShapeDtypeStruct((chunk,), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((1,), jnp.int32),  # q_start
        jax.ShapeDtypeStruct((l, t, hkv, dh), jnp.float32),  # kv_k
        jax.ShapeDtypeStruct((l, t, hkv, dh), jnp.float32),  # kv_v
    ]
    decode = [
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # pos
        jax.ShapeDtypeStruct((batch, l, t, hkv, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, l, t, hkv, dh), jnp.float32),
    ]
    return prefill, decode


def param_specs(dims: M.ModelDims) -> list:
    shapes = M.param_shapes(dims)
    return [
        jax.ShapeDtypeStruct(shapes[name], jnp.float32)
        for name in M.PARAM_ORDER
    ]


def lower_entries(dims: M.ModelDims, chunk: int, batch: int):
    pspecs = param_specs(dims)
    prefill_in, decode_in = entry_specs(dims, chunk, batch)
    prefill_hlo = to_hlo_text(
        jax.jit(make_prefill_fn(dims, chunk)).lower(*pspecs, *prefill_in)
    )
    decode_hlo = to_hlo_text(
        jax.jit(make_decode_fn(dims, batch)).lower(*pspecs, *decode_in)
    )
    return prefill_hlo, decode_hlo


def write_weights(out_dir: str, dims: M.ModelDims) -> list:
    """Write weights.bin; return the manifest parameter table."""
    params = M.init_params(jax.random.PRNGKey(WEIGHT_SEED), dims)
    table = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for name in M.PARAM_ORDER:
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "offset_bytes": offset,
                    "size_bytes": arr.nbytes,
                }
            )
            offset += arr.nbytes
    return table


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _shape_list(specs) -> list:
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
    ]


def build(out_dir: str, chunk: int, batch: int) -> None:
    os.makedirs(out_dir, exist_ok=True)
    dims = M.TINY

    prefill_hlo, decode_hlo = lower_entries(dims, chunk, batch)
    prefill_file = f"prefill_c{chunk}.hlo.txt"
    decode_file = f"decode_b{batch}.hlo.txt"
    with open(os.path.join(out_dir, prefill_file), "w") as f:
        f.write(prefill_hlo)
    with open(os.path.join(out_dir, decode_file), "w") as f:
        f.write(decode_hlo)

    param_table = write_weights(out_dir, dims)
    prefill_in, decode_in = entry_specs(dims, chunk, batch)

    l, t = dims.n_layers, dims.max_seq
    manifest = {
        "format_version": 1,
        "model": {
            "name": dims.name,
            "vocab": dims.vocab,
            "d_model": dims.d_model,
            "n_layers": dims.n_layers,
            "n_heads": dims.n_heads,
            "n_kv_heads": dims.n_kv_heads,
            "head_dim": dims.head_dim,
            "d_ff": dims.d_ff,
            "max_seq": dims.max_seq,
            "param_count": dims.param_count(),
        },
        "weights_file": "weights.bin",
        "weights_sha256": _sha256(os.path.join(out_dir, "weights.bin")),
        "params": param_table,
        "entries": {
            "prefill": {
                "file": prefill_file,
                "chunk": chunk,
                "dynamic_inputs": _shape_list(prefill_in),
                "outputs": [
                    {"shape": [chunk, dims.vocab], "dtype": "float32"},
                    {
                        "shape": [l, t, dims.n_kv_heads, dims.head_dim],
                        "dtype": "float32",
                    },
                    {
                        "shape": [l, t, dims.n_kv_heads, dims.head_dim],
                        "dtype": "float32",
                    },
                ],
            },
            "decode": {
                "file": decode_file,
                "batch": batch,
                "dynamic_inputs": _shape_list(decode_in),
                "outputs": [
                    {"shape": [batch, dims.vocab], "dtype": "float32"},
                    {
                        "shape": [batch, l, t, dims.n_kv_heads, dims.head_dim],
                        "dtype": "float32",
                    },
                    {
                        "shape": [batch, l, t, dims.n_kv_heads, dims.head_dim],
                        "dtype": "float32",
                    },
                ],
            },
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"artifacts: {prefill_file} ({len(prefill_hlo)} chars), "
        f"{decode_file} ({len(decode_hlo)} chars), weights.bin "
        f"({dims.param_count()} params), manifest.json -> {out_dir}"
    )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--chunk", type=int, default=DEFAULT_CHUNK)
    parser.add_argument("--batch", type=int, default=DEFAULT_DECODE_BATCH)
    args = parser.parse_args()
    build(args.out_dir, args.chunk, args.batch)


if __name__ == "__main__":
    main()
