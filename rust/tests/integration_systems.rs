//! Cross-system integration tests: every approach serves realistic
//! traces end to end on the simulated heterogeneous cluster, and the
//! relationships the paper's design arguments predict hold.

use cronus::config::{DeploymentConfig, SystemKind};
use cronus::simgpu::model_desc::{LLAMA3_8B, QWEN2_7B};
use cronus::simgpu::spec::{A10, A100, A30};
use cronus::systems::{build_system, replay_trace, RunOutcome};
use cronus::workload::arrival::{at_rate, stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};
use cronus::workload::Request;

fn azure(n: usize, seed: u64) -> Vec<Request> {
    let t = generate(n, &AzureTraceConfig::default(), seed);
    stamp(&t, ArrivalProcess::AllAtOnce)
}

fn run(kind: SystemKind, cfg: &DeploymentConfig, trace: &[Request]) -> RunOutcome {
    replay_trace(build_system(kind, cfg).as_mut(), trace)
}

#[test]
fn all_systems_serve_all_configs() {
    let trace = azure(60, 1);
    for (_, cfg) in DeploymentConfig::paper_matrix() {
        for kind in SystemKind::ALL {
            let out = run(kind, &cfg, &trace);
            assert_eq!(
                out.report.n_finished,
                trace.len(),
                "{} on {}+{}",
                kind.name(),
                cfg.high_gpu.name,
                cfg.low_gpu.name
            );
            assert!(out.report.throughput_rps > 0.0);
            assert!(out.report.ttft_p99_s > 0.0);
            assert!(out.report.tbt_p99_s >= 0.0);
            assert_eq!(out.instances.len(), 2);
        }
    }
}

#[test]
fn metrics_are_internally_consistent() {
    let trace = azure(80, 2);
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    for kind in SystemKind::ALL {
        let out = run(kind, &cfg, &trace);
        let r = &out.report;
        assert!(r.ttft_p50_s <= r.ttft_p99_s, "{}", kind.name());
        assert!(r.tbt_p50_s <= r.tbt_p99_s);
        assert!(r.e2e_p50_s <= r.e2e_p99_s);
        assert!(r.ttft_mean_s <= r.e2e_p99_s);
        assert!(r.makespan_s >= r.e2e_p99_s - 1e-9);
        // Total decode work is fixed by the trace.
        let tokens: u64 = out.instances.iter().map(|i| i.tokens_decoded).sum();
        let expected: u64 =
            trace.iter().map(|r| (r.output_len - 1) as u64).sum();
        assert!(
            tokens >= expected,
            "{}: decoded {tokens} < expected {expected}",
            kind.name()
        );
    }
}

#[test]
fn cronus_beats_both_disaggregated_variants_on_throughput() {
    // The headline claim: partially disaggregated prefill dominates both
    // full disaggregations on every cell.
    let trace = azure(250, 3);
    for (label, cfg) in DeploymentConfig::paper_matrix() {
        let cronus = run(SystemKind::Cronus, &cfg, &trace).report.throughput_rps;
        let hl = run(SystemKind::DisaggHighLow, &cfg, &trace).report.throughput_rps;
        let lh = run(SystemKind::DisaggLowHigh, &cfg, &trace).report.throughput_rps;
        assert!(cronus > hl, "{label}: Cronus {cronus} <= H-L {hl}");
        assert!(cronus > lh, "{label}: Cronus {cronus} <= L-H {lh}");
    }
}

#[test]
fn cronus_beats_pp_on_throughput() {
    let trace = azure(250, 4);
    for (label, cfg) in DeploymentConfig::paper_matrix() {
        let cronus = run(SystemKind::Cronus, &cfg, &trace).report.throughput_rps;
        let pp = run(SystemKind::PpChunked, &cfg, &trace).report.throughput_rps;
        assert!(cronus > 1.3 * pp, "{label}: Cronus {cronus} vs PP {pp}");
    }
}

#[test]
fn disagg_low_end_is_the_bottleneck() {
    // Appendix B / Table 3: in both disaggregated configurations the
    // low-end GPU runs at ~100% *relative utilization* (system throughput
    // over that instance's standalone max) while the high-end GPU is far
    // below.  Uses the same metric as the paper.
    use cronus::launcher::{standalone_decode_rps, standalone_prefill_rps};
    use cronus::simgpu::perfmodel::PerfModel;
    let trace = azure(250, 5);
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let hi_pm = PerfModel::new(cfg.high_gpu, cfg.model);
    let lo_pm = PerfModel::new(cfg.low_gpu, cfg.model);
    for kind in [SystemKind::DisaggHighLow, SystemKind::DisaggLowHigh] {
        let sys_rps = run(kind, &cfg, &trace).report.throughput_rps;
        // Prefill side / decode side standalone capacities for this role
        // assignment.
        let (prefill_cap, decode_cap, low_is_decode) =
            if kind == SystemKind::DisaggHighLow {
                (
                    standalone_prefill_rps(&hi_pm, &trace),
                    standalone_decode_rps(&cfg, &lo_pm, &trace),
                    true,
                )
            } else {
                (
                    standalone_prefill_rps(&lo_pm, &trace),
                    standalone_decode_rps(&cfg, &hi_pm, &trace),
                    false,
                )
            };
        let prefill_util = sys_rps / prefill_cap;
        let decode_util = sys_rps / decode_cap;
        let (low_util, high_util) = if low_is_decode {
            (decode_util, prefill_util)
        } else {
            (prefill_util, decode_util)
        };
        assert!(
            low_util > 0.75,
            "{}: low-end relative utilization {low_util:.2} should be ~1",
            kind.name()
        );
        assert!(
            high_util < 0.65 && high_util < low_util,
            "{}: high-end relative utilization {high_util:.2} should idle",
            kind.name()
        );
    }
}

#[test]
fn latency_shape_at_moderate_load() {
    // Fig. 4's orderings at a sub-saturation fixed-interval rate.
    let cfg = DeploymentConfig::paper(A100, A30, LLAMA3_8B);
    let trace = generate(150, &AzureTraceConfig::default(), 6);
    let rate = 1.5; // below every system's capacity on A100+A30
    let mut ttft = std::collections::HashMap::new();
    let mut tbt = std::collections::HashMap::new();
    for kind in SystemKind::ALL {
        let out = run(kind, &cfg, &at_rate(&trace, rate));
        assert_eq!(out.report.n_finished, trace.len(), "{}", kind.name());
        ttft.insert(kind.name(), out.report.ttft_p99_s);
        tbt.insert(kind.name(), out.report.tbt_p99_s);
    }
    // TTFT: H-L (prefill on dedicated A100) beats Cronus; Cronus beats
    // L-H (all prefill on the low-end GPU) and PP (accumulated comm).
    assert!(ttft["Disagg. H-L"] <= ttft["Cronus"], "{ttft:?}");
    assert!(ttft["Cronus"] < ttft["Disagg. L-H"], "{ttft:?}");
    assert!(ttft["Cronus"] < ttft["PP+Chunked"], "{ttft:?}");
    // TBT: L-H (dedicated decode GPU) beats Cronus; Cronus beats PP.
    assert!(tbt["Disagg. L-H"] <= tbt["Cronus"], "{tbt:?}");
    assert!(tbt["Cronus"] < tbt["PP+Chunked"], "{tbt:?}");
}

#[test]
fn qwen_outperforms_llama_on_decode_bound_systems() {
    // Qwen2-7B's narrower GQA (56 KiB vs 128 KiB per token) lifts
    // throughput of every decode-limited configuration.
    let trace = azure(250, 7);
    for kind in [SystemKind::DisaggHighLow, SystemKind::Cronus] {
        let llama = run(
            kind,
            &DeploymentConfig::paper(A100, A30, LLAMA3_8B),
            &trace,
        )
        .report
        .throughput_rps;
        let qwen = run(
            kind,
            &DeploymentConfig::paper(A100, A30, QWEN2_7B),
            &trace,
        )
        .report
        .throughput_rps;
        assert!(qwen > llama, "{}: qwen {qwen} <= llama {llama}", kind.name());
    }
}

#[test]
fn a30_beats_a10_everywhere() {
    // Upgrading the low-end card must never hurt.
    let trace = azure(200, 8);
    for kind in SystemKind::ALL {
        let a10 = run(kind, &DeploymentConfig::paper(A100, A10, LLAMA3_8B), &trace)
            .report
            .throughput_rps;
        let a30 = run(kind, &DeploymentConfig::paper(A100, A30, LLAMA3_8B), &trace)
            .report
            .throughput_rps;
        assert!(
            a30 >= 0.95 * a10,
            "{}: a30 {a30} markedly worse than a10 {a10}",
            kind.name()
        );
    }
}

#[test]
fn systems_are_deterministic() {
    let trace = azure(50, 9);
    let cfg = DeploymentConfig::paper(A100, A10, QWEN2_7B);
    for kind in SystemKind::ALL {
        let a = run(kind, &cfg, &trace).report;
        let b = run(kind, &cfg, &trace).report;
        assert_eq!(a.makespan_s, b.makespan_s, "{}", kind.name());
        assert_eq!(a.ttft_p99_s, b.ttft_p99_s);
        assert_eq!(a.tbt_p99_s, b.tbt_p99_s);
    }
}

#[test]
fn poisson_arrivals_work_end_to_end() {
    let cfg = DeploymentConfig::paper(A100, A30, LLAMA3_8B);
    let trace = generate(100, &AzureTraceConfig::default(), 10);
    let trace = stamp(&trace, ArrivalProcess::Poisson { rate_rps: 2.0, seed: 1 });
    let out = run(SystemKind::Cronus, &cfg, &trace);
    assert_eq!(out.report.n_finished, 100);
}

#[test]
fn cronus_ttft_less_sensitive_to_low_end_gpu_than_dp() {
    // §5.3: "TTFT P99 of DP increases significantly when A30 is
    // downgraded to A10 ... Cronus is less sensitive."
    let trace = generate(200, &AzureTraceConfig::default(), 12);
    let rate = 1.2;
    let ttft = |kind, low| {
        let cfg = DeploymentConfig::paper(A100, low, LLAMA3_8B);
        run(kind, &cfg, &at_rate(&trace, rate)).report.ttft_p99_s
    };
    let dp_degradation = ttft(SystemKind::DpChunked, A10) / ttft(SystemKind::DpChunked, A30);
    let cronus_degradation = ttft(SystemKind::Cronus, A10) / ttft(SystemKind::Cronus, A30);
    assert!(
        cronus_degradation < dp_degradation,
        "cronus {cronus_degradation:.3} vs dp {dp_degradation:.3}"
    );
}

#[test]
fn tbt_shape_on_a10_cell() {
    // The paper's strongest TBT contrasts come from the A100+A10 cell,
    // where the low-end GPU's decode iterations are slowest: DP and
    // Disagg. H-L decode some/all requests on the A10 and pay for it.
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let trace = generate(150, &AzureTraceConfig::default(), 13);
    let rate = 0.9; // below Disagg. H-L's capacity on this cell
    let mut tbt = std::collections::HashMap::new();
    for kind in SystemKind::ALL {
        let out = run(kind, &cfg, &at_rate(&trace, rate));
        assert_eq!(out.report.n_finished, trace.len(), "{}", kind.name());
        tbt.insert(kind.name(), out.report.tbt_p99_s);
    }
    assert!(tbt["Cronus"] < tbt["DP+Chunked"], "{tbt:?}");
    assert!(tbt["Cronus"] < tbt["Disagg. H-L"], "{tbt:?}");
    assert!(tbt["Cronus"] < tbt["PP+Chunked"], "{tbt:?}");
}
