//! Events-identical pin for the zero-allocation engine refactor.
//!
//! The `oracle` module below is a **verbatim copy of the pre-refactor
//! `EngineInstance`** (hash-map request table, single `running` vector,
//! per-call allocations) — the golden reference committed with the
//! refactor PR.  Every scenario drives the oracle and the refactored
//! engine in lockstep through the same submission schedule and asserts
//! that plans (batch composition, order, bit-exact durations) and event
//! streams (order, ids) are byte-identical, then cross-checks an FNV-1a
//! digest of both streams plus every accounting counter.
//!
//! A second family of tests pins the *system-level* `SystemEvent`
//! stream: replaying the paper trace must produce a digest identical to
//! the stream assembled by hand-driven online stepping, for Cronus and
//! both baselines.

use cronus::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::A100;
use cronus::workload::arrival::{at_rate, stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

/// FNV-1a 64-bit, folding little-endian words.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn event(&mut self, ev: &EngineEvent) {
        let (tag, id) = match ev {
            EngineEvent::FirstToken(id) => (1u64, *id),
            EngineEvent::Token(id) => (2, *id),
            EngineEvent::Finished(id) => (3, *id),
            EngineEvent::KvReceived(id) => (4, *id),
            EngineEvent::Preempted(id) => (5, *id),
        };
        self.u64(tag);
        self.u64(id);
    }

    fn finish(self) -> u64 {
        self.0
    }
}

/// The pre-refactor engine, kept verbatim as the golden reference.
mod oracle {
    use std::collections::VecDeque;

    use cronus::engine::{EngineEvent, EngineRequest, Phase};
    use cronus::kvcache::BlockAllocator;
    use cronus::simgpu::link::LinkSpec;
    use cronus::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};

    pub type ReqId = u64;
    type FxHashMap<K, V> = cronus::util::fxhash::FxHashMap<K, V>;

    #[derive(Clone, Debug)]
    pub struct OraclePlan {
        pub prefill_parts: Vec<(ReqId, usize, bool)>,
        pub decode_ids: Vec<ReqId>,
        pub kv_recv: Vec<(ReqId, usize)>,
        pub shape: IterationShape,
        pub duration_s: f64,
    }

    pub struct OracleEngine {
        pm: PerfModel,
        link: LinkSpec,
        max_batched_tokens: usize,
        max_running: usize,
        kv: BlockAllocator,
        waiting: VecDeque<ReqId>,
        /// Admission order (oldest first) — preemption evicts from the back.
        running: Vec<ReqId>,
        reqs: FxHashMap<ReqId, EngineRequest>,
        /// Tokens already reported per request (survives preemption).
        emitted: FxHashMap<ReqId, usize>,
        pub busy_time_s: f64,
        pub n_iterations: u64,
        pub n_preemptions: u64,
        pub tokens_prefilled: u64,
        pub tokens_decoded: u64,
    }

    impl OracleEngine {
        pub fn new(
            pm: PerfModel,
            link: LinkSpec,
            max_batched_tokens: usize,
            max_running: usize,
            block_size: usize,
            kv_capacity_tokens: usize,
        ) -> Self {
            let n_blocks = kv_capacity_tokens / block_size;
            OracleEngine {
                pm,
                link,
                max_batched_tokens,
                max_running,
                kv: BlockAllocator::new(n_blocks, block_size),
                waiting: VecDeque::new(),
                running: Vec::new(),
                reqs: FxHashMap::default(),
                emitted: FxHashMap::default(),
                busy_time_s: 0.0,
                n_iterations: 0,
                n_preemptions: 0,
                tokens_prefilled: 0,
                tokens_decoded: 0,
            }
        }

        pub fn submit(&mut self, req: EngineRequest) {
            debug_assert!(!self.reqs.contains_key(&req.id));
            self.waiting.push_back(req.id);
            self.emitted.entry(req.id).or_insert(0);
            self.reqs.insert(req.id, req);
        }

        pub fn has_work(&self) -> bool {
            !self.waiting.is_empty() || !self.running.is_empty()
        }

        pub fn plan_iteration(&mut self) -> Option<OraclePlan> {
            let mut budget = self.max_batched_tokens;
            let mut shape = IterationShape::default();
            let mut prefill_parts = Vec::new();
            let mut decode_ids = Vec::new();
            let mut kv_recv = Vec::new();

            // 1. Decode-first: every running decode request gets one token.
            let decoding: Vec<ReqId> = self
                .running
                .iter()
                .copied()
                .filter(|id| self.reqs[id].is_decoding())
                .collect();
            for id in decoding {
                if budget == 0 {
                    break;
                }
                if !self.reqs[&id].is_decoding() {
                    continue;
                }
                let ctx = self.reqs[&id].context_len();
                loop {
                    match self.kv.grow(id, ctx + 1) {
                        Ok(()) => break,
                        Err(_) => {
                            if let Some(victim) = self.pick_preemption_victim(id) {
                                self.preempt(victim);
                            } else {
                                break;
                            }
                        }
                    }
                }
                if self.kv.tokens_of(id).map(|t| t >= ctx + 1) != Some(true) {
                    continue;
                }
                budget -= 1;
                shape.n_decode += 1;
                shape.decode_ctx_sum += ctx;
                decode_ids.push(id);
            }

            // 2. Fill remaining budget with prefill chunks (head-of-line).
            let prefilling: Vec<ReqId> = self
                .running
                .iter()
                .copied()
                .filter(|id| self.reqs[id].is_prefilling())
                .collect();
            for id in prefilling {
                if budget == 0 {
                    break;
                }
                let r = &self.reqs[&id];
                let remaining = r.prefill_remaining();
                if remaining == 0 {
                    continue;
                }
                let chunk = remaining.min(budget);
                let done = match r.phase {
                    Phase::Prefilling { done } => done,
                    _ => 0,
                };
                let ctx_end = r.prefill_offset + done + chunk;
                shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end });
                prefill_parts.push((id, chunk, chunk == remaining));
                budget -= chunk;
            }

            // 3. Admit from the waiting queue.
            while !self.waiting.is_empty() && self.running.len() < self.max_running {
                let id = *self.waiting.front().unwrap();
                let r = &self.reqs[&id];
                let needs_recv = r.needs_kv_recv;
                let local_prefill = r.local_prefill_len();
                if !needs_recv && budget == 0 {
                    break;
                }
                let headroom_blocks = self
                    .running
                    .iter()
                    .filter(|id| self.reqs[id].is_decoding())
                    .count();
                let need = self.kv.blocks_for(r.input_len) + headroom_blocks;
                if need > self.kv.free_blocks() {
                    break;
                }
                self.kv.allocate(id, r.input_len).expect("checked can_allocate");
                self.waiting.pop_front();
                self.running.push(id);
                let r = self.reqs.get_mut(&id).unwrap();
                r.phase = Phase::Prefilling { done: 0 };
                if needs_recv {
                    kv_recv.push((id, r.prefill_offset));
                    r.needs_kv_recv = false;
                } else {
                    let chunk = local_prefill.min(budget);
                    if chunk == 0 {
                        continue;
                    }
                    shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end: chunk });
                    prefill_parts.push((id, chunk, chunk == local_prefill));
                    budget -= chunk;
                }
            }

            if shape.is_empty() && kv_recv.is_empty() {
                return None;
            }

            let compute_t = self.pm.iteration_time(&shape);
            let transfer_t = kv_recv
                .iter()
                .map(|(_, tokens)| {
                    self.link
                        .kv_transfer_time(*tokens, self.pm.model.kv_bytes_per_token())
                })
                .fold(0.0f64, f64::max);
            let duration_s = compute_t.max(transfer_t);

            self.n_iterations += 1;
            self.busy_time_s += duration_s;

            Some(OraclePlan { prefill_parts, decode_ids, kv_recv, shape, duration_s })
        }

        pub fn complete_iteration(&mut self, plan: &OraclePlan) -> Vec<EngineEvent> {
            let mut events = Vec::new();

            for (id, tokens) in &plan.kv_recv {
                events.push(EngineEvent::KvReceived(*id));
                self.tokens_prefilled += *tokens as u64;
                let r = self.reqs.get_mut(id).unwrap();
                if r.local_prefill_len() == 0 {
                    self.finish_prefill(*id, &mut events);
                }
            }

            for (id, chunk, finishes) in &plan.prefill_parts {
                let r = match self.reqs.get_mut(id) {
                    Some(r) if r.is_prefilling() => r,
                    _ => continue,
                };
                let done = match r.phase {
                    Phase::Prefilling { done } => done,
                    _ => 0,
                };
                r.phase = Phase::Prefilling { done: done + chunk };
                self.tokens_prefilled += *chunk as u64;
                if *finishes {
                    self.finish_prefill(*id, &mut events);
                }
            }

            for id in &plan.decode_ids {
                let r = match self.reqs.get_mut(id) {
                    Some(r) if r.is_decoding() => r,
                    _ => continue,
                };
                if let Phase::Decoding { generated } = r.phase {
                    let new_gen = generated + 1;
                    r.phase = Phase::Decoding { generated: new_gen };
                    self.tokens_decoded += 1;
                    let emitted = self.emitted.get_mut(id).unwrap();
                    if new_gen > *emitted {
                        *emitted = new_gen;
                        events.push(EngineEvent::Token(*id));
                    }
                    if new_gen >= r.output_len {
                        r.phase = Phase::Finished;
                        events.push(EngineEvent::Finished(*id));
                        self.retire(*id);
                    }
                }
            }

            events
        }

        fn finish_prefill(&mut self, id: ReqId, events: &mut Vec<EngineEvent>) {
            let emitted = *self.emitted.get(&id).unwrap_or(&0);
            let r = self.reqs.get_mut(&id).unwrap();
            if emitted == 0 {
                r.phase = Phase::Decoding { generated: 1 };
                events.push(EngineEvent::FirstToken(id));
                *self.emitted.get_mut(&id).unwrap() = 1;
                if r.output_len <= 1 {
                    r.phase = Phase::Finished;
                    events.push(EngineEvent::Finished(id));
                    self.retire(id);
                }
            } else {
                r.phase = Phase::Decoding { generated: emitted };
                if emitted >= r.output_len {
                    r.phase = Phase::Finished;
                    events.push(EngineEvent::Finished(id));
                    self.retire(id);
                }
            }
        }

        fn retire(&mut self, id: ReqId) {
            self.running.retain(|x| *x != id);
            let _ = self.kv.release(id);
        }

        fn pick_preemption_victim(&self, protect: ReqId) -> Option<ReqId> {
            self.running.iter().rev().copied().find(|id| *id != protect)
        }

        fn preempt(&mut self, id: ReqId) {
            self.n_preemptions += 1;
            let _ = self.kv.release(id);
            self.running.retain(|x| *x != id);
            let r = self.reqs.get_mut(&id).unwrap();
            r.prefill_offset = 0;
            r.needs_kv_recv = false;
            r.phase = Phase::Queued;
            self.waiting.push_front(id);
        }
    }
}

/// An engine-level workload: (arrival_ns, request) plus engine geometry.
struct Scenario {
    name: &'static str,
    max_batched_tokens: usize,
    max_running: usize,
    block_size: usize,
    kv_capacity_tokens: usize,
    arrivals: Vec<(u64, EngineRequest)>,
}

/// Drive oracle and refactored engine in lockstep; panic on the first
/// divergence; return the (shared) stream digest and the preemption
/// count (so scenarios can assert the paths they target were hit).
fn run_lockstep(sc: &Scenario) -> (u64, u64) {
    let pm = PerfModel::new(A100, LLAMA3_8B);
    let mut new_e = EngineInstance::new(
        sc.name,
        pm,
        LinkSpec::INFINIBAND_100G,
        sc.max_batched_tokens,
        sc.max_running,
        sc.block_size,
        sc.kv_capacity_tokens,
    );
    let mut old_e = oracle::OracleEngine::new(
        pm,
        LinkSpec::INFINIBAND_100G,
        sc.max_batched_tokens,
        sc.max_running,
        sc.block_size,
        sc.kv_capacity_tokens,
    );

    let mut new_digest = Fnv::new();
    let mut old_digest = Fnv::new();
    let mut plan = IterationPlan::default();
    let mut events = Vec::new();
    let mut t_ns = 0u64;
    let mut next = 0usize;
    let mut steps = 0u64;
    loop {
        steps += 1;
        assert!(steps < 1_000_000, "[{}] lockstep did not converge", sc.name);
        while next < sc.arrivals.len() && sc.arrivals[next].0 <= t_ns {
            let req = sc.arrivals[next].1.clone();
            new_e.submit(req.clone());
            old_e.submit(req);
            next += 1;
        }

        let new_planned = new_e.plan_iteration_into(&mut plan);
        let old_plan = old_e.plan_iteration();
        assert_eq!(
            new_planned,
            old_plan.is_some(),
            "[{}] plan presence diverged at t={t_ns}ns",
            sc.name
        );
        let Some(old_plan) = old_plan else {
            if next < sc.arrivals.len() {
                t_ns = sc.arrivals[next].0; // idle until the next arrival
                continue;
            }
            break;
        };

        // Batch composition must match element-for-element.
        assert_eq!(plan.prefill_parts, old_plan.prefill_parts, "[{}] t={t_ns}", sc.name);
        assert_eq!(plan.decode_ids, old_plan.decode_ids, "[{}] t={t_ns}", sc.name);
        assert_eq!(plan.kv_recv, old_plan.kv_recv, "[{}] t={t_ns}", sc.name);
        assert_eq!(plan.shape.prefill, old_plan.shape.prefill, "[{}] t={t_ns}", sc.name);
        assert_eq!(plan.shape.n_decode, old_plan.shape.n_decode, "[{}] t={t_ns}", sc.name);
        assert_eq!(
            plan.shape.decode_ctx_sum, old_plan.shape.decode_ctx_sum,
            "[{}] t={t_ns}",
            sc.name
        );
        // Durations must be bit-identical, not merely close.
        assert_eq!(
            plan.duration_s.to_bits(),
            old_plan.duration_s.to_bits(),
            "[{}] duration diverged at t={t_ns}: {} vs {}",
            sc.name,
            plan.duration_s,
            old_plan.duration_s
        );

        new_e.complete_iteration_into(&plan, &mut events);
        let old_events = old_e.complete_iteration(&old_plan);
        assert_eq!(events, old_events, "[{}] event stream diverged at t={t_ns}", sc.name);

        new_digest.u64(plan.duration_s.to_bits());
        old_digest.u64(old_plan.duration_s.to_bits());
        for ev in &events {
            new_digest.event(ev);
        }
        for ev in &old_events {
            old_digest.event(ev);
        }

        t_ns = t_ns.saturating_add((plan.duration_s * 1e9).round() as u64);
    }

    assert!(!new_e.has_work(), "[{}] refactored engine stuck", sc.name);
    assert!(!old_e.has_work(), "[{}] oracle engine stuck", sc.name);

    // Accounting must agree to the last token and the last f64 bit.
    assert_eq!(new_e.n_iterations, old_e.n_iterations);
    assert_eq!(new_e.n_preemptions, old_e.n_preemptions);
    assert_eq!(new_e.tokens_prefilled, old_e.tokens_prefilled);
    assert_eq!(new_e.tokens_decoded, old_e.tokens_decoded);
    assert_eq!(new_e.busy_time_s.to_bits(), old_e.busy_time_s.to_bits());

    let (nd, od) = (new_digest.finish(), old_digest.finish());
    assert_eq!(nd, od, "[{}] stream digests diverged", sc.name);
    (nd, new_e.n_preemptions)
}

fn paper_arrivals() -> Vec<(u64, EngineRequest)> {
    let trace = generate(300, &AzureTraceConfig::default(), 42);
    let trace = at_rate(&trace, 4.0);
    trace
        .iter()
        .map(|r| (r.arrival_ns, EngineRequest::whole(r.id, r.input_len, r.output_len)))
        .collect()
}

#[test]
fn golden_paper_trace_events_identical() {
    let (digest, _) = run_lockstep(&Scenario {
        name: "paper-trace",
        max_batched_tokens: 512,
        max_running: 256,
        block_size: 16,
        kv_capacity_tokens: 400_000,
        arrivals: paper_arrivals(),
    });
    println!("golden digest [paper-trace]: {digest:#018x}");
}

#[test]
fn golden_partial_prefill_offsets_events_identical() {
    // Cronus-style arrivals: a third of the requests carry a partial
    // prefix (KV transfer on admission), a few fully disaggregated.
    let trace = generate(200, &AzureTraceConfig::default(), 7);
    let trace = at_rate(&trace, 6.0);
    let arrivals: Vec<(u64, EngineRequest)> = trace
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let req = if i % 7 == 0 {
                EngineRequest::with_offset(r.id, r.input_len, r.output_len, r.input_len)
            } else if i % 3 == 0 {
                EngineRequest::with_offset(
                    r.id,
                    r.input_len,
                    r.output_len,
                    r.input_len / 2,
                )
            } else {
                EngineRequest::whole(r.id, r.input_len, r.output_len)
            };
            (r.arrival_ns, req)
        })
        .collect();
    let (digest, _) = run_lockstep(&Scenario {
        name: "partial-prefill",
        max_batched_tokens: 512,
        max_running: 256,
        block_size: 16,
        kv_capacity_tokens: 300_000,
        arrivals,
    });
    println!("golden digest [partial-prefill]: {digest:#018x}");
}

#[test]
fn golden_preemption_stress_events_identical() {
    // Six long-output requests land at t = 0 in a pool that holds their
    // prompts but not their decode growth: constant preemption and
    // head-of-line readmission — the path where membership-epoch
    // bookkeeping could plausibly diverge from the old retain-based
    // removal (including the corner where a victim is re-admitted and
    // fully re-prefilled within the very iteration that planned its
    // decode step).
    let offsets = [0usize, 64, 0, 0, 128, 0];
    let arrivals: Vec<(u64, EngineRequest)> = (0..6u64)
        .map(|i| (0, EngineRequest::with_offset(i, 128, 300, offsets[i as usize])))
        .collect();
    let (digest, preemptions) = run_lockstep(&Scenario {
        name: "preemption-stress",
        max_batched_tokens: 512,
        max_running: 64,
        block_size: 16,
        kv_capacity_tokens: 1_024,
        arrivals,
    });
    assert!(preemptions > 0, "stress scenario never preempted");
    println!("golden digest [preemption-stress]: {digest:#018x} ({preemptions} preemptions)");
}

#[test]
fn golden_burst_admission_events_identical() {
    // Everything arrives at t = 0: exercises the admission loop (whose
    // headroom check went from O(n) rescans to the incremental counter)
    // under maximum queue pressure.
    let trace = generate(150, &AzureTraceConfig::default(), 23);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
    let arrivals: Vec<(u64, EngineRequest)> = trace
        .iter()
        .map(|r| (r.arrival_ns, EngineRequest::whole(r.id, r.input_len, r.output_len)))
        .collect();
    let (digest, _) = run_lockstep(&Scenario {
        name: "burst",
        max_batched_tokens: 512,
        max_running: 128,
        block_size: 16,
        kv_capacity_tokens: 250_000,
        arrivals,
    });
    println!("golden digest [burst]: {digest:#018x}");
}

// ---------------------------------------------------------------------------
// System-level stream pins: the full SystemEvent stream (ids, variants,
// timestamps) must be identical whether assembled by `replay_trace_collect`
// or by hand-driven online stepping — for Cronus and both baselines.
// ---------------------------------------------------------------------------

mod system_stream {
    use cronus::config::{DeploymentConfig, SystemKind};
    use cronus::simclock::SimTime;
    use cronus::simgpu::model_desc::LLAMA3_8B;
    use cronus::simgpu::spec::{A10, A100};
    use cronus::systems::{build_system, replay_trace_collect, SystemEvent};
    use cronus::workload::arrival::at_rate;
    use cronus::workload::azure::{generate, AzureTraceConfig};

    use super::Fnv;

    fn digest_stream(events: &[SystemEvent]) -> u64 {
        let mut d = Fnv::new();
        for ev in events {
            let (tag, id, t) = match ev {
                SystemEvent::FirstToken { id, t } => (1u64, *id, t.0),
                SystemEvent::Token { id, t } => (2, *id, t.0),
                SystemEvent::Finished { id, t } => (3, *id, t.0),
                SystemEvent::Shed { id, t, .. } => (4, *id, t.0),
                SystemEvent::ScaleUp { pair, t } => (5, *pair as u64, t.0),
                SystemEvent::ScaleDown { pair, t } => (6, *pair as u64, t.0),
                SystemEvent::PairFailed { pair, t } => (7, *pair as u64, t.0),
                SystemEvent::PairRecovered { pair, t } => (8, *pair as u64, t.0),
            };
            d.u64(tag);
            d.u64(id);
            d.u64(t);
        }
        d.finish()
    }

    fn replay_vs_stepped(kind: SystemKind, n: usize, seed: u64) {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(n, &AzureTraceConfig::default(), seed);
        let trace = at_rate(&trace, 4.0);

        let mut batch = build_system(kind, &cfg);
        let (_, replay_events, _) = replay_trace_collect(batch.as_mut(), &trace);

        let mut online = build_system(kind, &cfg);
        let mut stepped_events = Vec::new();
        for r in &trace {
            let t = SimTime(r.arrival_ns);
            while let Some(next) = online.next_event_at() {
                if next >= t {
                    break;
                }
                stepped_events.extend(online.advance(next));
            }
            online.submit(t, *r);
        }
        stepped_events.extend(online.advance(SimTime(u64::MAX)));
        online.drain();

        assert_eq!(
            replay_events.len(),
            stepped_events.len(),
            "{kind:?}: stream lengths diverged"
        );
        assert_eq!(replay_events, stepped_events, "{kind:?}: streams diverged");
        let d = digest_stream(&replay_events);
        assert_eq!(d, digest_stream(&stepped_events));
        println!("system stream digest [{kind:?}]: {d:#018x}");
    }

    #[test]
    fn cronus_stream_digest_stable_across_drive_modes() {
        replay_vs_stepped(SystemKind::Cronus, 120, 42);
    }

    #[test]
    fn dp_stream_digest_stable_across_drive_modes() {
        replay_vs_stepped(SystemKind::DpChunked, 80, 11);
    }

    #[test]
    fn pp_stream_digest_stable_across_drive_modes() {
        replay_vs_stepped(SystemKind::PpChunked, 60, 13);
    }
}
