//! Chaos fuzz for the fault-injection subsystem: randomized
//! deterministic fault plans × routing policies, pinning the recovery
//! invariants — every submitted request still terminates exactly once,
//! the merged stream stays monotone, same-seed runs are byte-identical
//! *including* failure events, and an inert plan leaves a run
//! byte-identical to one with no plan at all.

use std::collections::HashMap;

use cronus::checker::InvariantChecker;
use cronus::config::topology::ClusterConfig;
use cronus::cronus::router::RoutePolicy;
use cronus::faults::{FaultConfig, FaultPlan, RetryBackoff};
use cronus::metrics::Report;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::systems::cluster::ClusterSystem;
use cronus::systems::driver::replay_trace_collect;
use cronus::systems::{prefill_tokens_executed, SystemEvent};
use cronus::util::rng::Rng;
use cronus::workload::arrival::at_rate;
use cronus::workload::azure::{generate, AzureTraceConfig};
use cronus::workload::Request;

fn trace(n: usize, seed: u64, rate_rps: f64) -> Vec<Request> {
    at_rate(&generate(n, &AzureTraceConfig::default(), seed), rate_rps)
}

/// One randomized chaos round: a seeded fault plan on a random fleet
/// under a random policy.  Returns the report and event streams of two
/// identical runs for the caller's byte-identity and oracle checks.
fn chaos_round(
    rng: &mut Rng,
) -> (Report, Vec<SystemEvent>, Vec<SystemEvent>, Vec<Request>) {
    let seed = rng.next_u64();
    let n_pairs = rng.range_usize(1, 4);
    let policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
    let rate = 6.0 + rng.f64() * 14.0;
    let trace = trace(30, seed, rate);
    let fcfg = FaultConfig {
        seed,
        n_failures: rng.range_usize(1, 5),
        mtbf_s: 0.3 + rng.f64() * 1.5,
        mttr_s: 0.2 + rng.f64() * 1.5,
        fail_stop_frac: [0.0, 0.3, 1.0][rng.range_usize(0, 3)],
        max_retries: rng.range_usize(2, 8),
        retry_base_s: rng.f64() * 0.1,
        ..FaultConfig::default()
    };
    let plan = fcfg.build_plan(n_pairs).expect("generated plan is valid");
    let run = || {
        let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, policy)
            .with_faults(plan.clone(), fcfg.backoff());
        replay_trace_collect(&mut sys, &trace)
    };
    let (out_a, events_a, _) = run();
    let (_, events_b, _) = run();
    (out_a.report, events_a, events_b, trace)
}

#[test]
fn chaos_every_request_terminates_exactly_once() {
    let mut rng = Rng::new(0xFA_0175);
    let mut saw_failure = false;
    for _ in 0..12 {
        let (report, events, events_b, trace) = chaos_round(&mut rng);

        // The shared oracle must agree with every hand-rolled check
        // below (it was extracted from this suite — keep them in
        // lockstep so a divergence flags a checker bug).
        let mut checker = InvariantChecker::new().with_faults(true);
        checker.expect_trace(&trace);
        for ev in &events {
            checker.on_event(ev);
        }
        checker.check_report(&report);
        let summary = checker.finish();
        assert!(summary.ok(), "{}", summary.render());

        // Same seed, same plan ⇒ byte-identical streams, failures and
        // recoveries included.
        assert_eq!(events, events_b, "chaos run is not deterministic");

        // Monotone merged stream, fault events included.
        assert!(
            events.windows(2).all(|w| w[0].time() <= w[1].time()),
            "event stream went backwards"
        );

        saw_failure |= events
            .iter()
            .any(|e| matches!(e, SystemEvent::PairFailed { .. }));

        // Finished xor Shed, exactly once per trace request — a pair
        // failure may abort and re-serve a request but must never lose
        // or double-terminate it.
        let mut finished: HashMap<u64, usize> = HashMap::new();
        let mut shed: HashMap<u64, usize> = HashMap::new();
        let mut tokens: HashMap<u64, usize> = HashMap::new();
        for ev in &events {
            match ev {
                SystemEvent::Finished { id, .. } => {
                    *finished.entry(*id).or_insert(0) += 1
                }
                SystemEvent::Shed { id, .. } => *shed.entry(*id).or_insert(0) += 1,
                SystemEvent::FirstToken { id, .. } | SystemEvent::Token { id, .. } => {
                    *tokens.entry(*id).or_insert(0) += 1
                }
                _ => {}
            }
        }
        for r in &trace {
            let f = finished.get(&r.id).copied().unwrap_or(0);
            let s = shed.get(&r.id).copied().unwrap_or(0);
            assert_eq!(
                f + s,
                1,
                "request {} ended {f}x Finished / {s}x Shed",
                r.id
            );
            // A finished request streamed its full response; an abort
            // before the failure may have added partial tokens on top
            // (that work is retried from scratch), never removed any.
            if f == 1 {
                let got = tokens.get(&r.id).copied().unwrap_or(0);
                assert!(
                    got >= r.output_len,
                    "request {}: {got} token events < output_len {}",
                    r.id,
                    r.output_len
                );
            }
        }
    }
    assert!(saw_failure, "chaos rounds never injected a failure mid-run");
}

#[test]
fn inert_plan_is_byte_identical_to_no_plan() {
    let trace = trace(40, 17, 12.0);
    for policy in [RoutePolicy::LeastOutstandingTokens, RoutePolicy::KvAffinity] {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut plain = ClusterSystem::new(cfg.clone(), policy);
        let mut inert = ClusterSystem::new(cfg, policy)
            .with_faults(FaultPlan::empty(), RetryBackoff::default());
        let (out_p, events_p, stats_p) = replay_trace_collect(&mut plain, &trace);
        let (out_i, events_i, stats_i) = replay_trace_collect(&mut inert, &trace);
        assert_eq!(events_p, events_i, "inert plan changed the event stream");
        assert_eq!(stats_p, stats_i);
        assert_eq!(out_p.report.makespan_s, out_i.report.makespan_s);
        assert_eq!(out_p.report.ttft_p99_s, out_i.report.ttft_p99_s);
        assert_eq!(out_i.report.n_pair_failures, 0);
        assert_eq!(out_i.report.n_retries, 0);
    }
}

#[test]
fn configured_link_without_displacement_is_byte_identical_to_no_link() {
    // The migration machinery arms itself whenever a link is configured,
    // but with nothing displacing warm sessions (no drains, no SLO
    // rejections, no faults) it must never fire: the armed run's event
    // stream is byte-identical to the link-less one.
    use cronus::simgpu::link::LinkSpec;
    let trace = trace(40, 17, 12.0);
    for policy in [RoutePolicy::LeastOutstandingTokens, RoutePolicy::KvAffinity] {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let linked_cfg = cfg.clone().with_link(LinkSpec::INFINIBAND_100G);
        let mut plain = ClusterSystem::new(cfg, policy);
        let mut linked = ClusterSystem::new(linked_cfg, policy);
        let (out_p, events_p, stats_p) = replay_trace_collect(&mut plain, &trace);
        let (out_l, events_l, stats_l) = replay_trace_collect(&mut linked, &trace);
        assert_eq!(events_p, events_l, "an unused link changed the event stream");
        assert_eq!(stats_p, stats_l);
        assert_eq!(out_p.report.makespan_s, out_l.report.makespan_s);
        assert_eq!(out_p.report.ttft_p99_s, out_l.report.ttft_p99_s);
        assert_eq!(out_l.report.n_migrations, 0);
        assert_eq!(out_l.report.migrated_tokens, 0);
    }
}

#[test]
fn failed_pair_kv_never_migrates_even_with_a_link() {
    // A drained pair's KV is alive and ships over the link; a *failed*
    // pair's KV died with it.  Even on a fleet with a fast link
    // configured, an outage must produce zero migrations — the aborted
    // sessions re-prefill from scratch through the retry path.
    use cronus::simgpu::link::LinkSpec;
    use cronus::systems::driver::closed_loop_collect;
    use cronus::workload::session::{generate_sessions, SessionConfig};
    let scfg = SessionConfig {
        n_sessions: 8,
        min_turns: 3,
        max_turns: 4,
        think_mean_s: 0.4,
        start_window_s: 0.5,
        seed: 11,
        ..SessionConfig::default()
    };
    let sessions = generate_sessions(&scfg);
    let fcfg = FaultConfig {
        schedule: vec![cronus::faults::parse_schedule_entry("0@0.6+2").unwrap()],
        ..FaultConfig::default()
    };
    let cfg = ClusterConfig::mixed(2, LLAMA3_8B)
        .with_link(LinkSpec::parse("1000G").expect("spec"));
    let mut free = ClusterSystem::new(cfg.clone(), RoutePolicy::KvAffinity);
    let mut faulted = ClusterSystem::new(cfg, RoutePolicy::KvAffinity)
        .with_faults(fcfg.build_plan(2).expect("plan"), fcfg.backoff());
    let (out_free, _, _) = closed_loop_collect(&mut free, &sessions);
    let (out_f, events_f, _) = closed_loop_collect(&mut faulted, &sessions);

    assert_eq!(out_f.report.n_pair_failures, 1);
    assert!(
        out_f.report.n_retries >= 1,
        "the outage aborted nothing — move the failure into the burst"
    );
    // Dead KV never ships, however fast the link.
    assert_eq!(out_f.report.n_migrations, 0);
    assert_eq!(out_f.report.migrated_tokens, 0);
    assert_eq!(out_f.report.migration_time_s, 0.0);
    // And the fault-free run on the same linked fleet has nothing to
    // migrate either: no drains, no SLO.
    assert_eq!(out_free.report.n_migrations, 0);
    // The aborted prompts were re-prefilled from scratch.
    assert!(
        prefill_tokens_executed(&out_f) > prefill_tokens_executed(&out_free),
        "retries must re-prefill aborted prompts from scratch"
    );
    // Conservation under the outage.
    let r = &out_f.report;
    assert_eq!(r.n_finished + r.n_rejected, r.n_requests);
    assert!(
        events_f.windows(2).all(|w| w[0].time() <= w[1].time()),
        "event stream went backwards"
    );
}

#[test]
fn retried_work_reprefills_from_scratch() {
    // A transient outage mid-burst: the faulted run must re-execute the
    // prefill of every aborted request (KV died with the pair), so its
    // executed prefill tokens strictly exceed the fault-free run's.
    let trace = trace(30, 23, 15.0);
    let fcfg = FaultConfig {
        schedule: vec![cronus::faults::parse_schedule_entry("0@0.4+1.5").unwrap()],
        ..FaultConfig::default()
    };
    let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
    let mut plain =
        ClusterSystem::new(cfg.clone(), RoutePolicy::LeastOutstandingTokens);
    let mut faulted =
        ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens).with_faults(
            fcfg.build_plan(2).expect("plan"),
            fcfg.backoff(),
        );
    let (out_p, _, _) = replay_trace_collect(&mut plain, &trace);
    let (out_f, events_f, _) = replay_trace_collect(&mut faulted, &trace);

    assert_eq!(out_f.report.n_pair_failures, 1);
    assert_eq!(out_f.report.n_recovered, 1);
    assert!(
        out_f.report.n_retries >= 1,
        "the outage aborted nothing — move the failure into the burst"
    );
    assert!(
        events_f.iter().any(|e| matches!(e, SystemEvent::PairFailed { pair: 0, .. })),
        "PairFailed missing from the merged stream"
    );
    assert!(
        events_f
            .iter()
            .any(|e| matches!(e, SystemEvent::PairRecovered { pair: 0, .. })),
        "PairRecovered missing from the merged stream"
    );
    assert!(
        prefill_tokens_executed(&out_f) > prefill_tokens_executed(&out_p),
        "retries must re-prefill aborted prompts from scratch"
    );
    // Conservation still holds under the outage.
    let r = &out_f.report;
    assert_eq!(r.n_finished + r.n_rejected, trace.len());
}

#[test]
fn fail_stop_chaos_never_panics_and_sheds_the_rest() {
    // Kill every pair permanently mid-run: whatever was in flight is
    // retried into a fleet with no capacity and must drain as shed —
    // never hang, never panic.
    let trace = trace(20, 31, 10.0);
    let fcfg = FaultConfig {
        schedule: vec![
            cronus::faults::parse_schedule_entry("0@0.3").unwrap(),
            cronus::faults::parse_schedule_entry("1@0.5").unwrap(),
        ],
        max_retries: 3,
        ..FaultConfig::default()
    };
    let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
        .with_faults(fcfg.build_plan(2).expect("plan"), fcfg.backoff());
    let (out, events, _) = replay_trace_collect(&mut sys, &trace);
    let r = &out.report;
    assert_eq!(r.n_pair_failures, 2);
    assert_eq!(r.n_recovered, 0);
    assert_eq!(r.n_finished + r.n_rejected, trace.len());
    assert!(r.n_rejected >= 1, "a dead fleet must shed its backlog");
    let mut terminal: HashMap<u64, usize> = HashMap::new();
    for ev in &events {
        if let SystemEvent::Finished { id, .. } | SystemEvent::Shed { id, .. } = ev {
            *terminal.entry(*id).or_insert(0) += 1;
        }
    }
    for req in &trace {
        assert_eq!(terminal.get(&req.id), Some(&1), "request {} not conserved", req.id);
    }
}

#[test]
fn scaled_chaos_with_realistic_arrivals_is_clean() {
    // Production-shaped chaos: a few hundred requests arriving under
    // non-homogeneous processes (diurnal thinning, MMPP bursts) on a
    // multi-pair fleet with an active fault plan, judged by the shared
    // oracle.  On failure `check_scenarios` shrinks the scenario and
    // panics with a path to a minimal repro_*.toml capsule.
    use cronus::checker::{check_scenarios, Scenario, WorkloadSpec};
    use cronus::workload::arrival::ArrivalProcess;
    check_scenarios(
        "faults-chaos-arrivals",
        4,
        |rng| {
            let seed = rng.next_u64();
            let n_pairs = 2 + rng.range_usize(0, 3);
            let arrival = if rng.f64() < 0.5 {
                ArrivalProcess::diurnal(
                    6.0 + rng.f64() * 6.0,
                    20.0 + rng.f64() * 20.0,
                    2.0,
                    rng.next_u64(),
                )
                .expect("valid diurnal")
            } else {
                ArrivalProcess::bursty(
                    2.0,
                    30.0 + rng.f64() * 30.0,
                    0.5 + rng.f64(),
                    rng.next_u64(),
                )
                .expect("valid bursty")
            };
            let mut s = Scenario::minimal("chaos-arrivals");
            s.seed = seed;
            s.policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
            s.cluster = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
            s.workload = WorkloadSpec::OpenLoop {
                n_requests: 250 + rng.range_usize(0, 100),
                trace_seed: seed,
                arrival,
            };
            s.faults = Some(FaultConfig {
                seed,
                n_failures: 1 + rng.range_usize(0, 3),
                mtbf_s: 0.5 + rng.f64() * 2.0,
                mttr_s: 0.3 + rng.f64() * 1.5,
                fail_stop_frac: 0.3,
                ..FaultConfig::default()
            });
            s
        },
        |run| !run.summary.ok(),
    );
}
