//! Experiment-harness integration tests: run reduced-size versions of the
//! paper's tables/figures through the same launcher code `cargo bench`
//! uses, and assert the headline shapes.

use cronus::config::SystemKind;
use cronus::launcher::{fig3, fig4, table2, table3, ExperimentOpts};

fn opts() -> ExperimentOpts {
    ExperimentOpts { n_requests: 120, seed: 42 }
}

#[test]
fn table2_headline_shape() {
    let (_, data) = table2(&opts());
    assert_eq!(data.len(), 20);
    let get = |label: &str, kind: SystemKind| -> f64 {
        data.iter()
            .find(|(l, k, _)| l == label && *k == kind)
            .map(|(_, _, v)| *v)
            .unwrap()
    };
    for cell in [
        "A100+A10 llama3-8b",
        "A100+A10 qwen2-7b",
        "A100+A30 llama3-8b",
        "A100+A30 qwen2-7b",
    ] {
        let cronus = get(cell, SystemKind::Cronus);
        assert!(cronus > get(cell, SystemKind::PpChunked), "{cell}: vs PP");
        assert!(
            cronus > get(cell, SystemKind::DisaggLowHigh),
            "{cell}: vs L-H"
        );
        assert!(
            cronus > get(cell, SystemKind::DisaggHighLow),
            "{cell}: vs H-L"
        );
        // "similar or better throughput" than DP.
        assert!(
            cronus > 0.75 * get(cell, SystemKind::DpChunked),
            "{cell}: vs DP"
        );
    }
    // H-L on the LLaMA cells is the weakest configuration (memory-starved
    // low-end decode), as in the paper.
    assert!(
        get("A100+A10 llama3-8b", SystemKind::DisaggHighLow)
            < get("A100+A10 llama3-8b", SystemKind::DisaggLowHigh)
    );
}

#[test]
fn fig4_headline_shape() {
    let panels = fig4(&ExperimentOpts { n_requests: 100, seed: 42 }, 0.7);
    assert_eq!(panels.len(), 4);
    let idx =
        |k| SystemKind::ALL.iter().position(|x| *x == k).unwrap();
    for p in &panels {
        let ttft = |k| p.rows[idx(k)].1;
        let tbt = |k| p.rows[idx(k)].2;
        // TTFT: Cronus below DP-or-equal, below PP and L-H; H-L best.
        assert!(
            ttft(SystemKind::Cronus) < ttft(SystemKind::DisaggLowHigh),
            "{}: TTFT vs L-H",
            p.label
        );
        assert!(
            ttft(SystemKind::Cronus) < ttft(SystemKind::PpChunked),
            "{}: TTFT vs PP",
            p.label
        );
        assert!(
            ttft(SystemKind::DisaggHighLow) <= ttft(SystemKind::Cronus) * 1.05,
            "{}: H-L TTFT should be (near-)best",
            p.label
        );
        // TBT: L-H best; Cronus below PP.
        assert!(
            tbt(SystemKind::DisaggLowHigh) <= tbt(SystemKind::Cronus),
            "{}: L-H TBT best",
            p.label
        );
        assert!(
            tbt(SystemKind::Cronus) < tbt(SystemKind::PpChunked),
            "{}: TBT vs PP",
            p.label
        );
    }
}

#[test]
fn table3_shape() {
    let t = table3(&ExperimentOpts { n_requests: 150, seed: 42 });
    let s = t.render();
    // Parse the rendered rows back: every config line should show the
    // low-end side near 100%.  (Coarse smoke check; precise assertions
    // live in integration_systems::disagg_low_end_is_the_bottleneck.)
    assert!(s.contains("A100+A10 llama3-8b"));
    assert_eq!(s.matches('%').count(), 16, "4 configs x 4 utilization cells");
}

#[test]
fn fig3_fit_matches_paper_quality() {
    let t = fig3(0.008, 42).render();
    // All four fits should report R² ≥ 0.97.
    for line in t.lines().filter(|l| l.contains("0.9")) {
        assert!(!line.contains("| 0.8"), "weak fit: {line}");
    }
    assert!(t.contains("llama3-8b"));
    assert!(t.contains("qwen2-7b"));
}
