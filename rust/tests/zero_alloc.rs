//! Allocation-counting proofs of the zero-allocation hot paths (ISSUE
//! acceptance criteria; method documented in EXPERIMENTS.md §Perf and
//! §Cluster-perf).
//!
//! A counting global allocator wraps the system allocator.  Two proofs:
//!
//! * the engine hot path — warm an engine into steady 256-request
//!   decode, then measure windows of `plan_iteration_into` +
//!   `complete_iteration_into`;
//! * the cluster hot path — warm a 2-pair cluster into steady decode,
//!   then measure windows of `next_event_at` + `advance_into` (the
//!   calendar pop/re-key, per-pair stepping, k-way merge and pending
//!   drain all run inside the window).
//!
//! Both assert the steady-state windows perform **zero** heap
//! allocations.
//!
//! This file is a standalone integration-test binary on purpose: the
//! global allocator counts every allocation in the process, so the
//! measuring tests serialize on a mutex and nothing else runs in this
//! binary.
//!
//! The one amortized exception, excluded by construction here and
//! documented in EXPERIMENTS.md: a request's paged-KV block list doubles
//! its capacity when the context crosses a power-of-two block count
//! (~every 2× context growth).  The measured windows sit between
//! doubling points.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use cronus::engine::{EngineInstance, EngineRequest, IterationPlan};
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::A100;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// The counter is process-global, so the measuring tests must not
/// overlap: each one holds this lock for its whole body (the other test
/// thread blocks allocation-free while waiting).
static SERIAL: Mutex<()> = Mutex::new(());

struct CountingAlloc;

// SAFETY: defers to the system allocator; the counter is a relaxed
// atomic side effect.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_plan_complete_allocates_nothing() {
    let _serial = SERIAL.lock().unwrap();
    // Same geometry as the `engine plan+complete (256-decode batch)`
    // micro-benchmark: 256 requests of 800 prompt tokens that never
    // finish within the horizon.
    let pm = PerfModel::new(A100, LLAMA3_8B);
    let mut engine = EngineInstance::new(
        "zero-alloc",
        pm,
        LinkSpec::INFINIBAND_100G,
        512,
        512,
        16,
        400_000,
    );
    for i in 0..256 {
        engine.submit(EngineRequest::whole(i, 800, 100_000));
    }

    let mut plan = IterationPlan::default();
    let mut events = Vec::new();

    // Warm-up: admit everything, finish all prefills, let every scratch
    // buffer and block list reach steady capacity.  After 600 iterations
    // each context is ~1400 tokens (88 blocks of capacity 100): the next
    // block-list doubling is ~450 iterations away, far beyond the
    // measured windows.
    for _ in 0..600 {
        assert!(engine.plan_iteration_into(&mut plan));
        engine.complete_iteration_into(&plan, &mut events);
    }
    assert_eq!(engine.stats().n_decode, 256, "not in steady decode state");

    // Three measured windows; the first may still absorb one-off
    // warm-ups, the later windows must be allocation-free.
    let mut per_window = [0u64; 3];
    for w in per_window.iter_mut() {
        let before = allocs();
        for _ in 0..40 {
            engine.plan_iteration_into(&mut plan);
            engine.complete_iteration_into(&plan, &mut events);
        }
        *w = allocs() - before;
    }

    assert_eq!(
        per_window[1], 0,
        "steady-state window 2 allocated (windows: {per_window:?})"
    );
    assert_eq!(
        per_window[2], 0,
        "steady-state window 3 allocated (windows: {per_window:?})"
    );
    // The plan really carried the full batch each iteration.
    assert_eq!(plan.decode_ids.len(), 256);
}

#[test]
fn steady_state_cluster_advance_into_allocates_nothing() {
    use cronus::config::{ClusterConfig, DeploymentConfig};
    use cronus::cronus::router::RoutePolicy;
    use cronus::simclock::SimTime;
    use cronus::simgpu::spec::A10;
    use cronus::systems::cluster::ClusterSystem;
    use cronus::systems::{ServingSystem, SystemEvent};
    use cronus::workload::Request;

    let _serial = SERIAL.lock().unwrap();

    // Two identical pairs in steady decode with huge outputs: nothing
    // finishes inside the horizon, so every measured step is the pure
    // cluster advance path — calendar pop + per-pair `advance_into` +
    // k-way merge (the identical pairs produce events at the *same*
    // instants, so both streams merge on every step) + pending drain.
    let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let cfg = ClusterConfig::homogeneous(2, deployment);
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::RoundRobin);
    for i in 0..256u64 {
        let adm = sys.submit(SimTime::ZERO, Request::new(i, 0, 800, 1_000_000));
        assert_eq!(adm, cronus::systems::Admission::Accepted);
    }

    let mut out: Vec<SystemEvent> = Vec::new();
    // Warm-up: finish every prefill and park every growth-by-doubling
    // buffer between doubling points (the §Perf caveat, now with the
    // collector's per-request TBT vecs in the loop).  The identical
    // pairs step in lockstep (every decode instant is shared, so the
    // k-way merge runs on every measured step); 1600 advances ≈ 128
    // PPI-prefill instants + ~1470 decode iterations, which places
    // every request's TBT gap count well inside the [1024, 2048)
    // capacity octave — the staggered PPI admission spreads requests by
    // only ~150 gaps, far less than the octave width — and every
    // paged-KV block list inside its [100, 200)-block capacity span.
    // The 120 window iterations below stay hundreds of iterations away
    // from either boundary.
    for _ in 0..1600 {
        let t = sys.next_event_at().expect("cluster has work");
        sys.advance_into(t, &mut out);
        out.clear();
    }

    let mut per_window = [0u64; 3];
    for w in per_window.iter_mut() {
        let before = allocs();
        for _ in 0..40 {
            let t = sys.next_event_at().expect("cluster has work");
            sys.advance_into(t, &mut out);
            out.clear();
        }
        *w = allocs() - before;
    }

    assert_eq!(
        per_window[1], 0,
        "cluster steady-state window 2 allocated (windows: {per_window:?})"
    );
    assert_eq!(
        per_window[2], 0,
        "cluster steady-state window 3 allocated (windows: {per_window:?})"
    );
    // The windows really carried both pairs' full decode batches (one
    // token event per request per step, 128 requests per pair).
    assert!(
        out.capacity() >= 256,
        "advance windows never carried the full batches: cap {}",
        out.capacity()
    );
}
