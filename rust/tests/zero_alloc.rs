//! Allocation-counting proof of the zero-allocation engine hot path
//! (ISSUE acceptance criterion; method documented in EXPERIMENTS.md
//! §Perf).
//!
//! A counting global allocator wraps the system allocator; the test
//! warms an engine into steady 256-request decode, then runs measured
//! windows of `plan_iteration_into` + `complete_iteration_into` and
//! asserts the steady-state window performs **zero** heap allocations.
//!
//! This file is a standalone integration-test binary on purpose: the
//! global allocator counts every allocation in the process, so no other
//! test may run concurrently in the same binary.
//!
//! The one amortized exception, excluded by construction here and
//! documented in EXPERIMENTS.md: a request's paged-KV block list doubles
//! its capacity when the context crosses a power-of-two block count
//! (~every 2× context growth).  The measured windows sit between
//! doubling points.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cronus::engine::{EngineInstance, EngineRequest, IterationPlan};
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::A100;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers to the system allocator; the counter is a relaxed
// atomic side effect.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_plan_complete_allocates_nothing() {
    // Same geometry as the `engine plan+complete (256-decode batch)`
    // micro-benchmark: 256 requests of 800 prompt tokens that never
    // finish within the horizon.
    let pm = PerfModel::new(A100, LLAMA3_8B);
    let mut engine = EngineInstance::new(
        "zero-alloc",
        pm,
        LinkSpec::INFINIBAND_100G,
        512,
        512,
        16,
        400_000,
    );
    for i in 0..256 {
        engine.submit(EngineRequest::whole(i, 800, 100_000));
    }

    let mut plan = IterationPlan::default();
    let mut events = Vec::new();

    // Warm-up: admit everything, finish all prefills, let every scratch
    // buffer and block list reach steady capacity.  After 600 iterations
    // each context is ~1400 tokens (88 blocks of capacity 100): the next
    // block-list doubling is ~450 iterations away, far beyond the
    // measured windows.
    for _ in 0..600 {
        assert!(engine.plan_iteration_into(&mut plan));
        engine.complete_iteration_into(&plan, &mut events);
    }
    assert_eq!(engine.stats().n_decode, 256, "not in steady decode state");

    // Three measured windows; the first may still absorb one-off
    // warm-ups, the later windows must be allocation-free.
    let mut per_window = [0u64; 3];
    for w in per_window.iter_mut() {
        let before = allocs();
        for _ in 0..40 {
            engine.plan_iteration_into(&mut plan);
            engine.complete_iteration_into(&plan, &mut events);
        }
        *w = allocs() - before;
    }

    assert_eq!(
        per_window[1], 0,
        "steady-state window 2 allocated (windows: {per_window:?})"
    );
    assert_eq!(
        per_window[2], 0,
        "steady-state window 3 allocated (windows: {per_window:?})"
    );
    // The plan really carried the full batch each iteration.
    assert_eq!(plan.decode_ids.len(), 256);
}
