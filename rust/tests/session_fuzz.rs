//! Randomized closed-loop session fuzzer (tentpole satellite).
//!
//! For many seeds, the same generated multi-turn session workload is
//! served closed-loop on a mixed cluster under `KvAffinity` and
//! `LeastOutstandingTokens`, and the shared invariants are asserted:
//!
//! * every submitted turn ends Finished xor Shed exactly once;
//! * token conservation — a finished turn's event stream carries exactly
//!   `output_len` tokens (1 FirstToken + output_len−1 Tokens), a shed
//!   turn's none;
//! * the event stream is monotone in time;
//! * turn *k+1* is never submitted before turn *k*'s finish plus the
//!   user's think time (closed-loop causality);
//! * at equal completed-turn count, KV-affinity executes *strictly
//!   fewer* prefill tokens than load-only routing, exactly the resident
//!   prefixes it reports as saved, and surfaces a non-zero `kv_hit_rate`
//!   in the `Report` (the acceptance criterion of the issue);
//! * with a TTFT SLO configured, affinity never bypasses admission:
//!   everything still conserves and the run completes.
//!
//! Every run is additionally fed through the shared
//! [`InvariantChecker`] oracle, which was extracted from the hand-rolled
//! checks below — the two must agree, keeping the extraction honest.

use cronus::checker::InvariantChecker;
use cronus::config::topology::ClusterConfig;
use cronus::cronus::router::RoutePolicy;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::systems::cluster::ClusterSystem;
use cronus::systems::driver::{closed_loop_collect, ClosedLoopStats};
use cronus::systems::{prefill_tokens_executed, RunOutcome, SystemEvent};
use cronus::util::fxhash::FxHashMap;
use cronus::util::proptest_lite::{check, PropResult};
use cronus::workload::session::{
    generate_sessions, turn_request_id, Session, SessionConfig,
};

fn run_cfg(
    sessions: &[Session],
    cfg: ClusterConfig,
    policy: RoutePolicy,
    slo_ttft_s: Option<f64>,
) -> (RunOutcome, Vec<SystemEvent>, ClosedLoopStats) {
    let mut sys = ClusterSystem::new(cfg, policy).with_slo_ttft(slo_ttft_s);
    closed_loop_collect(&mut sys, sessions)
}

fn run(
    sessions: &[Session],
    n_pairs: usize,
    policy: RoutePolicy,
    slo_ttft_s: Option<f64>,
) -> (RunOutcome, Vec<SystemEvent>, ClosedLoopStats) {
    run_cfg(sessions, ClusterConfig::mixed(n_pairs, LLAMA3_8B), policy, slo_ttft_s)
}

/// The invariants every closed-loop run must satisfy, whatever the
/// policy or SLO.  `linked` declares whether an inter-pair link is
/// configured (gates the oracle's migration-counter laws).
fn verify_invariants(
    sessions: &[Session],
    out: &RunOutcome,
    events: &[SystemEvent],
    stats: &ClosedLoopStats,
    linked: bool,
    label: &str,
) -> PropResult {
    // The online oracle was extracted from this suite's hand-rolled
    // checks below; run both so they stay in lockstep.
    let mut checker = InvariantChecker::new().with_link(linked);
    checker.expect_sessions(sessions);
    for ev in events {
        checker.on_event(ev);
    }
    checker.check_report(&out.report);
    let summary = checker.finish();
    if !summary.ok() {
        return PropResult::Fail(format!(
            "{label}: invariant oracle disagrees\n{}",
            summary.render()
        ));
    }

    // Monotone event stream.
    for w in events.windows(2) {
        if w[0].time() > w[1].time() {
            return PropResult::Fail(format!("{label}: event stream went backwards"));
        }
    }

    let mut finished: FxHashMap<u64, usize> = FxHashMap::default();
    let mut shed: FxHashMap<u64, usize> = FxHashMap::default();
    let mut tokens: FxHashMap<u64, usize> = FxHashMap::default();
    let mut finish_time: FxHashMap<u64, cronus::simclock::SimTime> =
        FxHashMap::default();
    for ev in events {
        match ev {
            SystemEvent::Finished { id, t } => {
                *finished.entry(*id).or_insert(0) += 1;
                finish_time.insert(*id, *t);
            }
            SystemEvent::Shed { id, .. } => *shed.entry(*id).or_insert(0) += 1,
            SystemEvent::FirstToken { id, .. } | SystemEvent::Token { id, .. } => {
                *tokens.entry(*id).or_insert(0) += 1
            }
            SystemEvent::ScaleUp { .. }
            | SystemEvent::ScaleDown { .. }
            | SystemEvent::PairFailed { .. }
            | SystemEvent::PairRecovered { .. } => {}
        }
    }

    // Every *submitted* turn ends Finished xor Shed exactly once, with
    // exact token conservation.  Turns of aborted sessions that were
    // never submitted must not appear at all.
    let submitted: FxHashMap<u64, cronus::simclock::SimTime> =
        stats.submissions.iter().copied().collect();
    for s in sessions {
        for k in 0..s.turns.len() {
            let id = turn_request_id(s.id, k);
            let f = finished.get(&id).copied().unwrap_or(0);
            let sh = shed.get(&id).copied().unwrap_or(0);
            let was_offered = submitted.contains_key(&id)
                || shed.contains_key(&id); // rejected/dropped turns: Shed only
            if !was_offered {
                if f + sh + tokens.get(&id).copied().unwrap_or(0) != 0 {
                    return PropResult::Fail(format!(
                        "{label}: unsubmitted turn {id} produced events"
                    ));
                }
                continue;
            }
            if f + sh != 1 {
                return PropResult::Fail(format!(
                    "{label}: turn {id} ended {f}x Finished / {sh}x Shed"
                ));
            }
            let got = tokens.get(&id).copied().unwrap_or(0);
            let want = if f == 1 { s.turns[k].output_len } else { 0 };
            if got != want {
                return PropResult::Fail(format!(
                    "{label}: turn {id} emitted {got} tokens, expected {want}"
                ));
            }
        }
    }

    // Closed-loop causality: turn k submitted no earlier than turn k-1's
    // finish plus think time; turn 0 no earlier than the session start.
    for s in sessions {
        for k in 0..s.turns.len() {
            let id = turn_request_id(s.id, k);
            let t = match submitted.get(&id) {
                Some(&t) => t,
                None => continue,
            };
            let earliest = if k == 0 {
                cronus::simclock::SimTime(s.start_ns)
            } else {
                match finish_time.get(&turn_request_id(s.id, k - 1)) {
                    Some(prev) => prev.after_secs(s.turns[k].think_s),
                    None => {
                        return PropResult::Fail(format!(
                            "{label}: turn {id} submitted but predecessor never \
                             finished"
                        ))
                    }
                }
            };
            if t < earliest {
                return PropResult::Fail(format!(
                    "{label}: turn {id} submitted at {t} before finish+think \
                     {earliest}"
                ));
            }
        }
    }

    // Report-level conservation: submitted turns resolve as finished or
    // rejected, and the report agrees with the event stream.
    let n_finished: usize = finished.values().sum();
    let n_shed: usize = shed.values().sum();
    PropResult::assert_eq(
        &format!("{label}: report.n_finished"),
        out.report.n_finished,
        n_finished,
    )
    .and(|| {
        PropResult::assert_eq(
            &format!("{label}: report.n_rejected"),
            out.report.n_rejected,
            n_shed,
        )
    })
    .and(|| {
        PropResult::assert_eq(
            &format!("{label}: submitted turns all resolved"),
            stats.n_submitted,
            stats.n_finished_turns
                + stats.n_rejected_turns
                + stats.n_shed_turns
                + stats.n_dropped_turns,
        )
    })
    .and(|| {
        PropResult::assert_eq(
            &format!("{label}: stats.n_finished_turns"),
            stats.n_finished_turns,
            n_finished,
        )
    })
}

#[test]
fn fuzz_affinity_vs_load_only_routing() {
    check("closed-loop affinity vs LOT invariants", 8, |rng| {
        let scfg = SessionConfig {
            n_sessions: rng.range_usize(3, 9),
            min_turns: 2,
            max_turns: 2 + rng.range_usize(0, 3),
            think_mean_s: 0.2 + rng.f64() * 1.5,
            start_window_s: rng.f64() * 4.0,
            mean_new_input: 192.0 + rng.f64() * 256.0,
            max_new_input: 1024,
            mean_output: 96.0 + rng.f64() * 96.0,
            max_output: 384,
            seed: rng.next_u64(),
            ..SessionConfig::default()
        };
        let sessions = generate_sessions(&scfg);
        let n_pairs = rng.range_usize(2, 4);
        let total_turns: usize = sessions.iter().map(|s| s.turns.len()).sum();
        let total_input: u64 =
            sessions.iter().map(|s| s.total_input_tokens() as u64).sum();

        let (lot_out, lot_events, lot_stats) =
            run(&sessions, n_pairs, RoutePolicy::LeastOutstandingTokens, None);
        let (aff_out, aff_events, aff_stats) =
            run(&sessions, n_pairs, RoutePolicy::KvAffinity, None);

        let r =
            verify_invariants(&sessions, &lot_out, &lot_events, &lot_stats, false, "LOT")
                .and(|| {
                    verify_invariants(
                        &sessions,
                        &aff_out,
                        &aff_events,
                        &aff_stats,
                        false,
                        "KvAffinity",
                    )
                });
        if !matches!(r, PropResult::Ok) {
            return r;
        }

        // The exact prefill accounting below assumes no preemptions (a
        // preempted request re-prefills its prompt, inflating executed
        // tokens).  These workloads sit far below KV-pool pressure, so
        // preemption is not expected — but a seed that somehow triggers
        // one is a precondition miss, not an affinity bug.
        let preemptions = |out: &RunOutcome| -> u64 {
            out.instances.iter().map(|i| i.n_preemptions).sum()
        };
        if preemptions(&lot_out) + preemptions(&aff_out) > 0 {
            return PropResult::Discard;
        }

        // Without an SLO nothing is shed: both policies complete every
        // turn, so the prefill comparison is at equal completed turns.
        PropResult::assert_eq("LOT completes all", lot_stats.n_finished_turns, total_turns)
            .and(|| {
                PropResult::assert_eq(
                    "affinity completes all",
                    aff_stats.n_finished_turns,
                    total_turns,
                )
            })
            .and(|| {
                // KV-oblivious routing recomputes every prompt token.
                PropResult::assert_eq(
                    "LOT executes the full prompt stream",
                    prefill_tokens_executed(&lot_out),
                    total_input,
                )
            })
            .and(|| {
                PropResult::assert_true(
                    "affinity reports hits",
                    aff_out.report.n_kv_hits > 0
                        && aff_out.report.kv_hit_rate > 0.0
                        && aff_out.report.prefill_tokens_saved > 0,
                )
            })
            .and(|| {
                // Acceptance criterion: strictly fewer prefill tokens at
                // equal completed-turn count — and exactly the saved
                // amount fewer.
                PropResult::assert_eq(
                    "affinity skips exactly the saved prefix tokens",
                    prefill_tokens_executed(&aff_out),
                    total_input - aff_out.report.prefill_tokens_saved,
                )
            })
            .and(|| {
                PropResult::assert_true(
                    "strictly fewer prefill tokens under affinity",
                    prefill_tokens_executed(&aff_out)
                        < prefill_tokens_executed(&lot_out),
                )
            })
            .and(|| {
                PropResult::assert_eq(
                    "LOT never hits",
                    lot_out.report.n_kv_hits,
                    0,
                )
            })
    });
}

/// Mixed Cronus+DP fleets (ROADMAP DP prefix-credit item): every other
/// pair runs the DP dispatcher, which now honours `Request::kv_credit`,
/// so affinity may pin sessions on DP pairs and the exact savings
/// accounting must hold across the whole heterogeneous fleet — a DP
/// pair's skipped prefix shows up neither as computed prefill nor as a
/// KV transfer.
#[test]
fn fuzz_affinity_on_mixed_cronus_dp_fleet() {
    use cronus::config::SystemKind;
    check("closed-loop affinity on a Cronus+DP fleet", 6, |rng| {
        let scfg = SessionConfig {
            n_sessions: rng.range_usize(3, 8),
            min_turns: 2,
            max_turns: 2 + rng.range_usize(0, 3),
            think_mean_s: 0.2 + rng.f64(),
            start_window_s: rng.f64() * 3.0,
            mean_new_input: 192.0 + rng.f64() * 192.0,
            max_new_input: 1024,
            mean_output: 96.0 + rng.f64() * 64.0,
            max_output: 320,
            seed: rng.next_u64(),
            ..SessionConfig::default()
        };
        let sessions = generate_sessions(&scfg);
        let n_pairs = rng.range_usize(2, 4);
        let mut cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        for (i, p) in cfg.pairs.iter_mut().enumerate() {
            if i % 2 == 1 {
                p.system = SystemKind::DpChunked;
            }
        }
        let total_turns: usize = sessions.iter().map(|s| s.turns.len()).sum();
        let total_input: u64 =
            sessions.iter().map(|s| s.total_input_tokens() as u64).sum();

        let (lot_out, lot_events, lot_stats) = run_cfg(
            &sessions,
            cfg.clone(),
            RoutePolicy::LeastOutstandingTokens,
            None,
        );
        let (aff_out, aff_events, aff_stats) =
            run_cfg(&sessions, cfg, RoutePolicy::KvAffinity, None);

        let r = verify_invariants(
            &sessions,
            &lot_out,
            &lot_events,
            &lot_stats,
            false,
            "LOT+DP",
        )
        .and(|| {
            verify_invariants(
                &sessions,
                &aff_out,
                &aff_events,
                &aff_stats,
                false,
                "KvAffinity+DP",
            )
        });
        if !matches!(r, PropResult::Ok) {
            return r;
        }
        let preemptions = |out: &RunOutcome| -> u64 {
            out.instances.iter().map(|i| i.n_preemptions).sum()
        };
        if preemptions(&lot_out) + preemptions(&aff_out) > 0 {
            return PropResult::Discard;
        }

        PropResult::assert_eq(
            "mixed fleet: LOT completes all",
            lot_stats.n_finished_turns,
            total_turns,
        )
        .and(|| {
            PropResult::assert_eq(
                "mixed fleet: affinity completes all",
                aff_stats.n_finished_turns,
                total_turns,
            )
        })
        .and(|| {
            PropResult::assert_eq(
                "mixed fleet: LOT executes the full prompt stream",
                prefill_tokens_executed(&lot_out),
                total_input,
            )
        })
        .and(|| {
            PropResult::assert_true(
                "mixed fleet: affinity reports hits",
                aff_out.report.n_kv_hits > 0
                    && aff_out.report.prefill_tokens_saved > 0,
            )
        })
        .and(|| {
            PropResult::assert_eq(
                "mixed fleet: affinity skips exactly the saved prefix tokens",
                prefill_tokens_executed(&aff_out),
                total_input - aff_out.report.prefill_tokens_saved,
            )
        })
        .and(|| {
            PropResult::assert_true(
                "mixed fleet: strictly fewer prefill tokens under affinity",
                prefill_tokens_executed(&aff_out)
                    < prefill_tokens_executed(&lot_out),
            )
        })
    });
}

/// QoS inertness under closed-loop sessions: attaching a class registry
/// — even one declaring a premium class with a TBT SLO, a weight, and a
/// tier — must not perturb a run whose every turn stays in the default
/// class.  The event streams must match exactly, and the QoS run's
/// default-class breakdown must carry the whole run.
#[test]
fn fuzz_default_class_sessions_byte_identical_with_registry() {
    use cronus::qos::{ClassRegistry, ServiceClass};
    check("default-class closed loop ignores the registry", 6, |rng| {
        let scfg = SessionConfig {
            n_sessions: rng.range_usize(3, 8),
            min_turns: 2,
            max_turns: 2 + rng.range_usize(0, 3),
            think_mean_s: 0.2 + rng.f64(),
            start_window_s: rng.f64() * 3.0,
            mean_new_input: 192.0 + rng.f64() * 192.0,
            max_new_input: 1024,
            mean_output: 96.0 + rng.f64() * 64.0,
            max_output: 320,
            seed: rng.next_u64(),
            ..SessionConfig::default()
        };
        let sessions = generate_sessions(&scfg);
        let n_pairs = rng.range_usize(1, 4);
        let slo = if rng.f64() < 0.5 { Some(0.8 + rng.f64()) } else { None };

        let (plain_out, plain_events, plain_stats) =
            run(&sessions, n_pairs, RoutePolicy::KvAffinity, slo);

        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass {
            tier: 1,
            weight: 2.0,
            slo_tbt_p99_s: Some(0.25),
            ..ServiceClass::named("premium")
        });
        let mut sys =
            ClusterSystem::new(ClusterConfig::mixed(n_pairs, LLAMA3_8B), RoutePolicy::KvAffinity)
                .with_slo_ttft(slo)
                .with_classes(reg);
        let (qos_out, qos_events, qos_stats) = closed_loop_collect(&mut sys, &sessions);

        if plain_events != qos_events {
            return PropResult::Fail(
                "registry-attached default-class run diverged from the plain run"
                    .into(),
            );
        }
        PropResult::assert_eq(
            "finished turns",
            plain_stats.n_finished_turns,
            qos_stats.n_finished_turns,
        )
        .and(|| {
            PropResult::assert_eq(
                "report.n_finished",
                plain_out.report.n_finished,
                qos_out.report.n_finished,
            )
        })
        .and(|| {
            PropResult::assert_eq(
                "default class carries the whole run",
                qos_out.report.classes[0].n_finished,
                qos_out.report.n_finished,
            )
        })
        .and(|| {
            PropResult::assert_eq(
                "premium class stays empty",
                qos_out.report.classes[1].n_requests,
                0,
            )
        })
        .and(|| {
            verify_invariants(
                &sessions,
                &qos_out,
                &qos_events,
                &qos_stats,
                false,
                "QoS-default",
            )
        })
    });
}

/// Migration interplay (cross-pair KV migration tentpole): with an
/// inter-pair link and a twitchy controller draining pairs in every
/// think-time lull, drained pairs hand their warm sessions to survivors
/// over the wire.  Pins, per seed: same-seed byte-identity *including*
/// migration deliveries; the exact prefill accounting
/// (`executed == total − saved`) extends to migrated prefixes; the
/// link-less run degrades to plain eviction with zero migrations; and
/// handoff never changes how many turns complete.
#[test]
fn fuzz_drained_pairs_hand_sessions_over_the_link() {
    use cronus::simgpu::link::LinkSpec;
    use cronus::systems::AutoscaleConfig;
    use std::cell::Cell;
    let migrations_seen = Cell::new(0u64);
    check("drain handoff over the link", 6, |rng| {
        let scfg = SessionConfig {
            n_sessions: rng.range_usize(4, 9),
            min_turns: 2,
            max_turns: 2 + rng.range_usize(0, 3),
            think_mean_s: 1.2 + rng.f64(),
            start_window_s: rng.f64() * 0.5,
            mean_new_input: 192.0 + rng.f64() * 256.0,
            max_new_input: 1024,
            mean_output: 96.0 + rng.f64() * 96.0,
            max_output: 384,
            seed: rng.next_u64(),
            ..SessionConfig::default()
        };
        let sessions = generate_sessions(&scfg);
        let n_pairs = rng.range_usize(2, 4);
        let total_input: u64 =
            sessions.iter().map(|s| s.total_input_tokens() as u64).sum();
        let autoscale = AutoscaleConfig {
            initial_pairs: n_pairs,
            window_s: 0.25,
            cooldown_s: 0.25,
            scale_up_backlog: 2048.0,
            scale_down_backlog: 512.0,
            ..AutoscaleConfig::default()
        };
        let go = |linked: bool| {
            let mut cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
            if linked {
                cfg = cfg.with_link(LinkSpec::parse("400G").expect("spec"));
            }
            let mut sys = ClusterSystem::new(cfg, RoutePolicy::KvAffinity)
                .with_autoscale(autoscale.clone());
            closed_loop_collect(&mut sys, &sessions)
        };
        let (mig_out, mig_events, mig_stats) = go(true);
        let (rep_out, rep_events, _) = go(true);
        if mig_events != rep_events {
            return PropResult::Fail(
                "same-seed migration run diverged (deliveries included)".into(),
            );
        }
        if mig_out.report.n_migrations != rep_out.report.n_migrations
            || mig_out.report.migrated_tokens != rep_out.report.migrated_tokens
        {
            return PropResult::Fail("migration counters diverged".into());
        }
        migrations_seen
            .set(migrations_seen.get() + mig_out.report.n_migrations as u64);
        let (ev_out, ev_events, ev_stats) = go(false);

        let inv = verify_invariants(
            &sessions,
            &mig_out,
            &mig_events,
            &mig_stats,
            true,
            "migrate",
        )
        .and(|| {
            verify_invariants(&sessions, &ev_out, &ev_events, &ev_stats, false, "evict")
        });
        if !matches!(inv, PropResult::Ok) {
            return inv;
        }
        let preemptions = |out: &RunOutcome| -> u64 {
            out.instances.iter().map(|i| i.n_preemptions).sum()
        };
        if preemptions(&mig_out) + preemptions(&ev_out) > 0 {
            return PropResult::Discard;
        }

        PropResult::assert_eq("no link, no migration", ev_out.report.n_migrations, 0)
            .and(|| {
                PropResult::assert_eq(
                    "no link, no migrated tokens",
                    ev_out.report.migrated_tokens as usize,
                    0,
                )
            })
            .and(|| {
                // Exact accounting: migrated prefixes are *saved* at the
                // destination, neither recomputed nor double-counted.
                PropResult::assert_eq(
                    "migrated run skips exactly its saved tokens",
                    prefill_tokens_executed(&mig_out),
                    total_input - mig_out.report.prefill_tokens_saved,
                )
            })
            .and(|| {
                PropResult::assert_eq(
                    "evict run skips exactly its saved tokens",
                    prefill_tokens_executed(&ev_out),
                    total_input - ev_out.report.prefill_tokens_saved,
                )
            })
            .and(|| {
                // Without an SLO nothing sheds: handing sessions over
                // never changes how many turns complete.
                PropResult::assert_eq(
                    "handoff never loses turns",
                    mig_stats.n_finished_turns,
                    ev_stats.n_finished_turns,
                )
            })
    });
    assert!(
        migrations_seen.get() > 0,
        "no seed ever migrated a session — the drain handoff never fired"
    );
}

/// "Affinity never violates `--slo-ttft-ms`" is enforced at the
/// *admission* boundary: the resident pair is used only while its
/// prefix-credit-aware TTFT estimate meets the SLO (pinned by the
/// `affinity_falls_back_when_resident_pair_blows_the_slo` router unit
/// test), and everything dispatched went through `slo_admission`.
/// Measured TTFT is a prediction subject to estimator error — the same
/// deliberate scope as the open-loop SLO tests — so this fuzz asserts
/// the structural invariants plus exact conservation, not a hard bound
/// on realized latency.
#[test]
fn fuzz_affinity_under_slo_admission_conserves() {
    check("closed-loop affinity under SLO admission", 6, |rng| {
        let scfg = SessionConfig {
            n_sessions: rng.range_usize(3, 8),
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 0.2 + rng.f64(),
            start_window_s: rng.f64() * 2.0,
            mean_new_input: 256.0,
            max_new_input: 1024,
            mean_output: 128.0,
            max_output: 384,
            seed: rng.next_u64(),
            ..SessionConfig::default()
        };
        let sessions = generate_sessions(&scfg);
        // A moderately tight SLO: some turns may defer/shed, none may
        // vanish or double-resolve.
        let slo = Some(0.5 + rng.f64() * 1.5);
        let (out, events, stats) =
            run(&sessions, rng.range_usize(1, 4), RoutePolicy::KvAffinity, slo);
        verify_invariants(&sessions, &out, &events, &stats, false, "KvAffinity+SLO").and(
            || {
                PropResult::assert_eq(
                    "report conserves submitted turns",
                    out.report.n_finished + out.report.n_rejected,
                    out.report.n_requests,
                )
            },
        )
    });
}
