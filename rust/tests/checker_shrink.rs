//! Robustness-harness integration tests (tentpole satellite).
//!
//! Mutation-tests the invariant oracle — for each corruption of a
//! hand-built event stream (double finish, lost request, token
//! undercount, non-monotone timestamp, phantom migration) the checker
//! must report *exactly* the targeted violation and nothing else — and
//! pins the shrinker end to end: a production-scale failing scenario
//! (hundreds of requests, four pairs, diurnal arrivals, an active fault
//! plan) must reduce to a capsule of at most 3 requests on 1 pair with
//! at most 1 fault event that still fails the same property after a
//! round trip through its TOML file.

use cronus::checker::shrink::shrink;
use cronus::checker::{
    run_scenario, shrink_to_file, CheckSummary, InjectSpec, InvariantChecker,
    Scenario, ScenarioRun, ViolationKind, WorkloadSpec,
};
use cronus::config::topology::ClusterConfig;
use cronus::faults::FaultConfig;
use cronus::metrics::{Collector, Report};
use cronus::simclock::SimTime;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::systems::SystemEvent;
use cronus::workload::arrival::ArrivalProcess;
use cronus::workload::Request;

/// Two requests the synthetic streams below serve: id 1 wants 3 output
/// tokens, id 2 wants 2.
fn trace() -> Vec<Request> {
    vec![Request::new(1, 0, 8, 3), Request::new(2, 0, 8, 2)]
}

/// A stream that satisfies every invariant for [`trace`].
fn healthy_stream() -> Vec<SystemEvent> {
    vec![
        SystemEvent::FirstToken { id: 1, t: SimTime(10) },
        SystemEvent::Token { id: 1, t: SimTime(20) },
        SystemEvent::Token { id: 1, t: SimTime(30) },
        SystemEvent::Finished { id: 1, t: SimTime(30) },
        SystemEvent::FirstToken { id: 2, t: SimTime(40) },
        SystemEvent::Token { id: 2, t: SimTime(50) },
        SystemEvent::Finished { id: 2, t: SimTime(50) },
    ]
}

/// Build a [`Report`] that faithfully describes `events`, the way a
/// serving system's collector would — so a mutation test perturbs
/// exactly one invariant, not the report/stream agreement too.
fn report_for(events: &[SystemEvent]) -> Report {
    let mut c = Collector::new();
    let mut seen: Vec<u64> = Vec::new();
    for ev in events {
        if let SystemEvent::FirstToken { id, .. }
        | SystemEvent::Token { id, .. }
        | SystemEvent::Finished { id, .. }
        | SystemEvent::Shed { id, .. } = ev
        {
            if !seen.contains(id) {
                seen.push(*id);
                c.on_arrival(*id, SimTime::ZERO);
            }
        }
    }
    for ev in events {
        match ev {
            SystemEvent::FirstToken { id, t } | SystemEvent::Token { id, t } => {
                c.on_token(*id, *t)
            }
            SystemEvent::Finished { id, t } => c.on_finish(*id, *t),
            SystemEvent::Shed { id, .. } => c.on_shed(*id),
            _ => {}
        }
    }
    c.report("synthetic")
}

fn verdict(events: &[SystemEvent], report: &Report, linked: bool) -> CheckSummary {
    let mut checker = InvariantChecker::new().with_link(linked);
    checker.expect_trace(&trace());
    for ev in events {
        checker.on_event(ev);
    }
    checker.check_report(report);
    checker.finish()
}

/// At least one violation, all of them of `kind`, none suppressed.
fn assert_exactly(summary: &CheckSummary, kind: ViolationKind) {
    assert!(
        !summary.violations.is_empty(),
        "expected a {kind:?} violation, got a clean run"
    );
    assert!(
        summary.violations.iter().all(|v| v.kind == kind),
        "expected only {kind:?}:\n{}",
        summary.render()
    );
    assert_eq!(summary.n_suppressed, 0, "{}", summary.render());
}

#[test]
fn oracle_accepts_the_healthy_synthetic_stream() {
    let events = healthy_stream();
    let report = report_for(&events);
    let summary = verdict(&events, &report, false);
    assert!(summary.ok(), "{}", summary.render());
    assert_eq!(summary.n_events, events.len() as u64);
}

#[test]
fn mutation_double_finish_is_exactly_double_terminal() {
    let mut events = healthy_stream();
    events.insert(4, SystemEvent::Finished { id: 1, t: SimTime(30) });
    // Keep the report in agreement with the corrupt stream so the only
    // broken law is the terminal-exactness one.
    let mut report = report_for(&healthy_stream());
    report.n_finished += 1;
    report.n_requests += 1;
    assert_exactly(&verdict(&events, &report, false), ViolationKind::DoubleTerminal);
}

#[test]
fn mutation_lost_request_is_exactly_lost_request() {
    // Request 2 vanishes entirely: no tokens, no terminal.
    let events: Vec<SystemEvent> = healthy_stream()
        .into_iter()
        .filter(|ev| {
            !matches!(
                ev,
                SystemEvent::FirstToken { id: 2, .. }
                    | SystemEvent::Token { id: 2, .. }
                    | SystemEvent::Finished { id: 2, .. }
            )
        })
        .collect();
    let report = report_for(&events);
    assert_exactly(&verdict(&events, &report, false), ViolationKind::LostRequest);
}

#[test]
fn mutation_token_undercount_is_exactly_token_count_mismatch() {
    // Request 1 finishes after only 2 of its 3 promised tokens.
    let mut events = healthy_stream();
    events.remove(1);
    let report = report_for(&events);
    assert_exactly(
        &verdict(&events, &report, false),
        ViolationKind::TokenCountMismatch,
    );
}

#[test]
fn mutation_backwards_timestamp_is_exactly_time_regression() {
    let mut events = healthy_stream();
    let last = events.len() - 1;
    events[last] = SystemEvent::Finished { id: 2, t: SimTime(5) };
    let report = report_for(&events);
    assert_exactly(&verdict(&events, &report, false), ViolationKind::TimeRegression);
}

#[test]
fn mutation_phantom_migration_is_exactly_phantom_migration() {
    let events = healthy_stream();

    // A migration counter without a configured link…
    let mut report = report_for(&events);
    report.n_migrations = 1;
    report.migrated_tokens = 512;
    assert_exactly(&verdict(&events, &report, false), ViolationKind::PhantomMigration);

    // …a migration that moved zero tokens even with a link…
    let mut report = report_for(&events);
    report.n_migrations = 1;
    report.migrated_tokens = 0;
    assert_exactly(&verdict(&events, &report, true), ViolationKind::PhantomMigration);

    // …and migrated tokens with no migration to carry them.
    let mut report = report_for(&events);
    report.migrated_tokens = 256;
    assert_exactly(&verdict(&events, &report, true), ViolationKind::PhantomMigration);
}

/// The pinned shrink of the issue: a production-scale chaos scenario —
/// hundreds of requests under a diurnal arrival process across four
/// pairs with an active fault plan — seeded with a double-finish
/// corruption must reduce to at most 3 requests on 1 pair with at most
/// 1 fault event, still failing the same property.
#[test]
fn pinned_shrink_reduces_production_scale_chaos() {
    let mut s = Scenario::minimal("pinned-chaos");
    s.seed = 2026;
    s.cluster = ClusterConfig::mixed(4, LLAMA3_8B);
    s.workload = WorkloadSpec::OpenLoop {
        n_requests: 512,
        trace_seed: 13,
        arrival: ArrivalProcess::diurnal(8.0, 40.0, 4.0, 5).expect("valid arrival"),
    };
    s.faults = Some(FaultConfig {
        seed: 9,
        n_failures: 2,
        mtbf_s: 2.0,
        mttr_s: 1.0,
        ..FaultConfig::default()
    });
    s.inject = Some(InjectSpec::DoubleFinish);

    let fails =
        |run: &ScenarioRun| run.summary.has(ViolationKind::DoubleTerminal);
    let seed_run = run_scenario(&s).expect("seed scenario runs");
    assert!(fails(&seed_run), "seed must fail:\n{}", seed_run.summary.render());
    assert_eq!(seed_run.n_requests, 512);

    let out = shrink(&s, &fails).expect("shrink succeeds");
    let minimal = &out.scenario;
    assert_eq!(minimal.cluster.n_pairs(), 1, "fleet should collapse to one pair");
    let fault_events = minimal
        .faults
        .as_ref()
        .map_or(0, |f| f.schedule.len() + f.n_failures);
    assert!(
        fault_events <= 1,
        "fault plan should shrink to <=1 event, kept {fault_events}"
    );
    match &minimal.workload {
        WorkloadSpec::Explicit { requests } => {
            assert!(
                requests.len() <= 3,
                "expected <=3 requests, got {}",
                requests.len()
            );
        }
        other => panic!("workload should freeze to explicit requests, got {other:?}"),
    }

    // The capsule must still fail the same way after a round trip
    // through its serialized form — exactly what `cronus repro` loads.
    let text = minimal.to_toml();
    let back = Scenario::from_toml(&text).expect("capsule parses");
    assert_eq!(back.to_toml(), text, "capsule must round-trip byte-for-byte");
    let run = run_scenario(&back).expect("capsule runs");
    assert!(fails(&run), "minimal capsule lost the bug:\n{}", run.summary.render());
}

#[test]
fn shrink_to_file_honors_the_repro_dir_env() {
    let dir = std::env::temp_dir().join("cronus_checker_shrink_test");
    std::env::set_var("CRONUS_REPRO_DIR", &dir);
    let mut s = Scenario::minimal("filed");
    s.inject = Some(InjectSpec::LoseTerminal);
    let fails = |run: &ScenarioRun| run.summary.has(ViolationKind::LostRequest);
    let result = shrink_to_file(&s, &fails, "filed case");
    std::env::remove_var("CRONUS_REPRO_DIR");

    let (path, out) = result.expect("shrink_to_file succeeds");
    assert!(path.starts_with(&dir), "capsule landed at {}", path.display());
    assert_eq!(
        path.file_name().and_then(|n| n.to_str()),
        Some("repro_filed_case.toml"),
        "label must be sanitized into the file name"
    );
    let text = std::fs::read_to_string(&path).expect("capsule readable");
    assert_eq!(text, out.scenario.to_toml());
    let back = Scenario::from_toml(&text).expect("capsule parses");
    assert_eq!(back.to_toml(), text);
    let run = run_scenario(&back).expect("capsule runs");
    assert!(fails(&run), "filed capsule must still fail");
}

#[test]
fn capsule_files_replay_deterministically_from_disk() {
    use cronus::cronus::router::RoutePolicy;
    let mut s = Scenario::minimal("disk");
    s.seed = 11;
    s.policy = RoutePolicy::SloAware;
    s.slo_ttft_s = Some(2.0);
    s.cluster = ClusterConfig::mixed(2, LLAMA3_8B);
    s.workload = WorkloadSpec::OpenLoop {
        n_requests: 32,
        trace_seed: 3,
        arrival: ArrivalProcess::bursty(4.0, 40.0, 0.5, 9).expect("valid arrival"),
    };
    s.faults = Some(FaultConfig { n_failures: 1, ..FaultConfig::default() });

    let path = std::env::temp_dir().join("cronus_capsule_disk_test.toml");
    std::fs::write(&path, s.to_toml()).expect("capsule written");
    let back = Scenario::from_toml(&std::fs::read_to_string(&path).expect("readable"))
        .expect("capsule parses");
    assert_eq!(back.to_toml(), s.to_toml());

    // Same capsule, same run: the whole point of a repro file.
    let a = run_scenario(&s).expect("original runs");
    let b = run_scenario(&back).expect("reloaded runs");
    assert_eq!(a.events, b.events, "replay from disk diverged");
    assert!(a.summary.ok(), "{}", a.summary.render());
    assert!(b.summary.ok(), "{}", b.summary.render());
}
