//! Lockstep oracle for the cluster event calendar (tentpole acceptance
//! criterion).
//!
//! `NaiveClusterSystem` below embeds the pre-calendar cluster stepping
//! verbatim: every `submit`/`advance` scans and advances **all N
//! pairs**, merges the per-pair streams with a per-batch stable sort,
//! and `next_event_at` scans every pair.  The production
//! [`ClusterSystem`] replaced that with a lazily-invalidated per-pair
//! event calendar (O(due + log N)) and a k-way merge — this test proves
//! the two produce **byte-identical** `SystemEvent` streams, bit-equal
//! reports (every float compared by `to_bits`), identical per-instance
//! accounting and identical driver bookkeeping across:
//!
//! * all four routing policies,
//! * open-loop trace replay and closed-loop multi-turn sessions,
//! * SLO admission on and off,
//! * a mixed-kind fleet (Cronus + DP pairs, exercising the DP
//!   prefix-credit path), over multiple seeds.

use cronus::config::topology::ClusterConfig;
use cronus::config::SystemKind;
use cronus::cronus::router::{RoutePolicy, Router};
use cronus::metrics::Report;
use cronus::simclock::SimTime;
use cronus::systems::cluster::ClusterSystem;
use cronus::systems::driver::{closed_loop_collect, replay_trace_collect};
use cronus::systems::{
    build_system, Admission, InstanceStat, RunOutcome, ServingSystem, SystemEvent,
};
use cronus::util::fxhash::FxHashMap;
use cronus::workload::arrival::at_rate;
use cronus::workload::azure::{generate, AzureTraceConfig};
use cronus::workload::session::{generate_sessions, SessionConfig};
use cronus::workload::{Request, NO_SESSION};

// --- the retained pre-calendar reference stepper -------------------------

struct NaiveAssigned {
    pair: usize,
    tokens: u64,
    session_id: u64,
    final_turn: bool,
}

/// The scan-everything cluster stepper exactly as it shipped before the
/// event calendar, rebuilt on the crate's public API.
struct NaiveClusterSystem {
    cfg: ClusterConfig,
    label: String,
    slo_ttft_s: Option<f64>,
    router: Router,
    systems: Vec<Box<dyn ServingSystem>>,
    assigned: FxHashMap<u64, NaiveAssigned>,
    routed_counts: Vec<u64>,
    n_router_rejected: usize,
    pending: Vec<SystemEvent>,
}

impl NaiveClusterSystem {
    fn new(cfg: ClusterConfig, policy: RoutePolicy, slo: Option<f64>) -> Self {
        let label = format!("{} {}", cfg.label(), policy.name());
        let router = Router::new(policy, &cfg);
        let systems = cfg
            .pairs
            .iter()
            .map(|pair| build_system(pair.system, &pair.deployment))
            .collect();
        let n = cfg.n_pairs();
        NaiveClusterSystem {
            cfg,
            label,
            slo_ttft_s: slo,
            router,
            systems,
            assigned: FxHashMap::default(),
            routed_counts: vec![0; n],
            n_router_rejected: 0,
            pending: Vec::new(),
        }
    }

    /// The old stepping: advance *every* pair, then stable-sort the
    /// fresh batch segment by time.
    fn collect_until(&mut self, until: SimTime) {
        let start = self.pending.len();
        for (i, sys) in self.systems.iter_mut().enumerate() {
            for ev in sys.advance(until) {
                if let SystemEvent::Finished { id, .. } | SystemEvent::Shed { id, .. } =
                    &ev
                {
                    if let Some(a) = self.assigned.remove(id) {
                        assert_eq!(a.pair, i);
                        self.router.on_completed(a.pair, a.tokens);
                        let shed = matches!(ev, SystemEvent::Shed { .. });
                        if a.session_id != NO_SESSION && (a.final_turn || shed) {
                            self.router.release_session(a.session_id);
                        }
                    }
                }
                self.pending.push(ev);
            }
        }
        self.pending[start..].sort_by_key(|e| e.time());
    }

    fn take_pending_until(&mut self, until: SimTime) -> Vec<SystemEvent> {
        if self.pending.last().map_or(true, |e| e.time() <= until) {
            return std::mem::take(&mut self.pending);
        }
        let idx = self.pending.partition_point(|e| e.time() <= until);
        let rest = self.pending.split_off(idx);
        std::mem::replace(&mut self.pending, rest)
    }
}

impl ServingSystem for NaiveClusterSystem {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn submit(&mut self, t: SimTime, req: Request) -> Admission {
        self.collect_until(SimTime(t.0.saturating_sub(1)));

        if let Some(slo) = self.slo_ttft_s {
            match self.router.slo_admission(t, &req, slo) {
                Admission::Accepted => {}
                Admission::Rejected { reason } => {
                    self.n_router_rejected += 1;
                    if req.session_id != NO_SESSION {
                        self.router.release_session(req.session_id);
                    }
                    self.pending.push(SystemEvent::Shed {
                        id: req.id,
                        t,
                        reason: reason.clone(),
                    });
                    return Admission::Rejected { reason };
                }
                deferred @ Admission::Deferred { .. } => return deferred,
            }
        }

        let decision = match self.slo_ttft_s {
            Some(slo) => self.router.route_within_slo(&req, slo),
            None => self.router.route(&req),
        }
        .expect("oracle fleets always keep an active compatible pair");
        let pair = decision.pair;
        let mut pair_req = req;
        pair_req.kv_credit = decision.kv_credit;
        match self.systems[pair].submit(t, pair_req) {
            Admission::Accepted => {
                self.router.commit_route(&req, &decision);
                self.assigned.insert(
                    req.id,
                    NaiveAssigned {
                        pair,
                        tokens: decision.charged_tokens,
                        session_id: req.session_id,
                        final_turn: req.final_turn,
                    },
                );
                self.routed_counts[pair] += 1;
                Admission::Accepted
            }
            Admission::Rejected { reason } => {
                self.router.on_completed(pair, decision.charged_tokens);
                if req.session_id != NO_SESSION {
                    self.router.release_session(req.session_id);
                }
                self.routed_counts[pair] += 1;
                Admission::Rejected { reason }
            }
            deferred @ Admission::Deferred { .. } => {
                self.router.on_completed(pair, decision.charged_tokens);
                deferred
            }
        }
    }

    fn next_event_at(&self) -> Option<SimTime> {
        let mut next = self.pending.first().map(|e| e.time());
        for sys in &self.systems {
            next = match (next, sys.next_event_at()) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        next
    }

    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent> {
        self.collect_until(until);
        self.take_pending_until(until)
    }

    fn drain(&mut self) -> RunOutcome {
        self.collect_until(SimTime(u64::MAX));
        self.pending.clear();

        let mut reports: Vec<Report> = Vec::new();
        let mut instances: Vec<InstanceStat> = Vec::new();
        for (i, (pair, sys)) in
            self.cfg.pairs.iter().zip(self.systems.iter_mut()).enumerate()
        {
            if self.routed_counts[i] == 0 {
                instances.push(InstanceStat {
                    name: format!("p{i}:{} (idle)", pair.name),
                    busy_time_s: 0.0,
                    n_iterations: 0,
                    n_preemptions: 0,
                    tokens_prefilled: 0,
                    tokens_decoded: 0,
                    tokens_kv_received: 0,
                });
                continue;
            }
            let out = sys.drain();
            reports.push(out.report);
            for inst in out.instances {
                instances.push(InstanceStat {
                    name: format!("p{i}:{}", inst.name),
                    ..inst
                });
            }
        }
        let mut report = Report::merge(self.label.clone(), &reports);
        report.n_requests += self.n_router_rejected;
        report.n_rejected += self.n_router_rejected;
        report.n_kv_hits = self.router.kv_hits() as usize;
        report.prefill_tokens_saved = self.router.prefill_tokens_saved();
        report.n_prefix_routed = self.router.n_prefix_routed() as usize;
        report.kv_hit_rate = if report.n_prefix_routed > 0 {
            self.router.kv_hits() as f64 / report.n_prefix_routed as f64
        } else {
            0.0
        };
        RunOutcome { report, instances }
    }
}

// --- bit-equality helpers ------------------------------------------------

fn assert_f64_bits(label: &str, a: f64, b: f64) {
    assert_eq!(a.to_bits(), b.to_bits(), "{label}: {a} vs {b}");
}

fn assert_samples_bits(label: &str, a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len(), "{label}: sample counts differ");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_f64_bits(&format!("{label}[{k}]"), *x, *y);
    }
}

fn assert_outcomes_bit_equal(label: &str, a: &RunOutcome, b: &RunOutcome) {
    let (ra, rb) = (&a.report, &b.report);
    assert_eq!(ra.label, rb.label, "{label}: label");
    assert_eq!(ra.n_requests, rb.n_requests, "{label}: n_requests");
    assert_eq!(ra.n_finished, rb.n_finished, "{label}: n_finished");
    assert_eq!(ra.n_rejected, rb.n_rejected, "{label}: n_rejected");
    assert_eq!(ra.n_output_tokens, rb.n_output_tokens, "{label}: tokens");
    assert_eq!(ra.n_kv_hits, rb.n_kv_hits, "{label}: kv hits");
    assert_eq!(ra.n_prefix_routed, rb.n_prefix_routed, "{label}: prefix routed");
    assert_eq!(
        ra.prefill_tokens_saved, rb.prefill_tokens_saved,
        "{label}: saved"
    );
    assert_f64_bits(&format!("{label}: makespan"), ra.makespan_s, rb.makespan_s);
    assert_f64_bits(
        &format!("{label}: throughput"),
        ra.throughput_rps,
        rb.throughput_rps,
    );
    assert_f64_bits(
        &format!("{label}: tok throughput"),
        ra.token_throughput_tps,
        rb.token_throughput_tps,
    );
    assert_f64_bits(&format!("{label}: ttft mean"), ra.ttft_mean_s, rb.ttft_mean_s);
    assert_f64_bits(&format!("{label}: ttft p50"), ra.ttft_p50_s, rb.ttft_p50_s);
    assert_f64_bits(&format!("{label}: ttft p99"), ra.ttft_p99_s, rb.ttft_p99_s);
    assert_f64_bits(&format!("{label}: tbt mean"), ra.tbt_mean_s, rb.tbt_mean_s);
    assert_f64_bits(&format!("{label}: tbt p50"), ra.tbt_p50_s, rb.tbt_p50_s);
    assert_f64_bits(&format!("{label}: tbt p99"), ra.tbt_p99_s, rb.tbt_p99_s);
    assert_f64_bits(&format!("{label}: e2e p50"), ra.e2e_p50_s, rb.e2e_p50_s);
    assert_f64_bits(&format!("{label}: e2e p99"), ra.e2e_p99_s, rb.e2e_p99_s);
    assert_f64_bits(&format!("{label}: hit rate"), ra.kv_hit_rate, rb.kv_hit_rate);
    assert_samples_bits(&format!("{label}: ttft samples"), &ra.ttft_samples, &rb.ttft_samples);
    assert_samples_bits(&format!("{label}: tbt samples"), &ra.tbt_samples, &rb.tbt_samples);
    assert_samples_bits(&format!("{label}: e2e samples"), &ra.e2e_samples, &rb.e2e_samples);

    assert_eq!(a.instances.len(), b.instances.len(), "{label}: instances");
    for (ia, ib) in a.instances.iter().zip(&b.instances) {
        assert_eq!(ia.name, ib.name, "{label}: instance name");
        assert_f64_bits(
            &format!("{label}: {} busy", ia.name),
            ia.busy_time_s,
            ib.busy_time_s,
        );
        assert_eq!(ia.n_iterations, ib.n_iterations, "{label}: {}", ia.name);
        assert_eq!(ia.n_preemptions, ib.n_preemptions, "{label}: {}", ia.name);
        assert_eq!(ia.tokens_prefilled, ib.tokens_prefilled, "{label}: {}", ia.name);
        assert_eq!(ia.tokens_decoded, ib.tokens_decoded, "{label}: {}", ia.name);
        assert_eq!(
            ia.tokens_kv_received, ib.tokens_kv_received,
            "{label}: {}",
            ia.name
        );
    }
}

// --- the lockstep matrix -------------------------------------------------

/// A 3-pair mixed-kind fleet: two Cronus pairs and one DP pair, so the
/// oracle also covers the DP prefix-credit dispatch.
fn fleet() -> ClusterConfig {
    let mut cfg = ClusterConfig::mixed(3, cronus::simgpu::model_desc::LLAMA3_8B);
    cfg.pairs[2].system = SystemKind::DpChunked;
    cfg
}

fn open_loop_trace(seed: u64) -> Vec<Request> {
    let t = generate(30, &AzureTraceConfig::default(), seed);
    at_rate(&t, 8.0)
}

fn sessions(seed: u64) -> Vec<cronus::workload::session::Session> {
    generate_sessions(&SessionConfig {
        n_sessions: 4,
        min_turns: 2,
        max_turns: 4,
        think_mean_s: 0.4,
        start_window_s: 2.0,
        mean_new_input: 256.0,
        max_new_input: 1024,
        mean_output: 96.0,
        max_output: 256,
        seed,
        ..SessionConfig::default()
    })
}

#[test]
fn calendar_matches_naive_stepper_open_loop() {
    for seed in [11u64, 12] {
        let trace = open_loop_trace(seed);
        for policy in RoutePolicy::ALL {
            for slo in [None, Some(0.6)] {
                let label = format!(
                    "open-loop seed={seed} policy={} slo={slo:?}",
                    policy.name()
                );
                let mut naive = NaiveClusterSystem::new(fleet(), policy, slo);
                let (out_n, ev_n, stats_n) =
                    replay_trace_collect(&mut naive, &trace);
                let mut cal =
                    ClusterSystem::new(fleet(), policy).with_slo_ttft(slo);
                let (out_c, ev_c, stats_c) = replay_trace_collect(&mut cal, &trace);
                assert_eq!(stats_n, stats_c, "{label}: driver stats");
                assert_eq!(ev_n, ev_c, "{label}: event streams");
                assert_outcomes_bit_equal(&label, &out_n, &out_c);
                assert!(
                    out_c.report.n_finished > 0,
                    "{label}: degenerate run finished nothing"
                );
            }
        }
    }
}

#[test]
fn calendar_matches_naive_stepper_closed_loop() {
    for seed in [21u64, 22] {
        let workload = sessions(seed);
        for policy in RoutePolicy::ALL {
            for slo in [None, Some(1.0)] {
                let label = format!(
                    "closed-loop seed={seed} policy={} slo={slo:?}",
                    policy.name()
                );
                let mut naive = NaiveClusterSystem::new(fleet(), policy, slo);
                let (out_n, ev_n, stats_n) =
                    closed_loop_collect(&mut naive, &workload);
                let mut cal =
                    ClusterSystem::new(fleet(), policy).with_slo_ttft(slo);
                let (out_c, ev_c, stats_c) = closed_loop_collect(&mut cal, &workload);
                assert_eq!(stats_n, stats_c, "{label}: driver stats");
                assert_eq!(ev_n, ev_c, "{label}: event streams");
                assert_outcomes_bit_equal(&label, &out_n, &out_c);
            }
        }
    }
}

#[test]
fn calendar_matches_naive_under_burst() {
    // All-at-once bursts maximize same-instant ties: every pair has due
    // events at the same timestamps, so the k-way merge's (time, pair)
    // tie-break is exercised on every batch.
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    for policy in RoutePolicy::ALL {
        let t = generate(40, &AzureTraceConfig::default(), 31);
        let trace = stamp(&t, ArrivalProcess::AllAtOnce);
        let label = format!("burst policy={}", policy.name());
        let mut naive = NaiveClusterSystem::new(fleet(), policy, None);
        let (out_n, ev_n, _) = replay_trace_collect(&mut naive, &trace);
        let mut cal = ClusterSystem::new(fleet(), policy);
        let (out_c, ev_c, _) = replay_trace_collect(&mut cal, &trace);
        assert_eq!(ev_n, ev_c, "{label}: event streams");
        assert_outcomes_bit_equal(&label, &out_n, &out_c);
        assert_eq!(out_c.report.n_finished, 40, "{label}");
    }
}
