//! Elastic-fleet integration pins: conservation under scale-down (a
//! retiring pair never loses or duplicates a request), byte-identical
//! determinism of scaled runs, inertness of a controller that never
//! triggers, and the planner's never-worse-than-preset guarantee.

use std::collections::HashMap;

use cronus::config::topology::ClusterConfig;
use cronus::config::toml;
use cronus::cronus::router::RoutePolicy;
use cronus::launcher::bursty_trace;
use cronus::planner::{better, plan, PlannerConfig};
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::systems::cluster::ClusterSystem;
use cronus::systems::driver::replay_trace_collect;
use cronus::systems::{AutoscaleConfig, SystemEvent};
use cronus::workload::azure::{generate, AzureTraceConfig};
use cronus::workload::Request;

/// Thresholds tuned so a 40 rps burst forces scale-ups and a sparse
/// tail forces scale-downs within one run.
fn twitchy() -> AutoscaleConfig {
    AutoscaleConfig {
        min_pairs: 1,
        initial_pairs: 1,
        window_s: 0.5,
        scale_up_backlog: 3000.0,
        scale_down_backlog: 1500.0,
        cooldown_s: 0.2,
        ..Default::default()
    }
}

/// 60 requests at 40 rps, then 20 at one request per 10 s: the burst
/// saturates a single pair within half a second and the tail leaves at
/// most a request or two in flight, so with [`twitchy`] thresholds the
/// fleet must both grow and shrink during the run.
fn burst_then_sparse_tail(seed: u64) -> Vec<Request> {
    let mut trace = generate(80, &AzureTraceConfig::default(), seed);
    for (i, r) in trace.iter_mut().enumerate() {
        r.arrival_ns = if i < 60 {
            i as u64 * 25_000_000
        } else {
            60 * 25_000_000 + (i as u64 - 60) * 10_000_000_000
        };
    }
    trace
}

/// FNV-1a digest over the (tag, id, timestamp) stream, scale events
/// included (tags 5/6) and fault events (tags 7/8) — the same
/// byte-level pin the determinism suites apply to the fixed-fleet
/// paths.
fn digest_stream(events: &[SystemEvent]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for ev in events {
        let (tag, id, t) = match ev {
            SystemEvent::FirstToken { id, t } => (1u64, *id, t.0),
            SystemEvent::Token { id, t } => (2, *id, t.0),
            SystemEvent::Finished { id, t } => (3, *id, t.0),
            SystemEvent::Shed { id, t, .. } => (4, *id, t.0),
            SystemEvent::ScaleUp { pair, t } => (5, *pair as u64, t.0),
            SystemEvent::ScaleDown { pair, t } => (6, *pair as u64, t.0),
            SystemEvent::PairFailed { pair, t } => (7, *pair as u64, t.0),
            SystemEvent::PairRecovered { pair, t } => (8, *pair as u64, t.0),
        };
        mix(tag);
        mix(id);
        mix(t);
    }
    h
}

#[test]
fn scaling_conserves_every_request() {
    let trace = burst_then_sparse_tail(11);
    let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
        .with_autoscale(twitchy());
    let (out, events, stats) = replay_trace_collect(&mut sys, &trace);

    // The run actually exercised both directions.
    let ups = events.iter().filter(|e| matches!(e, SystemEvent::ScaleUp { .. })).count();
    let downs = events.iter().filter(|e| matches!(e, SystemEvent::ScaleDown { .. })).count();
    assert!(ups >= 1, "burst never scaled up");
    assert!(downs >= 1, "trickle never scaled down");
    assert_eq!(out.report.n_scale_ups, ups);
    assert_eq!(out.report.n_scale_downs, downs);

    // Conservation: every trace request terminates exactly once — no
    // request is lost or duplicated by activation or drain-then-retire.
    let mut terminal: HashMap<u64, u32> = HashMap::new();
    for ev in &events {
        if let SystemEvent::Finished { id, .. } | SystemEvent::Shed { id, .. } = ev {
            *terminal.entry(*id).or_insert(0) += 1;
        }
    }
    assert_eq!(terminal.len(), trace.len());
    for r in &trace {
        assert_eq!(terminal.get(&r.id), Some(&1), "request {} not conserved", r.id);
    }
    assert_eq!(stats.n_accepted, trace.len());
    assert_eq!(out.report.n_finished, trace.len());

    // Scale events stay time-ordered within the merged stream.
    assert!(events.windows(2).all(|w| w[0].time() <= w[1].time()));
}

#[test]
fn scaled_runs_are_byte_identical() {
    let trace = bursty_trace(90, 23, 40.0);
    let run = || {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::KvAffinity).with_autoscale(twitchy());
        replay_trace_collect(&mut sys, &trace)
    };
    let (out_a, events_a, stats_a) = run();
    let (out_b, events_b, stats_b) = run();
    assert!(
        events_a.iter().any(|e| matches!(e, SystemEvent::ScaleUp { .. })),
        "determinism pin must cover scale events"
    );
    assert_eq!(events_a, events_b, "scaled event streams diverged");
    assert_eq!(digest_stream(&events_a), digest_stream(&events_b));
    assert_eq!(stats_a, stats_b);
    assert_eq!(out_a.report.makespan_s, out_b.report.makespan_s);
    assert_eq!(out_a.report.ttft_p99_s, out_b.report.ttft_p99_s);
    assert_eq!(out_a.report.n_scale_ups, out_b.report.n_scale_ups);
    assert_eq!(out_a.report.n_scale_downs, out_b.report.n_scale_downs);
}

#[test]
fn inert_controller_matches_fixed_fleet_byte_for_byte() {
    // All pairs active from t=0 and thresholds no backlog can cross:
    // the controller observes but never acts, and the run must be
    // byte-identical to a plain fixed-fleet cluster.
    let trace = bursty_trace(60, 31, 40.0);
    let inert = AutoscaleConfig {
        min_pairs: 3,
        initial_pairs: 3,
        scale_up_backlog: f64::INFINITY,
        scale_down_backlog: 0.0,
        ..Default::default()
    };
    let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
    let mut fixed = ClusterSystem::new(cfg.clone(), RoutePolicy::LeastOutstandingTokens);
    let mut elastic = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
        .with_autoscale(inert);
    let (out_f, events_f, stats_f) = replay_trace_collect(&mut fixed, &trace);
    let (out_e, events_e, stats_e) = replay_trace_collect(&mut elastic, &trace);
    assert_eq!(events_f, events_e, "inert controller changed the event stream");
    assert_eq!(digest_stream(&events_f), digest_stream(&events_e));
    assert_eq!(stats_f, stats_e);
    assert_eq!(out_f.report.makespan_s, out_e.report.makespan_s);
    assert_eq!(out_f.report.ttft_p99_s, out_e.report.ttft_p99_s);
    assert_eq!(out_e.report.n_scale_ups, 0);
    assert_eq!(out_e.report.n_scale_downs, 0);
}

#[test]
fn planner_never_loses_to_the_mixed_preset_at_equal_budget() {
    // Budget exactly the 3-pair mixed() preset's cost: the preset is a
    // feasible candidate (and is seeded into the beam), so the planned
    // fleet must match or beat it on throughput-then-TTFT.
    let preset = ClusterConfig::mixed(3, LLAMA3_8B);
    let cfg = PlannerConfig {
        budget_cost_per_hour: Some(preset.cost_per_hour()),
        beam_width: 2,
        max_pairs: 3,
        n_requests: 25,
        ..Default::default()
    };
    let outcome = plan(&cfg).expect("the preset itself fits the budget");
    let baseline = outcome.baseline.as_ref().expect("preset prefix fits");
    assert_eq!(baseline.cluster.n_pairs(), 3);
    assert!(
        !better(baseline, &outcome.best),
        "planned fleet lost to the preset: {:.3} rps / {:.3} s vs {:.3} rps / {:.3} s",
        outcome.best.throughput_rps,
        outcome.best.ttft_p99_s,
        baseline.throughput_rps,
        baseline.ttft_p99_s
    );
    assert!(outcome.best.cost_per_hour <= preset.cost_per_hour() + 1e-9);

    // The emitted TOML loads back through the config layer unchanged.
    let doc = toml::parse(&outcome.toml).expect("planner emits parseable TOML");
    let mut rt = ClusterConfig::default();
    rt.apply_toml(&doc).expect("planner TOML applies");
    assert_eq!(rt.n_pairs(), outcome.best.cluster.n_pairs());
    for (a, b) in rt.pairs.iter().zip(&outcome.best.cluster.pairs) {
        assert_eq!(a.deployment.high_gpu, b.deployment.high_gpu);
        assert_eq!(a.deployment.low_gpu, b.deployment.low_gpu);
        assert_eq!(a.system, b.system);
        assert_eq!(a.rate_share, b.rate_share);
    }
}
