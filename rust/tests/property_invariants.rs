//! Property-based invariant tests (proptest_lite harness; the image
//! ships no proptest).  Each property runs across many deterministic
//! seeds and reports the failing seed on violation.

use cronus::cronus::ppi::{PartialPrefillInstance, PpiJob};
use cronus::engine::{EngineInstance, EngineRequest};
use cronus::kvcache::BlockAllocator;
use cronus::simclock::{EventQueue, SimTime};
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::{A10, A100};
use cronus::util::proptest_lite::{check, PropResult};
use cronus::util::stats;

#[test]
fn prop_allocator_never_double_owns() {
    check("allocator random ops keep invariants", 100, |rng| {
        let n_blocks = rng.range_usize(4, 200);
        let block_size = rng.range_usize(1, 32);
        let mut a = BlockAllocator::new(n_blocks, block_size);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            match rng.range(0, 3) {
                0 => {
                    let tokens = rng.range_usize(0, n_blocks * block_size + 10);
                    next_id += 1;
                    if a.allocate(next_id, tokens).is_ok() {
                        live.push(next_id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.range_usize(0, live.len());
                        let id = live.swap_remove(i);
                        if a.release(id).is_err() {
                            return PropResult::Fail("release of live failed".into());
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.range_usize(0, live.len());
                        let id = live[i];
                        let cur = a.tokens_of(id).unwrap();
                        let _ = a.grow(id, cur + rng.range_usize(0, 64));
                    }
                }
            }
            if let Err(e) = a.check_invariants() {
                return PropResult::Fail(e);
            }
        }
        // Releasing everything returns the pool to full.
        for id in live {
            a.release(id).unwrap();
        }
        PropResult::assert_eq("pool restored", a.free_blocks(), n_blocks)
    });
}

#[test]
fn prop_allocator_accounting_exact() {
    check("used + free == total always", 100, |rng| {
        let mut a = BlockAllocator::new(64, 16);
        for id in 0..rng.range(1, 20) {
            let _ = a.allocate(id, rng.range_usize(1, 300));
            if a.used_blocks() + a.free_blocks() != a.total_blocks() {
                return PropResult::Fail("block accounting drift".into());
            }
        }
        PropResult::Ok
    });
}

#[test]
fn prop_engine_conserves_tokens() {
    // Whatever the workload, every submitted request must finish with
    // exactly `output_len` reported tokens and no leaked KV.
    check("engine token conservation", 40, |rng| {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let kv_tokens = rng.range_usize(2_000, 40_000);
        let budget = [256usize, 512][rng.range_usize(0, 2)];
        let mut e = EngineInstance::new(
            "prop", pm, LinkSpec::INFINIBAND_100G, budget, 64, 16, kv_tokens,
        );
        let n = rng.range_usize(1, 30);
        let mut expected_tokens = 0usize;
        let mut submitted = Vec::new();
        for id in 0..n as u64 {
            let input = rng.range_usize(1, 1500);
            let output = rng.range_usize(1, 120);
            if input + output + 64 > kv_tokens {
                continue; // would never fit; engine would reject upstream
            }
            let offset = if rng.f64() < 0.3 {
                rng.range_usize(0, input + 1)
            } else {
                0
            };
            expected_tokens += output;
            submitted.push(id);
            e.submit(EngineRequest::with_offset(id, input, output, offset));
        }
        let mut first = 0usize;
        let mut tokens = 0usize;
        let mut finished = 0usize;
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            if guard > 200_000 {
                return PropResult::Fail("engine did not converge".into());
            }
            let Some(plan) = e.plan_iteration() else { break };
            for ev in e.complete_iteration(&plan) {
                match ev {
                    cronus::engine::EngineEvent::FirstToken(_) => {
                        first += 1;
                        tokens += 1;
                    }
                    cronus::engine::EngineEvent::Token(_) => tokens += 1,
                    cronus::engine::EngineEvent::Finished(_) => finished += 1,
                    _ => {}
                }
            }
            if let Err(msg) = e.check_invariants() {
                return PropResult::Fail(msg);
            }
        }
        if submitted.is_empty() {
            return PropResult::Discard;
        }
        PropResult::assert_eq("finished count", finished, submitted.len())
            .and(|| PropResult::assert_eq("first tokens", first, submitted.len()))
            .and(|| PropResult::assert_eq("total tokens", tokens, expected_tokens))
            .and(|| {
                PropResult::assert_eq(
                    "no leaked KV",
                    e.kv_allocator().used_blocks(),
                    0,
                )
            })
    });
}

#[test]
fn prop_engine_iteration_durations_positive_and_bounded() {
    check("iteration durations sane", 30, |rng| {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let mut e = EngineInstance::new(
            "prop", pm, LinkSpec::INFINIBAND_100G, 512, 64, 16, 100_000,
        );
        for id in 0..rng.range(1, 12) {
            e.submit(EngineRequest::whole(
                id,
                rng.range_usize(1, 4000),
                rng.range_usize(1, 60),
            ));
        }
        while e.has_work() {
            let Some(plan) = e.plan_iteration() else { break };
            if !(plan.duration_s > 0.0 && plan.duration_s < 10.0) {
                return PropResult::Fail(format!(
                    "weird iteration duration {}",
                    plan.duration_s
                ));
            }
            e.complete_iteration(&plan);
        }
        PropResult::Ok
    });
}

#[test]
fn prop_ppi_never_loses_jobs() {
    check("PPI job conservation under random op order", 60, |rng| {
        let pm = PerfModel::new(A10, LLAMA3_8B);
        let buffer = rng.range_usize(500, 5_000);
        let mut ppi = PartialPrefillInstance::new(pm, buffer);
        let mut next_id = 0u64;
        let mut in_flight: Vec<u64> = Vec::new(); // enqueued, not yet done
        let mut buffered: Vec<u64> = Vec::new();
        let mut running: Option<u64> = None;
        let mut done = 0usize;
        let total = rng.range_usize(5, 40);
        let mut started_total = 0usize;
        for _ in 0..1000 {
            if done == total {
                break;
            }
            let roll = rng.f64();
            if roll < 0.4 && (next_id as usize) < total && ppi.has_slot() {
                let len = rng.range_usize(1, buffer.min(2000));
                if let Some((job, _)) =
                    ppi.enqueue(PpiJob { id: next_id, partial_len: len })
                {
                    running = Some(job.id);
                    started_total += 1;
                } else {
                    in_flight.push(next_id);
                }
                next_id += 1;
            } else if roll < 0.7 && running.is_some() {
                let (job, next) = ppi.on_done();
                if Some(job.id) != running {
                    return PropResult::Fail("finished wrong job".into());
                }
                running = None;
                buffered.push(job.id);
                done += 1;
                if let Some((j, _)) = next {
                    in_flight.retain(|x| *x != j.id);
                    running = Some(j.id);
                    started_total += 1;
                }
            } else if !buffered.is_empty() {
                let i = rng.range_usize(0, buffered.len());
                let id = buffered.swap_remove(i);
                if let Some((j, _)) = ppi.release(id) {
                    in_flight.retain(|x| *x != j.id);
                    running = Some(j.id);
                    started_total += 1;
                }
            }
            if let Err(msg) = ppi.check_invariants() {
                return PropResult::Fail(msg);
            }
        }
        // No job may vanish: everything enqueued is either done, running,
        // or still queued.
        let accounted = done + running.is_some() as usize + in_flight.len();
        PropResult::assert_eq("jobs accounted", accounted, next_id as usize)
            .and(|| {
                PropResult::assert_true(
                    "starts never exceed enqueues",
                    started_total <= next_id as usize,
                )
            })
    });
}

#[test]
fn prop_event_queue_monotone() {
    check("event queue pops in non-decreasing time", 50, |rng| {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut pending = 0usize;
        let mut last = SimTime::ZERO;
        for i in 0..500u32 {
            if pending == 0 || rng.f64() < 0.55 {
                let t = q.now().0 + rng.range(0, 1_000_000);
                q.push(SimTime(t), i);
                pending += 1;
            } else {
                let (t, _) = q.pop().unwrap();
                pending -= 1;
                if t < last {
                    return PropResult::Fail(format!("time went backwards: {t} < {last}"));
                }
                last = t;
            }
        }
        PropResult::Ok
    });
}

#[test]
fn prop_percentile_bounds_and_monotonicity() {
    check("percentile within [min,max], monotone in p", 100, |rng| {
        let n = rng.range_usize(1, 200);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 1000.0 - 500.0).collect();
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut prev = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let v = stats::percentile(&xs, p);
            if v < min - 1e-9 || v > max + 1e-9 {
                return PropResult::Fail(format!("p{p} = {v} outside [{min}, {max}]"));
            }
            if v < prev - 1e-9 {
                return PropResult::Fail(format!("p{p} not monotone"));
            }
            prev = v;
        }
        PropResult::Ok
    });
}

#[test]
fn prop_ols_fit_recovers_planted_line() {
    check("OLS recovers planted coefficients", 60, |rng| {
        let k1 = rng.f64() * 10.0 - 5.0;
        let k2 = rng.f64() * 2.0 - 1.0;
        let b = rng.f64() * 100.0;
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..80 {
            let x1 = rng.f64() * 1000.0;
            let x2 = rng.f64() * 50.0;
            rows.push(vec![x1, x2]);
            ys.push(k1 * x1 + k2 * x2 + b);
        }
        let Some(fit) = stats::ols(&rows, &ys) else {
            return PropResult::Fail("fit failed".into());
        };
        PropResult::assert_true(
            "k1 recovered",
            (fit.beta[0] - k1).abs() < 1e-6 * (1.0 + k1.abs()),
        )
        .and(|| {
            PropResult::assert_true(
                "b recovered",
                (fit.beta[2] - b).abs() < 1e-5 * (1.0 + b.abs()),
            )
        })
    });
}

#[test]
fn prop_trace_generator_within_bounds() {
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("azure trace respects clipping bounds", 40, |rng| {
        let cfg = AzureTraceConfig::default();
        let trace = generate(rng.range_usize(1, 500), &cfg, rng.next_u64());
        for r in &trace {
            if r.input_len < cfg.min_input || r.input_len > cfg.max_input {
                return PropResult::Fail(format!("input {} out of bounds", r.input_len));
            }
            if r.output_len < cfg.min_output || r.output_len > cfg.max_output {
                return PropResult::Fail(format!("output {} out of bounds", r.output_len));
            }
        }
        PropResult::Ok
    });
}

#[test]
fn prop_balancer_split_always_valid() {
    use cronus::cronus::balancer::{Balancer, SplitPolicy};
    use cronus::engine::instance::EngineStats;
    use cronus::simgpu::fit::calibrate;
    let ppi = PerfModel::new(A10, LLAMA3_8B);
    let cpi = PerfModel::new(A100, LLAMA3_8B);
    let (p, c) = calibrate(&ppi, &cpi, 512, 0.01, 3);
    let balancer = Balancer::new(SplitPolicy::Balanced, p, c, 512);
    check("balancer split ∈ [1, input]", 200, |rng| {
        let input = rng.range_usize(1, 8192);
        let stats = EngineStats {
            n_decode: rng.range_usize(0, 512),
            decode_ctx_sum: rng.range_usize(0, 600_000),
            n_prefilling: rng.range_usize(0, 8),
            waiting: rng.range_usize(0, 50),
            free_blocks: rng.range_usize(0, 40_000),
            block_size: 16,
            total_blocks: 40_000,
        };
        let d = balancer.split(input, &stats);
        PropResult::assert_true(
            "bounds",
            d.partial_len >= 1 && d.partial_len <= input,
        )
    });
}

#[test]
fn prop_systems_finish_everything() {
    use cronus::config::{DeploymentConfig, SystemKind};
    use cronus::systems::{build_system, replay_trace};
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("every system finishes every request", 12, |rng| {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let n = rng.range_usize(5, 60);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let process = if rng.f64() < 0.5 {
            ArrivalProcess::AllAtOnce
        } else {
            ArrivalProcess::FixedInterval { interval_s: 0.2 + rng.f64() }
        };
        let trace = stamp(&trace, process);
        let kind = SystemKind::ALL[rng.range_usize(0, 5)];
        let out = replay_trace(build_system(kind, &cfg).as_mut(), &trace);
        PropResult::assert_eq("finished", out.report.n_finished, n).and(|| {
            PropResult::assert_true(
                "ttft <= e2e",
                out.report.ttft_p99_s <= out.report.e2e_p99_s + 1e-9,
            )
        })
    });
}

#[test]
fn prop_replay_conserves_requests_and_tokens() {
    // The online-API conservation law: every request submitted through
    // `replay_trace` ends exactly once as Finished or Shed, its event
    // stream carries exactly `output_len` tokens (1 FirstToken +
    // output_len-1 Tokens), and the engines' token accounting agrees
    // with the event stream.
    use cronus::config::{DeploymentConfig, SystemKind};
    use cronus::systems::{build_system, replay_trace_collect, SystemEvent};
    use cronus::util::fxhash::FxHashMap;
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("replay conserves requests and tokens", 10, |rng| {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let n = rng.range_usize(5, 50);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let process = if rng.f64() < 0.5 {
            ArrivalProcess::AllAtOnce
        } else {
            ArrivalProcess::Poisson {
                rate_rps: 0.5 + rng.f64() * 6.0,
                seed: rng.next_u64(),
            }
        };
        let trace = stamp(&trace, process);
        let kind = SystemKind::ALL[rng.range_usize(0, 5)];
        let mut sys = build_system(kind, &cfg);
        let (out, events, stats) = replay_trace_collect(sys.as_mut(), &trace);

        // The shared oracle was extracted from the hand-rolled checks
        // below; run both so the extraction stays honest.
        let mut checker = cronus::checker::InvariantChecker::new();
        checker.expect_trace(&trace);
        for ev in &events {
            checker.on_event(ev);
        }
        checker.check_report(&out.report);
        let summary = checker.finish();
        if !summary.ok() {
            return PropResult::Fail(format!(
                "invariant oracle disagrees\n{}",
                summary.render()
            ));
        }

        let mut finished: FxHashMap<u64, usize> = FxHashMap::default();
        let mut shed: FxHashMap<u64, usize> = FxHashMap::default();
        let mut tokens: FxHashMap<u64, usize> = FxHashMap::default();
        for ev in &events {
            match ev {
                SystemEvent::Finished { id, .. } => *finished.entry(*id).or_insert(0) += 1,
                SystemEvent::Shed { id, .. } => *shed.entry(*id).or_insert(0) += 1,
                SystemEvent::FirstToken { id, .. } | SystemEvent::Token { id, .. } => {
                    *tokens.entry(*id).or_insert(0) += 1
                }
                SystemEvent::ScaleUp { .. }
                | SystemEvent::ScaleDown { .. }
                | SystemEvent::PairFailed { .. }
                | SystemEvent::PairRecovered { .. } => {}
            }
        }
        // Terminal-state exactness: Finished xor Shed, exactly once.
        for r in &trace {
            let f = finished.get(&r.id).copied().unwrap_or(0);
            let s = shed.get(&r.id).copied().unwrap_or(0);
            if f + s != 1 {
                return PropResult::Fail(format!(
                    "request {} ended {f}x Finished / {s}x Shed",
                    r.id
                ));
            }
            let got = tokens.get(&r.id).copied().unwrap_or(0);
            let want = if f == 1 { r.output_len } else { 0 };
            if got != want {
                return PropResult::Fail(format!(
                    "request {}: {got} token events, expected {want}",
                    r.id
                ));
            }
        }
        // Event stream vs report vs engine accounting.
        let n_finished: usize = finished.values().sum();
        let n_shed: usize = shed.values().sum();
        let decoded: u64 = out.instances.iter().map(|i| i.tokens_decoded).sum();
        let expected_decoded: u64 = trace
            .iter()
            .filter(|r| finished.contains_key(&r.id))
            .map(|r| (r.output_len - 1) as u64)
            .sum();
        PropResult::assert_eq("report.n_finished", out.report.n_finished, n_finished)
            .and(|| PropResult::assert_eq("report.n_rejected", out.report.n_rejected, n_shed))
            .and(|| PropResult::assert_eq("accepted", stats.n_accepted, n_finished))
            .and(|| {
                PropResult::assert_true(
                    "engine decode accounting covers the event stream",
                    decoded >= expected_decoded,
                )
            })
    });
}

#[test]
fn online_cronus_paper_trace_matches_batch_replay() {
    // Regression pin for the API redesign: the online single-pair Cronus
    // driven request-by-request (explicit submit + fine-grained advance)
    // must reproduce the replay_trace report — which preserves the
    // pre-redesign batch event order — on the paper's workload, and the
    // one-pair cluster must agree too.
    use cronus::config::topology::ClusterConfig;
    use cronus::config::{DeploymentConfig, SystemKind};
    use cronus::cronus::router::RoutePolicy;
    use cronus::simclock::SimTime;
    use cronus::systems::cluster::build_cluster_system;
    use cronus::systems::{build_system, replay_trace, ServingSystem};
    use cronus::workload::arrival::at_rate;
    use cronus::workload::azure::{generate, AzureTraceConfig};

    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let trace = generate(300, &AzureTraceConfig::default(), 42);
    let trace = at_rate(&trace, 4.0);

    let batch = replay_trace(build_system(SystemKind::Cronus, &cfg).as_mut(), &trace);
    assert_eq!(batch.report.n_finished, 300);
    assert!(batch.report.ttft_p50_s > 0.0);
    assert!(batch.report.ttft_p99_s >= batch.report.ttft_p50_s);

    // Hand-driven online loop: advance to every event between arrivals.
    let mut online = build_system(SystemKind::Cronus, &cfg);
    for r in &trace {
        let t = SimTime(r.arrival_ns);
        while let Some(next) = online.next_event_at() {
            if next >= t {
                break;
            }
            online.advance(next);
        }
        online.submit(t, *r);
    }
    let online_out = online.drain();
    assert_eq!(online_out.report.n_finished, 300);
    assert_eq!(online_out.report.ttft_p50_s, batch.report.ttft_p50_s);
    assert_eq!(online_out.report.ttft_p99_s, batch.report.ttft_p99_s);
    assert_eq!(online_out.report.tbt_p99_s, batch.report.tbt_p99_s);
    assert_eq!(online_out.report.makespan_s, batch.report.makespan_s);

    // One-pair cluster, same workload: identical percentiles.
    let cluster_cfg = ClusterConfig::homogeneous(1, cfg);
    let mut cluster = build_cluster_system(&cluster_cfg, RoutePolicy::RoundRobin);
    let cluster_out = replay_trace(cluster.as_mut(), &trace);
    assert_eq!(cluster_out.report.n_finished, 300);
    assert_eq!(cluster_out.report.ttft_p50_s, batch.report.ttft_p50_s);
    assert_eq!(cluster_out.report.ttft_p99_s, batch.report.ttft_p99_s);
    assert_eq!(cluster_out.report.makespan_s, batch.report.makespan_s);
}

#[test]
fn prop_router_partitions_trace_exactly() {
    // Cluster routing invariant: across N pairs and any policy, no
    // request is dropped and none is routed twice — the per-pair
    // sub-traces are an exact partition of the input trace.
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::{RoutePolicy, Router};
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("router partitions the trace", 50, |rng| {
        let n_pairs = rng.range_usize(1, 9);
        let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        let policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
        let n = rng.range_usize(1, 250);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let process = if rng.f64() < 0.5 {
            ArrivalProcess::AllAtOnce
        } else {
            ArrivalProcess::Poisson { rate_rps: 1.0 + rng.f64() * 20.0, seed: rng.next_u64() }
        };
        let trace = stamp(&trace, process);
        let mut router = Router::new(policy, &cfg);
        let assignments: Vec<usize> =
            trace.iter().map(|r| router.route(r).expect("routable").pair).collect();
        if assignments.len() != n {
            return PropResult::Fail(format!(
                "{} assignments for {n} requests",
                assignments.len()
            ));
        }
        if let Some(bad) = assignments.iter().find(|&&i| i >= n_pairs) {
            return PropResult::Fail(format!("pair index {bad} out of range"));
        }
        // Partition check: group ids per pair, then verify they form the
        // input trace's id multiset — nothing dropped, nothing duplicated.
        let mut sub_ids: Vec<Vec<u64>> = vec![Vec::new(); n_pairs];
        for (req, &pair) in trace.iter().zip(&assignments) {
            sub_ids[pair].push(req.id);
        }
        let mut rebuilt: Vec<u64> = sub_ids.concat();
        rebuilt.sort_unstable();
        let mut expected: Vec<u64> = trace.iter().map(|r| r.id).collect();
        expected.sort_unstable();
        if rebuilt != expected {
            return PropResult::Fail(format!(
                "sub-traces are not a partition: {} ids rebuilt vs {} expected",
                rebuilt.len(),
                expected.len()
            ));
        }
        let per_pair: Vec<usize> = sub_ids.iter().map(|s| s.len()).collect();
        let counted: Vec<u64> = router.routed_counts();
        if per_pair.iter().map(|&c| c as u64).ne(counted.iter().copied()) {
            return PropResult::Fail(format!(
                "router counts {counted:?} disagree with sub-traces {per_pair:?}"
            ));
        }
        let routed_total: u64 = counted.iter().sum();
        PropResult::assert_eq("router accounting", routed_total, n as u64)
    });
}

#[test]
fn prop_cluster_system_serves_every_request() {
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::RoutePolicy;
    use cronus::systems::cluster::build_cluster_system;
    use cronus::systems::replay_trace;
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("cluster finishes everything", 8, |rng| {
        let n_pairs = rng.range_usize(1, 5);
        let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        let policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
        let n = rng.range_usize(4, 40);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
        let out = replay_trace(build_cluster_system(&cfg, policy).as_mut(), &trace);
        PropResult::assert_eq("finished", out.report.n_finished, n)
            .and(|| PropResult::assert_eq("arrived", out.report.n_requests, n))
    });
}

#[test]
fn prop_qos_per_class_conservation() {
    // QoS bookkeeping conservation: with a class registry attached and
    // random class stamping, every class's report breakdown must agree
    // exactly with the event stream — each admitted request ends
    // Finished xor Shed once in its own class, `n_requests == n_finished
    // + n_shed` after drain, and the class slices sum to the replay's
    // admission totals.  Retry-cap drops are synthetic driver events the
    // cluster never accepted, so they appear in neither side.
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::RoutePolicy;
    use cronus::qos::{ClassId, ClassRegistry, ServiceClass};
    use cronus::systems::cluster::ClusterSystem;
    use cronus::systems::{replay_trace_collect, SystemEvent};
    use cronus::util::fxhash::FxHashMap;
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("per-class QoS conservation", 10, |rng| {
        let n_pairs = rng.range_usize(1, 4);
        let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        let policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
        let mut reg = ClassRegistry::new();
        let premium = reg.register(ServiceClass {
            tier: 1,
            weight: 2.0,
            slo_ttft_s: Some(0.5 + rng.f64() * 2.0),
            ..ServiceClass::named("premium")
        });
        let batch = reg.register(ServiceClass::named("batch"));
        let n = rng.range_usize(10, 80);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let mut trace = stamp(
            &trace,
            ArrivalProcess::Poisson {
                rate_rps: 1.0 + rng.f64() * 12.0,
                seed: rng.next_u64(),
            },
        );
        let mut class_of: FxHashMap<u64, ClassId> = FxHashMap::default();
        for r in &mut trace {
            r.class = match rng.range(0, 3) {
                0 => ClassId::default(),
                1 => premium,
                _ => batch,
            };
            class_of.insert(r.id, r.class);
        }
        let mut sys = ClusterSystem::new(cfg, policy).with_classes(reg);
        let (out, events, stats) = replay_trace_collect(&mut sys, &trace);

        // The oracle's per-class conservation law must agree with the
        // explicit breakdown reconciliation below.
        let mut checker = cronus::checker::InvariantChecker::new();
        checker.expect_trace(&trace);
        for ev in &events {
            checker.on_event(ev);
        }
        checker.check_report(&out.report);
        let summary = checker.finish();
        if !summary.ok() {
            return PropResult::Fail(format!(
                "invariant oracle disagrees\n{}",
                summary.render()
            ));
        }

        let mut fin = [0usize; 3];
        let mut shed = [0usize; 3];
        for ev in &events {
            match ev {
                SystemEvent::Finished { id, .. } => {
                    fin[class_of[id].0 as usize] += 1;
                }
                SystemEvent::Shed { id, reason, .. }
                    if !reason.starts_with("dropped by the replay driver") =>
                {
                    shed[class_of[id].0 as usize] += 1;
                }
                _ => {}
            }
        }
        if out.report.classes.len() != 3 {
            return PropResult::Fail(format!(
                "{} class breakdowns for a 3-class registry",
                out.report.classes.len()
            ));
        }
        for (c, b) in out.report.classes.iter().enumerate() {
            if b.n_finished != fin[c] || b.n_shed != shed[c] {
                return PropResult::Fail(format!(
                    "class {}: breakdown {}f/{}s vs events {}f/{}s",
                    b.name, b.n_finished, b.n_shed, fin[c], shed[c]
                ));
            }
            if b.n_requests != b.n_finished + b.n_shed {
                return PropResult::Fail(format!(
                    "class {}: {} requests but {} finished + {} shed",
                    b.name, b.n_requests, b.n_finished, b.n_shed
                ));
            }
        }
        let total: usize = out.report.classes.iter().map(|b| b.n_requests).sum();
        PropResult::assert_eq(
            "class slices sum to accepted + rejected",
            total,
            stats.n_accepted + stats.n_rejected,
        )
    });
}

#[test]
fn prop_qos_model_pinned_class_routes_only_to_matching_pairs() {
    // Model-aware routing invariant: whatever the policy, a request of a
    // model-pinned class is only ever assigned to a pair deployed with
    // that model, while unconstrained requests may go anywhere.
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::{RoutePolicy, Router};
    use cronus::qos::{ClassRegistry, ServiceClass};
    use cronus::simgpu::model_desc::QWEN2_7B;
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};
    check("model-pinned class never mismatches", 30, |rng| {
        let n_pairs = rng.range_usize(2, 7);
        let mut cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
        // Re-deploy a random subset of pairs with the second model; keep
        // the fleet genuinely mixed.
        let mut n_qwen = 0usize;
        for i in 0..n_pairs {
            if rng.f64() < 0.5 {
                cfg.pairs[i].deployment.model = QWEN2_7B;
                n_qwen += 1;
            }
        }
        if n_qwen == 0 || n_qwen == n_pairs {
            return PropResult::Discard;
        }
        let mut reg = ClassRegistry::new();
        let pinned = reg.register(ServiceClass {
            model: Some(QWEN2_7B),
            ..ServiceClass::named("qwen-only")
        });
        let policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len())];
        let mut router = Router::new(policy, &cfg);
        router.set_class_registry(reg);
        let n = rng.range_usize(5, 120);
        let trace = generate(n, &AzureTraceConfig::default(), rng.next_u64());
        let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
        for (i, r) in trace.iter().enumerate() {
            let mut r = *r;
            if i % 2 == 0 {
                r.class = pinned;
            }
            if !router.has_active_compatible_pair(&r) {
                return PropResult::Fail(
                    "compatible pair exists but was not found".into(),
                );
            }
            let pair = router.route(&r).expect("routable").pair;
            if r.class == pinned && router.pair_model(pair).name != QWEN2_7B.name {
                return PropResult::Fail(format!(
                    "pinned request routed to pair {pair} serving '{}'",
                    router.pair_model(pair).name
                ));
            }
        }
        PropResult::Ok
    });
}

#[test]
fn qos_weight_two_class_admits_at_least_its_fair_share() {
    // Two classes offering identical request streams to one saturated
    // pair, weights 2:1: the DWRR ledger must defer the lighter class
    // once it runs a quantum ahead, so the weight-2 class ends up with
    // at least as many admitted requests (identical shapes make request
    // counts a faithful token-share proxy; without the ledger the split
    // would be an even 1:1 race).
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::RoutePolicy;
    use cronus::qos::{ClassRegistry, ServiceClass};
    use cronus::systems::cluster::ClusterSystem;
    use cronus::systems::replay_trace_collect;
    use cronus::workload::Request;

    let mut reg = ClassRegistry::new();
    let gold = reg.register(ServiceClass {
        weight: 2.0,
        ..ServiceClass::named("gold")
    });
    let bronze = reg.register(ServiceClass::named("bronze"));
    // 400 identical requests, alternating gold/bronze at 40 rps — far
    // beyond one pair's capacity, so the ledger is the binding
    // constraint at admission.
    let trace: Vec<Request> = (0..400u64)
        .map(|i| {
            let r = Request::new(i, i * 25_000_000, 768, 64);
            r.with_class(if i % 2 == 0 { gold } else { bronze })
        })
        .collect();
    let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
        .with_classes(reg);
    let (out, _events, stats) = replay_trace_collect(&mut sys, &trace);

    assert!(
        stats.n_deferred > 0,
        "saturation must trigger fairness deferrals"
    );
    let g = &out.report.classes[gold.0 as usize];
    let b = &out.report.classes[bronze.0 as usize];
    assert!(g.n_requests > 0 && b.n_requests > 0, "both classes admit");
    assert!(
        g.n_requests >= b.n_requests,
        "weight-2 gold admitted {} requests < weight-1 bronze's {}",
        g.n_requests,
        b.n_requests
    );
    // Conservation still holds under heavy deferral/drop pressure.
    let total: usize = out.report.classes.iter().map(|c| c.n_requests).sum();
    assert_eq!(total, stats.n_accepted + stats.n_rejected);
}

#[test]
fn qos_two_class_saturation_holds_premium_slo() {
    // The QoS acceptance criterion: on a saturated pair, an all-default
    // baseline blows the premium tenants' arrival-to-first-token P99,
    // while the classed run — fair-share ledger throttling batch plus
    // per-class SLO admission — keeps the premium class inside the same
    // SLO by shedding work that could never meet it.
    use cronus::config::topology::ClusterConfig;
    use cronus::cronus::router::RoutePolicy;
    use cronus::qos::{ClassRegistry, ServiceClass};
    use cronus::simclock::SimTime;
    use cronus::systems::cluster::ClusterSystem;
    use cronus::systems::{replay_trace_collect, SystemEvent};
    use cronus::util::fxhash::FxHashMap;
    use cronus::workload::arrival::at_rate;
    use cronus::workload::azure::{generate, AzureTraceConfig};

    // 160 requests at 8 rps into a single pair: well past capacity.
    // Every fifth request belongs to the premium tenant.
    let trace = generate(160, &AzureTraceConfig::default(), 42);
    let trace = at_rate(&trace, 8.0);
    let premium_ids: Vec<u64> =
        trace.iter().enumerate().filter(|(i, _)| i % 5 == 0).map(|(_, r)| r.id).collect();
    let arrival: FxHashMap<u64, SimTime> =
        trace.iter().map(|r| (r.id, SimTime(r.arrival_ns))).collect();

    // Baseline: no classes, no SLO — everyone waits in the same queue.
    let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
    let mut base =
        ClusterSystem::new(cfg.clone(), RoutePolicy::LeastOutstandingTokens);
    let (_base_out, base_events, _) = replay_trace_collect(&mut base, &trace);
    let mut base_ttft: Vec<f64> = base_events
        .iter()
        .filter_map(|ev| match ev {
            SystemEvent::FirstToken { id, t } if premium_ids.contains(id) => {
                Some(t.saturating_sub(arrival[id]).as_secs_f64())
            }
            _ => None,
        })
        .collect();
    assert_eq!(base_ttft.len(), premium_ids.len(), "baseline finishes everything");
    base_ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let baseline_p99 = stats::percentile(&base_ttft, 99.0);
    assert!(
        baseline_p99 > 1.0,
        "workload must saturate the pair (baseline premium P99 {baseline_p99:.3}s)"
    );

    // The premium SLO is half what the baseline delivers: the baseline
    // violates it by construction.
    let slo = 0.5 * baseline_p99;
    let mut reg = ClassRegistry::new();
    let premium = reg.register(ServiceClass {
        tier: 1,
        weight: 2.0,
        slo_ttft_s: Some(slo),
        ..ServiceClass::named("premium")
    });
    let batch = reg.register(ServiceClass::named("batch"));
    let classed_trace: Vec<_> = trace
        .iter()
        .enumerate()
        .map(|(i, r)| r.with_class(if i % 5 == 0 { premium } else { batch }))
        .collect();
    let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
        .with_classes(reg);
    let (out, _events, stats) = replay_trace_collect(&mut sys, &classed_trace);

    assert!(stats.n_deferred > 0, "the fair-share ledger must throttle batch");
    let p = &out.report.classes[premium.0 as usize];
    assert!(p.n_finished > 0, "premium traffic must still be served");
    assert!(
        p.ttft_p99_s <= slo,
        "classed premium P99 {:.3}s must hold the {slo:.3}s SLO \
         (baseline delivered {baseline_p99:.3}s)",
        p.ttft_p99_s
    );
    assert!(
        p.ttft_p99_s < baseline_p99,
        "classing must beat the baseline for the premium tenant"
    );
}

#[test]
fn prop_balancer_fast_path_matches_exhaustive() {
    // §Perf: the binary-search split must agree with the literal
    // Algorithm 1 scan (same grid, same argmin quality).
    use cronus::cronus::balancer::{Balancer, SplitPolicy};
    use cronus::engine::instance::EngineStats;
    use cronus::simgpu::fit::calibrate;
    let ppi = PerfModel::new(A10, LLAMA3_8B);
    let cpi = PerfModel::new(A100, LLAMA3_8B);
    let (p, c) = calibrate(&ppi, &cpi, 512, 0.01, 9);
    let balancer = Balancer::new(SplitPolicy::Balanced, p, c, 512);
    check("fast split == exhaustive split", 150, |rng| {
        let input = rng.range_usize(1, 8192);
        let stats = EngineStats {
            n_decode: rng.range_usize(0, 500),
            decode_ctx_sum: rng.range_usize(0, 700_000),
            n_prefilling: 0,
            waiting: 0,
            free_blocks: rng.range_usize(0, 40_000),
            block_size: 16,
            total_blocks: 40_000,
        };
        let fast = balancer.split(input, &stats);
        let slow = balancer.balanced_split_exhaustive(input, &stats);
        let fd = (fast.t_prefill_est - fast.t_chunked_est).abs();
        let sd = (slow.t_prefill_est - slow.t_chunked_est).abs();
        // Same candidate, or (on the rare plateau) an equally-balanced one.
        if fast.partial_len == slow.partial_len || fd <= sd * 1.0001 + 1e-12 {
            PropResult::Ok
        } else {
            PropResult::Fail(format!(
                "fast lp={} |diff|={fd:.6e} vs exhaustive lp={} |diff|={sd:.6e} (input {input})",
                fast.partial_len, slow.partial_len
            ))
        }
    });
}
