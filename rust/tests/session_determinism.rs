//! Closed-loop determinism pins (tentpole satellite), extending the
//! `events_golden.rs` lockstep pattern to the closed-loop path: the same
//! seed must produce *byte-identical* `SystemEvent` streams across two
//! independent runs, and the collecting / non-collecting drivers must
//! agree on every outcome number and on the submission schedule.

use cronus::config::topology::ClusterConfig;
use cronus::config::DeploymentConfig;
use cronus::cronus::balancer::SplitPolicy;
use cronus::cronus::frontend::CronusSystem;
use cronus::cronus::router::RoutePolicy;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::cluster::ClusterSystem;
use cronus::systems::driver::{closed_loop, closed_loop_collect};
use cronus::systems::SystemEvent;
use cronus::workload::session::{generate_sessions, Session, SessionConfig};

fn sessions(seed: u64) -> Vec<Session> {
    generate_sessions(&SessionConfig {
        n_sessions: 6,
        min_turns: 2,
        max_turns: 4,
        think_mean_s: 0.4,
        start_window_s: 2.0,
        mean_new_input: 256.0,
        max_new_input: 1024,
        mean_output: 128.0,
        max_output: 384,
        seed,
        ..SessionConfig::default()
    })
}

/// FNV-1a digest over the full (tag, id, timestamp) stream — mirroring
/// the byte-level pin `events_golden.rs` applies to the open-loop path.
fn digest_stream(events: &[SystemEvent]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for ev in events {
        let (tag, id, t) = match ev {
            SystemEvent::FirstToken { id, t } => (1u64, *id, t.0),
            SystemEvent::Token { id, t } => (2, *id, t.0),
            SystemEvent::Finished { id, t } => (3, *id, t.0),
            SystemEvent::Shed { id, t, .. } => (4, *id, t.0),
            SystemEvent::ScaleUp { pair, t } => (5, *pair as u64, t.0),
            SystemEvent::ScaleDown { pair, t } => (6, *pair as u64, t.0),
            SystemEvent::PairFailed { pair, t } => (7, *pair as u64, t.0),
            SystemEvent::PairRecovered { pair, t } => (8, *pair as u64, t.0),
        };
        mix(tag);
        mix(id);
        mix(t);
    }
    h
}

#[test]
fn same_seed_yields_byte_identical_streams() {
    let sessions = sessions(17);
    let run = || {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::KvAffinity);
        closed_loop_collect(&mut sys, &sessions)
    };
    let (out_a, events_a, stats_a) = run();
    let (out_b, events_b, stats_b) = run();

    assert!(!events_a.is_empty());
    assert_eq!(events_a, events_b, "event streams diverged across runs");
    let d = digest_stream(&events_a);
    assert_eq!(d, digest_stream(&events_b));
    println!("closed-loop stream digest [kv-affinity]: {d:#018x}");

    assert_eq!(stats_a, stats_b, "submission schedules diverged");
    assert_eq!(out_a.report.makespan_s, out_b.report.makespan_s);
    assert_eq!(out_a.report.ttft_samples, out_b.report.ttft_samples);
    assert_eq!(out_a.report.tbt_samples, out_b.report.tbt_samples);
    assert_eq!(out_a.report.n_kv_hits, out_b.report.n_kv_hits);
    assert_eq!(
        out_a.report.prefill_tokens_saved,
        out_b.report.prefill_tokens_saved
    );
}

#[test]
fn collect_and_noncollect_drivers_agree() {
    // The collecting and non-collecting closed-loop drivers interact
    // with the system identically — retaining the events must not change
    // a single outcome number or submission instant.
    let sessions = sessions(23);
    for policy in RoutePolicy::ALL {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut with = ClusterSystem::new(cfg.clone(), policy);
        let (out_c, events, stats_c) = closed_loop_collect(&mut with, &sessions);
        let mut without = ClusterSystem::new(cfg, policy);
        let (out_n, stats_n) = closed_loop(&mut without, &sessions);

        assert_eq!(stats_c, stats_n, "{}", policy.name());
        assert_eq!(out_c.report.n_finished, out_n.report.n_finished);
        assert_eq!(out_c.report.n_requests, out_n.report.n_requests);
        assert_eq!(out_c.report.makespan_s, out_n.report.makespan_s);
        assert_eq!(out_c.report.ttft_samples, out_n.report.ttft_samples);
        assert_eq!(out_c.report.tbt_samples, out_n.report.tbt_samples);
        assert_eq!(out_c.report.e2e_samples, out_n.report.e2e_samples);
        assert_eq!(out_c.report.n_kv_hits, out_n.report.n_kv_hits);
        assert_eq!(
            out_c.report.prefill_tokens_saved,
            out_n.report.prefill_tokens_saved
        );
        // The collected stream covers every finished turn.
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SystemEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, stats_c.n_finished_turns, "{}", policy.name());
    }
}

#[test]
fn checked_run_is_byte_identical_to_unchecked() {
    // The invariant oracle is a passive observer (acceptance criterion
    // of the robustness-harness issue): streaming a run through
    // `replay_trace_observed` with an `InvariantChecker` attached must
    // leave the event stream, the submission schedule, and every
    // outcome number byte-identical to the unchecked collecting run —
    // and the oracle must come back clean.
    use cronus::checker::InvariantChecker;
    use cronus::systems::driver::{replay_trace_collect, replay_trace_observed};
    use cronus::workload::arrival::{stamp, ArrivalProcess};
    use cronus::workload::azure::{generate, AzureTraceConfig};

    let trace = generate(120, &AzureTraceConfig::default(), 31);
    let trace =
        stamp(&trace, ArrivalProcess::Poisson { rate_rps: 6.0, seed: 9 });
    let cfg = ClusterConfig::mixed(2, LLAMA3_8B);

    let mut plain = ClusterSystem::new(cfg.clone(), RoutePolicy::KvAffinity);
    let (plain_out, plain_events, plain_stats) =
        replay_trace_collect(&mut plain, &trace);

    let mut checker = InvariantChecker::new();
    checker.expect_trace(&trace);
    let mut observed: Vec<SystemEvent> = Vec::new();
    let mut checked = ClusterSystem::new(cfg, RoutePolicy::KvAffinity);
    let (checked_out, checked_stats) =
        replay_trace_observed(&mut checked, &trace, &mut |ev| {
            checker.on_event(ev);
            observed.push(ev.clone());
        });
    checker.check_report(&checked_out.report);
    let summary = checker.finish();
    assert!(summary.ok(), "{}", summary.render());

    assert_eq!(plain_events, observed, "checked run diverged from unchecked");
    assert_eq!(digest_stream(&plain_events), digest_stream(&observed));
    assert_eq!(plain_stats, checked_stats, "submission schedules diverged");
    assert_eq!(plain_out.report.n_finished, checked_out.report.n_finished);
    assert_eq!(plain_out.report.n_rejected, checked_out.report.n_rejected);
    assert_eq!(plain_out.report.makespan_s, checked_out.report.makespan_s);
    assert_eq!(plain_out.report.ttft_samples, checked_out.report.ttft_samples);
    assert_eq!(plain_out.report.tbt_samples, checked_out.report.tbt_samples);
}

#[test]
fn one_pair_cluster_closed_loop_matches_bare_pair() {
    // A 1-pair cluster under a credit-less policy must serve the session
    // workload exactly like the bare Cronus pair: the cluster layer adds
    // routing, not behaviour.
    let sessions = sessions(29);
    let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let cfg = ClusterConfig::homogeneous(1, deployment.clone());
    let mut cluster = ClusterSystem::new(cfg, RoutePolicy::RoundRobin);
    let (cluster_out, cluster_stats) = closed_loop(&mut cluster, &sessions);
    let mut bare = CronusSystem::new(deployment, SplitPolicy::Balanced, false, "x");
    let (bare_out, bare_stats) = closed_loop(&mut bare, &sessions);

    assert_eq!(cluster_stats, bare_stats);
    assert_eq!(cluster_out.report.n_finished, bare_out.report.n_finished);
    assert_eq!(cluster_out.report.makespan_s, bare_out.report.makespan_s);
    assert_eq!(cluster_out.report.ttft_p99_s, bare_out.report.ttft_p99_s);
    assert_eq!(cluster_out.report.tbt_p99_s, bare_out.report.tbt_p99_s);
}
