//! PJRT runtime integration: load the AOT artifacts, execute the real
//! model, and check numerics against golden values computed with the
//! pure-jnp reference model (`model.full_forward_ref`, seed-0 weights).
//!
//! Golden generator (python/, run once):
//! ```python
//! params = M.init_params(jax.random.PRNGKey(0), M.TINY)
//! prompt = np.random.default_rng(123).integers(1, 2048, size=40)
//! # greedy-extend 6 tokens with M.full_forward_ref
//! ```
//!
//! Requires `make artifacts`.  Tests are skipped (not failed) when the
//! artifacts are missing so `cargo test` works before the Python step.

use cronus::runtime::{artifacts_dir, KvState, TokenModel};

fn model_or_skip() -> Option<TokenModel> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(TokenModel::load(&dir).expect("artifacts present but unloadable"))
}

/// `np.random.default_rng(123).integers(1, 2048, size=40)`.
fn golden_prompt() -> Vec<i32> {
    vec![
        32, 1397, 1214, 111, 1861, 452, 523, 378, 683, 361, 712, 1663, 924,
        1891, 921, 567, 1615, 1679, 1765, 1822, 52, 1051, 549, 502, 494,
        1688, 1622, 438, 839, 1518, 304, 1290, 898, 1899, 1514, 475, 1710,
        1636, 437, 1061,
    ]
}

/// Golden continuation from the jnp reference (greedy, 6 tokens).
const GOLDEN: [i32; 6] = [405, 514, 802, 88, 711, 482];

#[test]
fn manifest_and_weights_load() {
    let Some(model) = model_or_skip() else { return };
    assert_eq!(model.manifest.model_name, "tiny-llama");
    assert_eq!(model.manifest.n_layers, 4);
    assert_eq!(model.manifest.vocab, 2048);
    assert_eq!(model.chunk_size(), 64);
    assert_eq!(model.decode_batch_size(), 8);
}

#[test]
fn greedy_generation_matches_jnp_reference() {
    let Some(model) = model_or_skip() else { return };
    let prompt = golden_prompt();

    let mut kv = KvState::new(&model.manifest);
    let first = model.prefill_prompt(&prompt, &mut kv).unwrap();
    assert_eq!(first, GOLDEN[0], "first token (prefill) mismatch");

    // Decode the rest greedily.
    let mut tokens = vec![first];
    for step in 1..GOLDEN.len() {
        let pos = prompt.len() + step - 1;
        let mut entries = vec![(tokens[step - 1], pos, &mut kv)];
        let logits = model.decode_batch(&mut entries).unwrap();
        let tok = TokenModel::argmax(&logits[0]);
        assert_eq!(tok, GOLDEN[step], "decode step {step} mismatch");
        tokens.push(tok);
    }
}

#[test]
fn chunking_is_equivalent() {
    // Prefilling in chunk-width pieces or in ragged pieces must give the
    // same first token (the KV/causal-mask contract).
    let Some(model) = model_or_skip() else { return };
    let prompt = golden_prompt();

    let mut kv_a = KvState::new(&model.manifest);
    let a = model.prefill_prompt(&prompt, &mut kv_a).unwrap();

    let mut kv_b = KvState::new(&model.manifest);
    let mut last = Vec::new();
    let cuts = [0usize, 7, 19, 40];
    for w in cuts.windows(2) {
        last = model
            .prefill_chunk(&prompt[w[0]..w[1]], w[0], &mut kv_b)
            .unwrap();
    }
    let b = TokenModel::argmax(&last);
    assert_eq!(a, b);
    assert_eq!(kv_a.ctx_len, kv_b.ctx_len);
}

#[test]
fn batched_decode_matches_single() {
    let Some(model) = model_or_skip() else { return };
    let p1: Vec<i32> = (1..30).collect();
    let p2: Vec<i32> = (100..160).collect();

    // Singles.
    let mut kv1 = KvState::new(&model.manifest);
    let t1 = model.prefill_prompt(&p1, &mut kv1).unwrap();
    let mut kv2 = KvState::new(&model.manifest);
    let t2 = model.prefill_prompt(&p2, &mut kv2).unwrap();

    let mut kv1s = kv1.clone();
    let mut e = vec![(t1, p1.len(), &mut kv1s)];
    let s1 = TokenModel::argmax(&model.decode_batch(&mut e).unwrap()[0]);
    let mut kv2s = kv2.clone();
    let mut e = vec![(t2, p2.len(), &mut kv2s)];
    let s2 = TokenModel::argmax(&model.decode_batch(&mut e).unwrap()[0]);

    // Batched together.
    let mut kv1b = kv1.clone();
    let mut kv2b = kv2.clone();
    let mut entries = vec![(t1, p1.len(), &mut kv1b), (t2, p2.len(), &mut kv2b)];
    let logits = model.decode_batch(&mut entries).unwrap();
    assert_eq!(TokenModel::argmax(&logits[0]), s1);
    assert_eq!(TokenModel::argmax(&logits[1]), s2);
}

#[test]
fn generation_is_deterministic() {
    let Some(model) = model_or_skip() else { return };
    let prompt: Vec<i32> = (5..45).collect();
    let run = || {
        let mut kv = KvState::new(&model.manifest);
        let mut toks = vec![model.prefill_prompt(&prompt, &mut kv).unwrap()];
        for step in 1..5 {
            let pos = prompt.len() + step - 1;
            let mut e = vec![(toks[step - 1], pos, &mut kv)];
            let l = model.decode_batch(&mut e).unwrap();
            toks.push(TokenModel::argmax(&l[0]));
        }
        toks
    };
    assert_eq!(run(), run());
}

#[test]
fn rejects_oversized_inputs() {
    let Some(model) = model_or_skip() else { return };
    let mut kv = KvState::new(&model.manifest);
    let too_long = vec![1i32; model.chunk_size() + 1];
    assert!(model.prefill_chunk(&too_long, 0, &mut kv).is_err());
    assert!(model.prefill_chunk(&[], 0, &mut kv).is_err());
    let near_end = model.manifest.max_seq - 2;
    assert!(model.prefill_chunk(&[1, 2, 3], near_end, &mut kv).is_err());
}

#[test]
fn real_server_end_to_end() {
    use cronus::server::{RealServer, ServeRequest};
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let server = RealServer::start(&dir).unwrap();
    for i in 0..6u64 {
        let len = 16 + (i as usize * 11) % 48;
        let prompt: Vec<i32> =
            (0..len as i32).map(|x| (x * 37 + i as i32) % 2047 + 1).collect();
        server.submit(ServeRequest { id: i, prompt, max_new_tokens: 8 });
    }
    let responses = server.shutdown().unwrap();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert_eq!(r.tokens.len(), 8, "req {} token count", r.id);
        assert!(r.ttft_s > 0.0);
        assert!(r.tokens.iter().all(|t| (0..2048).contains(t)));
    }
}
