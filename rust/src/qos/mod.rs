//! Multi-tenant QoS: service classes, priority tiers, and weighted fair
//! sharing at cluster admission.
//!
//! A [`ServiceClass`] names a tenant's traffic contract: a priority
//! `tier`, a fair-share `weight`, optional TTFT / TBT-P99 SLOs, and
//! optionally the *model* the class must be served by (multi-model
//! fleets route such requests only to pairs deployed with that model).
//! Classes live in a [`ClassRegistry`] — class 0 is always the built-in
//! `default` class (weight 1, tier 0, no SLOs, any model), so a request
//! stream that never mentions classes behaves exactly as before the QoS
//! layer existed.
//!
//! Operators declare classes in a `[classes]` TOML table (one
//! `[classes.NAME]` sub-table per class; see `CONFIG.md`):
//!
//! ```toml
//! [classes.premium]
//! tenant = "acme"
//! tier = 1
//! weight = 2.0
//! slo_ttft_s = 1.5
//! slo_tbt_p99_s = 0.2
//!
//! [classes.batch]
//! tenant = "crawler"
//! weight = 1.0
//! ```
//!
//! The [`FairShareLedger`] is the admission-time sharing mechanism: a
//! deficit-weighted-round-robin ledger in *virtual time* (charged tokens
//! divided by class weight).  Every admitted request advances its
//! class's virtual time; a class that runs more than one quantum ahead
//! of another class that is still contending for capacity gets its next
//! submit **deferred** (the cluster returns `Admission::Deferred` and
//! the driver retries), so a bursty low-priority tenant cannot starve a
//! high-priority one at the admission gate.  Priority preemption is the
//! one asymmetry: an *over-SLO* request of a strictly higher tier
//! bypasses the fairness deferral — it jumps ahead of the queued
//! lower-tier backlog (which simply retries later; in-flight requests
//! and the engines beneath them are never touched).
//!
//! The ledger is deterministic: it is a pure function of the observed
//! submit/admit/finish sequence, with no clocks or randomness of its
//! own, so same-seed cluster runs remain byte-identical.

use crate::config::toml::TomlDoc;
use crate::simclock::SimTime;
use crate::simgpu::model_desc::{self, ModelDesc};

/// Index of a request's service class in the cluster's
/// [`ClassRegistry`].  `ClassId::default()` (0) is the built-in
/// `default` class; stamping it on every request reproduces the
/// pre-QoS behaviour byte-for-byte.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u16);

/// The built-in class every request starts in.
pub const DEFAULT_CLASS: ClassId = ClassId(0);

/// Tokens one class may run ahead of a contending class before the
/// fairness gate defers it (the DWRR quantum, in virtual-time tokens
/// before weight scaling).
pub const FAIR_QUANTUM_TOKENS: f64 = 4096.0;

/// How long after its last arrival a class with nothing in flight still
/// counts as *contending* (its deferred submits live in the driver's
/// retry queue, invisible to the cluster, so recency of demand is the
/// only signal available at admission).
pub const CONTENTION_WINDOW_S: f64 = 2.0;

/// Retry hint attached to a fairness deferral.
pub const FAIR_RETRY_S: f64 = 0.05;

/// One tenant traffic class.
#[derive(Clone, Debug)]
pub struct ServiceClass {
    /// Class name — the `[classes.NAME]` key, unique per registry.
    pub name: String,
    /// Owning tenant (reporting only; defaults to the class name).
    pub tenant: String,
    /// Priority tier: strictly higher tiers may bypass the fairness
    /// deferral when over their TTFT SLO (see [`FairShareLedger`]).
    pub tier: u8,
    /// Fair-share weight (> 0): a weight-2 class is entitled to twice
    /// the admitted tokens of a weight-1 class while both contend.
    pub weight: f64,
    /// Per-class TTFT SLO; overrides the cluster-wide SLO at admission.
    pub slo_ttft_s: Option<f64>,
    /// Per-class TBT P99 SLO: the router's TBT-aware admission defers
    /// new work that would blow this headroom for in-flight requests of
    /// the class.
    pub slo_tbt_p99_s: Option<f64>,
    /// Model this class must be served by (`None` = any pair).
    pub model: Option<ModelDesc>,
}

impl ServiceClass {
    /// A named class with default contract values (tier 0, weight 1,
    /// no SLOs, any model).
    pub fn named(name: &str) -> ServiceClass {
        ServiceClass {
            name: name.to_string(),
            tenant: name.to_string(),
            tier: 0,
            weight: 1.0,
            slo_ttft_s: None,
            slo_tbt_p99_s: None,
            model: None,
        }
    }
}

/// Ordered set of service classes; index = [`ClassId`].  Class 0 is
/// always the built-in `default`.
#[derive(Clone, Debug)]
pub struct ClassRegistry {
    classes: Vec<ServiceClass>,
}

impl Default for ClassRegistry {
    fn default() -> Self {
        ClassRegistry::new()
    }
}

impl ClassRegistry {
    /// Registry holding only the built-in `default` class.
    pub fn new() -> ClassRegistry {
        ClassRegistry { classes: vec![ServiceClass::named("default")] }
    }

    /// Register a class; returns its id.  Names must be unique.
    pub fn register(&mut self, class: ServiceClass) -> ClassId {
        assert!(
            self.id_of(&class.name).is_none(),
            "duplicate service class '{}'",
            class.name
        );
        assert!(class.weight > 0.0, "class weight must be > 0");
        assert!(self.classes.len() < u16::MAX as usize, "too many classes");
        self.classes.push(class);
        ClassId((self.classes.len() - 1) as u16)
    }

    pub fn id_of(&self, name: &str) -> Option<ClassId> {
        self.classes
            .iter()
            .position(|c| c.name == name)
            .map(|i| ClassId(i as u16))
    }

    /// Class behind `id`; unknown ids resolve to the default class so a
    /// stale stamp can never panic the serving path.
    pub fn get(&self, id: ClassId) -> &ServiceClass {
        self.classes.get(id.0 as usize).unwrap_or(&self.classes[0])
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        false // the default class always exists
    }

    pub fn iter(&self) -> impl Iterator<Item = &ServiceClass> {
        self.classes.iter()
    }

    /// Whether any non-default class is registered — the QoS machinery
    /// (ledger, per-class SLOs, model constraints) is inert otherwise.
    pub fn is_multi_class(&self) -> bool {
        self.classes.len() > 1
    }

    /// Whether any class declares a TBT P99 SLO (gates the TBT-aware
    /// admission estimate, which costs a per-pair scan).
    pub fn any_tbt_slo(&self) -> bool {
        self.classes.iter().any(|c| c.slo_tbt_p99_s.is_some())
    }

    /// Load `[classes.NAME]` sub-tables from a parsed TOML document.
    /// Class ids are assigned in sorted name order (the document's
    /// key order is a `BTreeMap`), so identical files always produce
    /// identical registries.  Unknown keys are rejected to catch typos.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        let mut names: Vec<&str> = Vec::new();
        for key in doc.section_keys("classes.") {
            let rest = &key["classes.".len()..];
            let name = rest
                .split('.')
                .next()
                .filter(|n| !n.is_empty() && rest.contains('.'))
                .ok_or_else(|| format!("bad [classes] key '{key}'"))?;
            if names.last() != Some(&name) {
                names.push(name);
            }
        }
        for name in names {
            if name == "default" {
                return Err("the 'default' class is built in and cannot be \
                            redefined"
                    .into());
            }
            let prefix = format!("classes.{name}.");
            for key in doc.section_keys(&prefix) {
                let field = &key[prefix.len()..];
                if !matches!(
                    field,
                    "tenant" | "tier" | "weight" | "slo_ttft_s"
                        | "slo_tbt_p99_s" | "model"
                ) {
                    return Err(format!(
                        "unknown key '{field}' in [classes.{name}]"
                    ));
                }
            }
            let mut class = ServiceClass::named(name);
            if let Some(t) = doc.get_str(&format!("{prefix}tenant")) {
                class.tenant = t.to_string();
            }
            if let Some(t) = doc.get_i64(&format!("{prefix}tier")) {
                if !(0..=255).contains(&t) {
                    return Err(format!("classes.{name}.tier out of range"));
                }
                class.tier = t as u8;
            }
            if let Some(w) = doc.get_f64(&format!("{prefix}weight")) {
                if !(w > 0.0 && w.is_finite()) {
                    return Err(format!("classes.{name}.weight must be > 0"));
                }
                class.weight = w;
            }
            if let Some(s) = doc.get_f64(&format!("{prefix}slo_ttft_s")) {
                if !(s > 0.0 && s.is_finite()) {
                    return Err(format!("classes.{name}.slo_ttft_s must be > 0"));
                }
                class.slo_ttft_s = Some(s);
            }
            if let Some(s) = doc.get_f64(&format!("{prefix}slo_tbt_p99_s")) {
                if !(s > 0.0 && s.is_finite()) {
                    return Err(format!(
                        "classes.{name}.slo_tbt_p99_s must be > 0"
                    ));
                }
                class.slo_tbt_p99_s = Some(s);
            }
            if let Some(m) = doc.get_str(&format!("{prefix}model")) {
                let desc = model_desc::by_name(m)
                    .ok_or_else(|| format!("unknown model '{m}' in [classes.{name}]"))?;
                class.model = Some(desc);
            }
            if self.id_of(name).is_some() {
                return Err(format!("duplicate service class '{name}'"));
            }
            self.register(class);
        }
        Ok(())
    }

    /// Emit the non-default classes as canonical `[classes.NAME]`
    /// sub-tables, sorted by name (the id order
    /// [`ClassRegistry::apply_toml`] assigns), so parse(emit) rebuilds
    /// an identical registry and re-emission is byte-identical.  The
    /// `tenant`/`tier`/`weight` keys are always written — a key-less
    /// `[classes.NAME]` header would vanish on re-parse, since the
    /// loader discovers classes through their flattened keys.  Returns
    /// an empty string for a default-only registry.
    pub fn to_toml(&self) -> String {
        let mut named: Vec<&ServiceClass> =
            self.classes.iter().filter(|c| c.name != "default").collect();
        named.sort_by(|a, b| a.name.cmp(&b.name));
        let mut out = String::new();
        for c in named {
            out.push_str(&format!("[classes.{}]\n", c.name));
            out.push_str(&format!("tenant = \"{}\"\n", c.tenant));
            out.push_str(&format!("tier = {}\n", c.tier));
            out.push_str(&format!("weight = {}\n", c.weight));
            if let Some(s) = c.slo_ttft_s {
                out.push_str(&format!("slo_ttft_s = {s}\n"));
            }
            if let Some(s) = c.slo_tbt_p99_s {
                out.push_str(&format!("slo_tbt_p99_s = {s}\n"));
            }
            if let Some(m) = c.model {
                out.push_str(&format!("model = \"{}\"\n", m.name));
            }
            out.push('\n');
        }
        out
    }
}

/// Deficit-weighted-round-robin ledger over service classes, applied at
/// the cluster submit path (see the module docs for the mechanism).
#[derive(Clone, Debug)]
pub struct FairShareLedger {
    weights: Vec<f64>,
    tiers: Vec<u8>,
    /// Virtual time per class: admitted tokens / weight.
    vtime: Vec<f64>,
    /// Requests admitted and not yet finished/shed, per class.
    inflight: Vec<u32>,
    /// Latest observed submit instant per class (seconds), or `-inf`.
    last_arrival_s: Vec<f64>,
    n_deferred: u64,
}

impl FairShareLedger {
    pub fn from_registry(reg: &ClassRegistry) -> FairShareLedger {
        FairShareLedger {
            weights: reg.iter().map(|c| c.weight).collect(),
            tiers: reg.iter().map(|c| c.tier).collect(),
            vtime: vec![0.0; reg.len()],
            inflight: vec![0; reg.len()],
            last_arrival_s: vec![f64::NEG_INFINITY; reg.len()],
            n_deferred: 0,
        }
    }

    fn idx(&self, c: ClassId) -> usize {
        (c.0 as usize).min(self.weights.len() - 1)
    }

    /// A class contends for capacity while it has work in flight or has
    /// submitted within the contention window (its deferred submits sit
    /// in the driver's retry queue, which the cluster cannot see).
    fn contending(&self, j: usize, now_s: f64) -> bool {
        self.inflight[j] > 0
            || now_s - self.last_arrival_s[j] <= CONTENTION_WINDOW_S
    }

    /// Record a submit attempt of class `c` at `t` (counted whether or
    /// not the request is subsequently admitted).
    pub fn note_arrival(&mut self, c: ClassId, t: SimTime) {
        let i = self.idx(c);
        let s = t.as_secs_f64();
        if s > self.last_arrival_s[i] {
            self.last_arrival_s[i] = s;
        }
    }

    /// Fairness gate for a class-`c` submit at `t`: `Some(retry_at)`
    /// defers the request, `None` admits it (subject to the cluster's
    /// other admission checks).  `over_slo` marks a request already at
    /// risk of blowing its own TTFT SLO — such a request of a strictly
    /// higher tier preempts (bypasses) the deferral against lower-tier
    /// contenders.
    pub fn check(&mut self, t: SimTime, c: ClassId, over_slo: bool) -> Option<SimTime> {
        let i = self.idx(c);
        let now_s = t.as_secs_f64();
        let slack = FAIR_QUANTUM_TOKENS / self.weights[i];
        for j in 0..self.weights.len() {
            if j == i || !self.contending(j, now_s) {
                continue;
            }
            if self.vtime[i] - self.vtime[j] <= slack {
                continue;
            }
            if over_slo && self.tiers[i] > self.tiers[j] {
                // Priority preemption: the over-SLO higher-tier request
                // jumps the queued lower-tier backlog.
                continue;
            }
            self.n_deferred += 1;
            return Some(t.after_secs(FAIR_RETRY_S));
        }
        None
    }

    /// Class `c` was admitted with `tokens` charged work.  An *idle*
    /// class (nothing in flight) first catches up to the busiest
    /// contenders' floor so it cannot bank unbounded credit while away.
    /// A continuously-active class keeps its deficit — that lag is
    /// exactly what entitles a heavier class to its larger share, so
    /// only a class re-entering from idle is caught up.
    pub fn on_admit(&mut self, c: ClassId, tokens: u64) {
        let i = self.idx(c);
        if self.inflight[i] == 0 {
            let floor = self
                .vtime
                .iter()
                .zip(&self.inflight)
                .enumerate()
                .filter(|&(j, (_, &inflight))| j != i && inflight > 0)
                .map(|(_, (&v, _))| v)
                .fold(f64::INFINITY, f64::min);
            if floor.is_finite() && self.vtime[i] < floor {
                self.vtime[i] = floor;
            }
        }
        self.vtime[i] += tokens as f64 / self.weights[i];
        self.inflight[i] += 1;
    }

    /// A class-`c` request left the system (finished or shed in flight).
    pub fn on_done(&mut self, c: ClassId) {
        let i = self.idx(c);
        self.inflight[i] = self.inflight[i].saturating_sub(1);
    }

    /// Virtual time of class `c` (tests / introspection).
    pub fn vtime(&self, c: ClassId) -> f64 {
        self.vtime[self.idx(c)]
    }

    /// Fairness deferrals issued so far.
    pub fn n_deferred(&self) -> u64 {
        self.n_deferred
    }

    /// Forget all load state (class contracts are kept).
    pub fn reset(&mut self) {
        self.vtime.fill(0.0);
        self.inflight.fill(0);
        self.last_arrival_s.fill(f64::NEG_INFINITY);
        self.n_deferred = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    fn two_class_registry() -> ClassRegistry {
        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass {
            tier: 1,
            weight: 2.0,
            slo_ttft_s: Some(1.0),
            ..ServiceClass::named("premium")
        });
        reg.register(ServiceClass::named("batch"));
        reg
    }

    #[test]
    fn classes_toml_round_trips_byte_for_byte() {
        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass {
            tenant: "acme".to_string(),
            tier: 1,
            weight: 2.0,
            slo_ttft_s: Some(1.5),
            slo_tbt_p99_s: Some(0.2),
            model: crate::simgpu::model_desc::by_name("qwen2-7b"),
            ..ServiceClass::named("premium")
        });
        reg.register(ServiceClass::named("batch"));
        let text = reg.to_toml();
        let doc = toml::parse(&text).expect("emitted TOML parses");
        let mut back = ClassRegistry::new();
        back.apply_toml(&doc).expect("applies");
        assert_eq!(back.to_toml(), text, "re-emission is byte-identical");
        assert_eq!(back.len(), reg.len());
        // Sorted name order: batch before premium.
        assert_eq!(back.get(ClassId(1)).name, "batch");
        let p = back.get(back.id_of("premium").unwrap());
        assert_eq!(p.tenant, "acme");
        assert_eq!(p.tier, 1);
        assert_eq!(p.weight, 2.0);
        assert_eq!(p.slo_ttft_s, Some(1.5));
        assert_eq!(p.model.map(|m| m.name), Some("qwen2-7b"));
        // Default-only registries emit nothing.
        assert_eq!(ClassRegistry::new().to_toml(), "");
    }

    #[test]
    fn registry_default_class_is_builtin() {
        let reg = ClassRegistry::new();
        assert_eq!(reg.len(), 1);
        assert!(!reg.is_multi_class());
        assert_eq!(reg.id_of("default"), Some(DEFAULT_CLASS));
        let d = reg.get(DEFAULT_CLASS);
        assert_eq!(d.tier, 0);
        assert_eq!(d.weight, 1.0);
        assert!(d.slo_ttft_s.is_none() && d.model.is_none());
        // Unknown ids resolve to the default class, never panic.
        assert_eq!(reg.get(ClassId(99)).name, "default");
    }

    #[test]
    fn apply_toml_parses_classes_sorted_by_name() {
        let doc = toml::parse(
            "[classes.premium]\ntenant = \"acme\"\ntier = 1\nweight = 2.0\n\
             slo_ttft_s = 1.5\nslo_tbt_p99_s = 0.2\nmodel = \"llama3-8b\"\n\
             [classes.batch]\nweight = 0.5\n",
        )
        .unwrap();
        let mut reg = ClassRegistry::new();
        reg.apply_toml(&doc).unwrap();
        assert_eq!(reg.len(), 3);
        // BTreeMap key order: batch before premium.
        assert_eq!(reg.get(ClassId(1)).name, "batch");
        assert_eq!(reg.get(ClassId(2)).name, "premium");
        let p = reg.get(reg.id_of("premium").unwrap());
        assert_eq!(p.tenant, "acme");
        assert_eq!(p.tier, 1);
        assert_eq!(p.weight, 2.0);
        assert_eq!(p.slo_ttft_s, Some(1.5));
        assert_eq!(p.slo_tbt_p99_s, Some(0.2));
        assert_eq!(p.model.unwrap().name, "llama3-8b");
        assert!(reg.any_tbt_slo());
        let b = reg.get(reg.id_of("batch").unwrap());
        assert_eq!(b.weight, 0.5);
        assert_eq!(b.tenant, "batch");
    }

    #[test]
    fn apply_toml_rejects_bad_tables() {
        let mut reg = ClassRegistry::new();
        for bad in [
            "[classes.default]\nweight = 2.0\n",
            "[classes.x]\nweight = 0.0\n",
            "[classes.x]\nweight = -1.0\n",
            "[classes.x]\ntier = 300\n",
            "[classes.x]\nslo_ttft_s = 0.0\n",
            "[classes.x]\nmodel = \"gpt5\"\n",
            "[classes.x]\nwieght = 2.0\n",
        ] {
            let doc = toml::parse(bad).unwrap();
            assert!(
                ClassRegistry::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
        // No [classes] section: registry unchanged.
        let doc = toml::parse("[cluster]\nhigh_gpu = \"a100\"\n").unwrap();
        reg.apply_toml(&doc).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn ledger_defers_the_class_running_ahead() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        let batch = reg.id_of("batch").unwrap();
        let t = SimTime::from_secs_f64(1.0);
        ledger.note_arrival(batch, t);
        ledger.note_arrival(premium, t);
        // Batch charges far ahead of its share.
        for _ in 0..10 {
            ledger.on_admit(batch, 2000);
        }
        // Premium (behind in virtual time) always passes.
        assert!(ledger.check(t, premium, false).is_none());
        // Batch is now > one quantum ahead of contending premium: defer.
        let deferred = ledger.check(t, batch, false);
        assert!(deferred.is_some(), "batch should defer");
        assert!(deferred.unwrap() > t);
        assert_eq!(ledger.n_deferred(), 1);
    }

    #[test]
    fn idle_class_does_not_bank_credit() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        let batch = reg.id_of("batch").unwrap();
        // Batch works alone for a long while.
        for _ in 0..100 {
            ledger.on_admit(batch, 4000);
        }
        // Premium arrives: its first admit catches up to batch's floor,
        // so batch is NOT a quantum behind afterwards.
        ledger.on_admit(premium, 1000);
        assert!(ledger.vtime(premium) >= ledger.vtime(batch));
    }

    #[test]
    fn active_laggard_keeps_its_deficit() {
        // The idle catch-up must not erase a continuously-active class's
        // lag: with both classes in flight, a weight-2 class charging
        // the same token stream as a weight-1 class stays behind in
        // virtual time — that deficit is exactly what entitles it to a
        // 2x admitted share once the gate starts deferring the leader.
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        let batch = reg.id_of("batch").unwrap();
        let t = SimTime::from_secs_f64(1.0);
        ledger.note_arrival(premium, t);
        ledger.note_arrival(batch, t);
        for _ in 0..12 {
            ledger.on_admit(premium, 1000);
            ledger.on_admit(batch, 1000);
        }
        // Premium (weight 2) advances at half rate; batch only caught up
        // on its first (idle) admit.
        assert_eq!(ledger.vtime(premium), 6_000.0);
        assert_eq!(ledger.vtime(batch), 12_500.0);
        // The fairness gate therefore defers the leader, not the laggard.
        assert!(ledger.check(t, premium, false).is_none());
        assert!(ledger.check(t, batch, false).is_some());
    }

    #[test]
    fn over_slo_high_tier_preempts_the_fairness_deferral() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        let batch = reg.id_of("batch").unwrap();
        let t = SimTime::from_secs_f64(1.0);
        ledger.note_arrival(batch, t);
        // Premium runs far ahead while batch contends.
        for _ in 0..20 {
            ledger.on_admit(premium, 2000);
        }
        assert!(ledger.check(t, premium, false).is_some(), "fairness defers");
        // ... but an over-SLO premium request (tier 1 > batch tier 0)
        // bypasses the deferral.
        assert!(ledger.check(t, premium, true).is_none(), "preemption admits");
        // The bypass never helps the *lower* tier: batch over-SLO while
        // premium contends still defers once batch runs ahead.
        let mut ledger = FairShareLedger::from_registry(&reg);
        ledger.note_arrival(premium, t);
        for _ in 0..20 {
            ledger.on_admit(batch, 2000);
        }
        assert!(ledger.check(t, batch, true).is_some(), "no low-tier bypass");
    }

    #[test]
    fn non_contending_class_never_causes_deferrals() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let batch = reg.id_of("batch").unwrap();
        // Premium never arrives and has nothing in flight; batch may
        // burst as far ahead as it likes.
        for _ in 0..50 {
            let t = SimTime::from_secs_f64(10.0);
            assert!(ledger.check(t, batch, false).is_none());
            ledger.on_admit(batch, 4000);
        }
        // After the contention window expires, a past arrival stops
        // counting too.
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        ledger.note_arrival(premium, SimTime::from_secs_f64(0.0));
        for _ in 0..50 {
            ledger.on_admit(batch, 4000);
        }
        let late = SimTime::from_secs_f64(100.0);
        assert!(ledger.check(late, batch, false).is_none());
    }

    #[test]
    fn inflight_keeps_a_class_contending() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let premium = reg.id_of("premium").unwrap();
        let batch = reg.id_of("batch").unwrap();
        ledger.on_admit(premium, 100); // premium has work in flight
        for _ in 0..50 {
            ledger.on_admit(batch, 4000);
        }
        let late = SimTime::from_secs_f64(100.0);
        assert!(ledger.check(late, batch, false).is_some());
        ledger.on_done(premium); // last premium request leaves
        assert!(ledger.check(late, batch, false).is_none());
    }

    #[test]
    fn reset_restores_a_fresh_ledger() {
        let reg = two_class_registry();
        let mut ledger = FairShareLedger::from_registry(&reg);
        let batch = reg.id_of("batch").unwrap();
        ledger.note_arrival(batch, SimTime::from_secs_f64(1.0));
        ledger.on_admit(batch, 4000);
        ledger.reset();
        assert_eq!(ledger.vtime(batch), 0.0);
        assert_eq!(ledger.n_deferred(), 0);
        let fresh = FairShareLedger::from_registry(&reg);
        assert_eq!(format!("{ledger:?}"), format!("{fresh:?}"));
    }
}
