//! The partial-prefill instance (PPI) — the low-end GPU's role in Cronus.
//!
//! The PPI runs the prefix prefill for one request at a time (the paper
//! caps the instance at two requests — one running, one waiting — so the
//! Balancer always decides with fresh CPI statistics).  Finished prefixes
//! sit in the KV-cache buffer until the CPI pulls them over the link;
//! the buffer is bounded by the low-end GPU's KV capacity, and a full
//! buffer back-pressures the next prefill start (the job stays admitted
//! but cannot begin computing until a transfer frees space).

use std::collections::VecDeque;

use crate::util::fxhash::FxHashMap;

use crate::engine::request::ReqId;
use crate::simgpu::perfmodel::PerfModel;

/// A request staged in the PPI.
#[derive(Clone, Copy, Debug)]
pub struct PpiJob {
    pub id: ReqId,
    pub partial_len: usize,
}

/// Maximum requests in the instance (paper §4.2: "at most two at a
/// time", so splits are computed with up-to-date CPI statistics).
pub const PPI_CAPACITY: usize = 2;

pub struct PartialPrefillInstance {
    pm: PerfModel,
    /// Currently computing job, if any.
    running: Option<PpiJob>,
    /// Admitted jobs not yet started (FIFO).
    queue: VecDeque<PpiJob>,
    /// Completed prefixes awaiting transfer: id -> tokens buffered.
    buffer: FxHashMap<ReqId, usize>,
    buffered_tokens: usize,
    buffer_capacity_tokens: usize,
    // --- accounting ---
    pub busy_time_s: f64,
    pub n_prefills: u64,
    pub tokens_prefilled: u64,
    /// Starts delayed because the KV buffer was full.
    pub n_buffer_stalls: u64,
}

impl PartialPrefillInstance {
    pub fn new(pm: PerfModel, buffer_capacity_tokens: usize) -> Self {
        PartialPrefillInstance {
            pm,
            running: None,
            queue: VecDeque::new(),
            buffer: FxHashMap::default(),
            buffered_tokens: 0,
            buffer_capacity_tokens,
            busy_time_s: 0.0,
            n_prefills: 0,
            tokens_prefilled: 0,
            n_buffer_stalls: 0,
        }
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }

    pub fn n_in_instance(&self) -> usize {
        self.queue.len() + self.running.is_some() as usize
    }

    /// Is there room for another request?
    pub fn has_slot(&self) -> bool {
        self.n_in_instance() < PPI_CAPACITY
    }

    /// Admit a job.  If the PPI is idle and the buffer has room, the job
    /// starts immediately: the caller schedules a completion event for
    /// the returned `(job, duration)`.
    pub fn enqueue(&mut self, job: PpiJob) -> Option<(PpiJob, f64)> {
        assert!(self.has_slot(), "PPI over capacity");
        self.queue.push_back(job);
        self.try_start()
    }

    /// Start the head-of-line job if the instance is idle and the buffer
    /// can absorb its output.
    fn try_start(&mut self) -> Option<(PpiJob, f64)> {
        if self.running.is_some() {
            return None;
        }
        let job = *self.queue.front()?;
        if self.buffered_tokens + job.partial_len > self.buffer_capacity_tokens {
            self.n_buffer_stalls += 1;
            return None;
        }
        self.queue.pop_front();
        let duration = self.pm.prefill_time(job.partial_len);
        self.running = Some(job);
        self.busy_time_s += duration;
        Some((job, duration))
    }

    /// The running prefill finished: move its KV to the buffer; start the
    /// next queued job if possible.  Returns `(finished, next-started)`.
    ///
    /// Zero-length jobs (warm session turns, or partials clamped to an
    /// undersized buffer) produce no KV, so nothing is buffered — there
    /// will be no transfer, hence no [`release`](Self::release), and an
    /// entry would leak forever.
    pub fn on_done(&mut self) -> (PpiJob, Option<(PpiJob, f64)>) {
        let job = self.running.take().expect("PPI done without running job");
        self.n_prefills += 1;
        self.tokens_prefilled += job.partial_len as u64;
        if job.partial_len > 0 {
            self.buffer.insert(job.id, job.partial_len);
            self.buffered_tokens += job.partial_len;
        }
        let started = self.try_start();
        (job, started)
    }

    /// The CPI finished pulling `id`'s prefix: free the buffer; a
    /// buffer-stalled job may now start.
    pub fn release(&mut self, id: ReqId) -> Option<(PpiJob, f64)> {
        if let Some(tokens) = self.buffer.remove(&id) {
            self.buffered_tokens -= tokens;
        }
        self.try_start()
    }

    pub fn buffered_tokens(&self) -> usize {
        self.buffered_tokens
    }

    /// Total KV tokens the buffer can hold (the low-end card's capacity).
    pub fn buffer_capacity_tokens(&self) -> usize {
        self.buffer_capacity_tokens
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_none()
    }

    /// Consistency checks for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.n_in_instance() > PPI_CAPACITY {
            return Err("PPI over capacity".into());
        }
        let sum: usize = self.buffer.values().sum();
        if sum != self.buffered_tokens {
            return Err(format!(
                "buffer accounting drift: {} vs {}",
                sum, self.buffered_tokens
            ));
        }
        if self.buffered_tokens > self.buffer_capacity_tokens {
            return Err("buffer over capacity".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::A10;

    fn ppi(buffer: usize) -> PartialPrefillInstance {
        PartialPrefillInstance::new(PerfModel::new(A10, LLAMA3_8B), buffer)
    }

    #[test]
    fn runs_one_at_a_time() {
        let mut p = ppi(100_000);
        let d1 = p.enqueue(PpiJob { id: 1, partial_len: 500 });
        assert!(d1.is_some(), "first job starts immediately");
        let d2 = p.enqueue(PpiJob { id: 2, partial_len: 700 });
        assert!(d2.is_none(), "second job queues");
        assert!(!p.has_slot(), "instance capped at two requests");
        let (done, next) = p.on_done();
        assert_eq!(done.id, 1);
        let (next_job, dur) = next.expect("queued job starts");
        assert_eq!(next_job.id, 2);
        assert!(dur > 0.0);
        assert!(p.has_slot());
        p.check_invariants().unwrap();
    }

    #[test]
    fn duration_matches_perf_model() {
        let mut p = ppi(100_000);
        let (_, d) = p.enqueue(PpiJob { id: 1, partial_len: 1000 }).unwrap();
        let expected = PerfModel::new(A10, LLAMA3_8B).prefill_time(1000);
        assert!((d - expected).abs() < 1e-12);
    }

    #[test]
    fn buffer_backpressure_stalls_start() {
        let mut p = ppi(1000);
        p.enqueue(PpiJob { id: 1, partial_len: 800 }).unwrap();
        let (_, next) = p.on_done(); // 800 tokens buffered
        assert!(next.is_none());
        // A 300-token job cannot start: 800 + 300 > 1000.
        let started = p.enqueue(PpiJob { id: 2, partial_len: 300 });
        assert!(started.is_none());
        assert_eq!(p.n_buffer_stalls, 1);
        // The stalled job keeps its slot: one more admission allowed, no
        // overwrite (regression test for a lost-request bug).
        assert!(p.has_slot());
        let started = p.enqueue(PpiJob { id: 3, partial_len: 100 });
        assert!(started.is_none(), "FIFO: job 2 must start first");
        assert!(!p.has_slot());
        // Releasing the buffer starts job 2 (not 3).
        let (job, _) = p.release(1).expect("stalled job resumes");
        assert_eq!(job.id, 2);
        assert_eq!(p.buffered_tokens(), 0);
        // job 3 starts after job 2 completes.
        let (done, next) = p.on_done();
        assert_eq!(done.id, 2);
        assert_eq!(next.unwrap().0.id, 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn accounting() {
        let mut p = ppi(100_000);
        p.enqueue(PpiJob { id: 1, partial_len: 600 }).unwrap();
        p.on_done();
        assert_eq!(p.n_prefills, 1);
        assert_eq!(p.tokens_prefilled, 600);
        assert!(p.busy_time_s > 0.0);
        assert_eq!(p.buffered_tokens(), 600);
    }

    #[test]
    fn zero_length_job_buffers_nothing() {
        // Warm session turns run through the PPI as zero-length handoffs;
        // they must not leave dangling buffer entries behind.
        let mut p = ppi(1000);
        p.enqueue(PpiJob { id: 1, partial_len: 0 }).unwrap();
        let (done, _) = p.on_done();
        assert_eq!(done.id, 1);
        assert_eq!(p.buffered_tokens(), 0);
        assert!(p.buffer.is_empty(), "zero-length job leaked a buffer entry");
        p.check_invariants().unwrap();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut p = ppi(1000);
        assert!(p.release(42).is_none());
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic]
    fn over_capacity_panics() {
        let mut p = ppi(100_000);
        p.enqueue(PpiJob { id: 1, partial_len: 10 });
        p.enqueue(PpiJob { id: 2, partial_len: 10 });
        p.enqueue(PpiJob { id: 3, partial_len: 10 });
    }
}
