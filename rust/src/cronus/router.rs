//! Cluster-level request router: dispatches each arriving request to one
//! of N heterogeneous (high-end, low-end) pairs.
//!
//! The router is the cluster analogue of the paper's per-pair frontend:
//! it sees only arrival-time information (request lengths and its own
//! bookkeeping), never simulator ground truth.  Load is tracked as a
//! *live backlog* per pair — tokens assigned by [`Router::route`] and
//! released by [`Router::on_completed`] when the owning
//! [`ClusterSystem`](crate::systems::cluster::ClusterSystem) observes the
//! pair's `Finished`/`Shed` events — so routing decisions react to what
//! the pairs actually served, not to a virtual drain-rate guess.  The
//! backlogs are mirrored into an indexed tournament tree (`LoadIndex`)
//! so the least-outstanding argmin on the routing hot path is O(1) per
//! arrival with O(log N) updates, not a full scan of the fleet.
//!
//! Four pluggable policies:
//!
//! * [`RoutePolicy::RoundRobin`] — weighted round-robin over the pairs'
//!   `rate_share`s (deficit form: route to the pair with the smallest
//!   `routed / share` ratio);
//! * [`RoutePolicy::LeastOutstandingTokens`] — route to the pair with the
//!   fewest outstanding (assigned − completed) tokens;
//! * [`RoutePolicy::SloAware`] — estimate each pair's TTFT for *this*
//!   request (backlog drain time + the pair's calibrated Eq. 2 prefill
//!   predictor) and route to the minimum, so slow-prefill pairs stop
//!   attracting long prompts before their tails blow up;
//! * [`RoutePolicy::KvAffinity`] — route a conversation's follow-up
//!   turns to the pair already holding the session's prefix KV (the
//!   *resident* pair), so the replayed context is neither recomputed nor
//!   transferred.  The router keeps a prefix-residency map (session →
//!   pair, with per-pair capacity-weighted LRU eviction); if the
//!   resident pair's estimated TTFT would blow the SLO the follow-up
//!   falls back to the load-based pick, and first turns / sessionless
//!   requests always use the load-based pick
//!   (least-outstanding-tokens).  KV placement dominating scheduling
//!   quality in heterogeneous disaggregated clusters is the core finding
//!   of HexGen-2 (2025) and the multi-vendor disaggregated serving line
//!   of work.
//!
//! `rate_share` participates in *every* policy: besides weighting
//! round-robin, it scales each pair's assumed service capacity in the
//! TTFT estimator ([`Router::estimated_ttft`]), so an operator boosting
//! a pair's share makes its backlog appear to drain faster and the
//! SLO-aware policy sends it proportionally more load.
//!
//! [`Router::slo_admission`] is the submit-time admission-control policy
//! (ROADMAP item): given a TTFT SLO, it accepts only when some pair's
//! estimate meets the target, defers (with a retry hint) when the
//! cluster is transiently overloaded, and rejects when no pair could
//! meet the target even when idle.  The estimate is *prefix-credit
//! aware*: a follow-up turn whose session KV is resident on a pair only
//! needs that pair to prefill the fresh suffix, so admission no longer
//! over-rejects follow-ups whose full prompt would be too slow.
//!
//! Every pair also carries an *active* flag ([`Router::set_pair_active`])
//! — the mechanism behind the cluster's elastic autoscaling.  An inactive
//! pair (standby, or draining toward retirement) is parked at +∞ in the
//! load index and skipped by every policy scan, the affinity target and
//! the SLO admission gate, while its remaining in-flight backlog keeps
//! draining through [`Router::on_completed`].  With all pairs active
//! (the default) the flag is free: every routing path behaves exactly as
//! before.
//!
//! With a [`ClassRegistry`](crate::qos::ClassRegistry) attached via
//! [`Router::set_class_registry`] the router is also *QoS-aware*:
//!
//! * **Model-aware routing** — every pair carries the model its
//!   `DeploymentConfig` deploys; a request whose service class pins a
//!   model is considered only on pairs serving that model.  The filter
//!   applies uniformly: all four policies' scans, the least-outstanding
//!   fast path, the affinity target and SLO admission (the cluster
//!   sheds with a distinct reason when no active pair is compatible,
//!   via [`Router::has_active_compatible_pair`]).
//! * **TBT-aware admission** — [`Router::estimated_tbt_inflation`]
//!   prices the decode-side cost of adding one more stream to a pair:
//!   the decode batch grows by one sequence and the batch context by
//!   the request's full context, stretching every in-flight request's
//!   inter-token gap (the pair's `PerfModel` decode iteration shape
//!   prices exactly this).  [`Router::tbt_admission`] defers a request
//!   when on *every* compatible active pair the projected decode
//!   iteration would blow the strictest TBT-P99 SLO among the classes
//!   already in flight there — protecting incumbents' decode tails the
//!   way `slo_admission` protects the arrival's own TTFT.
//!
//! Without a registry — or with one holding only the default class and
//! no TBT SLOs — every QoS path is inert and routing is byte-identical
//! to the pre-QoS router.
//!
//! With an interconnect configured ([`ClusterConfig::link`] or per-pair
//! overrides) the affinity policy stops throwing warm sessions away:
//! when the resident pair is SLO-infeasible or draining, the router
//! prices shipping the resident prefix over the link
//! ([`LinkSpec::kv_transfer_time`]) against recomputing it at each
//! candidate destination, and migrates whenever the transfer is
//! strictly cheaper — the migrated prefix arrives as `kv_credit` at the
//! destination with the transfer delay carried on the
//! [`RouteDecision`] (added to the TTFT estimate *and*, by the cluster,
//! to the actual admission instant).  Draining pairs hand their whole
//! residency over the link before retiring
//! ([`Router::handoff_pair_residency`]); a *failed* pair's KV is dead
//! and is still evicted, never migrated.  Without a link every
//! migration path is one dead branch and routing is byte-identical to
//! the pre-migration router.
//!
//! # Example
//!
//! Build a router over a two-pair fleet and dispatch one request:
//!
//! ```
//! use cronus::config::topology::ClusterConfig;
//! use cronus::cronus::router::{RoutePolicy, Router};
//! use cronus::simgpu::model_desc::LLAMA3_8B;
//! use cronus::workload::Request;
//!
//! let fleet = ClusterConfig::mixed(2, LLAMA3_8B);
//! let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &fleet);
//! let req = Request::new(0, 0, 512, 64);
//! let d = router.route(&req).expect("an active pair exists");
//! assert!(d.pair < fleet.n_pairs());
//! router.commit_route(&req, &d);
//! // ... the chosen pair serves the request, then completes it ...
//! router.on_completed(d.pair, d.charged_tokens);
//! assert_eq!(router.outstanding_tokens()[d.pair], 0.0);
//! ```

use std::collections::BTreeSet;

use crate::config::topology::ClusterConfig;
use crate::qos::{ClassId, ClassRegistry};
use crate::simclock::SimTime;
use crate::simgpu::fit::{calibrate, PrefillCoeffs};
use crate::simgpu::link::LinkSpec;
use crate::simgpu::model_desc::ModelDesc;
use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};
use crate::systems::Admission;
use crate::util::fxhash::FxHashMap;
use crate::workload::{Request, NO_SESSION};

/// Fraction of a pair's CPI KV capacity the router is willing to pin for
/// session prefix residency (the rest stays free for in-flight batches).
const KV_RESIDENCY_FRAC: f64 = 0.5;

/// Retry hint attached to a TBT-admission deferral: long enough for a
/// few decode streams to retire, short enough that the driver's retry
/// budget spans a realistic drain.
const TBT_RETRY_S: f64 = 0.05;

/// Reference prompt length for [`Router::best_ttft_headroom`] — the
/// fleet controller's TTFT-headroom probe prices a typical prompt, not
/// any particular request.
pub const HEADROOM_PROBE_TOKENS: usize = 512;

/// Routing policy of the cluster frontend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstandingTokens,
    SloAware,
    KvAffinity,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 4] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingTokens,
        RoutePolicy::SloAware,
        RoutePolicy::KvAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstandingTokens => "least-outstanding",
            RoutePolicy::SloAware => "slo-aware",
            RoutePolicy::KvAffinity => "kv-affinity",
        }
    }

    pub fn from_name(name: &str) -> Option<RoutePolicy> {
        match name
            .to_ascii_lowercase()
            .replace(['-', '_', ' '], "")
            .as_str()
        {
            "rr" | "roundrobin" => Some(RoutePolicy::RoundRobin),
            "lot" | "leastoutstanding" | "leastoutstandingtokens" => {
                Some(RoutePolicy::LeastOutstandingTokens)
            }
            "slo" | "sloaware" => Some(RoutePolicy::SloAware),
            "kv" | "affinity" | "kvaffinity" => Some(RoutePolicy::KvAffinity),
            _ => None,
        }
    }
}

/// Router-side view of one pair's load.
struct PairLoad {
    rate_share: f64,
    /// Estimated sustained service rate of the pair, tokens/second.
    drain_rate_tps: f64,
    /// The pair's calibrated Eq. 2 prefill predictor (PPI side).
    prefill: PrefillCoeffs,
    /// Live backlog: assigned-but-not-yet-completed tokens.
    outstanding_tokens: f64,
    n_routed: u64,
    tokens_routed: u64,
    /// Session prefix KV currently pinned on this pair (tokens).
    resident_tokens: u64,
    /// Residency budget (tokens): a [`KV_RESIDENCY_FRAC`] slice of the
    /// pair's CPI KV capacity, so bigger pairs keep more sessions warm
    /// (capacity-weighted eviction).
    residency_capacity_tokens: u64,
    /// Whether the pair's serving system can exploit a resident prefix
    /// stamped through `Request::kv_credit`.  Every in-tree system
    /// honours the credit now — the Cronus frontend family and both
    /// disaggregated baselines from the start, the DP dispatcher and
    /// the staged PP pipeline since they learned to stamp it through to
    /// their engines — so this is `true` for every pair; the field
    /// remains for future systems that re-prefill unconditionally.
    supports_credit: bool,
    /// Model the pair's deployment serves — requests whose service
    /// class pins a model are only routed to pairs serving it.
    model: ModelDesc,
    /// Decode-side (CPI) performance model, pricing the TBT-aware
    /// admission estimates.
    decode_pm: PerfModel,
    /// Committed-and-not-yet-finished requests (decode streams the TBT
    /// estimator assumes are batched here).
    n_streams: u32,
    /// Sum of those requests' full contexts (input + output tokens).
    ctx_sum: u64,
    /// Resident sessions ordered by last use — `(last_use, session_id)`
    /// with unique `last_use` values, so `first()` is the exact LRU
    /// victim in O(log S) (this used to be an O(S) scan of the whole
    /// residency map per eviction).
    lru: BTreeSet<(u64, u64)>,
    /// Whether the router may send new work here.  The fleet controller
    /// parks draining/standby pairs at `false`; every pair starts (and
    /// without autoscaling forever stays) active.
    active: bool,
}

/// Where one session's prefix KV lives.
#[derive(Clone, Copy, Debug)]
struct Residency {
    pair: usize,
    /// Context tokens resident (the session's prompt + response so far).
    tokens: u64,
    /// Monotone use counter for LRU eviction.
    last_use: u64,
    /// Instant (ns) the prefix finishes arriving on `pair` — non-zero
    /// only right after a drain handoff shipped it over the link.  A
    /// turn arriving earlier waits out the remainder of the transfer.
    ready_at: u64,
}

/// One cross-pair KV shipment attached to a [`RouteDecision`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvTransfer {
    /// Pair the prefix ships from (equal to the decision's `pair` when
    /// the delay is the residual of an earlier drain handoff — that
    /// shipment was already counted as a migration when it started).
    pub from: usize,
    /// Link delay in nanoseconds: the cluster submits the request to
    /// the destination pair exactly this much after the routing
    /// instant, so the transfer shows up in the measured TTFT.
    pub delay_ns: u64,
    /// Prefix tokens shipped (equals the decision's `kv_credit`).
    pub tokens: u64,
}

/// An affinity-policy routing target: the pair holding (or receiving)
/// the session's prefix KV, the credit it grants, and the shipment
/// backing it when the prefix moves or is still in flight.
#[derive(Clone, Copy, Debug)]
struct AffinityHit {
    pair: usize,
    credit: usize,
    transfer: Option<KvTransfer>,
}

/// Outcome of one routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    /// Chosen pair index.
    pub pair: usize,
    /// Resident-prefix tokens the pair may skip (0 on a miss; always
    /// `<= req.prefix_len`).  The cluster stamps this into the request's
    /// `kv_credit` before handing it to the pair.
    pub kv_credit: usize,
    /// Backlog tokens charged against the pair — release exactly this via
    /// [`Router::on_completed`] when the request leaves the system.
    pub charged_tokens: u64,
    /// KV shipment backing the credit, if the prefix is (still) on the
    /// wire.  `None` on every decision when no link is configured.
    pub transfer: Option<KvTransfer>,
}

impl PairLoad {
    /// Service rate the estimator assumes: the physical estimate scaled
    /// by the operator's `rate_share` capacity prior.
    fn effective_drain_tps(&self) -> f64 {
        (self.drain_rate_tps * self.rate_share).max(1e-9)
    }
}

/// Tournament tree (a complete binary segment tree) over the pairs'
/// live backlogs: O(1) argmin with ties to the lowest pair index,
/// O(log N) point update.  This is the indexed load structure behind
/// the [`RoutePolicy::LeastOutstandingTokens`] hot path — the policy's
/// argmin used to be a full O(N) scan on every arrival, which dominated
/// cluster routing cost at hundreds of pairs.
struct LoadIndex {
    /// Power-of-two leaf span (`>= n_pairs`).
    size: usize,
    /// `tree[1]` is the root; leaf `i` lives at `size + i`.  Each
    /// internal node stores the index of the minimum leaf in its
    /// subtree; ties prefer the left child, i.e. the lower pair index —
    /// exactly the scan's first-minimum tie-break.
    tree: Vec<usize>,
    /// Leaf loads; unused leaves (`i >= n_pairs`) hold +∞.
    vals: Vec<f64>,
}

impl LoadIndex {
    fn new(n: usize) -> LoadIndex {
        let size = n.next_power_of_two().max(1);
        let mut idx = LoadIndex {
            size,
            tree: vec![0; 2 * size],
            vals: vec![f64::INFINITY; size],
        };
        idx.vals[..n].fill(0.0);
        for (i, leaf) in idx.tree[size..].iter_mut().enumerate() {
            *leaf = i;
        }
        for node in (1..size).rev() {
            idx.tree[node] = idx.pick_child(node);
        }
        idx
    }

    fn pick_child(&self, node: usize) -> usize {
        let l = self.tree[2 * node];
        let r = self.tree[2 * node + 1];
        if self.vals[l] <= self.vals[r] {
            l
        } else {
            r
        }
    }

    /// Set pair `i`'s load and rebubble its root path: O(log N).
    fn set(&mut self, i: usize, v: f64) {
        self.vals[i] = v;
        let mut node = (self.size + i) / 2;
        while node >= 1 {
            self.tree[node] = self.pick_child(node);
            node /= 2;
        }
    }

    /// Pair with the smallest load (lowest index on ties): O(1).
    fn argmin(&self) -> usize {
        self.tree[1]
    }
}

/// The cluster dispatcher.  Deterministic: identical construction and
/// request/completion sequences produce identical assignments (LRU
/// eviction breaks ties on a unique monotone counter, never on hash
/// iteration order).
pub struct Router {
    policy: RoutePolicy,
    pairs: Vec<PairLoad>,
    /// Indexed mirror of the pairs' `outstanding_tokens`, kept in sync
    /// by [`charge`](Self::charge) / [`on_completed`](Self::on_completed)
    /// so the least-outstanding argmin is O(1) instead of a scan.
    load_index: LoadIndex,
    /// Session → residency of its prefix KV.  Maintained only under
    /// [`RoutePolicy::KvAffinity`]; empty (and therefore inert in the
    /// TTFT estimator) under the load-based policies.
    residency: FxHashMap<u64, Residency>,
    /// Monotone counter feeding `Residency::last_use`.
    use_seq: u64,
    // --- session/KV accounting (cluster-level metrics) ---
    n_kv_hits: u64,
    prefill_tokens_saved: u64,
    /// Follow-up turns (non-empty session prefix) committed.
    n_prefix_routed: u64,
    // --- QoS (None / default-only registry = all paths inert) ---
    /// Service classes, when the cluster runs multi-tenant QoS.
    classes: Option<ClassRegistry>,
    /// In-flight request count per `[pair][class]` — the TBT admission
    /// gate derives each pair's strictest incumbent TBT SLO from it.
    /// Empty until a registry is attached.
    class_inflight: Vec<Vec<u32>>,
    // --- cross-pair KV migration (no link configured = all paths inert) ---
    /// Cluster-wide inter-pair link, if migration is enabled.
    link: Option<LinkSpec>,
    /// Per-pair link overrides (`None` falls back to `link`).
    pair_links: Vec<Option<LinkSpec>>,
    /// Prefixes shipped across pairs instead of recomputed.
    n_migrations: u64,
    /// Context tokens those shipments carried.
    migrated_tokens: u64,
    /// Wall-clock seconds spent on the link by those shipments.
    migration_time_s: f64,
}

/// Coarse steady-state token throughput of a pair: the CPI running full
/// chunked-prefill batches over a typical decode population, plus half
/// the PPI's standalone prefill rate (its share of overlapped prefix
/// work).  A router-side estimate — only relative magnitudes matter.
fn estimated_token_rate(ppi: &PerfModel, cpi: &PerfModel, budget: usize) -> f64 {
    let budget = budget.max(1);
    let shape = IterationShape {
        prefill: vec![PrefillSeg { q_tokens: budget, ctx_end: budget.max(1024) }],
        n_decode: 64,
        decode_ctx_sum: 64 * 1200,
    };
    let cpi_rate = (budget + 64) as f64 / cpi.iteration_time(&shape);
    let ppi_rate = 2048.0 / ppi.prefill_time(2048);
    cpi_rate + 0.5 * ppi_rate
}

impl Router {
    /// Build a router for `cluster`, calibrating each pair's predictors
    /// the same way its Balancer does (§4.4 profiling + OLS).
    pub fn new(policy: RoutePolicy, cluster: &ClusterConfig) -> Router {
        assert!(!cluster.pairs.is_empty(), "router needs at least one pair");
        let pairs = cluster
            .pairs
            .iter()
            .map(|pair| {
                let d = &pair.deployment;
                let ppi_pm = PerfModel::new(d.low_gpu, d.model);
                let cpi_pm = PerfModel::new(d.high_gpu, d.model);
                let (prefill, _chunked) = calibrate(
                    &ppi_pm,
                    &cpi_pm,
                    d.engine.max_batched_tokens,
                    d.calibration_noise,
                    d.calibration_seed,
                );
                let cpi_capacity =
                    cpi_pm.kv_capacity_tokens(d.engine.activation_reserve_frac);
                PairLoad {
                    rate_share: pair.rate_share,
                    drain_rate_tps: estimated_token_rate(
                        &ppi_pm,
                        &cpi_pm,
                        d.engine.max_batched_tokens,
                    ),
                    prefill,
                    outstanding_tokens: 0.0,
                    n_routed: 0,
                    tokens_routed: 0,
                    resident_tokens: 0,
                    residency_capacity_tokens: (cpi_capacity as f64
                        * KV_RESIDENCY_FRAC)
                        as u64,
                    supports_credit: true,
                    model: d.model,
                    decode_pm: cpi_pm,
                    n_streams: 0,
                    ctx_sum: 0,
                    lru: BTreeSet::new(),
                    active: true,
                }
            })
            .collect();
        let load_index = LoadIndex::new(cluster.pairs.len());
        Router {
            policy,
            pairs,
            load_index,
            residency: FxHashMap::default(),
            use_seq: 0,
            n_kv_hits: 0,
            prefill_tokens_saved: 0,
            n_prefix_routed: 0,
            classes: None,
            class_inflight: Vec::new(),
            link: cluster.link,
            pair_links: cluster.pairs.iter().map(|p| p.link).collect(),
            n_migrations: 0,
            migrated_tokens: 0,
            migration_time_s: 0.0,
        }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Attach the cluster's service-class registry: enables model-aware
    /// routing (classes pinning a model) and TBT-aware admission
    /// (classes with `slo_tbt_p99_s`).  A registry holding only the
    /// default class changes nothing.
    pub fn set_class_registry(&mut self, registry: ClassRegistry) {
        self.class_inflight = vec![vec![0; registry.len()]; self.pairs.len()];
        self.classes = Some(registry);
    }

    /// Model the class of `req` pins the request to, if any.
    fn required_model(&self, req: &Request) -> Option<ModelDesc> {
        self.classes.as_ref().and_then(|r| r.get(req.class).model)
    }

    fn pair_serves(&self, i: usize, need: Option<ModelDesc>) -> bool {
        need.map_or(true, |m| self.pairs[i].model.name == m.name)
    }

    /// Whether some *active* pair serves the model `req`'s class pins
    /// (vacuously true for unconstrained requests).  The cluster sheds
    /// incompatible requests with a distinct reason before admission.
    pub fn has_active_compatible_pair(&self, req: &Request) -> bool {
        match self.required_model(req) {
            None => true,
            Some(m) => self
                .pairs
                .iter()
                .any(|p| p.active && p.model.name == m.name),
        }
    }

    /// Model served by pair `i` (from its deployment config).
    pub fn pair_model(&self, i: usize) -> ModelDesc {
        self.pairs[i].model
    }

    /// Reset every piece of load/session state to the just-constructed
    /// value, keeping the calibrated per-pair predictors (they are a
    /// pure function of the cluster config, so a reset router is
    /// indistinguishable from a freshly built one).  Lets a cluster
    /// `drain` reset for reuse without re-profiling all N pairs.
    pub fn reset(&mut self) {
        for (i, p) in self.pairs.iter_mut().enumerate() {
            p.outstanding_tokens = 0.0;
            p.n_routed = 0;
            p.tokens_routed = 0;
            p.resident_tokens = 0;
            p.n_streams = 0;
            p.ctx_sum = 0;
            p.lru.clear();
            p.active = true;
            self.load_index.set(i, 0.0);
        }
        for ci in &mut self.class_inflight {
            ci.fill(0);
        }
        self.residency.clear();
        self.use_seq = 0;
        self.n_kv_hits = 0;
        self.prefill_tokens_saved = 0;
        self.n_prefix_routed = 0;
        self.n_migrations = 0;
        self.migrated_tokens = 0;
        self.migration_time_s = 0.0;
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Include or exclude pair `i` from routing — the fleet controller's
    /// activation / drain switch.  An inactive pair is parked at +∞ in
    /// the load index and skipped by every policy scan, the affinity
    /// target and SLO admission; its in-flight backlog keeps draining
    /// via [`on_completed`](Self::on_completed) without resurrecting it.
    /// No-op when the flag already matches.
    pub fn set_pair_active(&mut self, i: usize, active: bool) {
        let p = &mut self.pairs[i];
        if p.active == active {
            return;
        }
        p.active = active;
        let v = if active { p.outstanding_tokens } else { f64::INFINITY };
        self.load_index.set(i, v);
    }

    /// Whether pair `i` currently receives new work.
    pub fn is_pair_active(&self, i: usize) -> bool {
        self.pairs[i].active
    }

    /// Pairs currently receiving new work.
    pub fn n_active_pairs(&self) -> usize {
        self.pairs.iter().filter(|p| p.active).count()
    }

    /// Drop every session resident on `pair` — called when the pair is
    /// retired and its KV memory goes away.  Follow-ups of the evicted
    /// sessions route as ordinary misses afterwards.  Returns how many
    /// sessions were evicted.
    pub fn evict_pair_residency(&mut self, pair: usize) -> usize {
        let mut n = 0;
        while let Some((_, id)) = self.pairs[pair].lru.pop_first() {
            self.residency.remove(&id);
            n += 1;
        }
        self.pairs[pair].resident_tokens = 0;
        n
    }

    /// A pair is draining toward retirement but its KV memory is still
    /// alive: ship each resident session's prefix to the cheapest viable
    /// destination over the link instead of evicting it.  Shipments
    /// serialize on the source's link starting at `now` (MRU sessions
    /// first — they are the likeliest to see another turn), each landing
    /// with a `ready_at` instant the TTFT estimator and the cluster's
    /// delayed admission honour.  Sessions with no viable destination
    /// (no link, no capacity, transfer not cheaper than recompute) are
    /// evicted as before.  Without any configured link this *is*
    /// [`evict_pair_residency`](Self::evict_pair_residency).  Returns how
    /// many sessions migrated.
    pub fn handoff_pair_residency(&mut self, pair: usize, now: SimTime) -> usize {
        if !self.migration_enabled() {
            self.evict_pair_residency(pair);
            return 0;
        }
        let mut cursor_ns = now.0;
        let mut moved = 0;
        while let Some((_, sid)) = self.pairs[pair].lru.pop_last() {
            let r = self.residency.remove(&sid).expect("lru entry has residency");
            self.pairs[pair].resident_tokens =
                self.pairs[pair].resident_tokens.saturating_sub(r.tokens);
            if r.ready_at > now.0 || r.tokens == 0 {
                continue; // still on the wire from an earlier handoff
            }
            let src_model = self.pairs[pair].model;
            let mut dest: Option<(usize, f64, f64)> = None;
            for (j, p) in self.pairs.iter().enumerate() {
                if j == pair
                    || !p.active
                    || !p.supports_credit
                    || p.model.name != src_model.name
                    || p.resident_tokens + r.tokens > p.residency_capacity_tokens
                {
                    continue;
                }
                let Some(xfer_s) = self.kv_transfer_s(pair, j, r.tokens) else {
                    continue;
                };
                if xfer_s >= p.prefill.predict(r.tokens as usize) {
                    continue; // recomputing the prefix there is cheaper
                }
                let load = p.outstanding_tokens;
                if dest.map_or(true, |(_, b, _)| load < b) {
                    dest = Some((j, load, xfer_s));
                }
            }
            let Some((j, _, xfer_s)) = dest else {
                continue; // no viable destination: plain eviction
            };
            cursor_ns = cursor_ns.saturating_add((xfer_s * 1e9) as u64);
            self.use_seq += 1;
            self.pairs[j].resident_tokens += r.tokens;
            self.pairs[j].lru.insert((self.use_seq, sid));
            self.residency.insert(
                sid,
                Residency {
                    pair: j,
                    tokens: r.tokens,
                    last_use: self.use_seq,
                    ready_at: cursor_ns,
                },
            );
            self.n_migrations += 1;
            self.migrated_tokens += r.tokens;
            self.migration_time_s += xfer_s;
            moved += 1;
        }
        moved
    }

    /// Prefix shipments across pairs so far (route-time and drain
    /// handoffs combined).
    pub fn n_migrations(&self) -> u64 {
        self.n_migrations
    }

    /// Context tokens those shipments carried.
    pub fn migrated_tokens(&self) -> u64 {
        self.migrated_tokens
    }

    /// Wall-clock seconds spent on the link by those shipments.
    pub fn migration_time_s(&self) -> f64 {
        self.migration_time_s
    }

    /// Calibrated sustained service-rate estimate per pair (tokens/s),
    /// before `rate_share` scaling — the topology planner reads these to
    /// assign capacity-proportional shares.
    pub fn drain_rates_tps(&self) -> Vec<f64> {
        self.pairs.iter().map(|p| p.drain_rate_tps).collect()
    }

    /// Current live backlog per pair (exposed for tests / reporting).
    pub fn outstanding_tokens(&self) -> Vec<f64> {
        self.pairs.iter().map(|p| p.outstanding_tokens).collect()
    }

    /// Requests routed to each pair so far.
    pub fn routed_counts(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.n_routed).collect()
    }

    /// Tokens (input + output, net of resident-prefix credit) routed to
    /// each pair so far.
    pub fn routed_tokens(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.tokens_routed).collect()
    }

    /// Estimated TTFT of `input_len` prefill tokens on pair `i` right
    /// now: drain the live backlog at the pair's rate-share-scaled
    /// service rate, then run the prefix on the PPI (conservative — the
    /// CPI usually shares the prefill).
    pub fn estimated_ttft(&self, i: usize, input_len: usize) -> f64 {
        let p = &self.pairs[i];
        p.outstanding_tokens / p.effective_drain_tps() + p.prefill.predict(input_len)
    }

    /// Prefix-credit-aware TTFT estimate for `req` on pair `i`: if the
    /// session's KV is resident there, only the fresh suffix needs
    /// prefilling.  (Fixes the old estimator, which assumed a full-prompt
    /// prefill for every request and so over-rejected follow-up turns at
    /// the SLO admission gate.)  A prefix still on the wire from a drain
    /// handoff adds the residual transfer time — the pair cannot start
    /// the credited prefill before the KV lands.
    pub fn estimated_ttft_for(&self, i: usize, req: &Request) -> f64 {
        self.estimated_ttft(i, req.input_len - self.resident_credit(i, req))
            + self.residual_ready_delay_ns(i, req) as f64 * 1e-9
    }

    /// Credit a residency record grants `req`: capped by the recorded
    /// prompt prefix and below `input_len` so at least one token is
    /// always computed.
    fn residency_credit(r: &Residency, req: &Request) -> usize {
        req.prefix_len
            .min(r.tokens as usize)
            .min(req.input_len.saturating_sub(1))
    }

    /// Resident-prefix tokens pair `i` could skip for `req` (0 unless the
    /// session's KV is resident on exactly this pair and the pair's
    /// system can exploit it).
    fn resident_credit(&self, pair: usize, req: &Request) -> usize {
        if req.session_id == NO_SESSION || !self.pairs[pair].supports_credit {
            return 0;
        }
        match self.residency.get(&req.session_id) {
            Some(r) if r.pair == pair => Self::residency_credit(r, req),
            _ => 0,
        }
    }

    /// Remaining nanoseconds until `req`'s prefix KV finishes arriving on
    /// `pair` (0 when it is already there, or resident elsewhere).
    fn residual_ready_delay_ns(&self, pair: usize, req: &Request) -> u64 {
        if req.session_id == NO_SESSION {
            return 0;
        }
        match self.residency.get(&req.session_id) {
            Some(r) if r.pair == pair => r.ready_at.saturating_sub(req.arrival_ns),
            _ => 0,
        }
    }

    /// The link reaching pair `i`, if any (per-pair override first, then
    /// the cluster-wide link).
    fn pair_link(&self, i: usize) -> Option<LinkSpec> {
        self.pair_links.get(i).copied().flatten().or(self.link)
    }

    /// Whether any link is configured at all — the migration feature
    /// gate.  False keeps every migration path a dead branch.
    fn migration_enabled(&self) -> bool {
        self.link.is_some() || self.pair_links.iter().any(|l| l.is_some())
    }

    /// Seconds to ship `tokens` of pair `from`'s KV to pair `to`, or
    /// `None` when either endpoint is linkless.  The slower endpoint's
    /// link is the bottleneck.
    fn kv_transfer_s(&self, from: usize, to: usize, tokens: u64) -> Option<f64> {
        let src = self.pair_link(from)?;
        let dst = self.pair_link(to)?;
        let bytes_per_token = self.pairs[from].model.kv_bytes_per_token();
        let a = src.kv_transfer_time(tokens as usize, bytes_per_token);
        let b = dst.kv_transfer_time(tokens as usize, bytes_per_token);
        Some(a.max(b))
    }

    /// The resident pair for `req`'s session under the affinity policy,
    /// with its credit — `None` on a miss, for non-session requests, or
    /// when neither serving in place nor migrating the prefix is viable
    /// (fall back to the load-based pick with zero credit).
    fn affinity_target(&self, req: &Request, slo: Option<f64>) -> Option<AffinityHit> {
        if self.policy != RoutePolicy::KvAffinity || req.session_id == NO_SESSION {
            return None;
        }
        let r = self.residency.get(&req.session_id)?;
        if !self.pair_serves(r.pair, self.required_model(req)) {
            // The session changed to a class pinning a different model
            // than the resident pair serves: a miss, never a mismatch
            // (and never a migration — the bytes are for the wrong model).
            return None;
        }
        if self.pairs[r.pair].active {
            let credit = self.resident_credit(r.pair, req);
            let within_slo =
                slo.map_or(true, |s| self.estimated_ttft_for(r.pair, req) <= s);
            if within_slo {
                let residual = self.residual_ready_delay_ns(r.pair, req);
                let transfer = (residual > 0).then(|| KvTransfer {
                    from: r.pair,
                    delay_ns: residual,
                    tokens: credit as u64,
                });
                return Some(AffinityHit { pair: r.pair, credit, transfer });
            }
            // SLO-infeasible in place: a migration may still beat a cold
            // re-prefill elsewhere.
        }
        // Resident pair draining/retired, or SLO-blown: price shipping
        // the prefix over the link instead of throwing it away.
        self.migration_target(r, req, slo)
    }

    /// Cheapest destination worth shipping `req`'s resident prefix to:
    /// the transfer must beat recomputing the prefix there, and the
    /// destination's estimated TTFT (including the transfer) must meet
    /// `slo` when one is given.  `None` when no link is configured or no
    /// destination qualifies.
    fn migration_target(
        &self,
        r: &Residency,
        req: &Request,
        slo: Option<f64>,
    ) -> Option<AffinityHit> {
        if r.ready_at > req.arrival_ns {
            // The prefix is itself still on the wire from an earlier
            // handoff — it cannot be re-shipped before it lands.
            return None;
        }
        let tokens = Self::residency_credit(r, req);
        if tokens == 0 {
            return None;
        }
        let need = self.required_model(req);
        let mut best: Option<(usize, f64, f64)> = None;
        for (j, p) in self.pairs.iter().enumerate() {
            if j == r.pair || !p.active || !p.supports_credit || !self.pair_serves(j, need)
            {
                continue;
            }
            let Some(xfer_s) = self.kv_transfer_s(r.pair, j, tokens as u64) else {
                continue;
            };
            // Price the alternative: prefilling the prefix from scratch
            // as part of the full prompt on this destination.
            let recompute_s =
                p.prefill.predict(req.input_len) - p.prefill.predict(req.input_len - tokens);
            if xfer_s >= recompute_s {
                continue;
            }
            let est = self.estimated_ttft(j, req.input_len - tokens) + xfer_s;
            if slo.is_some_and(|s| est > s) {
                continue;
            }
            if best.map_or(true, |(_, b, _)| est < b) {
                best = Some((j, est, xfer_s));
            }
        }
        best.map(|(pair, _, xfer_s)| AffinityHit {
            pair,
            credit: tokens,
            transfer: Some(KvTransfer {
                from: r.pair,
                delay_ns: (xfer_s * 1e9) as u64,
                tokens: tokens as u64,
            }),
        })
    }

    /// Pick the policy's best pair, optionally restricted to pairs whose
    /// estimated TTFT meets `slo`.  Falls back to the unrestricted best
    /// when no pair qualifies within the SLO (callers gate admission
    /// first, so this is a safety net, not a policy), and to `None`
    /// when no pair is active and model-compatible at all — the caller
    /// sheds deterministically instead of routing to a masked pair.
    /// Ties break toward the lowest pair index, keeping the assignment
    /// deterministic.
    fn pick(&self, req: &Request, slo: Option<f64>) -> Option<usize> {
        let need = self.required_model(req);
        // Hot path: the unconstrained least-outstanding argmin (also the
        // KvAffinity miss/first-turn fallback) is answered by the load
        // index in O(1) instead of scanning all N pairs.  SLO-filtered
        // and model-constrained routing still scan — those filters
        // depend on the request — as do the other policies' scores.
        if slo.is_none()
            && need.is_none()
            && matches!(
                self.policy,
                RoutePolicy::LeastOutstandingTokens | RoutePolicy::KvAffinity
            )
        {
            let i = self.load_index.argmin();
            if self.pairs[i].active {
                return Some(i);
            }
            // Every pair is parked at +∞ (all inactive): fall through to
            // the scan, which returns None instead of a masked pair.
        }
        let score = |p: &PairLoad, i: usize| -> f64 {
            match self.policy {
                RoutePolicy::RoundRobin => p.n_routed as f64 / p.rate_share,
                // KvAffinity falls back to the least-outstanding pick for
                // misses / first turns / sessionless load.
                RoutePolicy::LeastOutstandingTokens | RoutePolicy::KvAffinity => {
                    p.outstanding_tokens
                }
                RoutePolicy::SloAware => self.estimated_ttft_for(i, req),
            }
        };
        let mut best: Option<(usize, f64)> = None;
        for (i, p) in self.pairs.iter().enumerate() {
            if !p.active || !self.pair_serves(i, need) {
                continue;
            }
            if let Some(slo) = slo {
                if self.estimated_ttft_for(i, req) > slo {
                    continue;
                }
            }
            let s = score(p, i);
            if best.map_or(true, |(_, b)| s < b) {
                best = Some((i, s));
            }
        }
        match best {
            Some((i, _)) => Some(i),
            // No active compatible pair met the SLO filter: safety-net
            // unrestricted pick (admission gates first, so this is rare).
            None if slo.is_some() => self.pick(req, None),
            // No active compatible pair at all.  The old fallback
            // returned `load_index.argmin()`, which ignores the `active`
            // and model masks and so could route to a failed or
            // mismatched pair; report the condition instead and let the
            // caller shed deterministically.
            None => None,
        }
    }

    /// Record `req`'s load against `pair`'s live backlog; `credit`
    /// tokens of the prompt are resident there and will not be served
    /// again.  Returns the charged tokens.
    fn charge(&mut self, pair: usize, req: &Request, credit: usize) -> u64 {
        let load = (req.input_len - credit + req.output_len) as u64;
        let p = &mut self.pairs[pair];
        p.outstanding_tokens += load as f64;
        p.n_routed += 1;
        p.tokens_routed += load;
        if p.active {
            self.load_index.set(pair, self.pairs[pair].outstanding_tokens);
        }
        load
    }

    fn route_impl(&mut self, req: &Request, slo: Option<f64>) -> Option<RouteDecision> {
        let (pair, kv_credit, transfer) = match self.affinity_target(req, slo) {
            Some(hit) => (hit.pair, hit.credit, hit.transfer),
            None => (self.pick(req, slo)?, 0, None),
        };
        let charged_tokens = self.charge(pair, req, kv_credit);
        Some(RouteDecision { pair, kv_credit, charged_tokens, transfer })
    }

    /// Route one request; records its load as outstanding.  The caller
    /// must either [`commit_route`](Self::commit_route) the decision once
    /// the pair accepts, or release `charged_tokens` via
    /// [`on_completed`](Self::on_completed) if the pair turns it away.
    /// `None` when no active model-compatible pair exists (all failed or
    /// all mismatched): shed the request, nothing was charged.
    pub fn route(&mut self, req: &Request) -> Option<RouteDecision> {
        self.route_impl(req, None)
    }

    /// Route among the pairs whose estimated TTFT meets `slo_ttft_s`, so
    /// an admission decision ("some pair can serve this in time") is
    /// honoured by the dispatch itself, whatever the base policy.  Under
    /// KV affinity the resident pair wins only while it is SLO-feasible —
    /// otherwise a priced KV migration may carry the credit elsewhere.
    /// `None` as for [`route`](Self::route).
    pub fn route_within_slo(
        &mut self,
        req: &Request,
        slo_ttft_s: f64,
    ) -> Option<RouteDecision> {
        self.route_impl(req, Some(slo_ttft_s))
    }

    /// The pair accepted the routed request: record KV-hit metrics and,
    /// under the affinity policy, pin the session's post-turn context KV
    /// on the chosen pair (evicting least-recently-used sessions when the
    /// pair's residency budget overflows).
    pub fn commit_route(&mut self, req: &Request, decision: &RouteDecision) {
        let p = &mut self.pairs[decision.pair];
        p.n_streams += 1;
        p.ctx_sum += req.total_context() as u64;
        if let Some(ci) = self.class_inflight.get_mut(decision.pair) {
            let c = (req.class.0 as usize).min(ci.len() - 1);
            ci[c] += 1;
        }
        if req.session_id == NO_SESSION {
            return;
        }
        if req.prefix_len > 0 {
            self.n_prefix_routed += 1;
        }
        if decision.kv_credit > 0 {
            self.n_kv_hits += 1;
            self.prefill_tokens_saved += decision.kv_credit as u64;
        }
        if let Some(x) = decision.transfer {
            // A residual-delay transfer (`from == pair`) re-surfaces a
            // drain handoff already counted when the prefix started
            // moving; only a fresh cross-pair shipment counts here.
            if x.from != decision.pair {
                self.n_migrations += 1;
                self.migrated_tokens += x.tokens;
                self.migration_time_s += x.delay_ns as f64 * 1e-9;
            }
        }
        if self.policy == RoutePolicy::KvAffinity {
            self.note_residency(decision.pair, req);
        }
    }

    /// Pin `req`'s session KV (its full post-turn context) on `pair`.
    fn note_residency(&mut self, pair: usize, req: &Request) {
        self.use_seq += 1;
        if let Some(old) = self.residency.remove(&req.session_id) {
            self.pairs[old.pair].resident_tokens =
                self.pairs[old.pair].resident_tokens.saturating_sub(old.tokens);
            self.pairs[old.pair].lru.remove(&(old.last_use, req.session_id));
        }
        if !self.pairs[pair].supports_credit {
            // A PP pair re-prefills every prompt: pinning the session
            // there would make affinity stick follow-ups to it (skewing
            // load) without ever saving a token.  The stale residency on
            // the previous pair was still dropped above.
            return;
        }
        let tokens = (req.input_len + req.output_len) as u64;
        if tokens > self.pairs[pair].residency_capacity_tokens {
            return; // context too large to keep warm at all
        }
        while self.pairs[pair].resident_tokens + tokens
            > self.pairs[pair].residency_capacity_tokens
        {
            // Evict the least-recently-used session resident on this
            // pair: the first entry of the pair's ordered
            // `(last_use, session)` tree — O(log S) instead of the old
            // full residency-map scan.  `last_use` values are unique, so
            // the victim is exactly the scan's min and the eviction
            // order is deterministic.
            match self.pairs[pair].lru.pop_first() {
                Some((_, id)) => {
                    let r = self.residency.remove(&id).expect("victim exists");
                    self.pairs[pair].resident_tokens =
                        self.pairs[pair].resident_tokens.saturating_sub(r.tokens);
                }
                None => break,
            }
        }
        self.pairs[pair].resident_tokens += tokens;
        self.pairs[pair].lru.insert((self.use_seq, req.session_id));
        self.residency.insert(
            req.session_id,
            Residency { pair, tokens, last_use: self.use_seq, ready_at: 0 },
        );
    }

    /// A request previously routed to `pair` left the system (finished
    /// or shed): release its charged `tokens` from the live backlog.
    pub fn on_completed(&mut self, pair: usize, tokens: u64) {
        let p = &mut self.pairs[pair];
        p.outstanding_tokens = (p.outstanding_tokens - tokens as f64).max(0.0);
        if p.active {
            self.load_index.set(pair, self.pairs[pair].outstanding_tokens);
        }
    }

    /// A committed request of `class` with full context `ctx` left
    /// `pair` (finished or shed in flight): retire its decode stream
    /// from the TBT estimator's view.  The counterpart of the stream
    /// tracking [`commit_route`](Self::commit_route) does; callers that
    /// never use TBT admission may skip it (the counters are then
    /// advisory only).
    pub fn on_stream_completed(&mut self, pair: usize, class: ClassId, ctx: u64) {
        let p = &mut self.pairs[pair];
        p.n_streams = p.n_streams.saturating_sub(1);
        p.ctx_sum = p.ctx_sum.saturating_sub(ctx);
        if let Some(ci) = self.class_inflight.get_mut(pair) {
            let c = (class.0 as usize).min(ci.len() - 1);
            ci[c] = ci[c].saturating_sub(1);
        }
    }

    /// Estimated decode iteration time (≈ inter-token gap) on `pair`
    /// right now, from its committed stream count and context sum
    /// priced through the pair's decode-side `PerfModel`.  0 when
    /// nothing is in flight.
    pub fn estimated_tbt_s(&self, pair: usize) -> f64 {
        let p = &self.pairs[pair];
        if p.n_streams == 0 {
            return 0.0;
        }
        p.decode_pm.iteration_time(&IterationShape {
            prefill: Vec::new(),
            n_decode: p.n_streams as usize,
            decode_ctx_sum: p.ctx_sum as usize,
        })
    }

    /// How much admitting `req` onto `pair` would stretch the pair's
    /// decode iteration: one more stream in the batch, plus the
    /// request's full context in the batch's KV reads.  This is the
    /// TBT inflation every in-flight request on the pair would suffer.
    pub fn estimated_tbt_inflation(&self, pair: usize, req: &Request) -> f64 {
        (self.projected_tbt_s(pair, req) - self.estimated_tbt_s(pair)).max(0.0)
    }

    /// Decode iteration time on `pair` *with* `req` added to the batch.
    fn projected_tbt_s(&self, pair: usize, req: &Request) -> f64 {
        let p = &self.pairs[pair];
        p.decode_pm.iteration_time(&IterationShape {
            prefill: Vec::new(),
            n_decode: p.n_streams as usize + 1,
            decode_ctx_sum: p.ctx_sum as usize + req.total_context(),
        })
    }

    /// TBT-aware admission: defer `req` (returning a retry hint) when
    /// on every compatible active pair, adding its decode stream would
    /// push the pair's projected iteration time past the strictest
    /// TBT-P99 SLO among the classes already in flight there.  `None`
    /// admits: some pair has TBT headroom (or hosts no TBT-constrained
    /// incumbents), or no class declares a TBT SLO at all.
    pub fn tbt_admission(&self, now: SimTime, req: &Request) -> Option<SimTime> {
        let reg = self.classes.as_ref()?;
        if !reg.any_tbt_slo() {
            return None;
        }
        let need = self.required_model(req);
        let mut saw_pair = false;
        for (i, p) in self.pairs.iter().enumerate() {
            if !p.active || !self.pair_serves(i, need) {
                continue;
            }
            saw_pair = true;
            let strictest = self.class_inflight[i]
                .iter()
                .enumerate()
                .filter(|&(_, &n)| n > 0)
                .filter_map(|(c, _)| reg.get(ClassId(c as u16)).slo_tbt_p99_s)
                .fold(f64::INFINITY, f64::min);
            if !strictest.is_finite() {
                // No TBT-constrained incumbent on this pair: admit.
                return None;
            }
            if self.projected_tbt_s(i, req) <= strictest {
                return None; // headroom holds on this pair
            }
        }
        if saw_pair {
            Some(now.after_secs(TBT_RETRY_S))
        } else {
            None // nothing to protect; the model-compat shed handles it
        }
    }

    /// Best (largest) TTFT-SLO headroom any active pair offers a
    /// reference [`HEADROOM_PROBE_TOKENS`]-token prompt right now —
    /// the fleet controller's beyond-backlog scale-up signal.  `None`
    /// when no pair is active.
    pub fn best_ttft_headroom(&self, slo_ttft_s: f64) -> Option<f64> {
        self.pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.active)
            .map(|(i, _)| slo_ttft_s - self.estimated_ttft(i, HEADROOM_PROBE_TOKENS))
            .fold(None, |acc: Option<f64>, h| {
                Some(acc.map_or(h, |a: f64| a.max(h)))
            })
    }

    /// A session ended (its final turn completed, or a turn was shed and
    /// the conversation aborted): drop its prefix residency so the KV
    /// budget goes back to live sessions.
    ///
    /// A conversation abandoned *between* turns (e.g. the closed-loop
    /// driver dropping a deferred turn at its retry cap, or a user who
    /// simply leaves) never produces a terminal event the cluster could
    /// translate into this call — the router cannot distinguish a
    /// thinking user from a departed one.  Such residency ages out via
    /// the per-pair LRU eviction instead, exactly like an idle entry in
    /// a real KV cache.
    pub fn release_session(&mut self, session_id: u64) {
        if let Some(r) = self.residency.remove(&session_id) {
            self.pairs[r.pair].resident_tokens =
                self.pairs[r.pair].resident_tokens.saturating_sub(r.tokens);
            self.pairs[r.pair].lru.remove(&(r.last_use, session_id));
        }
    }

    /// Pair currently holding `session_id`'s prefix KV, if any.
    pub fn session_residency(&self, session_id: u64) -> Option<usize> {
        self.residency.get(&session_id).map(|r| r.pair)
    }

    /// Sessions currently resident across the cluster.
    pub fn resident_sessions(&self) -> usize {
        self.residency.len()
    }

    /// Resident session-KV tokens per pair.
    pub fn resident_tokens(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.resident_tokens).collect()
    }

    /// Override pair `i`'s residency budget (tokens) — for tests and for
    /// operators tuning how much CPI KV may be pinned by warm sessions.
    pub fn set_residency_capacity_tokens(&mut self, i: usize, tokens: u64) {
        self.pairs[i].residency_capacity_tokens = tokens;
    }

    /// Follow-up turns routed to their resident pair.
    pub fn kv_hits(&self) -> u64 {
        self.n_kv_hits
    }

    /// Prefill tokens skipped by KV hits.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.prefill_tokens_saved
    }

    /// Follow-up turns (non-empty prefix) committed, hit or miss — the
    /// denominator of the cluster's `kv_hit_rate`.
    pub fn n_prefix_routed(&self) -> u64 {
        self.n_prefix_routed
    }

    /// Submit-time SLO admission control: may this request be admitted
    /// under a TTFT target of `slo_ttft_s` seconds?
    ///
    /// * `Accepted` — some pair's prefix-credit-aware estimate
    ///   ([`estimated_ttft_for`](Self::estimated_ttft_for)) meets the
    ///   target;
    /// * `Rejected` — no pair could meet the target even with an empty
    ///   backlog (the prompt is inherently too slow for the SLO);
    /// * `Deferred` — transient overload: retry once the least-loaded
    ///   candidate's backlog should have drained below the SLO headroom.
    ///
    /// A follow-up turn is judged on the prefill each pair would
    /// actually run: on the resident pair only the fresh suffix counts,
    /// so long conversations stop being over-rejected once their prefix
    /// KV is warm.
    pub fn slo_admission(
        &self,
        now: SimTime,
        req: &Request,
        slo_ttft_s: f64,
    ) -> Admission {
        let need = self.required_model(req);
        let mut saw_compatible = false;
        let mut best_idle = f64::INFINITY;
        // Best pair *among those that could meet the SLO when idle* —
        // an infeasible pair must not drive the retry hint, or a
        // transiently loaded feasible pair would be retried on a
        // meaningless (near-zero) backlog estimate and dropped.
        let mut best_feasible: Option<(usize, f64)> = None;
        for (i, p) in self.pairs.iter().enumerate() {
            if !p.active || !self.pair_serves(i, need) {
                continue;
            }
            saw_compatible = true;
            let eff_len = req.input_len - self.resident_credit(i, req);
            let idle = p.prefill.predict(eff_len);
            best_idle = best_idle.min(idle);
            // In-flight migrated KV delays the credited prefill start.
            let est = self.estimated_ttft(i, eff_len)
                + self.residual_ready_delay_ns(i, req) as f64 * 1e-9;
            if est <= slo_ttft_s {
                return Admission::Accepted;
            }
            if idle <= slo_ttft_s
                && best_feasible.map_or(true, |(_, b)| est < b)
            {
                best_feasible = Some((i, est));
            }
        }
        if !saw_compatible {
            if let Some(m) = need {
                return Admission::Rejected {
                    reason: format!("no active pair serves model '{}'", m.name),
                };
            }
        }
        if best_idle > slo_ttft_s {
            return Admission::Rejected {
                reason: format!(
                    "prefill alone needs {best_idle:.3}s > TTFT SLO {slo_ttft_s:.3}s \
                     on every pair"
                ),
            };
        }
        // Wait until the best feasible candidate's backlog fits the SLO
        // headroom (the Option is Some here: best_idle <= slo).
        let (best_pair, _) = best_feasible.expect("feasible pair exists");
        let p = &self.pairs[best_pair];
        let eff_len = req.input_len - self.resident_credit(best_pair, req);
        let headroom_tokens = (slo_ttft_s - p.prefill.predict(eff_len)).max(0.0)
            * p.effective_drain_tps();
        let excess = (p.outstanding_tokens - headroom_tokens).max(0.0);
        let wait_s = (excess / p.effective_drain_tps()).max(1e-3);
        Admission::Deferred { retry_at: now.after_secs(wait_s) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::topology::{ClusterConfig, PairConfig};
    use crate::config::{DeploymentConfig, SystemKind};
    use crate::qos::ServiceClass;
    use crate::simgpu::model_desc::{LLAMA3_8B, QWEN2_7B};
    use crate::simgpu::spec::{A10, A100, A30, T4};
    use crate::workload::arrival::{stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Request> {
        let t = generate(n, &AzureTraceConfig::default(), seed);
        stamp(&t, ArrivalProcess::AllAtOnce)
    }

    fn route_all(router: &mut Router, trace: &[Request]) -> Vec<usize> {
        trace.iter().map(|r| router.route(r).expect("routable").pair).collect()
    }

    /// Turn `k` of session `sid`: `prefix` replayed tokens + fresh tail.
    fn session_req(sid: u64, prefix: usize, fresh: usize, output: usize) -> Request {
        Request {
            id: sid * 1000 + prefix as u64,
            arrival_ns: 0,
            input_len: prefix + fresh,
            output_len: output,
            session_id: sid,
            prefix_len: prefix,
            kv_credit: 0,
            final_turn: false,
            class: ClassId::default(),
        }
    }

    #[test]
    fn round_robin_is_fair_with_equal_shares() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        route_all(&mut router, &trace(100, 1));
        assert_eq!(router.routed_counts(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn weighted_round_robin_respects_shares() {
        let mut cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        cfg.pairs[0].rate_share = 3.0;
        cfg.pairs[1].rate_share = 1.0;
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        route_all(&mut router, &trace(200, 2));
        assert_eq!(router.routed_counts(), vec![150, 50]);
    }

    #[test]
    fn least_outstanding_always_picks_current_min() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        for r in &trace(150, 3) {
            let before = router.outstanding_tokens();
            let min = before.iter().cloned().fold(f64::INFINITY, f64::min);
            let idx = router.route(r).expect("routable").pair;
            assert!(
                before[idx] <= min + 1e-9,
                "routed to {idx} with backlog {} > min {min}",
                before[idx]
            );
        }
    }

    #[test]
    fn least_outstanding_balances_tokens() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        route_all(&mut router, &trace(400, 4));
        let tokens = router.routed_tokens();
        let max = *tokens.iter().max().unwrap() as f64;
        let min = *tokens.iter().min().unwrap() as f64;
        assert!(min > 0.85 * max, "token imbalance under LOT: {tokens:?}");
    }

    #[test]
    fn slo_aware_prefers_the_faster_prefill_pair() {
        let slow = PairConfig::cronus(DeploymentConfig::paper(A100, T4, LLAMA3_8B));
        let fast = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![slow, fast]);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let t = trace(1, 5);
        assert_eq!(router.route(&t[0]).expect("routable").pair, 1, "idle cluster: fastest prefill wins");
        // Under sustained all-at-once load the faster pair absorbs more.
        route_all(&mut router, &trace(199, 5));
        let counts = router.routed_counts();
        assert!(counts[1] > counts[0], "slo-aware counts {counts:?}");
    }

    #[test]
    fn completions_release_live_backlog() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let t = trace(1, 6);
        let d = router.route(&t[0]).expect("routable");
        let pair = d.pair;
        let load = (t[0].input_len + t[0].output_len) as u64;
        assert_eq!(d.charged_tokens, load, "no credit: full load charged");
        assert!(router.outstanding_tokens()[pair] > 0.0);
        router.on_completed(pair, load);
        assert_eq!(router.outstanding_tokens()[pair], 0.0);
        // Over-release clamps at zero instead of going negative.
        router.on_completed(pair, load);
        assert_eq!(router.outstanding_tokens()[pair], 0.0);
    }

    #[test]
    fn rate_share_scales_the_slo_estimator() {
        // Two physically identical pairs; pair 0 is given 3x the share.
        // With equal backlogs its estimated TTFT must be lower, so the
        // SLO-aware policy sends it the bulk of a burst.
        let mut cfg = ClusterConfig::homogeneous(
            2,
            DeploymentConfig::paper(A100, A10, LLAMA3_8B),
        );
        cfg.pairs[0].rate_share = 3.0;
        cfg.pairs[1].rate_share = 1.0;
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        route_all(&mut router, &trace(100, 13));
        let tokens = router.routed_tokens();
        assert!(
            tokens[0] > 2 * tokens[1],
            "high-share pair should absorb most load: {tokens:?}"
        );
    }

    #[test]
    fn route_within_slo_skips_infeasible_pairs() {
        // Pair 0 (T4) is listed first and wins the LOT tie on an empty
        // cluster, but its estimated TTFT blows the SLO; the
        // SLO-constrained route must pick the A30 pair instead so the
        // admission decision is honoured by the dispatch.
        let slow = PairConfig::cronus(DeploymentConfig::paper(A100, T4, LLAMA3_8B));
        let fast = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![slow, fast]);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let req = trace(1, 15)[0];
        let slow_est = router.estimated_ttft(0, req.input_len);
        let fast_est = router.estimated_ttft(1, req.input_len);
        assert!(fast_est < slow_est);
        let slo = (fast_est + slow_est) / 2.0; // feasible only on pair 1
        assert_eq!(router.route_within_slo(&req, slo).expect("routable").pair, 1);
        // With an SLO nobody meets, it falls back to the plain pick.
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        assert_eq!(router.route_within_slo(&req, 0.0).expect("routable").pair, 0);
    }

    #[test]
    fn slo_admission_accepts_defers_and_rejects() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let now = SimTime::ZERO;
        // Idle cluster, generous SLO: accepted.
        assert_eq!(
            router.slo_admission(now, &Request::new(0, 0, 1000, 64), 10.0),
            Admission::Accepted
        );
        // An SLO below the idle prefill time of every pair: rejected.
        assert!(matches!(
            router.slo_admission(now, &Request::new(0, 0, 8000, 64), 1e-6),
            Admission::Rejected { .. }
        ));
        // Pile on load until the estimate blows the SLO, then expect a
        // deferral with a strictly future retry hint.
        let slo = router.estimated_ttft(0, 1000) + 0.05;
        for r in &trace(400, 14) {
            let _ = router.route(r);
        }
        match router.slo_admission(now, &Request::new(0, 0, 1000, 64), slo) {
            Admission::Deferred { retry_at } => assert!(retry_at > now),
            other => panic!("expected Deferred, got {other:?}"),
        }
    }

    #[test]
    fn single_pair_routes_everything_to_it() {
        let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let cfg = ClusterConfig::homogeneous(1, deployment);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            let a = route_all(&mut router, &trace(20, 7));
            assert!(a.iter().all(|&i| i == 0), "{}", policy.name());
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let cfg = ClusterConfig::mixed(5, LLAMA3_8B);
        let t = trace(120, 8);
        for policy in RoutePolicy::ALL {
            let a = route_all(&mut Router::new(policy, &cfg), &t);
            let b = route_all(&mut Router::new(policy, &cfg), &t);
            assert_eq!(a, b, "{}", policy.name());
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        for policy in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::from_name(policy.name()), Some(policy));
        }
        assert_eq!(
            RoutePolicy::from_name("LOT"),
            Some(RoutePolicy::LeastOutstandingTokens)
        );
        assert_eq!(RoutePolicy::from_name("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::from_name("kv"), Some(RoutePolicy::KvAffinity));
        assert_eq!(
            RoutePolicy::from_name("KV-Affinity"),
            Some(RoutePolicy::KvAffinity)
        );
        assert!(RoutePolicy::from_name("random").is_none());
    }

    // --- KV-affinity ---

    #[test]
    fn affinity_routes_follow_up_to_resident_pair_with_credit() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        // Turn 0 (no prefix): load-based pick, then commit pins residency.
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        assert_eq!(d0.kv_credit, 0);
        router.commit_route(&t0, &d0);
        assert_eq!(router.session_residency(1), Some(d0.pair));
        assert_eq!(router.resident_tokens()[d0.pair], 900);
        // Turn 1 replays the 900-token context: same pair, full credit.
        let t1 = session_req(1, 900, 300, 80);
        let d1 = router.route(&t1).expect("routable");
        assert_eq!(d1.pair, d0.pair, "follow-up must stick to the resident pair");
        assert_eq!(d1.kv_credit, 900);
        // Backlog is charged for the fresh work only.
        assert_eq!(d1.charged_tokens, (300 + 80) as u64);
        router.commit_route(&t1, &d1);
        assert_eq!(router.kv_hits(), 1);
        assert_eq!(router.prefill_tokens_saved(), 900);
        assert_eq!(router.n_prefix_routed(), 1);
        // A different session starts fresh: no credit.
        let other = session_req(2, 0, 500, 50);
        assert_eq!(router.route(&other).expect("routable").kv_credit, 0);
    }

    #[test]
    fn non_affinity_policies_never_grant_credit() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastOutstandingTokens,
            RoutePolicy::SloAware,
        ] {
            let mut router = Router::new(policy, &cfg);
            let t0 = session_req(1, 0, 800, 100);
            let d0 = router.route(&t0).expect("routable");
            router.commit_route(&t0, &d0);
            let t1 = session_req(1, 900, 300, 80);
            let d1 = router.route(&t1).expect("routable");
            assert_eq!(d1.kv_credit, 0, "{}", policy.name());
            router.commit_route(&t1, &d1);
            assert_eq!(router.kv_hits(), 0, "{}", policy.name());
            assert_eq!(router.n_prefix_routed(), 1, "{}", policy.name());
        }
    }

    #[test]
    fn residency_capacity_evicts_least_recently_used() {
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        // Budget fits two ~1000-token sessions, not three.
        router.set_residency_capacity_tokens(0, 2500);
        for sid in 1..=3u64 {
            let t = session_req(sid, 0, 900, 100);
            let d = router.route(&t).expect("routable");
            router.commit_route(&t, &d);
        }
        // Session 1 (least recently used) was evicted to fit session 3.
        assert_eq!(router.session_residency(1), None);
        assert_eq!(router.session_residency(2), Some(0));
        assert_eq!(router.session_residency(3), Some(0));
        assert_eq!(router.resident_sessions(), 2);
        assert_eq!(router.resident_tokens()[0], 2000);
        // An evicted session's follow-up is a miss: no credit.
        let t1 = session_req(1, 1000, 200, 50);
        assert_eq!(router.route(&t1).expect("routable").kv_credit, 0);
        // A context bigger than the whole budget is never pinned.
        let huge = session_req(9, 0, 4000, 100);
        let d = router.route(&huge).expect("routable");
        router.commit_route(&huge, &d);
        assert_eq!(router.session_residency(9), None);
    }

    #[test]
    fn affinity_falls_back_when_resident_pair_blows_the_slo() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        let resident = d0.pair;
        // Bury the resident pair in backlog: affinity keeps routing the
        // session's turns there, and none complete.
        for _ in 0..150 {
            let t = session_req(1, 900, 2000, 100);
            let d = router.route(&t).expect("routable");
            assert_eq!(d.pair, resident);
            router.commit_route(&t, &d);
        }
        let t1 = session_req(1, 900, 300, 80);
        let slo = router.estimated_ttft(1 - resident, t1.input_len) + 0.1;
        assert!(
            router.estimated_ttft_for(resident, &t1) > slo,
            "resident pair must be infeasible for this test"
        );
        let d1 = router.route_within_slo(&t1, slo).expect("routable");
        assert_eq!(d1.pair, 1 - resident, "SLO-infeasible resident pair skipped");
        assert_eq!(d1.kv_credit, 0, "fallback pair holds no prefix KV");
    }

    #[test]
    fn pp_pairs_now_support_residency_and_credit() {
        // PP prefix-credit satellite: the staged pipeline now honours
        // `kv_credit` like DP, so affinity may pin sessions on PP pairs
        // and grant them credit like any Cronus pair.
        let mut pp = PairConfig::cronus(DeploymentConfig::paper(A100, A10, LLAMA3_8B));
        pp.system = SystemKind::PpChunked;
        let cronus = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![pp, cronus]);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        assert_eq!(d0.pair, 0, "empty PP pair wins the LOT tie");
        router.commit_route(&t0, &d0);
        assert_eq!(router.session_residency(1), Some(0));
        let t1 = session_req(1, 900, 300, 80);
        let d1 = router.route(&t1).expect("routable");
        assert_eq!(d1.pair, 0, "follow-up sticks to the resident PP pair");
        assert_eq!(d1.kv_credit, 900);
        assert_eq!(d1.charged_tokens, 380);
        router.commit_route(&t1, &d1);
        assert_eq!(router.kv_hits(), 1);
        assert_eq!(router.prefill_tokens_saved(), 900);
    }

    #[test]
    fn dp_pairs_now_support_residency_and_credit() {
        // ROADMAP DP prefix-credit item: the DP dispatcher honours
        // `kv_credit`, so affinity may pin sessions on DP pairs and
        // grant them credit like any Cronus pair.
        let mut dp = PairConfig::cronus(DeploymentConfig::paper(A100, A10, LLAMA3_8B));
        dp.system = SystemKind::DpChunked;
        let cronus = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![dp, cronus]);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        assert_eq!(d0.pair, 0, "empty DP pair wins the LOT tie");
        router.commit_route(&t0, &d0);
        assert_eq!(router.session_residency(1), Some(0));
        let t1 = session_req(1, 900, 300, 80);
        let d1 = router.route(&t1).expect("routable");
        assert_eq!(d1.pair, 0, "follow-up sticks to the resident DP pair");
        assert_eq!(d1.kv_credit, 900);
        assert_eq!(d1.charged_tokens, 380);
        router.commit_route(&t1, &d1);
        assert_eq!(router.kv_hits(), 1);
        assert_eq!(router.prefill_tokens_saved(), 900);
    }

    #[test]
    fn reset_restores_the_freshly_built_state() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        route_all(&mut router, &trace(40, 19));
        assert!(router.resident_sessions() > 0);
        router.reset();
        // Indistinguishable from a new router: same counters, empty
        // residency, zeroed (index-consistent) backlogs, same routes.
        assert_eq!(router.outstanding_tokens(), vec![0.0; 3]);
        assert_eq!(router.routed_counts(), vec![0; 3]);
        assert_eq!(router.resident_sessions(), 0);
        assert_eq!(router.resident_tokens(), vec![0; 3]);
        assert_eq!(router.kv_hits(), 0);
        assert_eq!(router.prefill_tokens_saved(), 0);
        assert_eq!(router.n_prefix_routed(), 0);
        let t = trace(30, 20);
        let replayed = route_all(&mut router, &t);
        let fresh = route_all(&mut Router::new(RoutePolicy::KvAffinity, &cfg), &t);
        assert_eq!(replayed, fresh);
    }

    #[test]
    fn load_index_matches_scan_argmin() {
        // The O(1) indexed argmin must agree with a naive scan over the
        // live backlogs after any charge/complete sequence, ties to the
        // lowest pair index (the routing hot path's determinism pin).
        let cfg = ClusterConfig::mixed(5, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let t = trace(60, 17);
        let mut charged: Vec<(usize, u64)> = Vec::new();
        for (k, r) in t.iter().enumerate() {
            let scan = {
                let loads = router.outstanding_tokens();
                let mut best = 0usize;
                for (i, &v) in loads.iter().enumerate() {
                    if v < loads[best] {
                        best = i;
                    }
                }
                best
            };
            let d = router.route(r).expect("routable");
            assert_eq!(d.pair, scan, "arrival {k}");
            charged.push((d.pair, d.charged_tokens));
            // Release a few in-flight requests along the way so the
            // index sees decreases (and the zero clamp) too.
            if k % 3 == 2 {
                let (pair, tokens) = charged.remove(0);
                router.on_completed(pair, tokens);
            }
        }
        for (pair, tokens) in charged {
            router.on_completed(pair, tokens);
        }
        // Everything released: all backlogs zero, tie breaks to pair 0.
        assert_eq!(router.outstanding_tokens(), vec![0.0; 5]);
        assert_eq!(router.route(&t[0]).expect("routable").pair, 0);
    }

    #[test]
    fn release_session_frees_residency() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        assert_eq!(router.resident_sessions(), 1);
        router.release_session(1);
        assert_eq!(router.resident_sessions(), 0);
        assert_eq!(router.resident_tokens(), vec![0, 0]);
        // Releasing an unknown session is a no-op.
        router.release_session(99);
        assert_eq!(router.resident_sessions(), 0);
    }

    #[test]
    fn lru_eviction_matches_reference_scan() {
        // Satellite pin: the per-pair (last_use → session) tree must
        // evict exactly the session the old O(S) residency-map scan
        // chose, at every step of a randomized commit sequence.
        use crate::util::rng::Rng;
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let caps = [3000u64, 2000u64];
        router.set_residency_capacity_tokens(0, caps[0]);
        router.set_residency_capacity_tokens(1, caps[1]);
        // Reference model replicating the pre-index scan eviction:
        // (session, pair, tokens, last_use), victim = min last_use on
        // the overflowing pair.
        let mut model: Vec<(u64, usize, u64, u64)> = Vec::new();
        let mut use_seq = 0u64;
        let mut rng = Rng::new(0xD1CE);
        for step in 0..400 {
            let sid = rng.range(1, 13);
            // Every 25th context is too large to keep warm on either
            // pair, exercising the "drop old entry, insert nothing" path.
            let fresh =
                if step % 25 == 24 { 4000 } else { rng.range_usize(100, 1500) };
            let output = rng.range_usize(40, 160);
            let req = session_req(sid, 0, fresh, output);
            let d = router.route(&req).expect("routable");
            router.commit_route(&req, &d);
            // Mirror note_residency with the old scan semantics.
            use_seq += 1;
            model.retain(|&(s, _, _, _)| s != sid);
            let tokens = (req.input_len + req.output_len) as u64;
            if tokens <= caps[d.pair] {
                let used = |m: &Vec<(u64, usize, u64, u64)>| -> u64 {
                    m.iter().filter(|e| e.1 == d.pair).map(|e| e.2).sum()
                };
                while used(&model) + tokens > caps[d.pair] {
                    let victim = model
                        .iter()
                        .filter(|e| e.1 == d.pair)
                        .min_by_key(|e| e.3)
                        .map(|e| e.0)
                        .expect("an entry must exist to overflow");
                    model.retain(|&(s, _, _, _)| s != victim);
                }
                model.push((sid, d.pair, tokens, use_seq));
            }
            // The router must agree with the reference at every step.
            assert_eq!(router.resident_sessions(), model.len(), "step {step}");
            for &(s, p, _, _) in &model {
                assert_eq!(router.session_residency(s), Some(p), "step {step}");
            }
            let want: [u64; 2] = [0, 1].map(|p| {
                model.iter().filter(|e| e.1 == p).map(|e| e.2).sum::<u64>()
            });
            assert_eq!(router.resident_tokens(), want.to_vec(), "step {step}");
        }
    }

    // --- elastic fleet: pair activation / drain ---

    #[test]
    fn inactive_pairs_are_skipped_by_every_policy() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            router.set_pair_active(0, false);
            assert!(!router.is_pair_active(0));
            assert_eq!(router.n_active_pairs(), 2);
            for r in &trace(60, 21) {
                assert_ne!(router.route(r).expect("routable").pair, 0, "{}", policy.name());
            }
            // Reactivation puts the pair back into rotation.
            router.set_pair_active(0, true);
            let routed = route_all(&mut router, &trace(60, 22));
            assert!(routed.contains(&0), "{}", policy.name());
        }
    }

    #[test]
    fn draining_pair_completions_do_not_resurrect_it() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let t = trace(10, 23);
        let decisions: Vec<RouteDecision> = t.iter().map(|r| router.route(r).expect("routable")).collect();
        router.set_pair_active(0, false);
        for d in &decisions {
            if d.pair == 0 {
                router.on_completed(0, d.charged_tokens);
            }
        }
        // Pair 0 drained to an empty backlog, but it is inactive: every
        // new arrival still goes to pair 1.
        assert_eq!(router.outstanding_tokens()[0], 0.0);
        for r in &trace(20, 24) {
            assert_eq!(router.route(r).expect("routable").pair, 1);
        }
    }

    #[test]
    fn affinity_does_not_stick_to_an_inactive_resident_pair() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        router.set_pair_active(d0.pair, false);
        let t1 = session_req(1, 900, 300, 80);
        let d1 = router.route(&t1).expect("routable");
        assert_ne!(d1.pair, d0.pair, "follow-up must leave the draining pair");
        assert_eq!(d1.kv_credit, 0, "the other pair holds no prefix KV");
    }

    #[test]
    fn retiring_a_pair_evicts_its_residency() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        let t1 = session_req(2, 0, 700, 90);
        let d1 = router.route(&t1).expect("routable");
        router.commit_route(&t1, &d1);
        assert_ne!(d0.pair, d1.pair, "LOT spreads the two sessions");
        assert_eq!(router.resident_sessions(), 2);
        assert_eq!(router.evict_pair_residency(d0.pair), 1);
        assert_eq!(router.session_residency(1), None);
        assert_eq!(router.session_residency(2), Some(d1.pair));
        assert_eq!(router.resident_tokens()[d0.pair], 0);
    }

    #[test]
    fn slo_admission_ignores_inactive_pairs() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let slo = router.estimated_ttft(0, 1000).max(router.estimated_ttft(1, 1000)) + 0.05;
        router.set_pair_active(1, false);
        // Bury the only active pair.
        for r in &trace(400, 25) {
            let _ = router.route(r);
        }
        let req = Request::new(0, 0, 1000, 64);
        // An idle pair 1 would accept, but it is inactive: deferred.
        assert!(matches!(
            router.slo_admission(SimTime::ZERO, &req, slo),
            Admission::Deferred { .. }
        ));
        router.set_pair_active(1, true);
        assert_eq!(router.slo_admission(SimTime::ZERO, &req, slo), Admission::Accepted);
    }

    #[test]
    fn estimated_ttft_accounts_for_resident_prefix() {
        // Regression (tentpole satellite): the SLO admission path used to
        // assume a full-prompt prefill for every request, over-rejecting
        // follow-up turns whose prefix KV is already resident.
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 500, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        router.on_completed(d0.pair, d0.charged_tokens);
        // Follow-up: 600 resident + 400 fresh.  Pick an SLO between the
        // fresh-only and full-prompt idle prefill times.
        let t1 = session_req(1, 600, 400, 50);
        let full = router.estimated_ttft(0, t1.input_len);
        let fresh = router.estimated_ttft(0, t1.input_len - 600);
        assert!(fresh < full);
        let slo = (fresh + full) / 2.0;
        assert!(
            router.estimated_ttft_for(0, &t1) <= slo,
            "credit-aware estimate must see only the fresh suffix"
        );
        // Old behaviour (full-prompt estimate) would have rejected: the
        // idle full-prompt prefill already exceeds the SLO.
        assert_eq!(router.slo_admission(SimTime::ZERO, &t1, slo), Admission::Accepted);
        // A sessionless request of the same length is still rejected.
        let cold = Request::new(7, 0, t1.input_len, 50);
        assert!(matches!(
            router.slo_admission(SimTime::ZERO, &cold, slo),
            Admission::Rejected { .. }
        ));
    }

    // --- QoS: model-aware routing + TBT-aware admission ---

    #[test]
    fn model_constrained_requests_only_land_on_compatible_pairs() {
        let llama = PairConfig::cronus(DeploymentConfig::paper(A100, A10, LLAMA3_8B));
        let qwen = PairConfig::cronus(DeploymentConfig::paper(A100, A30, QWEN2_7B));
        let cfg = ClusterConfig::new(vec![llama, qwen]);
        let mut reg = ClassRegistry::new();
        let mut sc = ServiceClass::named("qwen-tenant");
        sc.model = Some(QWEN2_7B);
        let qwen_class = reg.register(sc);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            router.set_class_registry(reg.clone());
            assert_eq!(router.pair_model(0).name, LLAMA3_8B.name);
            assert_eq!(router.pair_model(1).name, QWEN2_7B.name);
            for r in &trace(40, 33) {
                let pinned = r.with_class(qwen_class);
                let d = router.route(&pinned).expect("routable");
                assert_eq!(d.pair, 1, "{}", policy.name());
                router.commit_route(&pinned, &d);
            }
            // Unconstrained traffic still uses the (less loaded) llama pair.
            let routed = route_all(&mut router, &trace(40, 34));
            assert!(routed.contains(&0), "{}", policy.name());
            // Compatibility probe drives the cluster's model shed.
            let probe = Request::new(9_999, 0, 300, 40).with_class(qwen_class);
            assert!(router.has_active_compatible_pair(&probe));
            router.set_pair_active(1, false);
            assert!(!router.has_active_compatible_pair(&probe));
            assert!(router.has_active_compatible_pair(&Request::new(9_998, 0, 300, 40)));
            match router.slo_admission(SimTime::ZERO, &probe, 10.0) {
                Admission::Rejected { reason } => {
                    assert!(reason.contains(QWEN2_7B.name), "{reason}")
                }
                other => panic!("expected model-shed rejection, got {other:?}"),
            }
        }
    }

    #[test]
    fn model_constrained_affinity_never_sticks_to_an_incompatible_pair() {
        // Residency pinned while a class is unconstrained must not leak a
        // dispatch onto an incompatible pair once the class pins a model.
        let llama = PairConfig::cronus(DeploymentConfig::paper(A100, A10, LLAMA3_8B));
        let qwen = PairConfig::cronus(DeploymentConfig::paper(A100, A30, QWEN2_7B));
        let cfg = ClusterConfig::new(vec![llama, qwen]);
        let mut reg = ClassRegistry::new();
        let mut sc = ServiceClass::named("qwen-tenant");
        sc.model = Some(QWEN2_7B);
        let qwen_class = reg.register(sc);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        router.set_class_registry(reg);
        // Turn 0 (default class) pins the session on the llama pair.
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        assert_eq!(d0.pair, 0);
        router.commit_route(&t0, &d0);
        // The follow-up arrives pinned to qwen: the resident pair is a
        // miss (not a mismatch dispatch) and the route lands on pair 1.
        let t1 = session_req(1, 900, 300, 80).with_class(qwen_class);
        let d1 = router.route(&t1).expect("routable");
        assert_eq!(d1.pair, 1, "affinity must yield to the model constraint");
        assert_eq!(d1.kv_credit, 0, "the compatible pair holds no prefix KV");
    }

    #[test]
    fn tbt_admission_protects_incumbent_decode_tails() {
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let newcomer = Request::new(100, 0, 400, 60);
        // No registry: the gate is inert.
        let plain = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        assert!(plain.tbt_admission(SimTime::ZERO, &newcomer).is_none());
        // A class whose TBT SLO no loaded decode batch can meet.
        let mut reg = ClassRegistry::new();
        let mut strict = ServiceClass::named("strict");
        strict.slo_tbt_p99_s = Some(1e-9);
        let strict_id = reg.register(strict);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        router.set_class_registry(reg);
        // No constrained incumbent in flight: pass.
        assert!(router.tbt_admission(SimTime::ZERO, &newcomer).is_none());
        let inc = Request::new(1, 0, 800, 100).with_class(strict_id);
        let d = router.route(&inc).expect("routable");
        router.commit_route(&inc, &d);
        assert!(router.estimated_tbt_s(0) > 0.0);
        assert!(router.estimated_tbt_inflation(0, &newcomer) > 0.0);
        // Admitting the newcomer would blow the incumbent's TBT SLO on
        // the only pair: deferred with a forward retry hint.
        let retry = router.tbt_admission(SimTime::ZERO, &newcomer);
        assert!(retry.is_some() && retry.unwrap() > SimTime::ZERO);
        // Once the incumbent's stream retires the gate opens again.
        router.on_stream_completed(d.pair, strict_id, inc.total_context() as u64);
        assert!(router.tbt_admission(SimTime::ZERO, &newcomer).is_none());
        // A lax SLO never defers even with the incumbent in flight.
        let mut lax_reg = ClassRegistry::new();
        let mut lax = ServiceClass::named("lax");
        lax.slo_tbt_p99_s = Some(10.0);
        let lax_id = lax_reg.register(lax);
        let mut lax_router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        lax_router.set_class_registry(lax_reg);
        let inc2 = Request::new(2, 0, 800, 100).with_class(lax_id);
        let d2 = lax_router.route(&inc2).expect("routable");
        lax_router.commit_route(&inc2, &d2);
        assert!(lax_router.tbt_admission(SimTime::ZERO, &newcomer).is_none());
    }

    #[test]
    fn default_class_routing_is_byte_identical_with_registry_attached() {
        // The byte-identity pin: attaching a registry changes nothing for
        // default-class traffic, whatever other classes it declares.
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let t = trace(120, 31);
        for policy in RoutePolicy::ALL {
            let mut plain = Router::new(policy, &cfg);
            let mut qos = Router::new(policy, &cfg);
            let mut reg = ClassRegistry::new();
            reg.register(ServiceClass::named("premium"));
            qos.set_class_registry(reg);
            assert_eq!(
                route_all(&mut plain, &t),
                route_all(&mut qos, &t),
                "{}",
                policy.name()
            );
        }
    }

    #[test]
    fn best_ttft_headroom_tracks_load_and_activation() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let idle = router.best_ttft_headroom(1.0).unwrap();
        assert!(idle > 0.0, "idle pairs have headroom under a 1s SLO");
        for r in &trace(300, 35) {
            let d = router.route(r).expect("routable");
            router.commit_route(r, &d);
        }
        let loaded = router.best_ttft_headroom(1.0).unwrap();
        assert!(loaded < idle, "backlog erodes headroom");
        router.set_pair_active(0, false);
        router.set_pair_active(1, false);
        assert!(router.best_ttft_headroom(1.0).is_none());
    }

    // --- terminal-fallback mask regression + KV migration ---

    #[test]
    fn route_sheds_when_no_active_compatible_pair_exists() {
        // Satellite regression: the old terminal fallback returned
        // `load_index.argmin()` ignoring the `active` and model masks,
        // so an all-failed fleet still "routed" to pair 0.
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let req = Request::new(0, 0, 400, 60);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            router.set_pair_active(0, false);
            router.set_pair_active(1, false);
            assert_eq!(router.route(&req), None, "{}", policy.name());
            assert_eq!(
                router.route_within_slo(&req, 10.0),
                None,
                "{}",
                policy.name()
            );
            // One survivor: routing resumes, deterministically to it.
            router.set_pair_active(1, true);
            assert_eq!(
                router.route(&req).expect("routable").pair,
                1,
                "{}",
                policy.name()
            );
        }
        // All-mismatched: a class pinning a model nobody serves.
        let mut reg = ClassRegistry::new();
        let mut sc = ServiceClass::named("qwen-tenant");
        sc.model = Some(QWEN2_7B);
        let qwen_class = reg.register(sc);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        router.set_class_registry(reg);
        assert_eq!(router.route(&req.with_class(qwen_class)), None);
    }

    #[test]
    fn affinity_slo_check_agrees_with_estimated_ttft_for() {
        // Satellite: `affinity_target` used to hand-compute
        // `estimated_ttft(pair, len - credit)`; both paths are now the
        // same function, so a boundary SLO flips them together.
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        let t1 = session_req(1, 900, 300, 80);
        let est = router.estimated_ttft_for(d0.pair, &t1);
        assert_eq!(
            est,
            router.estimated_ttft(d0.pair, t1.input_len - 900),
            "single-sourced credit-aware estimate"
        );
        // Exactly at the estimate the resident pair is still feasible.
        let d = router.route_within_slo(&t1, est).expect("routable");
        assert_eq!(d.pair, d0.pair);
        assert_eq!(d.kv_credit, 900);
        // Infinitesimally below it, the affinity hit is refused (and
        // with no link configured, nothing migrates: a plain miss).
        let mut router2 = Router::new(RoutePolicy::KvAffinity, &cfg);
        let d0b = router2.route(&t0).expect("routable");
        router2.commit_route(&t0, &d0b);
        let d2 = router2.route_within_slo(&t1, est * 0.999).expect("routable");
        assert_eq!(d2.kv_credit, 0, "SLO below the credit-aware estimate");
        assert_eq!(d2.transfer, None);
    }

    #[test]
    fn slo_blown_resident_pair_migrates_the_prefix_over_the_link() {
        let link = LinkSpec::parse("1000G").unwrap();
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B).with_link(link);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        let resident = d0.pair;
        // Bury the resident pair under the session's own heavy turns.
        for _ in 0..150 {
            let t = session_req(1, 900, 2000, 100);
            let d = router.route(&t).expect("routable");
            assert_eq!(d.pair, resident);
            router.commit_route(&t, &d);
        }
        let t1 = session_req(1, 900, 300, 80);
        let slo = router.estimated_ttft(1 - resident, t1.input_len) + 0.1;
        assert!(
            router.estimated_ttft_for(resident, &t1) > slo,
            "resident pair must be infeasible for this test"
        );
        let d1 = router.route_within_slo(&t1, slo).expect("routable");
        assert_eq!(d1.pair, 1 - resident, "SLO-infeasible resident pair left");
        assert_eq!(d1.kv_credit, 900, "the prefix ships instead of recomputing");
        let x = d1.transfer.expect("a migration backs the credit");
        assert_eq!(x.from, resident);
        assert_eq!(x.tokens, 900);
        assert!(x.delay_ns > 0);
        router.commit_route(&t1, &d1);
        assert_eq!(router.n_migrations(), 1);
        assert_eq!(router.migrated_tokens(), 900);
        assert!(router.migration_time_s() > 0.0);
        // The residency followed the session to the destination.
        assert_eq!(router.session_residency(1), Some(1 - resident));
        // Migration counters reset with the rest of the router state.
        router.reset();
        assert_eq!(router.n_migrations(), 0);
        assert_eq!(router.migrated_tokens(), 0);
        assert_eq!(router.migration_time_s(), 0.0);
    }

    #[test]
    fn handoff_ships_residency_and_eviction_stays_without_a_link() {
        // Without a link, the handoff *is* the old eviction.
        let plain_cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut plain = Router::new(RoutePolicy::KvAffinity, &plain_cfg);
        let t0 = session_req(1, 0, 800, 100);
        let d0 = plain.route(&t0).expect("routable");
        plain.commit_route(&t0, &d0);
        assert_eq!(plain.handoff_pair_residency(d0.pair, SimTime::ZERO), 0);
        assert_eq!(plain.session_residency(1), None);
        assert_eq!(plain.n_migrations(), 0);

        // With a link, a draining pair ships its residency over.
        let link = LinkSpec::parse("1000G").unwrap();
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B).with_link(link);
        let mut router = Router::new(RoutePolicy::KvAffinity, &cfg);
        let d0 = router.route(&t0).expect("routable");
        router.commit_route(&t0, &d0);
        router.set_pair_active(d0.pair, false);
        let moved = router.handoff_pair_residency(d0.pair, SimTime::ZERO);
        assert_eq!(moved, 1);
        assert_eq!(router.session_residency(1), Some(1 - d0.pair));
        assert_eq!(router.resident_tokens()[d0.pair], 0);
        assert_eq!(router.resident_tokens()[1 - d0.pair], 900);
        assert_eq!(router.n_migrations(), 1);
        assert_eq!(router.migrated_tokens(), 900);
        assert!(router.migration_time_s() > 0.0);
        // A turn arriving while the KV is still on the wire carries the
        // residual delay (from == pair: not a second migration) and the
        // estimator prices the wait.
        let t_early = session_req(1, 900, 300, 80); // arrival_ns == 0
        let base = router.estimated_ttft(1 - d0.pair, 300);
        assert!(router.estimated_ttft_for(1 - d0.pair, &t_early) > base);
        let de = router.route(&t_early).expect("routable");
        assert_eq!(de.pair, 1 - d0.pair);
        assert_eq!(de.kv_credit, 900);
        let xe = de.transfer.expect("residual transfer delay");
        assert_eq!(xe.from, de.pair, "residual, not a fresh migration");
        assert!(xe.delay_ns > 0);
        // A turn arriving well after the transfer landed sees plain
        // resident credit with no delay.
        let mut t_late = session_req(1, 900, 300, 80);
        t_late.arrival_ns = 10_000_000_000;
        let dl = router.route(&t_late).expect("routable");
        assert_eq!(dl.pair, 1 - d0.pair);
        assert_eq!(dl.kv_credit, 900);
        assert_eq!(dl.transfer, None, "KV already landed: no residual");
    }
}
