//! Cluster-level request router: dispatches each arriving request to one
//! of N heterogeneous (high-end, low-end) pairs.
//!
//! The router is the cluster analogue of the paper's per-pair frontend:
//! it sees only arrival-time information (request lengths and its own
//! bookkeeping), never simulator ground truth.  Load is tracked as a
//! *virtual backlog* per pair — outstanding tokens that drain at a rate
//! estimated from the pair's [`PerfModel`]s — mirroring how production
//! routers work off stale/estimated load signals rather than perfect
//! instantaneous state.
//!
//! Three pluggable policies:
//!
//! * [`RoutePolicy::RoundRobin`] — weighted round-robin over the pairs'
//!   `rate_share`s (deficit form: route to the pair with the smallest
//!   `routed / share` ratio);
//! * [`RoutePolicy::LeastOutstandingTokens`] — route to the pair with the
//!   fewest outstanding (assigned − drained) tokens;
//! * [`RoutePolicy::SloAware`] — estimate each pair's TTFT for *this*
//!   request (queue drain time + the pair's calibrated Eq. 2 prefill
//!   predictor) and route to the minimum, so slow-prefill pairs stop
//!   attracting long prompts before their tails blow up.

use crate::config::topology::ClusterConfig;
use crate::simgpu::fit::{calibrate, PrefillCoeffs};
use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};
use crate::workload::Request;

/// Routing policy of the cluster frontend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstandingTokens,
    SloAware,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingTokens,
        RoutePolicy::SloAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstandingTokens => "least-outstanding",
            RoutePolicy::SloAware => "slo-aware",
        }
    }

    pub fn from_name(name: &str) -> Option<RoutePolicy> {
        match name
            .to_ascii_lowercase()
            .replace(['-', '_', ' '], "")
            .as_str()
        {
            "rr" | "roundrobin" => Some(RoutePolicy::RoundRobin),
            "lot" | "leastoutstanding" | "leastoutstandingtokens" => {
                Some(RoutePolicy::LeastOutstandingTokens)
            }
            "slo" | "sloaware" => Some(RoutePolicy::SloAware),
            _ => None,
        }
    }
}

/// Router-side view of one pair's load.
struct PairLoad {
    rate_share: f64,
    /// Estimated sustained service rate of the pair, tokens/second.
    drain_rate_tps: f64,
    /// The pair's calibrated Eq. 2 prefill predictor (PPI side).
    prefill: PrefillCoeffs,
    /// Virtual backlog: assigned-but-not-yet-drained tokens.
    outstanding_tokens: f64,
    n_routed: u64,
    tokens_routed: u64,
}

/// The cluster dispatcher.  Deterministic: identical construction and
/// request sequences produce identical assignments.
pub struct Router {
    policy: RoutePolicy,
    pairs: Vec<PairLoad>,
    last_ns: u64,
}

/// Coarse steady-state token throughput of a pair: the CPI running full
/// chunked-prefill batches over a typical decode population, plus half
/// the PPI's standalone prefill rate (its share of overlapped prefix
/// work).  A router-side estimate — only relative magnitudes matter.
fn estimated_token_rate(ppi: &PerfModel, cpi: &PerfModel, budget: usize) -> f64 {
    let budget = budget.max(1);
    let shape = IterationShape {
        prefill: vec![PrefillSeg { q_tokens: budget, ctx_end: budget.max(1024) }],
        n_decode: 64,
        decode_ctx_sum: 64 * 1200,
    };
    let cpi_rate = (budget + 64) as f64 / cpi.iteration_time(&shape);
    let ppi_rate = 2048.0 / ppi.prefill_time(2048);
    cpi_rate + 0.5 * ppi_rate
}

impl Router {
    /// Build a router for `cluster`, calibrating each pair's predictors
    /// the same way its Balancer does (§4.4 profiling + OLS).
    pub fn new(policy: RoutePolicy, cluster: &ClusterConfig) -> Router {
        assert!(!cluster.pairs.is_empty(), "router needs at least one pair");
        let pairs = cluster
            .pairs
            .iter()
            .map(|pair| {
                let d = &pair.deployment;
                let ppi_pm = PerfModel::new(d.low_gpu, d.model);
                let cpi_pm = PerfModel::new(d.high_gpu, d.model);
                let (prefill, _chunked) = calibrate(
                    &ppi_pm,
                    &cpi_pm,
                    d.engine.max_batched_tokens,
                    d.calibration_noise,
                    d.calibration_seed,
                );
                PairLoad {
                    rate_share: pair.rate_share,
                    drain_rate_tps: estimated_token_rate(
                        &ppi_pm,
                        &cpi_pm,
                        d.engine.max_batched_tokens,
                    ),
                    prefill,
                    outstanding_tokens: 0.0,
                    n_routed: 0,
                    tokens_routed: 0,
                }
            })
            .collect();
        Router { policy, pairs, last_ns: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Current virtual backlog per pair (exposed for tests / reporting).
    pub fn outstanding_tokens(&self) -> Vec<f64> {
        self.pairs.iter().map(|p| p.outstanding_tokens).collect()
    }

    /// Requests routed to each pair so far.
    pub fn routed_counts(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.n_routed).collect()
    }

    /// Tokens (input + output) routed to each pair so far.
    pub fn routed_tokens(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.tokens_routed).collect()
    }

    /// Estimated TTFT of `input_len` on pair `i` right now: drain the
    /// backlog, then run the prefix on the PPI (conservative — the CPI
    /// usually shares the prefill).
    pub fn estimated_ttft(&self, i: usize, input_len: usize) -> f64 {
        let p = &self.pairs[i];
        p.outstanding_tokens / p.drain_rate_tps + p.prefill.predict(input_len)
    }

    /// Age the virtual backlogs to `t_ns` (arrival times are monotone in
    /// every trace; stale timestamps are clamped).
    fn advance_to(&mut self, t_ns: u64) {
        if t_ns <= self.last_ns {
            return;
        }
        let dt = (t_ns - self.last_ns) as f64 / 1e9;
        self.last_ns = t_ns;
        for p in &mut self.pairs {
            p.outstanding_tokens = f64::max(0.0, p.outstanding_tokens - dt * p.drain_rate_tps);
        }
    }

    /// Route one request; returns the chosen pair index and records the
    /// load.  Ties break toward the lowest pair index, keeping the
    /// assignment deterministic.
    pub fn route(&mut self, req: &Request) -> usize {
        self.advance_to(req.arrival_ns);
        let score = |p: &PairLoad, i: usize| -> f64 {
            match self.policy {
                RoutePolicy::RoundRobin => p.n_routed as f64 / p.rate_share,
                RoutePolicy::LeastOutstandingTokens => p.outstanding_tokens,
                RoutePolicy::SloAware => self.estimated_ttft(i, req.input_len),
            }
        };
        let mut best = 0usize;
        let mut best_score = score(&self.pairs[0], 0);
        for (i, p) in self.pairs.iter().enumerate().skip(1) {
            let s = score(p, i);
            if s < best_score {
                best = i;
                best_score = s;
            }
        }
        let load = (req.input_len + req.output_len) as u64;
        let p = &mut self.pairs[best];
        p.outstanding_tokens += load as f64;
        p.n_routed += 1;
        p.tokens_routed += load;
        best
    }

    /// Route a whole trace (in order), returning one pair index per
    /// request.
    pub fn route_trace(&mut self, trace: &[Request]) -> Vec<usize> {
        trace.iter().map(|r| self.route(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::topology::{ClusterConfig, PairConfig};
    use crate::config::DeploymentConfig;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100, A30, T4};
    use crate::workload::arrival::{stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Request> {
        let t = generate(n, &AzureTraceConfig::default(), seed);
        stamp(&t, ArrivalProcess::AllAtOnce)
    }

    #[test]
    fn round_robin_is_fair_with_equal_shares() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        router.route_trace(&trace(100, 1));
        assert_eq!(router.routed_counts(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn weighted_round_robin_respects_shares() {
        let mut cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        cfg.pairs[0].rate_share = 3.0;
        cfg.pairs[1].rate_share = 1.0;
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        router.route_trace(&trace(200, 2));
        assert_eq!(router.routed_counts(), vec![150, 50]);
    }

    #[test]
    fn least_outstanding_always_picks_current_min() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        for r in &trace(150, 3) {
            let before = router.outstanding_tokens();
            let min = before.iter().cloned().fold(f64::INFINITY, f64::min);
            let idx = router.route(r);
            assert!(
                before[idx] <= min + 1e-9,
                "routed to {idx} with backlog {} > min {min}",
                before[idx]
            );
        }
    }

    #[test]
    fn least_outstanding_balances_tokens() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        router.route_trace(&trace(400, 4));
        let tokens = router.routed_tokens();
        let max = *tokens.iter().max().unwrap() as f64;
        let min = *tokens.iter().min().unwrap() as f64;
        assert!(min > 0.85 * max, "token imbalance under LOT: {tokens:?}");
    }

    #[test]
    fn slo_aware_prefers_the_faster_prefill_pair() {
        let slow = PairConfig::cronus(DeploymentConfig::paper(A100, T4, LLAMA3_8B));
        let fast = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![slow, fast]);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let t = trace(1, 5);
        assert_eq!(router.route(&t[0]), 1, "idle cluster: fastest prefill wins");
        // Under sustained all-at-once load the faster pair absorbs more.
        router.route_trace(&trace(199, 5));
        let counts = router.routed_counts();
        assert!(counts[1] > counts[0], "slo-aware counts {counts:?}");
    }

    #[test]
    fn backlog_drains_between_arrivals() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let mut t = trace(1, 6);
        t[0].arrival_ns = 0;
        router.route(&t[0]);
        assert!(router.outstanding_tokens()[0] > 0.0);
        // An arrival far in the future sees a fully drained cluster.
        t[0].arrival_ns = 3_600_000_000_000; // 1h
        t[0].id = 1;
        router.route(&t[0]);
        let outstanding = router.outstanding_tokens();
        assert_eq!(outstanding[1], 0.0);
    }

    #[test]
    fn single_pair_routes_everything_to_it() {
        let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let cfg = ClusterConfig::homogeneous(1, deployment);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            let a = router.route_trace(&trace(20, 7));
            assert!(a.iter().all(|&i| i == 0), "{}", policy.name());
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let cfg = ClusterConfig::mixed(5, LLAMA3_8B);
        let t = trace(120, 8);
        for policy in RoutePolicy::ALL {
            let a = Router::new(policy, &cfg).route_trace(&t);
            let b = Router::new(policy, &cfg).route_trace(&t);
            assert_eq!(a, b, "{}", policy.name());
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        for policy in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::from_name(policy.name()), Some(policy));
        }
        assert_eq!(
            RoutePolicy::from_name("LOT"),
            Some(RoutePolicy::LeastOutstandingTokens)
        );
        assert_eq!(RoutePolicy::from_name("rr"), Some(RoutePolicy::RoundRobin));
        assert!(RoutePolicy::from_name("random").is_none());
    }
}
