//! Cluster-level request router: dispatches each arriving request to one
//! of N heterogeneous (high-end, low-end) pairs.
//!
//! The router is the cluster analogue of the paper's per-pair frontend:
//! it sees only arrival-time information (request lengths and its own
//! bookkeeping), never simulator ground truth.  Load is tracked as a
//! *live backlog* per pair — tokens assigned by [`Router::route`] and
//! released by [`Router::on_completed`] when the owning
//! [`ClusterSystem`](crate::systems::cluster::ClusterSystem) observes the
//! pair's `Finished`/`Shed` events — so routing decisions react to what
//! the pairs actually served, not to a virtual drain-rate guess.
//!
//! Three pluggable policies:
//!
//! * [`RoutePolicy::RoundRobin`] — weighted round-robin over the pairs'
//!   `rate_share`s (deficit form: route to the pair with the smallest
//!   `routed / share` ratio);
//! * [`RoutePolicy::LeastOutstandingTokens`] — route to the pair with the
//!   fewest outstanding (assigned − completed) tokens;
//! * [`RoutePolicy::SloAware`] — estimate each pair's TTFT for *this*
//!   request (backlog drain time + the pair's calibrated Eq. 2 prefill
//!   predictor) and route to the minimum, so slow-prefill pairs stop
//!   attracting long prompts before their tails blow up.
//!
//! `rate_share` participates in *every* policy: besides weighting
//! round-robin, it scales each pair's assumed service capacity in the
//! TTFT estimator ([`Router::estimated_ttft`]), so an operator boosting
//! a pair's share makes its backlog appear to drain faster and the
//! SLO-aware policy sends it proportionally more load.
//!
//! [`Router::slo_admission`] is the submit-time admission-control policy
//! (ROADMAP item): given a TTFT SLO, it accepts only when some pair's
//! estimate meets the target, defers (with a retry hint) when the
//! cluster is transiently overloaded, and rejects when no pair could
//! meet the target even when idle.

use crate::config::topology::ClusterConfig;
use crate::simclock::SimTime;
use crate::simgpu::fit::{calibrate, PrefillCoeffs};
use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};
use crate::systems::Admission;
use crate::workload::Request;

/// Routing policy of the cluster frontend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstandingTokens,
    SloAware,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingTokens,
        RoutePolicy::SloAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstandingTokens => "least-outstanding",
            RoutePolicy::SloAware => "slo-aware",
        }
    }

    pub fn from_name(name: &str) -> Option<RoutePolicy> {
        match name
            .to_ascii_lowercase()
            .replace(['-', '_', ' '], "")
            .as_str()
        {
            "rr" | "roundrobin" => Some(RoutePolicy::RoundRobin),
            "lot" | "leastoutstanding" | "leastoutstandingtokens" => {
                Some(RoutePolicy::LeastOutstandingTokens)
            }
            "slo" | "sloaware" => Some(RoutePolicy::SloAware),
            _ => None,
        }
    }
}

/// Router-side view of one pair's load.
struct PairLoad {
    rate_share: f64,
    /// Estimated sustained service rate of the pair, tokens/second.
    drain_rate_tps: f64,
    /// The pair's calibrated Eq. 2 prefill predictor (PPI side).
    prefill: PrefillCoeffs,
    /// Live backlog: assigned-but-not-yet-completed tokens.
    outstanding_tokens: f64,
    n_routed: u64,
    tokens_routed: u64,
}

impl PairLoad {
    /// Service rate the estimator assumes: the physical estimate scaled
    /// by the operator's `rate_share` capacity prior.
    fn effective_drain_tps(&self) -> f64 {
        (self.drain_rate_tps * self.rate_share).max(1e-9)
    }
}

/// The cluster dispatcher.  Deterministic: identical construction and
/// request/completion sequences produce identical assignments.
pub struct Router {
    policy: RoutePolicy,
    pairs: Vec<PairLoad>,
}

/// Coarse steady-state token throughput of a pair: the CPI running full
/// chunked-prefill batches over a typical decode population, plus half
/// the PPI's standalone prefill rate (its share of overlapped prefix
/// work).  A router-side estimate — only relative magnitudes matter.
fn estimated_token_rate(ppi: &PerfModel, cpi: &PerfModel, budget: usize) -> f64 {
    let budget = budget.max(1);
    let shape = IterationShape {
        prefill: vec![PrefillSeg { q_tokens: budget, ctx_end: budget.max(1024) }],
        n_decode: 64,
        decode_ctx_sum: 64 * 1200,
    };
    let cpi_rate = (budget + 64) as f64 / cpi.iteration_time(&shape);
    let ppi_rate = 2048.0 / ppi.prefill_time(2048);
    cpi_rate + 0.5 * ppi_rate
}

impl Router {
    /// Build a router for `cluster`, calibrating each pair's predictors
    /// the same way its Balancer does (§4.4 profiling + OLS).
    pub fn new(policy: RoutePolicy, cluster: &ClusterConfig) -> Router {
        assert!(!cluster.pairs.is_empty(), "router needs at least one pair");
        let pairs = cluster
            .pairs
            .iter()
            .map(|pair| {
                let d = &pair.deployment;
                let ppi_pm = PerfModel::new(d.low_gpu, d.model);
                let cpi_pm = PerfModel::new(d.high_gpu, d.model);
                let (prefill, _chunked) = calibrate(
                    &ppi_pm,
                    &cpi_pm,
                    d.engine.max_batched_tokens,
                    d.calibration_noise,
                    d.calibration_seed,
                );
                PairLoad {
                    rate_share: pair.rate_share,
                    drain_rate_tps: estimated_token_rate(
                        &ppi_pm,
                        &cpi_pm,
                        d.engine.max_batched_tokens,
                    ),
                    prefill,
                    outstanding_tokens: 0.0,
                    n_routed: 0,
                    tokens_routed: 0,
                }
            })
            .collect();
        Router { policy, pairs }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Current live backlog per pair (exposed for tests / reporting).
    pub fn outstanding_tokens(&self) -> Vec<f64> {
        self.pairs.iter().map(|p| p.outstanding_tokens).collect()
    }

    /// Requests routed to each pair so far.
    pub fn routed_counts(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.n_routed).collect()
    }

    /// Tokens (input + output) routed to each pair so far.
    pub fn routed_tokens(&self) -> Vec<u64> {
        self.pairs.iter().map(|p| p.tokens_routed).collect()
    }

    /// Estimated TTFT of `input_len` on pair `i` right now: drain the
    /// live backlog at the pair's rate-share-scaled service rate, then
    /// run the prefix on the PPI (conservative — the CPI usually shares
    /// the prefill).
    pub fn estimated_ttft(&self, i: usize, input_len: usize) -> f64 {
        let p = &self.pairs[i];
        p.outstanding_tokens / p.effective_drain_tps() + p.prefill.predict(input_len)
    }

    /// Pick the policy's best pair, optionally restricted to pairs whose
    /// estimated TTFT meets `slo`.  Falls back to the unrestricted best
    /// when no pair qualifies (callers gate admission first, so this is
    /// a safety net, not a policy).  Ties break toward the lowest pair
    /// index, keeping the assignment deterministic.
    fn pick(&self, req: &Request, slo: Option<f64>) -> usize {
        let score = |p: &PairLoad, i: usize| -> f64 {
            match self.policy {
                RoutePolicy::RoundRobin => p.n_routed as f64 / p.rate_share,
                RoutePolicy::LeastOutstandingTokens => p.outstanding_tokens,
                RoutePolicy::SloAware => self.estimated_ttft(i, req.input_len),
            }
        };
        let mut best: Option<(usize, f64)> = None;
        for (i, p) in self.pairs.iter().enumerate() {
            if let Some(slo) = slo {
                if self.estimated_ttft(i, req.input_len) > slo {
                    continue;
                }
            }
            let s = score(p, i);
            if best.map_or(true, |(_, b)| s < b) {
                best = Some((i, s));
            }
        }
        match best {
            Some((i, _)) => i,
            None => self.pick(req, None),
        }
    }

    /// Record `req`'s load against `pair`'s live backlog.
    fn charge(&mut self, pair: usize, req: &Request) {
        let load = (req.input_len + req.output_len) as u64;
        let p = &mut self.pairs[pair];
        p.outstanding_tokens += load as f64;
        p.n_routed += 1;
        p.tokens_routed += load;
    }

    /// Route one request; returns the chosen pair index and records its
    /// load as outstanding.
    pub fn route(&mut self, req: &Request) -> usize {
        let best = self.pick(req, None);
        self.charge(best, req);
        best
    }

    /// Route among the pairs whose estimated TTFT meets `slo_ttft_s`, so
    /// an admission decision ("some pair can serve this in time") is
    /// honoured by the dispatch itself, whatever the base policy.
    pub fn route_within_slo(&mut self, req: &Request, slo_ttft_s: f64) -> usize {
        let best = self.pick(req, Some(slo_ttft_s));
        self.charge(best, req);
        best
    }

    /// A request previously routed to `pair` left the system (finished
    /// or shed): release its `tokens` from the live backlog.
    pub fn on_completed(&mut self, pair: usize, tokens: u64) {
        let p = &mut self.pairs[pair];
        p.outstanding_tokens = (p.outstanding_tokens - tokens as f64).max(0.0);
    }

    /// Submit-time SLO admission control: may this request be admitted
    /// under a TTFT target of `slo_ttft_s` seconds?
    ///
    /// * `Accepted` — some pair's [`estimated_ttft`](Self::estimated_ttft)
    ///   meets the target;
    /// * `Rejected` — no pair could meet the target even with an empty
    ///   backlog (the prompt is inherently too slow for the SLO);
    /// * `Deferred` — transient overload: retry once the least-loaded
    ///   candidate's backlog should have drained below the SLO headroom.
    pub fn slo_admission(
        &self,
        now: SimTime,
        input_len: usize,
        slo_ttft_s: f64,
    ) -> Admission {
        let mut best_idle = f64::INFINITY;
        // Best pair *among those that could meet the SLO when idle* —
        // an infeasible pair must not drive the retry hint, or a
        // transiently loaded feasible pair would be retried on a
        // meaningless (near-zero) backlog estimate and dropped.
        let mut best_feasible: Option<(usize, f64)> = None;
        for (i, p) in self.pairs.iter().enumerate() {
            let idle = p.prefill.predict(input_len);
            best_idle = best_idle.min(idle);
            let est = self.estimated_ttft(i, input_len);
            if est <= slo_ttft_s {
                return Admission::Accepted;
            }
            if idle <= slo_ttft_s
                && best_feasible.map_or(true, |(_, b)| est < b)
            {
                best_feasible = Some((i, est));
            }
        }
        if best_idle > slo_ttft_s {
            return Admission::Rejected {
                reason: format!(
                    "prefill alone needs {best_idle:.3}s > TTFT SLO {slo_ttft_s:.3}s \
                     on every pair"
                ),
            };
        }
        // Wait until the best feasible candidate's backlog fits the SLO
        // headroom (the Option is Some here: best_idle <= slo).
        let (best_pair, _) = best_feasible.expect("feasible pair exists");
        let p = &self.pairs[best_pair];
        let headroom_tokens = (slo_ttft_s - p.prefill.predict(input_len)).max(0.0)
            * p.effective_drain_tps();
        let excess = (p.outstanding_tokens - headroom_tokens).max(0.0);
        let wait_s = (excess / p.effective_drain_tps()).max(1e-3);
        Admission::Deferred { retry_at: now.after_secs(wait_s) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::topology::{ClusterConfig, PairConfig};
    use crate::config::DeploymentConfig;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100, A30, T4};
    use crate::workload::arrival::{stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Request> {
        let t = generate(n, &AzureTraceConfig::default(), seed);
        stamp(&t, ArrivalProcess::AllAtOnce)
    }

    fn route_all(router: &mut Router, trace: &[Request]) -> Vec<usize> {
        trace.iter().map(|r| router.route(r)).collect()
    }

    #[test]
    fn round_robin_is_fair_with_equal_shares() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        route_all(&mut router, &trace(100, 1));
        assert_eq!(router.routed_counts(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn weighted_round_robin_respects_shares() {
        let mut cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        cfg.pairs[0].rate_share = 3.0;
        cfg.pairs[1].rate_share = 1.0;
        let mut router = Router::new(RoutePolicy::RoundRobin, &cfg);
        route_all(&mut router, &trace(200, 2));
        assert_eq!(router.routed_counts(), vec![150, 50]);
    }

    #[test]
    fn least_outstanding_always_picks_current_min() {
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        for r in &trace(150, 3) {
            let before = router.outstanding_tokens();
            let min = before.iter().cloned().fold(f64::INFINITY, f64::min);
            let idx = router.route(r);
            assert!(
                before[idx] <= min + 1e-9,
                "routed to {idx} with backlog {} > min {min}",
                before[idx]
            );
        }
    }

    #[test]
    fn least_outstanding_balances_tokens() {
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        route_all(&mut router, &trace(400, 4));
        let tokens = router.routed_tokens();
        let max = *tokens.iter().max().unwrap() as f64;
        let min = *tokens.iter().min().unwrap() as f64;
        assert!(min > 0.85 * max, "token imbalance under LOT: {tokens:?}");
    }

    #[test]
    fn slo_aware_prefers_the_faster_prefill_pair() {
        let slow = PairConfig::cronus(DeploymentConfig::paper(A100, T4, LLAMA3_8B));
        let fast = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![slow, fast]);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let t = trace(1, 5);
        assert_eq!(router.route(&t[0]), 1, "idle cluster: fastest prefill wins");
        // Under sustained all-at-once load the faster pair absorbs more.
        route_all(&mut router, &trace(199, 5));
        let counts = router.routed_counts();
        assert!(counts[1] > counts[0], "slo-aware counts {counts:?}");
    }

    #[test]
    fn completions_release_live_backlog() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let t = trace(1, 6);
        let pair = router.route(&t[0]);
        let load = (t[0].input_len + t[0].output_len) as u64;
        assert!(router.outstanding_tokens()[pair] > 0.0);
        router.on_completed(pair, load);
        assert_eq!(router.outstanding_tokens()[pair], 0.0);
        // Over-release clamps at zero instead of going negative.
        router.on_completed(pair, load);
        assert_eq!(router.outstanding_tokens()[pair], 0.0);
    }

    #[test]
    fn rate_share_scales_the_slo_estimator() {
        // Two physically identical pairs; pair 0 is given 3x the share.
        // With equal backlogs its estimated TTFT must be lower, so the
        // SLO-aware policy sends it the bulk of a burst.
        let mut cfg = ClusterConfig::homogeneous(
            2,
            DeploymentConfig::paper(A100, A10, LLAMA3_8B),
        );
        cfg.pairs[0].rate_share = 3.0;
        cfg.pairs[1].rate_share = 1.0;
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        route_all(&mut router, &trace(100, 13));
        let tokens = router.routed_tokens();
        assert!(
            tokens[0] > 2 * tokens[1],
            "high-share pair should absorb most load: {tokens:?}"
        );
    }

    #[test]
    fn route_within_slo_skips_infeasible_pairs() {
        // Pair 0 (T4) is listed first and wins the LOT tie on an empty
        // cluster, but its estimated TTFT blows the SLO; the
        // SLO-constrained route must pick the A30 pair instead so the
        // admission decision is honoured by the dispatch.
        let slow = PairConfig::cronus(DeploymentConfig::paper(A100, T4, LLAMA3_8B));
        let fast = PairConfig::cronus(DeploymentConfig::paper(A100, A30, LLAMA3_8B));
        let cfg = ClusterConfig::new(vec![slow, fast]);
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        let req = trace(1, 15)[0];
        let slow_est = router.estimated_ttft(0, req.input_len);
        let fast_est = router.estimated_ttft(1, req.input_len);
        assert!(fast_est < slow_est);
        let slo = (fast_est + slow_est) / 2.0; // feasible only on pair 1
        assert_eq!(router.route_within_slo(&req, slo), 1);
        // With an SLO nobody meets, it falls back to the plain pick.
        let mut router = Router::new(RoutePolicy::LeastOutstandingTokens, &cfg);
        assert_eq!(router.route_within_slo(&req, 0.0), 0);
    }

    #[test]
    fn slo_admission_accepts_defers_and_rejects() {
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut router = Router::new(RoutePolicy::SloAware, &cfg);
        let now = SimTime::ZERO;
        // Idle cluster, generous SLO: accepted.
        assert_eq!(router.slo_admission(now, 1000, 10.0), Admission::Accepted);
        // An SLO below the idle prefill time of every pair: rejected.
        assert!(matches!(
            router.slo_admission(now, 8000, 1e-6),
            Admission::Rejected { .. }
        ));
        // Pile on load until the estimate blows the SLO, then expect a
        // deferral with a strictly future retry hint.
        let slo = router.estimated_ttft(0, 1000) + 0.05;
        for r in &trace(400, 14) {
            router.route(r);
        }
        match router.slo_admission(now, 1000, slo) {
            Admission::Deferred { retry_at } => assert!(retry_at > now),
            other => panic!("expected Deferred, got {other:?}"),
        }
    }

    #[test]
    fn single_pair_routes_everything_to_it() {
        let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let cfg = ClusterConfig::homogeneous(1, deployment);
        for policy in RoutePolicy::ALL {
            let mut router = Router::new(policy, &cfg);
            let a = route_all(&mut router, &trace(20, 7));
            assert!(a.iter().all(|&i| i == 0), "{}", policy.name());
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let cfg = ClusterConfig::mixed(5, LLAMA3_8B);
        let t = trace(120, 8);
        for policy in RoutePolicy::ALL {
            let a = route_all(&mut Router::new(policy, &cfg), &t);
            let b = route_all(&mut Router::new(policy, &cfg), &t);
            assert_eq!(a, b, "{}", policy.name());
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        for policy in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::from_name(policy.name()), Some(policy));
        }
        assert_eq!(
            RoutePolicy::from_name("LOT"),
            Some(RoutePolicy::LeastOutstandingTokens)
        );
        assert_eq!(RoutePolicy::from_name("rr"), Some(RoutePolicy::RoundRobin));
        assert!(RoutePolicy::from_name("random").is_none());
    }
}
