//! The paper's contribution: partially disaggregated prefill.
//!
//! A Cronus deployment pairs one low-end and one high-end GPU:
//!
//! * the **frontend** ([`frontend`]) accepts requests and holds them until
//!   the partial-prefill instance has a free slot;
//! * the **Balancer** ([`balancer`], paper §4.3 + Algorithm 1) picks the
//!   partial-prefill length for each request so that the time the low-end
//!   GPU spends on the prefix equals the time the high-end GPU needs to
//!   finish the remainder via chunked prefill — keeping both pipeline
//!   stages at equal throughput;
//! * the **partial-prefill instance** ([`ppi`], low-end GPU) prefills the
//!   prefix, one request at a time, buffering the produced KV;
//! * the **chunked-prefill instance** (the high-end GPU's
//!   [`crate::engine::EngineInstance`]) fetches the prefix KV during the
//!   request's first iteration — overlapped with other requests' compute
//!   (Fig. 2) — then finishes the prefill in chunks piggybacked on
//!   decode iterations, and serves the whole decode phase.
//!
//! The two disaggregated-prefill baselines are this same machinery with
//! the split forced to the full prompt ([`balancer::SplitPolicy::Full`]),
//! optionally with the GPU roles swapped (Disagg. H-L) — exactly how the
//! paper implements them ("we use the same code as our partial prefill
//! implementation, but always set the partial prefill length to the input
//! length").

//! Scaling out, the cluster-level **router** ([`router`]) dispatches
//! arriving requests across many such pairs (round-robin,
//! least-outstanding-tokens, or SLO-aware TTFT estimation) — see
//! [`crate::systems::cluster`] for the N-pair serving system.

pub mod balancer;
pub mod frontend;
pub mod ppi;
pub mod router;

pub use balancer::{Balancer, SplitPolicy};
pub use frontend::CronusSystem;
pub use ppi::PartialPrefillInstance;
pub use router::{RoutePolicy, Router};
