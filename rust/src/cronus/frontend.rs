//! The Cronus frontend: event-driven driver tying Balancer, PPI and CPI
//! together on the simulated cluster (paper Fig. 1).
//!
//! Request flow (numbers = the paper's Fig. 1 annotations):
//! 1. an arriving request waits in the frontend until the PPI has a slot;
//! 2. the Balancer reads fresh CPI statistics and picks the partial
//!    prefill length;
//! 3. the request is dispatched to the PPI;
//! 4. when the PPI finishes the prefix, the frontend is notified and
//! 5. sends the chunked-prefill request (prompt + processed-prefix
//!    length) to the CPI;
//! 6./7. the CPI's first iteration for the request pulls the prefix KV
//!    from the PPI buffer over the link, overlapped with other requests'
//!    compute; subsequent iterations run standard chunked prefill, then
//!    decode.
//!
//! With [`SplitPolicy::Full`] this same driver *is* the disaggregated-
//! prefill baseline (L→H, or H→L with `swap_gpus`).
//!
//! The system is *online*: engines, event queue, balancer and metrics
//! live in [`CronusSystem`] as long-lived state, so the driver can be
//! stepped request by request via the `submit` / `advance` / `drain`
//! lifecycle (see [`crate::systems::ServingSystem`]).  Oversized prompts
//! are rejected at `submit` time and surfaced both as
//! [`SystemEvent::Shed`] and in [`Report::n_rejected`](crate::metrics::Report).

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::cronus::balancer::{Balancer, SplitPolicy};
use crate::cronus::ppi::{PartialPrefillInstance, PpiJob};
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::{Collector, ReqId};
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::fit::calibrate;
use crate::simgpu::perfmodel::PerfModel;
use crate::systems::{
    drain_pending_into, earliest_instant, past_deadline, record_engine_event,
    Admission, InstanceStat, RunOutcome, ServingSystem, SystemEvent,
};
use crate::util::fxhash::FxHashMap;
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    PpiDone,
    CpiDone,
}

/// The long-lived event-loop state of one Cronus pair.
struct CronusState {
    balancer: Balancer,
    cpi: EngineInstance,
    ppi: PartialPrefillInstance,
    q: EventQueue<Ev>,
    metrics: Collector,
    /// Accepted requests waiting for a PPI slot (paper step ①).
    frontend: VecDeque<u64>,
    /// Request records by id (the PPI handoff needs lengths).
    reqs: FxHashMap<u64, Request>,
    cpi_plan: Option<IterationPlan>,
    /// Recycled plan buffer: capacity survives across iterations so the
    /// steady-state plan/complete loop allocates nothing.
    plan_spare: IterationPlan,
    /// Reusable engine-event buffer for `complete_iteration_into`.
    ev_buf: Vec<EngineEvent>,
    cpi_capacity_tokens: usize,
    n_rejected: usize,
    /// Events produced but not yet collected via `advance`.
    pending: Vec<SystemEvent>,
}

impl CronusState {
    fn build(cfg: &DeploymentConfig, policy: SplitPolicy, swap_gpus: bool) -> CronusState {
        let (ppi_pm, cpi_pm) = role_models(cfg, swap_gpus);

        // Calibrate the Balancer's predictors by profiling, exactly as
        // the paper does (§4.4).
        let (prefill_coeffs, chunked_coeffs) = calibrate(
            &ppi_pm,
            &cpi_pm,
            cfg.engine.max_batched_tokens,
            cfg.calibration_noise,
            cfg.calibration_seed,
        );
        let balancer = Balancer::new(
            policy,
            prefill_coeffs,
            chunked_coeffs,
            cfg.engine.max_batched_tokens,
        );

        let cpi = EngineInstance::from_params(
            format!("CPI({})", cpi_pm.gpu.name),
            cpi_pm,
            cfg.link,
            &cfg.engine,
            cfg.engine.max_batched_tokens,
        );
        let ppi = PartialPrefillInstance::new(
            ppi_pm,
            ppi_pm.kv_capacity_tokens(cfg.engine.activation_reserve_frac),
        );
        let cpi_capacity_tokens =
            cpi.kv_allocator().total_blocks() * cpi.kv_allocator().block_size();

        CronusState {
            balancer,
            cpi,
            ppi,
            q: EventQueue::new(),
            metrics: Collector::new(),
            frontend: VecDeque::new(),
            reqs: FxHashMap::default(),
            cpi_plan: None,
            plan_spare: IterationPlan::default(),
            ev_buf: Vec::new(),
            cpi_capacity_tokens,
            n_rejected: 0,
            pending: Vec::new(),
        }
    }

    /// Pop and apply internal events; `inclusive` controls whether events
    /// *at* `until` run (advance) or stay queued (submit's pre-drain).
    fn run_until(&mut self, until: SimTime, inclusive: bool) {
        while let Some(t) = self.q.peek_time() {
            if past_deadline(t, until, inclusive) {
                break;
            }
            let (now, ev) = self.q.pop().unwrap();
            self.handle(now, ev);
        }
    }

    fn handle(&mut self, now: SimTime, ev: Ev) {
        match ev {
            Ev::PpiDone => {
                let (job, next) = self.ppi.on_done();
                let r = self.reqs[&job.id];
                // ⑤ chunked-prefill request: original prompt plus the
                // already-processed prefix length — the session prefix
                // resident from a previous turn (`kv_credit`, free) plus
                // the PPI's partial prefill (transferred over the link).
                self.cpi.submit(EngineRequest::with_prefix_credit(
                    job.id,
                    r.input_len,
                    r.output_len,
                    r.kv_credit + job.partial_len,
                    r.kv_credit,
                ));
                if let Some((_next_job, dur)) = next {
                    self.q.push_after(dur, Ev::PpiDone);
                }
            }
            Ev::CpiDone => {
                let plan = self.cpi_plan.take().expect("CpiDone without plan");
                let mut events = std::mem::take(&mut self.ev_buf);
                self.cpi.complete_iteration_into(&plan, &mut events);
                for &ev in &events {
                    if record_engine_event(&mut self.metrics, &mut self.pending, now, ev)
                    {
                        if let EngineEvent::Finished(id) = ev {
                            // The request left the system; drop its record
                            // so a long-running online frontend stays
                            // bounded.
                            self.reqs.remove(&id);
                        }
                    } else if let EngineEvent::KvReceived(id) = ev {
                        // ⑦ transfer complete: PPI buffer freed.
                        if let Some((_job, dur)) = self.ppi.release(id) {
                            self.q.push_after(dur, Ev::PpiDone);
                        }
                    }
                }
                // Recycle both buffers for the next iteration.
                self.ev_buf = events;
                self.plan_spare = plan;
            }
        }
        self.pump();
    }

    /// ①–③ dispatch frontend → PPI whenever a slot is free, and keep the
    /// CPI busy.  Runs after every event and every submission.
    fn pump(&mut self) {
        while !self.frontend.is_empty() {
            let r = self.reqs[self.frontend.front().unwrap()];
            // Cold requests wait for a PPI slot (paper step ①); warm
            // turns queued behind a blocked cold head keep FIFO order.
            if r.kv_credit == 0 && !self.ppi.has_slot() {
                break;
            }
            let id = self.frontend.pop_front().unwrap();
            if r.kv_credit > 0 {
                // A warm follow-up turn's resident prefix lives in the
                // *CPI's* KV pool; the PPI holds none of the session's
                // KV, so it has nothing to contribute.  The fresh suffix
                // goes straight to the CPI's chunked prefill — whose
                // Eq. 3 model prices attention over the full resident
                // context — without queueing behind unrelated cold
                // prefills for a PPI slot it does not need.
                self.cpi.submit(EngineRequest::with_prefix_credit(
                    id,
                    r.input_len,
                    r.output_len,
                    r.kv_credit,
                    r.kv_credit,
                ));
                continue;
            }
            let decision = self.balancer.split(r.input_len, &self.cpi.stats());
            // The PPI's KV buffer bounds the prefix it can hold: a
            // low-end card too small for the model (e.g. 16 GiB for
            // an 8B model in a mixed cluster) degrades to pure
            // chunked prefill on the CPI instead of stalling.
            let partial_len =
                decision.partial_len.min(self.ppi.buffer_capacity_tokens());
            if let Some((_job, dur)) = self.ppi.enqueue(PpiJob { id, partial_len }) {
                self.q.push_after(dur, Ev::PpiDone);
            }
        }

        if self.cpi_plan.is_none() {
            let mut plan = std::mem::take(&mut self.plan_spare);
            if self.cpi.plan_iteration_into(&mut plan) {
                self.q.push_after(plan.duration_s, Ev::CpiDone);
                self.cpi_plan = Some(plan);
            } else {
                self.plan_spare = plan; // keep the warmed capacity
            }
        }
    }
}

pub struct CronusSystem {
    cfg: DeploymentConfig,
    policy: SplitPolicy,
    /// Swap GPU roles: PPI on the high-end, CPI on the low-end GPU
    /// (the Disagg. H-L configuration).
    swap_gpus: bool,
    label: String,
    /// Built lazily on first use; consumed by `drain`.
    st: Option<CronusState>,
}

/// Performance models for (PPI GPU, CPI GPU) under `swap_gpus`.
fn role_models(cfg: &DeploymentConfig, swap_gpus: bool) -> (PerfModel, PerfModel) {
    let (ppi_gpu, cpi_gpu) = if swap_gpus {
        (cfg.high_gpu, cfg.low_gpu)
    } else {
        (cfg.low_gpu, cfg.high_gpu)
    };
    (
        PerfModel::new(ppi_gpu, cfg.model),
        PerfModel::new(cpi_gpu, cfg.model),
    )
}

impl CronusSystem {
    pub fn new(
        cfg: DeploymentConfig,
        policy: SplitPolicy,
        swap_gpus: bool,
        label: impl Into<String>,
    ) -> Self {
        CronusSystem { cfg, policy, swap_gpus, label: label.into(), st: None }
    }

    /// Performance models for (PPI GPU, CPI GPU) under the current role
    /// assignment.
    pub fn perf_models(&self) -> (PerfModel, PerfModel) {
        role_models(&self.cfg, self.swap_gpus)
    }

    fn state(&mut self) -> &mut CronusState {
        if self.st.is_none() {
            self.st = Some(CronusState::build(&self.cfg, self.policy, self.swap_gpus));
        }
        self.st.as_mut().unwrap()
    }
}

impl ServingSystem for CronusSystem {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn submit(&mut self, t: SimTime, req: Request) -> Admission {
        let st = self.state();
        // Process everything scheduled before the arrival, then anchor
        // the clock at the arrival instant.
        st.run_until(t, false);
        st.q.advance_now(t);
        st.metrics.on_arrival(req.id, t);
        let mut req = req;
        req.clamp_kv_credit();
        if req.input_len > st.cpi_capacity_tokens {
            // Cannot ever fit the CPI's KV pool; reject (vLLM would too).
            st.n_rejected += 1;
            st.metrics.on_shed(req.id);
            let reason = format!(
                "prompt of {} tokens exceeds the CPI KV capacity of {} tokens",
                req.input_len, st.cpi_capacity_tokens
            );
            st.pending.push(SystemEvent::Shed { id: req.id, t, reason: reason.clone() });
            return Admission::Rejected { reason };
        }
        st.reqs.insert(req.id, req);
        st.frontend.push_back(req.id);
        st.pump();
        Admission::Accepted
    }

    fn next_event_at(&self) -> Option<SimTime> {
        let st = self.st.as_ref()?;
        earliest_instant(&st.pending, st.q.peek_time())
    }

    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent> {
        let mut out = Vec::new();
        self.advance_into(until, &mut out);
        out
    }

    fn advance_into(&mut self, until: SimTime, out: &mut Vec<SystemEvent>) {
        if let Some(st) = self.st.as_mut() {
            st.run_until(until, true);
            drain_pending_into(&mut st.pending, until, out);
        }
    }

    fn abort_inflight(&mut self) -> Vec<ReqId> {
        let Some(old) = self.st.take() else {
            return Vec::new();
        };
        let mut ids: Vec<ReqId> = old.reqs.keys().copied().collect();
        ids.sort_unstable();
        if ids.is_empty() && old.pending.is_empty() {
            // Nothing in flight — keep the live state, skip the rebuild.
            self.st = Some(old);
            return ids;
        }
        // Rebuild the event loop from scratch: queued iterations, PPI
        // jobs and every byte of KV state die with the fault.  Banked
        // metrics (finished/shed records) and utilization counters carry
        // over; the aborted requests' records are forgotten so the
        // cluster can re-submit them elsewhere.
        let mut st = CronusState::build(&self.cfg, self.policy, self.swap_gpus);
        st.metrics = old.metrics;
        st.n_rejected = old.n_rejected;
        st.pending = old.pending;
        for id in &ids {
            st.metrics.forget(*id);
        }
        st.ppi.busy_time_s = old.ppi.busy_time_s;
        st.ppi.n_prefills = old.ppi.n_prefills;
        st.ppi.tokens_prefilled = old.ppi.tokens_prefilled;
        st.ppi.n_buffer_stalls = old.ppi.n_buffer_stalls;
        st.cpi.busy_time_s = old.cpi.busy_time_s;
        st.cpi.n_iterations = old.cpi.n_iterations;
        st.cpi.n_preemptions = old.cpi.n_preemptions;
        st.cpi.tokens_prefilled = old.cpi.tokens_prefilled;
        st.cpi.tokens_decoded = old.cpi.tokens_decoded;
        st.cpi.tokens_kv_received = old.cpi.tokens_kv_received;
        self.st = Some(st);
        ids
    }

    fn drain(&mut self) -> RunOutcome {
        let mut st = match self.st.take() {
            Some(st) => st,
            None => CronusState::build(&self.cfg, self.policy, self.swap_gpus),
        };
        st.run_until(SimTime(u64::MAX), true);
        let report = st.metrics.report(self.label.clone());
        debug_assert_eq!(report.n_rejected, st.n_rejected);
        RunOutcome {
            report,
            instances: vec![
                InstanceStat {
                    name: format!("PPI({})", st.ppi.perf_model().gpu.name),
                    busy_time_s: st.ppi.busy_time_s,
                    n_iterations: st.ppi.n_prefills,
                    n_preemptions: 0,
                    tokens_prefilled: st.ppi.tokens_prefilled,
                    tokens_decoded: 0,
                    tokens_kv_received: 0,
                },
                InstanceStat {
                    name: st.cpi.name.clone(),
                    busy_time_s: st.cpi.busy_time_s,
                    n_iterations: st.cpi.n_iterations,
                    n_preemptions: st.cpi.n_preemptions,
                    tokens_prefilled: st.cpi.tokens_prefilled,
                    tokens_decoded: st.cpi.tokens_decoded,
                    tokens_kv_received: st.cpi.tokens_kv_received,
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::driver::replay_trace;
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn small_trace(n: usize) -> Vec<Request> {
        generate(n, &AzureTraceConfig::default(), 11)
    }

    #[test]
    fn cronus_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "Cronus");
        let out = replay_trace(&mut sys, &small_trace(50));
        assert_eq!(out.report.n_finished, 50);
        assert_eq!(out.report.n_rejected, 0);
        assert!(out.report.throughput_rps > 0.0);
        assert!(out.report.ttft_p99_s > 0.0);
        assert!(out.report.tbt_p99_s > 0.0);
    }

    #[test]
    fn disagg_lh_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Full, false, "Disagg. L-H");
        let out = replay_trace(&mut sys, &small_trace(30));
        assert_eq!(out.report.n_finished, 30);
        // All prefill ran on the PPI.
        let ppi = &out.instances[0];
        let total_input: u64 =
            small_trace(30).iter().map(|r| r.input_len as u64).sum();
        assert_eq!(ppi.tokens_prefilled, total_input);
    }

    #[test]
    fn disagg_hl_swaps_roles() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Full, true, "Disagg. H-L");
        let (ppi_pm, cpi_pm) = sys.perf_models();
        assert_eq!(ppi_pm.gpu.name, "A100-80G");
        assert_eq!(cpi_pm.gpu.name, "A10");
        let out = replay_trace(&mut sys, &small_trace(20));
        assert_eq!(out.report.n_finished, 20);
    }

    #[test]
    fn cronus_splits_are_partial() {
        // In the balanced mode the CPI must do *some* prefill work
        // (otherwise it degenerates to disaggregated prefill).
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "Cronus");
        let out = replay_trace(&mut sys, &small_trace(50));
        let ppi = &out.instances[0];
        let cpi = &out.instances[1];
        assert!(ppi.tokens_prefilled > 0, "PPI idle");
        assert!(
            cpi.tokens_prefilled > ppi.tokens_prefilled / 20,
            "CPI did almost no prefill: {} vs {}",
            cpi.tokens_prefilled,
            ppi.tokens_prefilled
        );
        assert!(cpi.tokens_decoded > 0);
    }

    #[test]
    fn deterministic_runs() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = small_trace(25);
        let a = replay_trace(
            &mut CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x"),
            &trace,
        );
        let b = replay_trace(
            &mut CronusSystem::new(cfg, SplitPolicy::Balanced, false, "x"),
            &trace,
        );
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
    }

    #[test]
    fn oversized_request_is_rejected_and_shed() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "Cronus");
        let huge = Request::new(0, 0, 10_000_000, 8);
        let adm = sys.submit(SimTime::ZERO, huge);
        assert!(matches!(adm, Admission::Rejected { .. }), "{adm:?}");
        let events = sys.advance(SimTime(u64::MAX));
        assert!(
            events.iter().any(|e| matches!(e, SystemEvent::Shed { id: 0, .. })),
            "{events:?}"
        );
        let out = sys.drain();
        assert_eq!(out.report.n_requests, 1);
        assert_eq!(out.report.n_finished, 0);
        assert_eq!(out.report.n_rejected, 1);
    }

    #[test]
    fn kv_credit_skips_resident_prefix_prefill() {
        use crate::systems::prefill_tokens_executed;
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        // Same follow-up turn, cold (no credit) vs warm (600 of the 1000
        // prompt tokens resident from the previous turn).
        let mut cold_req = Request::new(1, 0, 1000, 16);
        cold_req.session_id = 1;
        cold_req.prefix_len = 600;
        let mut warm_req = cold_req;
        warm_req.kv_credit = 600;

        let run = |req: Request| {
            let mut sys =
                CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x");
            replay_trace(&mut sys, &[req])
        };
        let cold = run(cold_req);
        let warm = run(warm_req);
        assert_eq!(cold.report.n_finished, 1);
        assert_eq!(warm.report.n_finished, 1);
        // Executed prefill = prompt minus the resident credit, exactly.
        assert_eq!(prefill_tokens_executed(&cold), 1000);
        assert_eq!(prefill_tokens_executed(&warm), 400);
        // Skipping 600 prefill tokens can only help the finish time.
        assert!(warm.report.makespan_s <= cold.report.makespan_s);
    }

    #[test]
    fn online_stepping_matches_oneshot_drain() {
        // Driving with many small `advance` steps must not change the
        // outcome vs. letting `drain` run everything at once.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = small_trace(20);

        let mut stepped = CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x");
        let mut n_events = 0usize;
        for r in &trace {
            stepped.submit(SimTime(r.arrival_ns), *r);
        }
        while let Some(t) = stepped.next_event_at() {
            n_events += stepped.advance(t).len();
        }
        let a = stepped.drain();

        let mut oneshot = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "x");
        for r in &trace {
            oneshot.submit(SimTime(r.arrival_ns), *r);
        }
        let b = oneshot.drain();

        assert!(n_events > 0);
        assert_eq!(a.report.n_finished, 20);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
        assert_eq!(a.report.tbt_p99_s, b.report.tbt_p99_s);
    }

    #[test]
    fn drain_resets_for_reuse() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "x");
        let trace = small_trace(10);
        let a = replay_trace(&mut sys, &trace);
        let b = replay_trace(&mut sys, &trace);
        assert_eq!(a.report.n_finished, 10);
        assert_eq!(b.report.n_finished, 10);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
    }
}
