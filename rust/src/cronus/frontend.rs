//! The Cronus frontend: event-driven driver tying Balancer, PPI and CPI
//! together on the simulated cluster (paper Fig. 1).
//!
//! Request flow (numbers = the paper's Fig. 1 annotations):
//! 1. an arriving request waits in the frontend until the PPI has a slot;
//! 2. the Balancer reads fresh CPI statistics and picks the partial
//!    prefill length;
//! 3. the request is dispatched to the PPI;
//! 4. when the PPI finishes the prefix, the frontend is notified and
//! 5. sends the chunked-prefill request (prompt + processed-prefix
//!    length) to the CPI;
//! 6./7. the CPI's first iteration for the request pulls the prefix KV
//!    from the PPI buffer over the link, overlapped with other requests'
//!    compute; subsequent iterations run standard chunked prefill, then
//!    decode.
//!
//! With [`SplitPolicy::Full`] this same driver *is* the disaggregated-
//! prefill baseline (L→H, or H→L with `swap_gpus`).

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::cronus::balancer::{Balancer, SplitPolicy};
use crate::cronus::ppi::{PartialPrefillInstance, PpiJob};
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::Collector;
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::fit::calibrate;
use crate::simgpu::perfmodel::PerfModel;
use crate::systems::{InstanceStat, RunOutcome, ServingSystem};
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(usize),
    PpiDone,
    CpiDone,
}

pub struct CronusSystem {
    cfg: DeploymentConfig,
    policy: SplitPolicy,
    /// Swap GPU roles: PPI on the high-end, CPI on the low-end GPU
    /// (the Disagg. H-L configuration).
    swap_gpus: bool,
    label: String,
}

impl CronusSystem {
    pub fn new(
        cfg: DeploymentConfig,
        policy: SplitPolicy,
        swap_gpus: bool,
        label: impl Into<String>,
    ) -> Self {
        CronusSystem { cfg, policy, swap_gpus, label: label.into() }
    }

    /// Performance models for (PPI GPU, CPI GPU) under the current role
    /// assignment.
    pub fn perf_models(&self) -> (PerfModel, PerfModel) {
        let (ppi_gpu, cpi_gpu) = if self.swap_gpus {
            (self.cfg.high_gpu, self.cfg.low_gpu)
        } else {
            (self.cfg.low_gpu, self.cfg.high_gpu)
        };
        (
            PerfModel::new(ppi_gpu, self.cfg.model),
            PerfModel::new(cpi_gpu, self.cfg.model),
        )
    }
}

impl ServingSystem for CronusSystem {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn run(&mut self, trace: &[Request]) -> RunOutcome {
        let cfg = &self.cfg;
        let (ppi_pm, cpi_pm) = self.perf_models();

        // Calibrate the Balancer's predictors by profiling, exactly as
        // the paper does (§4.4).
        let (prefill_coeffs, chunked_coeffs) = calibrate(
            &ppi_pm,
            &cpi_pm,
            cfg.engine.max_batched_tokens,
            cfg.calibration_noise,
            cfg.calibration_seed,
        );
        let balancer = Balancer::new(
            self.policy,
            prefill_coeffs,
            chunked_coeffs,
            cfg.engine.max_batched_tokens,
        );

        let mut cpi = EngineInstance::from_params(
            format!("CPI({})", cpi_pm.gpu.name),
            cpi_pm,
            cfg.link,
            &cfg.engine,
            cfg.engine.max_batched_tokens,
        );
        let mut ppi = PartialPrefillInstance::new(
            ppi_pm,
            ppi_pm.kv_capacity_tokens(cfg.engine.activation_reserve_frac),
        );

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut metrics = Collector::new();
        for (i, r) in trace.iter().enumerate() {
            q.push(SimTime(r.arrival_ns), Ev::Arrival(i));
        }
        let mut frontend: VecDeque<usize> = VecDeque::new();
        let mut cpi_plan: Option<IterationPlan> = None;
        let mut rejected = 0usize;
        let cpi_capacity_tokens =
            cpi.kv_allocator().total_blocks() * cpi.kv_allocator().block_size();

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrival(i) => {
                    metrics.on_arrival(trace[i].id, now);
                    frontend.push_back(i);
                }
                Ev::PpiDone => {
                    let (job, next) = ppi.on_done();
                    let r = trace
                        .iter()
                        .find(|r| r.id == job.id)
                        .expect("PPI job for unknown request");
                    // ⑤ chunked-prefill request: original prompt plus the
                    // already-processed prefix length.
                    cpi.submit(EngineRequest::with_offset(
                        job.id,
                        r.input_len,
                        r.output_len,
                        job.partial_len,
                    ));
                    if let Some((_next_job, dur)) = next {
                        q.push_after(dur, Ev::PpiDone);
                    }
                }
                Ev::CpiDone => {
                    let plan = cpi_plan.take().expect("CpiDone without plan");
                    for ev in cpi.complete_iteration(&plan) {
                        match ev {
                            EngineEvent::FirstToken(id) | EngineEvent::Token(id) => {
                                metrics.on_token(id, now)
                            }
                            EngineEvent::Finished(id) => metrics.on_finish(id, now),
                            EngineEvent::KvReceived(id) => {
                                // ⑦ transfer complete: PPI buffer freed.
                                if let Some((_job, dur)) = ppi.release(id) {
                                    q.push_after(dur, Ev::PpiDone);
                                }
                            }
                            EngineEvent::Preempted(_) => {}
                        }
                    }
                }
            }

            // ①–③ dispatch frontend -> PPI whenever a slot is free.
            while ppi.has_slot() && !frontend.is_empty() {
                let i = frontend.pop_front().unwrap();
                let r = &trace[i];
                if r.input_len > cpi_capacity_tokens {
                    rejected += 1; // cannot ever fit; reject (vLLM would too)
                    continue;
                }
                let decision = balancer.split(r.input_len, &cpi.stats());
                // The PPI's KV buffer bounds the prefix it can hold: a
                // low-end card too small for the model (e.g. 16 GiB for
                // an 8B model in a mixed cluster) degrades to pure
                // chunked prefill on the CPI instead of stalling.
                let partial_len =
                    decision.partial_len.min(ppi.buffer_capacity_tokens());
                if let Some((_job, dur)) =
                    ppi.enqueue(PpiJob { id: r.id, partial_len })
                {
                    q.push_after(dur, Ev::PpiDone);
                }
            }

            // Keep the CPI busy.
            if cpi_plan.is_none() {
                if let Some(plan) = cpi.plan_iteration() {
                    q.push_after(plan.duration_s, Ev::CpiDone);
                    cpi_plan = Some(plan);
                }
            }
        }

        if rejected > 0 {
            eprintln!("{}: rejected {rejected} oversized requests", self.label);
        }

        let report = metrics.report(self.label.clone());
        RunOutcome {
            report,
            instances: vec![
                InstanceStat {
                    name: format!("PPI({})", ppi.perf_model().gpu.name),
                    busy_time_s: ppi.busy_time_s,
                    n_iterations: ppi.n_prefills,
                    n_preemptions: 0,
                    tokens_prefilled: ppi.tokens_prefilled,
                    tokens_decoded: 0,
                },
                InstanceStat {
                    name: cpi.name.clone(),
                    busy_time_s: cpi.busy_time_s,
                    n_iterations: cpi.n_iterations,
                    n_preemptions: cpi.n_preemptions,
                    tokens_prefilled: cpi.tokens_prefilled,
                    tokens_decoded: cpi.tokens_decoded,
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn small_trace(n: usize) -> Vec<Request> {
        generate(n, &AzureTraceConfig::default(), 11)
    }

    #[test]
    fn cronus_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "Cronus");
        let out = sys.run(&small_trace(50));
        assert_eq!(out.report.n_finished, 50);
        assert!(out.report.throughput_rps > 0.0);
        assert!(out.report.ttft_p99_s > 0.0);
        assert!(out.report.tbt_p99_s > 0.0);
    }

    #[test]
    fn disagg_lh_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Full, false, "Disagg. L-H");
        let out = sys.run(&small_trace(30));
        assert_eq!(out.report.n_finished, 30);
        // All prefill ran on the PPI.
        let ppi = &out.instances[0];
        let total_input: u64 =
            small_trace(30).iter().map(|r| r.input_len as u64).sum();
        assert_eq!(ppi.tokens_prefilled, total_input);
    }

    #[test]
    fn disagg_hl_swaps_roles() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Full, true, "Disagg. H-L");
        let (ppi_pm, cpi_pm) = sys.perf_models();
        assert_eq!(ppi_pm.gpu.name, "A100-80G");
        assert_eq!(cpi_pm.gpu.name, "A10");
        let out = sys.run(&small_trace(20));
        assert_eq!(out.report.n_finished, 20);
    }

    #[test]
    fn cronus_splits_are_partial() {
        // In the balanced mode the CPI must do *some* prefill work
        // (otherwise it degenerates to disaggregated prefill).
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "Cronus");
        let out = sys.run(&small_trace(50));
        let ppi = &out.instances[0];
        let cpi = &out.instances[1];
        assert!(ppi.tokens_prefilled > 0, "PPI idle");
        assert!(
            cpi.tokens_prefilled > ppi.tokens_prefilled / 20,
            "CPI did almost no prefill: {} vs {}",
            cpi.tokens_prefilled,
            ppi.tokens_prefilled
        );
        assert!(cpi.tokens_decoded > 0);
    }

    #[test]
    fn deterministic_runs() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = small_trace(25);
        let a = CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x")
            .run(&trace);
        let b = CronusSystem::new(cfg, SplitPolicy::Balanced, false, "x").run(&trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
    }
}
