//! The Balancer — paper §4.3 / §4.4 and Algorithm 1 (Appendix A).
//!
//! For each incoming request it chooses the partial-prefill length `L_p`:
//! the prefix prefilled on the low-end GPU while the high-end GPU
//! overlaps earlier requests' decode, such that
//!
//! ```text
//!   T_parprefill(L_p)  ≈  T_chunked(L_in - L_p)
//! ```
//!
//! Both sides are estimated with the linear predictors of §4.4, whose
//! coefficients come from profiling (see [`crate::simgpu::fit`]):
//!
//! * Eq. 2: `T_prefill(L) = k_p · L + b_p` on the PPI's GPU;
//! * Eq. 3: `t_chunked = k_ctxp · L_ctx + k_ctxd · Σ L_D + b_c` per
//!   iteration on the CPI's GPU, summed over iterations as an arithmetic
//!   series (Eq. 1).
//!
//! Candidate `L_p` values are sampled evenly between 1 and `L_in`
//! (Algorithm 1 uses 512 candidates); the candidate minimizing
//! `|T_prefill − T_chunked|` wins.  If the CPI lacks free KV blocks for
//! the prompt, the whole prefill goes to the PPI (`L_p = L_in`).

use crate::engine::instance::EngineStats;
use crate::simgpu::fit::{ChunkedCoeffs, PrefillCoeffs};

/// How to split each request's prefill.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitPolicy {
    /// Algorithm 1 (Cronus).
    Balanced,
    /// Always the full prompt (the disaggregated-prefill baselines).
    Full,
    /// Fixed fraction of the prompt (ablation).
    FixedFraction(f64),
}

/// Decision record (kept for ablation benches / debugging).
#[derive(Clone, Copy, Debug)]
pub struct SplitDecision {
    pub partial_len: usize,
    pub t_prefill_est: f64,
    pub t_chunked_est: f64,
}

pub struct Balancer {
    policy: SplitPolicy,
    prefill: PrefillCoeffs,
    chunked: ChunkedCoeffs,
    /// Max batched tokens per CPI iteration (B in Algorithm 1).
    max_batched_tokens: usize,
    /// Number of evenly spaced candidates (512 in Algorithm 1).
    n_candidates: usize,
}

impl Balancer {
    pub fn new(
        policy: SplitPolicy,
        prefill: PrefillCoeffs,
        chunked: ChunkedCoeffs,
        max_batched_tokens: usize,
    ) -> Self {
        Balancer {
            policy,
            prefill,
            chunked,
            max_batched_tokens,
            n_candidates: 512,
        }
    }

    pub fn with_candidates(mut self, n: usize) -> Self {
        self.n_candidates = n.max(1);
        self
    }

    /// Pick the partial-prefill length for a request of `input_len`
    /// tokens, given fresh CPI statistics.
    pub fn split(&self, input_len: usize, cpi: &EngineStats) -> SplitDecision {
        match self.policy {
            SplitPolicy::Full => SplitDecision {
                partial_len: input_len,
                t_prefill_est: self.prefill.predict(input_len),
                t_chunked_est: 0.0,
            },
            SplitPolicy::FixedFraction(f) => {
                let lp = ((input_len as f64 * f).ceil() as usize)
                    .clamp(1, input_len);
                SplitDecision {
                    partial_len: lp,
                    t_prefill_est: self.prefill.predict(lp),
                    t_chunked_est: self.estimate_chunked(input_len, lp, cpi),
                }
            }
            SplitPolicy::Balanced => self.balanced_split(input_len, cpi),
        }
    }

    /// Algorithm 1.
    ///
    /// Performance note (EXPERIMENTS.md §Perf): `T_prefill(L_p)` is
    /// strictly increasing in `L_p` and `T_chunked(L_in − L_p)` is
    /// non-increasing, so the signed difference crosses zero exactly
    /// once over the candidate grid.  Instead of scanning all 512
    /// candidates (the literal Algorithm 1 loop, ~4 µs/decision), we
    /// binary-search the crossing and compare its two neighbours —
    /// identical argmin, O(log n) predictor evaluations.  The exhaustive
    /// scan is kept as `balanced_split_exhaustive` and a property test
    /// asserts the two agree.
    fn balanced_split(&self, input_len: usize, cpi: &EngineStats) -> SplitDecision {
        // If the CPI cannot hold the prompt's KV, keep everything on the
        // PPI (first branch of Algorithm 1).
        let blocks_needed = input_len.div_ceil(cpi.block_size.max(1));
        if cpi.free_blocks < blocks_needed {
            return SplitDecision {
                partial_len: input_len,
                t_prefill_est: self.prefill.predict(input_len),
                t_chunked_est: 0.0,
            };
        }

        let n_cand = self.n_candidates.min(input_len);
        let eval = |i: usize| -> SplitDecision {
            let lp = (input_len * i).div_ceil(n_cand).clamp(1, input_len);
            let t_prefill = self.prefill.predict(lp);
            let t_chunked = self.estimate_chunked(input_len, lp, cpi);
            SplitDecision { partial_len: lp, t_prefill_est: t_prefill, t_chunked_est: t_chunked }
        };
        let diff = |d: &SplitDecision| d.t_prefill_est - d.t_chunked_est;

        // Find the smallest candidate index whose signed difference is
        // >= 0 (it exists: at i = n_cand, T_chunked = 0 and T_prefill > 0).
        let (mut lo, mut hi) = (1usize, n_cand);
        let first = eval(lo);
        if diff(&first) >= 0.0 {
            return first; // PPI already slower at the smallest split
        }
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if diff(&eval(mid)) >= 0.0 {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        // The |difference| minimum is at the crossing's neighbours.
        let below = eval(lo);
        let above = eval(hi);
        if diff(&below).abs() <= diff(&above).abs() {
            below
        } else {
            above
        }
    }

    /// The literal Algorithm 1 scan over every candidate (used by tests
    /// to validate the binary-search fast path, and available for
    /// experimentation with non-monotone predictors).
    pub fn balanced_split_exhaustive(
        &self,
        input_len: usize,
        cpi: &EngineStats,
    ) -> SplitDecision {
        let blocks_needed = input_len.div_ceil(cpi.block_size.max(1));
        if cpi.free_blocks < blocks_needed {
            return SplitDecision {
                partial_len: input_len,
                t_prefill_est: self.prefill.predict(input_len),
                t_chunked_est: 0.0,
            };
        }

        let mut best = SplitDecision {
            partial_len: input_len,
            t_prefill_est: self.prefill.predict(input_len),
            t_chunked_est: 0.0,
        };
        let mut best_diff = (best.t_prefill_est - best.t_chunked_est).abs();

        let n_cand = self.n_candidates.min(input_len);
        for i in 1..=n_cand {
            // L_p candidates: ceil(i/n · L_in), deduplicated by stepping.
            let lp = (input_len * i).div_ceil(n_cand).clamp(1, input_len);
            let t_prefill = self.prefill.predict(lp);
            let t_chunked = self.estimate_chunked(input_len, lp, cpi);
            let diff = (t_prefill - t_chunked).abs();
            if diff < best_diff {
                best_diff = diff;
                best = SplitDecision {
                    partial_len: lp,
                    t_prefill_est: t_prefill,
                    t_chunked_est: t_chunked,
                };
            }
        }
        best
    }

    /// Total chunked-prefill time for the remainder `L_in - L_p` on the
    /// CPI (Eq. 1 + Eq. 3, exactly as in Algorithm 1).
    fn estimate_chunked(
        &self,
        input_len: usize,
        lp: usize,
        cpi: &EngineStats,
    ) -> f64 {
        let l_c = input_len.saturating_sub(lp);
        if l_c == 0 {
            return 0.0;
        }
        // Prefill tokens available per iteration: budget minus one token
        // per decode request in the batch.
        let n_p = self.max_batched_tokens.saturating_sub(cpi.n_decode).max(1);
        let n_iter = l_c.div_ceil(n_p);
        // Context at the start of the last iteration (Algorithm 1).
        let l_last = lp + (l_c / n_p) * n_p;
        let avg_ctx = (input_len + l_last) as f64 / 2.0;
        n_iter as f64
            * self
                .chunked
                .predict(avg_ctx, cpi.decode_ctx_sum as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::fit::calibrate;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::perfmodel::PerfModel;
    use crate::simgpu::spec::{A10, A100};

    fn mk_balancer(policy: SplitPolicy) -> Balancer {
        let ppi = PerfModel::new(A10, LLAMA3_8B);
        let cpi = PerfModel::new(A100, LLAMA3_8B);
        let (p, c) = calibrate(&ppi, &cpi, 512, 0.0, 1);
        Balancer::new(policy, p, c, 512)
    }

    fn stats(free_blocks: usize, n_decode: usize, ctx_sum: usize) -> EngineStats {
        EngineStats {
            n_decode,
            decode_ctx_sum: ctx_sum,
            n_prefilling: 0,
            waiting: 0,
            free_blocks,
            block_size: 16,
            total_blocks: 40_000,
        }
    }

    #[test]
    fn balanced_split_equalizes_times() {
        let b = mk_balancer(SplitPolicy::Balanced);
        let d = b.split(2048, &stats(30_000, 48, 48 * 1200));
        assert!(d.partial_len >= 1 && d.partial_len <= 2048);
        // The chosen split should roughly balance both estimates.
        let rel = (d.t_prefill_est - d.t_chunked_est).abs()
            / d.t_prefill_est.max(d.t_chunked_est);
        assert!(rel < 0.25, "imbalance {rel}: {d:?}");
        // And be interior (neither all-PPI nor almost-none).
        assert!(
            d.partial_len > 64 && d.partial_len < 2048,
            "degenerate split {}",
            d.partial_len
        );
    }

    #[test]
    fn no_free_blocks_forces_full_prefill() {
        let b = mk_balancer(SplitPolicy::Balanced);
        let d = b.split(2048, &stats(10, 0, 0));
        assert_eq!(d.partial_len, 2048);
    }

    #[test]
    fn full_policy_always_full() {
        let b = mk_balancer(SplitPolicy::Full);
        let d = b.split(1500, &stats(30_000, 10, 10_000));
        assert_eq!(d.partial_len, 1500);
    }

    #[test]
    fn fixed_fraction_policy() {
        let b = mk_balancer(SplitPolicy::FixedFraction(0.25));
        let d = b.split(1000, &stats(30_000, 0, 0));
        assert_eq!(d.partial_len, 250);
    }

    #[test]
    fn busier_cpi_shifts_more_to_ppi() {
        // With a heavily loaded CPI, finishing the remainder there is
        // slower, so the balanced split pushes more prefix to the PPI.
        let b = mk_balancer(SplitPolicy::Balanced);
        let idle = b.split(2048, &stats(30_000, 0, 0)).partial_len;
        let busy = b.split(2048, &stats(30_000, 400, 400 * 1500)).partial_len;
        assert!(
            busy > idle,
            "busy CPI should increase partial len: idle={idle} busy={busy}"
        );
    }

    #[test]
    fn short_prompts_still_split_validly() {
        let b = mk_balancer(SplitPolicy::Balanced);
        for input in [1usize, 2, 7, 63] {
            let d = b.split(input, &stats(30_000, 16, 16_000));
            assert!(d.partial_len >= 1 && d.partial_len <= input, "{d:?}");
        }
    }

    #[test]
    fn decision_is_deterministic() {
        let b = mk_balancer(SplitPolicy::Balanced);
        let s = stats(30_000, 48, 60_000);
        assert_eq!(b.split(1777, &s).partial_len, b.split(1777, &s).partial_len);
    }
}
