//! Discrete-event simulation core: simulated time + a stable event queue.
//!
//! All paper experiments run on this clock (deterministic and seedable);
//! wall-clock only appears in the end-to-end example, where the real tiny
//! model executes via PJRT.  Time is integer nanoseconds to keep event
//! ordering exact and platform-independent.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in nanoseconds since experiment start.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> SimTime {
        debug_assert!(s >= 0.0 && s.is_finite(), "bad duration {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn from_millis(ms: u64) -> SimTime {
        SimTime(ms * 1_000_000)
    }

    /// Saturating addition of a duration in seconds.
    pub fn after_secs(self, s: f64) -> SimTime {
        SimTime(self.0.saturating_add((s * 1e9).round() as u64))
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

/// Heap entry: (time, insertion sequence) gives a stable FIFO tie-break,
/// which keeps simulations deterministic when events share a timestamp.
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue with stable FIFO ordering at equal times.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: SimTime::ZERO }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `t`.  Scheduling in the past is a
    /// logic error and panics in debug builds; in release it clamps to now
    /// (the event fires immediately, preserving forward progress).
    pub fn push(&mut self, t: SimTime, event: E) {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        let t = t.max(self.now);
        self.heap.push(Entry { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` `dt_secs` seconds from now.
    pub fn push_after(&mut self, dt_secs: f64, event: E) {
        self.push(self.now.after_secs(dt_secs), event);
    }

    /// Advance the clock to `t` without popping an event — used by
    /// online drivers when an external arrival lands between internal
    /// events, so relative scheduling ([`push_after`](Self::push_after))
    /// is anchored at the arrival instant.  Never moves backwards; the
    /// caller must have drained every event scheduled before `t` first.
    pub fn advance_now(&mut self, t: SimTime) {
        debug_assert!(
            self.peek_time().map_or(true, |pt| pt >= t),
            "advance_now({t}) past a pending event"
        );
        if t > self.now {
            self.now = t;
        }
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let entry = self.heap.pop()?;
        self.now = entry.time;
        Some((entry.time, entry.event))
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_roundtrip() {
        let t = SimTime::from_secs_f64(1.25);
        assert_eq!(t.0, 1_250_000_000);
        assert!((t.as_secs_f64() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), "c");
        q.push(SimTime(10), "a");
        q.push(SimTime(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn equal_times_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(SimTime(5), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_on_pop() {
        let mut q = EventQueue::new();
        q.push(SimTime(100), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(100));
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs_f64(1.0), "first");
        q.pop();
        q.push_after(0.5, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(1.5));
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(SimTime(10), 1);
        q.push(SimTime(50), 3);
        assert_eq!(q.pop().unwrap(), (SimTime(10), 1));
        q.push(SimTime(20), 2);
        assert_eq!(q.pop().unwrap(), (SimTime(20), 2));
        assert_eq!(q.pop().unwrap(), (SimTime(50), 3));
        assert!(q.pop().is_none());
    }

    #[test]
    fn advance_now_moves_clock_forward_only() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.advance_now(SimTime(500));
        assert_eq!(q.now(), SimTime(500));
        q.advance_now(SimTime(100)); // backwards: no-op
        assert_eq!(q.now(), SimTime(500));
        q.push_after(1.0, 7);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(500).after_secs(1.0));
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push(SimTime(1), ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
