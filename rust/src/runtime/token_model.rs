//! The real model on the request path: compiled HLO entry points, weight
//! literals, per-request KV state, greedy sampling.
//!
//! PJRT execution needs the `xla` bindings, which the offline build does
//! not ship; the executing implementation is therefore gated behind the
//! `pjrt` cargo feature.  Without it, [`TokenModel::load`] returns an
//! error explaining how to enable real serving, and everything else in
//! the crate (the full simulation stack) works unchanged.

use std::path::Path;

use crate::runtime::manifest::Manifest;
use crate::util::error::Result;

#[cfg(not(feature = "pjrt"))]
use crate::bail;

#[cfg(feature = "pjrt")]
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Host-side KV cache of one request: `[L, T, H_kv, D_h]` f32, flattened.
#[derive(Clone)]
pub struct KvState {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Tokens with valid KV (the request's current context length).
    pub ctx_len: usize,
}

impl KvState {
    pub fn new(manifest: &Manifest) -> Self {
        let n: usize = manifest.kv_shape().iter().product();
        KvState { k: vec![0.0; n], v: vec![0.0; n], ctx_len: 0 }
    }
}

#[cfg(feature = "pjrt")]
fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes,
    )?)
}

#[cfg(feature = "pjrt")]
fn i32_literal(dims: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

/// The tiny LLaMA-style model, loaded once and executed per scheduled
/// iteration.  Not `Sync`: owned by the serving worker thread.
pub struct TokenModel {
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    #[allow(dead_code)]
    client: PjRtClient,
    #[cfg(feature = "pjrt")]
    prefill_exe: PjRtLoadedExecutable,
    #[cfg(feature = "pjrt")]
    decode_exe: PjRtLoadedExecutable,
    /// Weight literals in `PARAM_ORDER` (the manifest's order).
    #[cfg(feature = "pjrt")]
    weights: Vec<Literal>,
}

#[cfg(feature = "pjrt")]
impl TokenModel {
    /// Load manifest + weights, compile both entry points on the PJRT CPU
    /// client.  This is the one-time cost; afterwards the request path is
    /// pure Rust + PJRT.
    pub fn load(dir: &Path) -> Result<TokenModel> {
        use crate::util::error::Context;
        use crate::bail;

        let manifest = Manifest::load(dir)?;
        let raw = std::fs::read(&manifest.weights_file)
            .with_context(|| format!("reading {:?}", manifest.weights_file))?;
        if raw.len() != manifest.weights_bytes() {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                raw.len(),
                manifest.weights_bytes()
            );
        }
        let mut weights = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let bytes = &raw[p.offset_bytes..p.offset_bytes + p.size_bytes];
            let lit = Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &p.shape,
                bytes,
            )?;
            weights.push(lit);
        }

        let client = PjRtClient::cpu()?;
        let load = |path: &Path| -> Result<PjRtLoadedExecutable> {
            use crate::util::error::Context as _;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = load(&manifest.prefill.file)?;
        let decode_exe = load(&manifest.decode.file)?;
        Ok(TokenModel { manifest, client, prefill_exe, decode_exe, weights })
    }

    /// Run one prefill chunk for one request.  `tokens` may be shorter
    /// than the chunk width (it is zero-padded); `q_start` is the absolute
    /// position of `tokens[0]`.  Returns the logits row of the **last
    /// valid token** and updates `kv` in place.
    pub fn prefill_chunk(
        &self,
        tokens: &[i32],
        q_start: usize,
        kv: &mut KvState,
    ) -> Result<Vec<f32>> {
        use crate::bail;

        let c = self.chunk_size();
        if tokens.is_empty() || tokens.len() > c {
            bail!("chunk must have 1..={c} tokens, got {}", tokens.len());
        }
        if q_start + tokens.len() > self.manifest.max_seq {
            bail!("prefill beyond max_seq");
        }
        let mut padded = vec![0i32; c];
        padded[..tokens.len()].copy_from_slice(tokens);

        let kv_dims = self.manifest.kv_shape().to_vec();
        let mut inputs: Vec<Literal> = self.weights.to_vec();
        inputs.push(i32_literal(&[c], &padded)?);
        inputs.push(i32_literal(&[1], &[q_start as i32])?);
        inputs.push(f32_literal(&kv_dims, &kv.k)?);
        inputs.push(f32_literal(&kv_dims, &kv.v)?);

        let result = self.prefill_exe.execute::<Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let (logits, new_k, new_v) = result.to_tuple3()?;
        let logits: Vec<f32> = logits.to_vec()?;
        kv.k = new_k.to_vec()?;
        kv.v = new_v.to_vec()?;
        kv.ctx_len = q_start + tokens.len();

        let vocab = self.manifest.vocab;
        let last = tokens.len() - 1;
        Ok(logits[last * vocab..(last + 1) * vocab].to_vec())
    }

    /// Run one batched decode step.  `entries[i] = (token, position, kv)`;
    /// unused batch slots are padded internally.  Returns one logits row
    /// per entry and updates each `KvState` in place.
    pub fn decode_batch(
        &self,
        entries: &mut [(i32, usize, &mut KvState)],
    ) -> Result<Vec<Vec<f32>>> {
        use crate::bail;

        let b = self.decode_batch_size();
        if entries.is_empty() || entries.len() > b {
            bail!("decode batch must have 1..={b} entries, got {}", entries.len());
        }
        let kv_shape = self.manifest.kv_shape();
        let per: usize = kv_shape.iter().product();

        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut k = vec![0.0f32; b * per];
        let mut v = vec![0.0f32; b * per];
        for (i, (tok, p, kv)) in entries.iter().enumerate() {
            tokens[i] = *tok;
            pos[i] = *p as i32;
            k[i * per..(i + 1) * per].copy_from_slice(&kv.k);
            v[i * per..(i + 1) * per].copy_from_slice(&kv.v);
        }

        let mut batched_dims = vec![b];
        batched_dims.extend_from_slice(&kv_shape);
        let mut inputs: Vec<Literal> = self.weights.to_vec();
        inputs.push(i32_literal(&[b], &tokens)?);
        inputs.push(i32_literal(&[b], &pos)?);
        inputs.push(f32_literal(&batched_dims, &k)?);
        inputs.push(f32_literal(&batched_dims, &v)?);

        let result = self.decode_exe.execute::<Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let (logits, new_k, new_v) = result.to_tuple3()?;
        let logits: Vec<f32> = logits.to_vec()?;
        let new_k: Vec<f32> = new_k.to_vec()?;
        let new_v: Vec<f32> = new_v.to_vec()?;

        let vocab = self.manifest.vocab;
        let mut out = Vec::with_capacity(entries.len());
        for (i, (_, p, kv)) in entries.iter_mut().enumerate() {
            kv.k.copy_from_slice(&new_k[i * per..(i + 1) * per]);
            kv.v.copy_from_slice(&new_v[i * per..(i + 1) * per]);
            kv.ctx_len = *p + 1;
            out.push(logits[i * vocab..(i + 1) * vocab].to_vec());
        }
        Ok(out)
    }
}

#[cfg(not(feature = "pjrt"))]
impl TokenModel {
    /// Validate the artifacts, then report that this build cannot execute
    /// them (the `pjrt` feature is off in the offline build).
    pub fn load(dir: &Path) -> Result<TokenModel> {
        let _ = Manifest::load(dir)?;
        bail!(
            "artifacts at {dir:?} are valid, but rust_pallas was built \
             without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the vendored `xla` bindings) \
             to execute the real model"
        );
    }

    pub fn prefill_chunk(
        &self,
        _tokens: &[i32],
        _q_start: usize,
        _kv: &mut KvState,
    ) -> Result<Vec<f32>> {
        bail!("rust_pallas was built without the `pjrt` feature");
    }

    pub fn decode_batch(
        &self,
        _entries: &mut [(i32, usize, &mut KvState)],
    ) -> Result<Vec<Vec<f32>>> {
        bail!("rust_pallas was built without the `pjrt` feature");
    }
}

impl TokenModel {
    pub fn chunk_size(&self) -> usize {
        self.manifest.prefill.width
    }

    pub fn decode_batch_size(&self) -> usize {
        self.manifest.decode.width
    }

    /// Greedy sampling.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            if x > best_v {
                best_v = x;
                best = i;
            }
        }
        best as i32
    }

    /// Convenience: full prefill of a prompt via repeated chunks; returns
    /// the first generated token.
    pub fn prefill_prompt(&self, prompt: &[i32], kv: &mut KvState) -> Result<i32> {
        let c = self.chunk_size();
        let mut last_logits = Vec::new();
        let mut start = 0;
        while start < prompt.len() {
            let end = (start + c).min(prompt.len());
            last_logits = self.prefill_chunk(&prompt[start..end], start, kv)?;
            start = end;
        }
        Ok(Self::argmax(&last_logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(TokenModel::argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(TokenModel::argmax(&[-5.0]), 0);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn load_without_pjrt_reports_feature() {
        // No artifacts directory: the manifest read fails first.
        let e = TokenModel::load(Path::new("/nonexistent")).unwrap_err();
        assert!(e.to_string().contains("manifest.json"));
    }

    // Full PJRT round-trip tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run).
}
