//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (parameter order, shapes, entry-point files).

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::{self, Value};
use crate::{anyhow, bail};

/// One parameter tensor's slot in `weights.bin`.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// One compiled entry point.
#[derive(Clone, Debug)]
pub struct EntryPoint {
    pub file: PathBuf,
    /// chunk size (prefill) or batch size (decode).
    pub width: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model_name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_model: usize,
    pub max_seq: usize,
    pub param_count: u64,
    pub weights_file: PathBuf,
    pub params: Vec<ParamEntry>,
    pub prefill: EntryPoint,
    pub decode: EntryPoint,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_value(&v, dir)
    }

    pub fn from_value(v: &Value, dir: &Path) -> Result<Manifest> {
        let model = v.get("model").ok_or_else(|| anyhow!("missing 'model'"))?;
        let get = |obj: &Value, key: &str| -> Result<usize> {
            obj.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("missing model.{key}"))
        };

        let mut params = Vec::new();
        let mut expected_offset = 0usize;
        for entry in v
            .get("params")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("missing 'params'"))?
        {
            let name = entry
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?
                .to_string();
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("param {name} missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape")))
                .collect::<Result<_>>()?;
            let offset_bytes = entry
                .get("offset_bytes")
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("param {name} missing offset"))?;
            let size_bytes = entry
                .get("size_bytes")
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("param {name} missing size"))?;
            if offset_bytes != expected_offset {
                bail!("param {name}: non-contiguous offset");
            }
            let elems: usize = shape.iter().product();
            if size_bytes != elems * 4 {
                bail!("param {name}: size {size_bytes} != shape {shape:?} * f32");
            }
            expected_offset += size_bytes;
            params.push(ParamEntry { name, shape, offset_bytes, size_bytes });
        }

        let entry_point = |key: &str, width_key: &str| -> Result<EntryPoint> {
            let e = v
                .path(&["entries", key])
                .ok_or_else(|| anyhow!("missing entries.{key}"))?;
            Ok(EntryPoint {
                file: dir.join(
                    e.get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("entries.{key}.file"))?,
                ),
                width: e
                    .get(width_key)
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow!("entries.{key}.{width_key}"))?,
            })
        };

        Ok(Manifest {
            model_name: model
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("unknown")
                .to_string(),
            vocab: get(model, "vocab")?,
            n_layers: get(model, "n_layers")?,
            n_heads: get(model, "n_heads")?,
            n_kv_heads: get(model, "n_kv_heads")?,
            head_dim: get(model, "head_dim")?,
            d_model: get(model, "d_model")?,
            max_seq: get(model, "max_seq")?,
            param_count: model
                .get("param_count")
                .and_then(Value::as_f64)
                .unwrap_or(0.0) as u64,
            weights_file: dir.join(
                v.get("weights_file")
                    .and_then(Value::as_str)
                    .unwrap_or("weights.bin"),
            ),
            params,
            prefill: entry_point("prefill", "chunk")?,
            decode: entry_point("decode", "batch")?,
        })
    }

    /// Total bytes `weights.bin` must have.
    pub fn weights_bytes(&self) -> usize {
        self.params.iter().map(|p| p.size_bytes).sum()
    }

    /// KV cache shape per request: `[n_layers, max_seq, n_kv_heads, head_dim]`.
    pub fn kv_shape(&self) -> [usize; 4] {
        [self.n_layers, self.max_seq, self.n_kv_heads, self.head_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "model": {"name": "tiny-llama", "vocab": 2048, "d_model": 256,
                "n_layers": 4, "n_heads": 8, "n_kv_heads": 2, "head_dim": 32,
                "d_ff": 704, "max_seq": 512, "param_count": 3868928},
      "weights_file": "weights.bin",
      "params": [
        {"name": "embed", "shape": [2048, 256], "offset_bytes": 0, "size_bytes": 2097152},
        {"name": "attn_norm", "shape": [4, 256], "offset_bytes": 2097152, "size_bytes": 4096}
      ],
      "entries": {
        "prefill": {"file": "prefill_c64.hlo.txt", "chunk": 64},
        "decode": {"file": "decode_b8.hlo.txt", "batch": 8}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(&v, Path::new("/x")).unwrap();
        assert_eq!(m.model_name, "tiny-llama");
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.prefill.width, 64);
        assert_eq!(m.decode.width, 8);
        assert_eq!(m.prefill.file, PathBuf::from("/x/prefill_c64.hlo.txt"));
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.kv_shape(), [4, 512, 2, 32]);
        assert_eq!(m.weights_bytes(), 2097152 + 4096);
    }

    #[test]
    fn rejects_non_contiguous_params() {
        let bad = SAMPLE.replace("\"offset_bytes\": 2097152", "\"offset_bytes\": 999");
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_value(&v, Path::new("/x")).is_err());
    }

    #[test]
    fn rejects_size_shape_mismatch() {
        let bad = SAMPLE.replace("\"size_bytes\": 4096", "\"size_bytes\": 4097");
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_value(&v, Path::new("/x")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // built by `make artifacts`
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model_name, "tiny-llama");
        assert_eq!(m.params.len(), 12);
        let bin = std::fs::metadata(&m.weights_file).unwrap().len() as usize;
        assert_eq!(bin, m.weights_bytes());
    }
}
