//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them on
//! the request path, with Python nowhere in sight.
//!
//! `make artifacts` (the build-time Python path) produces:
//!
//! * `prefill_c{C}.hlo.txt` / `decode_b{B}.hlo.txt` — HLO **text** for the
//!   two model entry points (text, not serialized protos: the crate's
//!   xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids; the
//!   text parser reassigns them);
//! * `weights.bin` + `manifest.json` — parameters and the wire format.
//!
//! [`TokenModel`] compiles each entry point once
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile`) and then serves `prefill_chunk` / `decode_batch`
//! calls from the Rust hot path.

pub mod manifest;
pub mod token_model;

pub use manifest::Manifest;
pub use token_model::{KvState, TokenModel};

/// Default artifacts directory, overridable via `CRONUS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("CRONUS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
