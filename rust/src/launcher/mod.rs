//! Experiment launchers — the shared implementations behind the CLI
//! (`cronus bench-*`) and the `cargo bench` targets.  One function per
//! paper table/figure (see DESIGN.md §4 for the experiment index).

use crate::benchkit::{time_once, Table};
use crate::checker::oracle::CheckSummary;
use crate::config::topology::ClusterConfig;
use crate::config::{DeploymentConfig, SystemKind};
use crate::cronus::balancer::SplitPolicy;
use crate::cronus::frontend::CronusSystem;
use crate::cronus::router::RoutePolicy;
use crate::engine::{EngineInstance, EngineRequest};
use crate::faults::FaultConfig;
use crate::simgpu::fit;
use crate::simgpu::link::LinkSpec;
use crate::simgpu::model_desc;
use crate::simgpu::perfmodel::PerfModel;
use crate::systems::cluster::{build_cluster_system, ClusterSystem};
use crate::systems::driver::{closed_loop, ClosedLoopStats};
use crate::systems::driver::{replay_trace, replay_trace_collect};
use crate::systems::{
    build_system, prefill_tokens_executed, AutoscaleConfig, RunOutcome, SystemEvent,
};
use crate::qos::{ClassId, ClassRegistry, ServiceClass};
use crate::util::rng::Rng;
use crate::workload::arrival::{at_rate, stamp, ArrivalProcess};
use crate::workload::azure::{generate, AzureTraceConfig};
use crate::workload::session::{generate_sessions, total_turns, Session, SessionConfig};
use crate::workload::Request;

/// Shared experiment options.
#[derive(Clone, Copy, Debug)]
pub struct ExperimentOpts {
    /// Requests per run (the paper uses 1000).
    pub n_requests: usize,
    pub seed: u64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts { n_requests: 1000, seed: 42 }
    }
}

/// The paper's workload: Azure-2023-like conversation trace.
pub fn paper_trace(opts: &ExperimentOpts) -> Vec<Request> {
    generate(opts.n_requests, &AzureTraceConfig::default(), opts.seed)
}

/// Max-throughput measurement (Table 2): all requests at t = 0.
pub fn max_throughput(
    kind: SystemKind,
    cfg: &DeploymentConfig,
    trace: &[Request],
) -> RunOutcome {
    let trace = stamp(trace, ArrivalProcess::AllAtOnce);
    replay_trace(build_system(kind, cfg).as_mut(), &trace)
}

/// Latency measurement (Fig. 4): fixed-interval arrivals at `rate_rps`.
pub fn latency_at_rate(
    kind: SystemKind,
    cfg: &DeploymentConfig,
    trace: &[Request],
    rate_rps: f64,
) -> RunOutcome {
    let trace = at_rate(trace, rate_rps);
    replay_trace(build_system(kind, cfg).as_mut(), &trace)
}

// ---------------------------------------------------------------------------
// Table 2
// ---------------------------------------------------------------------------

/// Reproduce Table 2: maximum throughput (requests/second) for every
/// approach on every (GPU pair, model) cell.
pub fn table2(opts: &ExperimentOpts) -> (Table, Vec<(String, SystemKind, f64)>) {
    let matrix = DeploymentConfig::paper_matrix();
    let mut table = Table::new(
        "Table 2: Maximum throughput (requests per second)",
        &[
            "Approach",
            "A100+A10 LLaMA3-8B",
            "A100+A10 Qwen2-7B",
            "A100+A30 LLaMA3-8B",
            "A100+A30 Qwen2-7B",
        ],
    );
    let trace = paper_trace(opts);
    let mut data = Vec::new();
    for kind in SystemKind::ALL {
        let mut cells = vec![kind.name().to_string()];
        for (label, cfg) in &matrix {
            let out = max_throughput(kind, cfg, &trace);
            debug_assert_eq!(out.report.n_finished, trace.len());
            cells.push(format!("{:.2}", out.report.throughput_rps));
            data.push((label.clone(), kind, out.report.throughput_rps));
        }
        table.row(cells);
    }
    (table, data)
}

// ---------------------------------------------------------------------------
// Fig. 4
// ---------------------------------------------------------------------------

/// One Fig. 4 panel: TTFT P99 and TBT P99 per system for one deployment
/// cell at a sub-saturation request rate.
pub struct Fig4Panel {
    pub label: String,
    pub rate_rps: f64,
    /// (system, ttft_p99_s, tbt_p99_s)
    pub rows: Vec<(SystemKind, f64, f64)>,
}

/// Reproduce Fig. 4: TTFT/TBT P99 under fixed-interval load.  Each
/// system is measured at `rate_frac` × *its own* maximum throughput
/// (iso-utilization): the sustainable-load latency the paper's figure
/// characterizes — at any single common rate the slower systems are
/// either nearly idle or diverging, and neither regime is informative.
pub fn fig4(opts: &ExperimentOpts, rate_frac: f64) -> Vec<Fig4Panel> {
    let matrix = DeploymentConfig::paper_matrix();
    let trace = paper_trace(opts);
    let mut panels = Vec::new();
    for (label, cfg) in &matrix {
        let mut rows = Vec::new();
        let mut mean_rate = 0.0;
        for kind in SystemKind::ALL {
            let cap = max_throughput(kind, cfg, &trace).report.throughput_rps;
            let rate = (cap * rate_frac).max(0.1);
            mean_rate += rate / SystemKind::ALL.len() as f64;
            let out = latency_at_rate(kind, cfg, &trace, rate);
            rows.push((kind, out.report.ttft_p99_s, out.report.tbt_p99_s));
        }
        panels.push(Fig4Panel { label: label.clone(), rate_rps: mean_rate, rows });
    }
    panels
}

pub fn fig4_tables(panels: &[Fig4Panel]) -> (Table, Table) {
    let mut header = vec!["Approach".to_string()];
    for p in panels {
        header.push(format!("{} @{:.2}rps", p.label, p.rate_rps));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut ttft = Table::new("Fig. 4 (row 1): TTFT P99 (s)", &header_refs);
    let mut tbt = Table::new("Fig. 4 (row 2): TBT P99 (s)", &header_refs);
    for (i, kind) in SystemKind::ALL.iter().enumerate() {
        let mut trow = vec![kind.name().to_string()];
        let mut brow = vec![kind.name().to_string()];
        for p in panels {
            let (_, t, b) = p.rows[i];
            trow.push(format!("{t:.3}"));
            brow.push(format!("{b:.4}"));
        }
        ttft.row(trow);
        tbt.row(brow);
    }
    (ttft, tbt)
}

// ---------------------------------------------------------------------------
// Table 3
// ---------------------------------------------------------------------------

/// Standalone max prefill throughput (req/s) of a dedicated prefill
/// instance on `pm`'s GPU: sequential whole-prompt prefills.
pub fn standalone_prefill_rps(pm: &PerfModel, trace: &[Request]) -> f64 {
    let total: f64 =
        trace.iter().map(|r| pm.prefill_time(r.input_len)).sum();
    trace.len() as f64 / total
}

/// Standalone max decode throughput (req/s) of a dedicated decode
/// instance on `pm`'s GPU: all prompts arrive as already-prefilled KV
/// (offset = input length) and only decode runs locally.
pub fn standalone_decode_rps(
    cfg: &DeploymentConfig,
    pm: &PerfModel,
    trace: &[Request],
) -> f64 {
    let mut engine = EngineInstance::from_params(
        "standalone-decode",
        *pm,
        cfg.link,
        &cfg.engine,
        cfg.engine.max_batched_tokens,
    );
    for r in trace {
        engine.submit(EngineRequest::with_offset(
            r.id,
            r.input_len,
            r.output_len,
            r.input_len,
        ));
    }
    let mut t = 0.0f64;
    let mut finished = 0usize;
    // Zero-allocation stepping: one plan + one event buffer, reused.
    let mut plan = crate::engine::IterationPlan::default();
    let mut events = Vec::new();
    while engine.has_work() {
        if !engine.plan_iteration_into(&mut plan) {
            break;
        }
        t += plan.duration_s;
        engine.complete_iteration_into(&plan, &mut events);
        for ev in &events {
            if matches!(ev, crate::engine::EngineEvent::Finished(_)) {
                finished += 1;
            }
        }
    }
    if t > 0.0 {
        finished as f64 / t
    } else {
        0.0
    }
}

/// Reproduce Table 3: relative GPU utilization of disaggregated prefill —
/// system max throughput divided by each instance's standalone max
/// throughput.
pub fn table3(opts: &ExperimentOpts) -> Table {
    let matrix = DeploymentConfig::paper_matrix();
    let trace = paper_trace(opts);
    let mut table = Table::new(
        "Table 3: relative GPU utilization rate in disaggregated prefill",
        &[
            "Configuration",
            "H-L Prefill",
            "H-L Decode",
            "L-H Prefill",
            "L-H Decode",
        ],
    );
    for (label, cfg) in &matrix {
        let mut cells = vec![label.clone()];
        for kind in [SystemKind::DisaggHighLow, SystemKind::DisaggLowHigh] {
            let out = max_throughput(kind, cfg, &trace);
            let sys_rps = out.report.throughput_rps;
            let sys = CronusSystem::new(
                cfg.clone(),
                SplitPolicy::Full,
                kind == SystemKind::DisaggHighLow,
                "probe",
            );
            let (ppi_pm, cpi_pm) = sys.perf_models();
            let prefill_cap = standalone_prefill_rps(&ppi_pm, &trace);
            let decode_cap = standalone_decode_rps(cfg, &cpi_pm, &trace);
            cells.push(format!("{:.0}%", 100.0 * sys_rps / prefill_cap));
            cells.push(format!("{:.0}%", 100.0 * sys_rps / decode_cap));
        }
        table.row(cells);
    }
    table
}

// ---------------------------------------------------------------------------
// Fig. 3
// ---------------------------------------------------------------------------

/// Reproduce Fig. 3: linearity of the chunked-prefill iteration time in
/// (prefill context, decode context) on the high-end GPU, with the fit's
/// R² and MAPE as the paper reports them.
pub fn fig3(noise: f64, seed: u64) -> Table {
    let mut table = Table::new(
        "Fig. 3: chunked prefill iteration time model (A100, 512-token chunks)",
        &["Model", "k_ctxp (µs/tok)", "k_ctxd (ns/tok)", "b_c (ms)", "R²", "MAPE"],
    );
    for model in [
        crate::simgpu::model_desc::LLAMA3_8B,
        crate::simgpu::model_desc::QWEN2_7B,
    ] {
        let pm = PerfModel::new(crate::simgpu::spec::A100, model);
        let mut rng = Rng::new(seed);
        let pcs: Vec<usize> = (1..=16).map(|i| i * 512).collect();
        let dcs: Vec<usize> = (0..=8).map(|i| i * 16_384).collect();
        let samples = fit::profile_chunked(&pm, 512, &pcs, &dcs, 48, noise, &mut rng);
        let f = fit::fit_chunked(&samples).expect("fit");
        table.row(vec![
            model.name.to_string(),
            format!("{:.3}", f.k_ctxp * 1e6),
            format!("{:.1}", f.k_ctxd * 1e9),
            format!("{:.3}", f.b_c * 1e3),
            format!("{:.4}", f.r2),
            format!("{:.2}%", f.mape * 100.0),
        ]);
    }
    // Eq. 2 fits (prefill on the low-end GPUs), for completeness.
    for gpu in [crate::simgpu::spec::A30, crate::simgpu::spec::A10] {
        let pm = PerfModel::new(gpu, crate::simgpu::model_desc::LLAMA3_8B);
        let mut rng = Rng::new(seed ^ 1);
        let lengths: Vec<usize> = (1..=16).map(|i| i * 512).collect();
        let samples = fit::profile_prefill(&pm, &lengths, noise.max(0.05), &mut rng);
        let f = fit::fit_prefill(&samples).expect("fit");
        table.row(vec![
            format!("prefill Eq.2 on {}", gpu.name),
            format!("{:.3}", f.k_p * 1e6),
            "-".into(),
            format!("{:.3}", f.b_p * 1e3),
            format!("{:.4}", f.r2),
            format!("{:.2}%", f.mape * 100.0),
        ]);
    }
    table
}

// ---------------------------------------------------------------------------
// Cluster scale-out (beyond the paper: N mixed pairs, one router)
// ---------------------------------------------------------------------------

/// One point of the cluster scale-out sweep.
pub struct ClusterSweepPoint {
    pub n_pairs: usize,
    pub outcome: RunOutcome,
    /// Throughput relative to the 1-pair baseline of the same sweep.
    pub scaling: f64,
}

/// Per-pair CPI utilization (busy time / cluster makespan) of a run,
/// rendered like `92/88/95%`.
pub fn cpi_utilization_summary(outcome: &RunOutcome) -> String {
    let makespan = outcome.report.makespan_s.max(1e-12);
    let cells: Vec<String> = outcome
        .instances
        .iter()
        .filter(|i| i.name.contains("CPI"))
        .map(|i| format!("{:.0}", 100.0 * i.busy_time_s / makespan))
        .collect();
    if cells.is_empty() {
        "-".to_string()
    } else {
        format!("{}%", cells.join("/"))
    }
}

/// Sweep the standard mixed-capability fleet ([`ClusterConfig::mixed`])
/// from 1 to `max_pairs` pairs under `policy`.  `slo_ttft_s` enables
/// router SLO admission control (requests the cluster cannot serve
/// within the TTFT target are shed or deferred instead of queueing).
pub fn cluster_sweep(
    opts: &ExperimentOpts,
    policy: RoutePolicy,
    max_pairs: usize,
    slo_ttft_s: Option<f64>,
) -> (Table, Vec<ClusterSweepPoint>) {
    let cluster = ClusterConfig::mixed(max_pairs.max(1), model_desc::LLAMA3_8B);
    cluster_sweep_topology(opts, policy, &cluster, slo_ttft_s)
}

/// Sweep an explicit topology (e.g. loaded from a `[topology]` TOML
/// section) by growing the cluster over its pair-list prefixes: point k
/// deploys the first k pairs.  Measures max throughput (all requests at
/// t = 0) and cluster-wide latency tails; the 1-pair point is the
/// scaling baseline.
pub fn cluster_sweep_topology(
    opts: &ExperimentOpts,
    policy: RoutePolicy,
    cluster: &ClusterConfig,
    slo_ttft_s: Option<f64>,
) -> (Table, Vec<ClusterSweepPoint>) {
    let trace = stamp(&paper_trace(opts), ArrivalProcess::AllAtOnce);
    let mut table = Table::new(
        format!(
            "Cluster scale-out, policy = {} ({} requests, all-at-once{})",
            policy.name(),
            opts.n_requests,
            match slo_ttft_s {
                Some(slo) => format!(", TTFT SLO {slo:.2}s"),
                None => String::new(),
            }
        ),
        &[
            "Pairs",
            "Topology (low-end)",
            "thpt (req/s)",
            "scaling",
            "TTFT p99 (s)",
            "TBT p99 (s)",
            "shed",
            "CPI util/pair",
        ],
    );
    let mut points: Vec<ClusterSweepPoint> = Vec::new();
    let mut base_rps = 0.0;
    for n_pairs in 1..=cluster.n_pairs() {
        let cfg = ClusterConfig::new(cluster.pairs[..n_pairs].to_vec());
        let lows: Vec<&str> = cfg.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        let mut sys = ClusterSystem::new(cfg, policy).with_slo_ttft(slo_ttft_s);
        // Driver-dropped deferrals are already folded into the report.
        let outcome = replay_trace(&mut sys, &trace);
        if n_pairs == 1 {
            base_rps = outcome.report.throughput_rps;
        }
        let scaling = if base_rps > 0.0 {
            outcome.report.throughput_rps / base_rps
        } else {
            0.0
        };
        table.row(vec![
            n_pairs.to_string(),
            lows.join("|"),
            format!("{:.2}", outcome.report.throughput_rps),
            format!("{scaling:.2}x"),
            format!("{:.3}", outcome.report.ttft_p99_s),
            format!("{:.4}", outcome.report.tbt_p99_s),
            outcome.report.n_rejected.to_string(),
            cpi_utilization_summary(&outcome),
        ]);
        points.push(ClusterSweepPoint { n_pairs, outcome, scaling });
    }
    (table, points)
}

// ---------------------------------------------------------------------------
// Closed-loop sessions + KV-affinity routing (beyond the paper)
// ---------------------------------------------------------------------------

/// One row of the closed-loop session sweep.
pub struct SessionPoint {
    pub policy: RoutePolicy,
    pub outcome: RunOutcome,
    pub stats: ClosedLoopStats,
    /// Prefill tokens the cluster actually computed (excludes KV
    /// transfers and resident session prefixes).
    pub prefill_tokens_executed: u64,
}

/// Serve a session workload closed-loop on a cluster under `policy`.
pub fn closed_loop_cluster(
    cluster: &ClusterConfig,
    policy: RoutePolicy,
    slo_ttft_s: Option<f64>,
    sessions: &[Session],
) -> (RunOutcome, ClosedLoopStats) {
    let mut sys =
        ClusterSystem::new(cluster.clone(), policy).with_slo_ttft(slo_ttft_s);
    closed_loop(&mut sys, sessions)
}

/// The standard closed-loop session workload for the affinity benches:
/// `seed` keeps it reproducible, `think_mean_s` models the user.
pub fn session_workload(
    n_sessions: usize,
    think_mean_s: f64,
    seed: u64,
) -> Vec<Session> {
    generate_sessions(&SessionConfig {
        n_sessions,
        think_mean_s,
        seed,
        ..SessionConfig::default()
    })
}

/// Drive the same closed-loop session workload under every routing
/// policy and tabulate turns served, latency tails, executed prefill
/// and KV-affinity hit accounting — the measurement behind
/// `cronus bench-cluster --closed-loop` and `benches/session_affinity`.
pub fn session_affinity_sweep(
    sessions: &[Session],
    cluster: &ClusterConfig,
    slo_ttft_s: Option<f64>,
) -> (Table, Vec<SessionPoint>) {
    let n_turns = total_turns(sessions);
    let mut table = Table::new(
        format!(
            "Closed-loop sessions: {} sessions / {} turns on {}{}",
            sessions.len(),
            n_turns,
            cluster.label(),
            match slo_ttft_s {
                Some(slo) => format!(", TTFT SLO {slo:.2}s"),
                None => String::new(),
            }
        ),
        &[
            "Policy",
            "turns",
            "thpt (req/s)",
            "TTFT p99 (s)",
            "TBT p99 (s)",
            "prefill tok",
            "kv hits",
            "hit rate",
            "saved tok",
            "shed",
        ],
    );
    let mut points = Vec::new();
    for policy in RoutePolicy::ALL {
        let (outcome, stats) = closed_loop_cluster(cluster, policy, slo_ttft_s, sessions);
        let executed = prefill_tokens_executed(&outcome);
        let r = &outcome.report;
        table.row(vec![
            policy.name().to_string(),
            format!("{}/{}", stats.n_finished_turns, n_turns),
            format!("{:.2}", r.throughput_rps),
            format!("{:.3}", r.ttft_p99_s),
            format!("{:.4}", r.tbt_p99_s),
            executed.to_string(),
            r.n_kv_hits.to_string(),
            format!("{:.0}%", 100.0 * r.kv_hit_rate),
            r.prefill_tokens_saved.to_string(),
            r.n_rejected.to_string(),
        ]);
        points.push(SessionPoint {
            policy,
            outcome,
            stats,
            prefill_tokens_executed: executed,
        });
    }
    (table, points)
}

// ---------------------------------------------------------------------------
// Cluster hot path: stepping overhead vs fleet size (EXPERIMENTS.md
// §Cluster-perf)
// ---------------------------------------------------------------------------

/// One point of the cluster hot-path sweep.
pub struct HotpathPoint {
    pub n_pairs: usize,
    /// Wall time of the whole replay (submit + advance + drain).
    pub wall_s: f64,
    /// Wall time per submitted request.
    pub ns_per_arrival: f64,
    /// Every `SystemEvent` the run produced (tokens + terminals).
    pub n_events: u64,
    pub events_per_s: f64,
    pub outcome: RunOutcome,
}

/// Measure the cluster stepping overhead as the fleet grows: the same
/// open-loop trace is replayed through a [`ClusterSystem`] at each pair
/// count under least-outstanding-tokens routing.  With the event
/// calendar, `submit`/`advance`/`next_event_at` touch only pairs with
/// due events, so ns/arrival must grow sublinearly in the pair count
/// (the pre-calendar stepper scanned all N pairs per arrival).  The
/// total simulated work is fixed by the trace, so the pair-count axis
/// isolates the cluster-layer overhead this PR indexes away.
pub fn cluster_hotpath_sweep(
    pair_counts: &[usize],
    n_requests: usize,
    rate_rps: f64,
    seed: u64,
) -> (Table, Vec<HotpathPoint>) {
    let base = generate(n_requests, &AzureTraceConfig::default(), seed);
    let trace = at_rate(&base, rate_rps);
    let mut table = Table::new(
        format!(
            "Cluster hot path: {n_requests} requests at {rate_rps:.0} rps, \
             least-outstanding routing"
        ),
        &["Pairs", "wall (s)", "ns/arrival", "events", "events/s", "finished"],
    );
    let mut points = Vec::new();
    for &n_pairs in pair_counts {
        let cfg = ClusterConfig::mixed(n_pairs, model_desc::LLAMA3_8B);
        let mut sys =
            ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens);
        let (outcome, wall_s) = time_once(|| replay_trace(&mut sys, &trace));
        let r = &outcome.report;
        // One FirstToken/Token per output token plus one terminal event
        // per request — the full stream the run produced.
        let n_events =
            (r.n_output_tokens + r.n_finished + r.n_rejected) as u64;
        let ns_per_arrival = wall_s * 1e9 / n_requests.max(1) as f64;
        let events_per_s = n_events as f64 / wall_s.max(1e-12);
        table.row(vec![
            n_pairs.to_string(),
            format!("{wall_s:.3}"),
            format!("{ns_per_arrival:.0}"),
            n_events.to_string(),
            format!("{events_per_s:.0}"),
            r.n_finished.to_string(),
        ]);
        points.push(HotpathPoint {
            n_pairs,
            wall_s,
            ns_per_arrival,
            n_events,
            events_per_s,
            outcome,
        });
    }
    (table, points)
}

/// Cluster max-throughput measurement (the Table 2 procedure lifted to
/// N pairs): all requests at t = 0.
pub fn cluster_max_throughput(
    cfg: &ClusterConfig,
    policy: RoutePolicy,
    trace: &[Request],
) -> RunOutcome {
    let trace = stamp(trace, ArrivalProcess::AllAtOnce);
    replay_trace(build_cluster_system(cfg, policy).as_mut(), &trace)
}

/// Cluster latency measurement (the Fig. 4 procedure lifted to N pairs):
/// fixed-interval arrivals at `rate_rps` into the router.
pub fn cluster_latency_at_rate(
    cfg: &ClusterConfig,
    policy: RoutePolicy,
    trace: &[Request],
    rate_rps: f64,
) -> RunOutcome {
    let trace = at_rate(trace, rate_rps);
    replay_trace(build_cluster_system(cfg, policy).as_mut(), &trace)
}

/// A two-phase arrival pattern for exercising the fleet controller: the
/// first 70% of requests arrive at `burst_rps`, the rest at a 10x
/// slower trickle — queue pressure forces a scale-up, the trickle lets
/// the fleet drain back down.
pub fn bursty_trace(n: usize, seed: u64, burst_rps: f64) -> Vec<Request> {
    let base = generate(n, &AzureTraceConfig::default(), seed);
    let split = base.len() * 7 / 10;
    let burst_gap = 1e9 / burst_rps.max(1e-3);
    let mut t_ns = 0.0f64;
    base.iter()
        .enumerate()
        .map(|(i, r)| {
            let mut r = *r;
            r.arrival_ns = t_ns as u64;
            t_ns += if i < split { burst_gap } else { 10.0 * burst_gap };
            r
        })
        .collect()
}

/// The `--autoscale` experiment: replay a burst-then-trickle trace
/// through an elastic fleet and tabulate every scale event with the
/// active pair count after it.
pub fn autoscale_demo(
    opts: &ExperimentOpts,
    cluster: &ClusterConfig,
    policy: RoutePolicy,
    autoscale: &AutoscaleConfig,
) -> (Table, RunOutcome) {
    let trace = bursty_trace(opts.n_requests, opts.seed, 40.0);
    let mut sys =
        ClusterSystem::new(cluster.clone(), policy).with_autoscale(autoscale.clone());
    let (out, events, _stats) = replay_trace_collect(&mut sys, &trace);
    let mut active = autoscale
        .initial_pairs
        .clamp(autoscale.min_pairs.max(1), cluster.n_pairs());
    let mut table = Table::new(
        format!(
            "elastic fleet: {} on {} requests (burst then trickle)",
            cluster.label(),
            trace.len()
        ),
        &["t (s)", "event", "pair", "active pairs"],
    );
    for ev in &events {
        let (label, pair, t) = match ev {
            SystemEvent::ScaleUp { pair, t } => ("scale-up", *pair, *t),
            SystemEvent::ScaleDown { pair, t } => ("scale-down", *pair, *t),
            _ => continue,
        };
        active = match label {
            "scale-up" => active + 1,
            _ => active.saturating_sub(1),
        };
        table.row(vec![
            format!("{:.3}", t.as_secs_f64()),
            label.to_string(),
            pair.to_string(),
            active.to_string(),
        ]);
    }
    (table, out)
}

// ---------------------------------------------------------------------------
// Multi-tenant QoS: service classes + weighted fair sharing (beyond the
// paper; EXPERIMENTS.md §QoS isolation)
// ---------------------------------------------------------------------------

/// The standard two-class demo contract set: an interactive `premium`
/// class (tier 1, weight 2, a TTFT SLO) and a bulk `batch` class
/// (tier 0, weight 1, no SLO).  Returns the registry and the premium
/// class id for stamping.
pub fn demo_class_registry(slo_ttft_s: f64) -> (ClassRegistry, ClassId) {
    let mut reg = ClassRegistry::new();
    let premium = reg.register(ServiceClass {
        tenant: "tenant-a".to_string(),
        tier: 1,
        weight: 2.0,
        slo_ttft_s: Some(slo_ttft_s),
        ..ServiceClass::named("premium")
    });
    reg.register(ServiceClass {
        tenant: "tenant-b".to_string(),
        ..ServiceClass::named("batch")
    });
    (reg, premium)
}

/// The same registry with every contract stripped (tier 0, weight 1, no
/// SLOs) — labels-only, so a baseline run reports the identical
/// per-class breakdown while admission behaves exactly like the
/// pre-QoS first-come first-served cluster.
fn labels_only(reg: &ClassRegistry) -> ClassRegistry {
    let mut plain = ClassRegistry::new();
    for c in reg.iter().skip(1) {
        plain.register(ServiceClass {
            tenant: c.tenant.clone(),
            model: c.model,
            ..ServiceClass::named(&c.name)
        });
    }
    plain
}

/// One run of the QoS demo: `label` is `baseline` (labels-only classes)
/// or `classed` (full contracts).
pub struct QosDemoPoint {
    pub label: &'static str,
    pub outcome: RunOutcome,
}

/// The `--classes` experiment: the same open-loop arrivals — 3 premium
/// requests in every 10, the rest batch — served twice on the same
/// fleet.  The baseline run carries the class *labels* but no
/// contracts (plain FCFS admission); the classed run enables the full
/// QoS subsystem (weighted fair sharing, per-class SLO admission,
/// over-SLO tier bypass).  The table shows each class's tail latency
/// under both, which is the isolation the subsystem buys.
pub fn qos_classes_demo(
    opts: &ExperimentOpts,
    cluster: &ClusterConfig,
    policy: RoutePolicy,
    rate_rps: f64,
    slo_ttft_s: f64,
) -> (Table, Vec<QosDemoPoint>) {
    let (registry, _) = demo_class_registry(slo_ttft_s);
    qos_classes_demo_with(opts, cluster, policy, rate_rps, registry)
}

/// [`qos_classes_demo`] over an arbitrary registry (e.g. one loaded
/// from a `[classes]` TOML table).  The interactive 3-in-10 share is
/// stamped with the highest-tier non-default class (ties to the lowest
/// id); the rest with the lowest-tier one.  Falls back to the built-in
/// premium/batch pair when the registry has fewer than two non-default
/// classes.
pub fn qos_classes_demo_with(
    opts: &ExperimentOpts,
    cluster: &ClusterConfig,
    policy: RoutePolicy,
    rate_rps: f64,
    registry: ClassRegistry,
) -> (Table, Vec<QosDemoPoint>) {
    let (registry, hot, cold) = if registry.len() >= 3 {
        let mut ids: Vec<ClassId> =
            (1..registry.len() as u16).map(ClassId).collect();
        // Highest tier first, ties to the lowest id.
        ids.sort_by_key(|&c| (std::cmp::Reverse(registry.get(c).tier), c.0));
        let (hot, cold) = (ids[0], *ids.last().unwrap());
        (registry, hot, cold)
    } else {
        let (reg, premium) = demo_class_registry(1.0);
        let batch = reg.id_of("batch").unwrap();
        (reg, premium, batch)
    };
    let slo_note = registry
        .get(hot)
        .slo_ttft_s
        .map_or("no TTFT SLO".to_string(), |s| format!("TTFT SLO {s:.2}s"));
    let hot_name = registry.get(hot).name.clone();
    let base = at_rate(&paper_trace(opts), rate_rps);
    // Deterministic class stamping: 3 interactive (hot) requests in
    // every 10 arrivals, the rest bulk (cold).
    let trace: Vec<Request> = base
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut r = *r;
            r.class = if i % 10 < 3 { hot } else { cold };
            r
        })
        .collect();

    let mut run = |label: &'static str, reg: ClassRegistry| {
        let mut sys =
            ClusterSystem::new(cluster.clone(), policy).with_classes(reg);
        QosDemoPoint { label, outcome: replay_trace(&mut sys, &trace) }
    };
    let points =
        vec![run("baseline", labels_only(&registry)), run("classed", registry)];

    let mut table = Table::new(
        format!(
            "Service classes on {}: {} requests at {rate_rps:.1} rps, \
             '{hot_name}' {slo_note} (3 '{hot_name}' per 10 arrivals)",
            cluster.label(),
            trace.len()
        ),
        &[
            "Run",
            "Class",
            "reqs",
            "finished",
            "shed",
            "thpt (req/s)",
            "TTFT p99 (s)",
            "TBT p99 (s)",
        ],
    );
    for p in &points {
        for c in &p.outcome.report.classes {
            table.row(vec![
                p.label.to_string(),
                c.name.clone(),
                c.n_requests.to_string(),
                c.n_finished.to_string(),
                c.n_shed.to_string(),
                format!("{:.2}", c.throughput_rps),
                format!("{:.3}", c.ttft_p99_s),
                format!("{:.4}", c.tbt_p99_s),
            ]);
        }
    }
    (table, points)
}

// ---------------------------------------------------------------------------
// Fault injection & recovery (beyond the paper; EXPERIMENTS.md §Faults)
// ---------------------------------------------------------------------------

/// One run of the fault-injection demo: `label` is `fault-free` (no
/// plan attached) or `faulted` (the deterministic plan injected).
pub struct FaultsDemoPoint {
    pub label: &'static str,
    pub outcome: RunOutcome,
}

/// The `--faults` experiment: the same open-loop arrivals served twice
/// on the same fleet — once fault-free, once with the deterministic
/// fault plan built from `faults` (scheduled and/or seeded pair
/// failures) injected mid-run.  The table shows what graceful
/// degradation costs: failures survived, aborted work retried through
/// admission, recovery latency, and the tail-latency delta against the
/// undisturbed baseline.
pub fn faults_demo(
    opts: &ExperimentOpts,
    cluster: &ClusterConfig,
    policy: RoutePolicy,
    rate_rps: f64,
    faults: &FaultConfig,
) -> Result<(Table, Vec<FaultsDemoPoint>), String> {
    let plan = faults.build_plan(cluster.n_pairs())?;
    if plan.is_empty() {
        return Err(
            "fault plan is empty: set faults.n_failures or faults.schedule".into(),
        );
    }
    let trace = at_rate(&paper_trace(opts), rate_rps);
    let run = |label: &'static str, faulted: bool| {
        let mut sys = ClusterSystem::new(cluster.clone(), policy);
        if faulted {
            sys = sys.with_faults(plan.clone(), faults.backoff());
        }
        FaultsDemoPoint { label, outcome: replay_trace(&mut sys, &trace) }
    };
    let points = vec![run("fault-free", false), run("faulted", true)];

    let mut table = Table::new(
        format!(
            "Fault injection on {}: {} requests at {rate_rps:.1} rps, \
             {} planned failure(s)",
            cluster.label(),
            trace.len(),
            plan.len()
        ),
        &[
            "Run",
            "reqs",
            "finished",
            "shed",
            "faults",
            "retried",
            "recovered",
            "mean rec (s)",
            "thpt (req/s)",
            "TTFT p99 (s)",
        ],
    );
    for p in &points {
        let r = &p.outcome.report;
        let mean_rec = if r.recovery_latency_s.is_empty() {
            "-".to_string()
        } else {
            let mean = r.recovery_latency_s.iter().sum::<f64>()
                / r.recovery_latency_s.len() as f64;
            format!("{mean:.3}")
        };
        table.row(vec![
            p.label.to_string(),
            r.n_requests.to_string(),
            r.n_finished.to_string(),
            r.n_rejected.to_string(),
            r.n_pair_failures.to_string(),
            r.n_retries.to_string(),
            r.n_recovered.to_string(),
            mean_rec,
            format!("{:.2}", r.throughput_rps),
            format!("{:.3}", r.ttft_p99_s),
        ]);
    }
    Ok((table, points))
}

// ---------------------------------------------------------------------------
// Cross-pair KV migration (beyond the paper; EXPERIMENTS.md §Migration)
// ---------------------------------------------------------------------------

/// One run of the migration demo: `label` is `no-link` (drains evict
/// warm sessions) or `migrate` (the inter-pair link ships them).
pub struct MigrationDemoPoint {
    pub label: &'static str,
    pub outcome: RunOutcome,
    pub stats: ClosedLoopStats,
    /// Prefill tokens the cluster actually computed (excludes KV
    /// transfers and resident session prefixes).
    pub prefill_tokens_executed: u64,
}

/// The `--migrate` experiment: a closed-loop session workload whose
/// think-time lulls let a twitchy fleet controller drain pairs between
/// turns, served twice on the same fleet and seed.  Without a link every
/// drain evicts the drained pair's warm prefixes and the sessions'
/// next turns re-prefill from scratch; with `link` configured the
/// drained pair hands its residency to a surviving pair over the wire
/// wherever `kv_transfer_time < recompute`.  Both runs complete the
/// same turns — the migrated one executes strictly fewer prefill
/// tokens, which is the entire payoff.
pub fn migration_demo(
    opts: &ExperimentOpts,
    cluster: &ClusterConfig,
    link: LinkSpec,
) -> (Table, Vec<MigrationDemoPoint>) {
    let n_sessions = opts.n_requests.max(2);
    let sessions = session_workload(n_sessions, 2.0, opts.seed);
    // Start wide and drain eagerly: every think-time lull retires a
    // pair, every turn burst brings one back.
    let autoscale = AutoscaleConfig {
        initial_pairs: cluster.n_pairs(),
        window_s: 0.25,
        cooldown_s: 0.25,
        scale_up_backlog: 2048.0,
        scale_down_backlog: 512.0,
        ..AutoscaleConfig::default()
    };
    let mut no_link = cluster.clone();
    no_link.link = None;
    for p in &mut no_link.pairs {
        p.link = None;
    }
    let linked = no_link.clone().with_link(link);
    let mut run = |label: &'static str, cfg: ClusterConfig| {
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::KvAffinity)
            .with_autoscale(autoscale.clone());
        let (outcome, stats) = closed_loop(&mut sys, &sessions);
        let prefill_tokens_executed = prefill_tokens_executed(&outcome);
        MigrationDemoPoint { label, outcome, stats, prefill_tokens_executed }
    };
    let points = vec![run("no-link", no_link), run("migrate", linked)];

    let n_turns = total_turns(&sessions);
    let mut table = Table::new(
        format!(
            "KV migration on {}: {} sessions / {} turns closed-loop, \
             link {}",
            cluster.label(),
            n_sessions,
            n_turns,
            link.spec()
        ),
        &[
            "Run",
            "turns",
            "prefill tok",
            "saved tok",
            "migrations",
            "migrated tok",
            "link (s)",
            "drains",
            "TTFT p99 (s)",
        ],
    );
    for p in &points {
        let r = &p.outcome.report;
        table.row(vec![
            p.label.to_string(),
            format!("{}/{}", p.stats.n_finished_turns, n_turns),
            p.prefill_tokens_executed.to_string(),
            r.prefill_tokens_saved.to_string(),
            r.n_migrations.to_string(),
            r.migrated_tokens.to_string(),
            format!("{:.4}", r.migration_time_s),
            r.n_scale_downs.to_string(),
            format!("{:.3}", r.ttft_p99_s),
        ]);
    }
    (table, points)
}

/// One-line (or, on failure, multi-line) verdict for a checked run —
/// shared by `bench-cluster --check` and `cronus repro`.
pub fn check_verdict(report: &crate::metrics::Report, summary: &CheckSummary) -> String {
    if summary.ok() {
        format!(
            "oracle: ok — {} events checked, {} finished / {} rejected, \
             no violations",
            summary.n_events, report.n_finished, report.n_rejected
        )
    } else {
        format!(
            "oracle: {} violation(s) in {} events\n{}",
            summary.violations.len(),
            summary.n_events,
            summary.render()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExperimentOpts {
        ExperimentOpts { n_requests: 20, seed: 7 }
    }

    #[test]
    fn table2_runs_small() {
        let (table, data) = table2(&tiny_opts());
        let s = table.render();
        assert!(s.contains("Cronus"));
        assert_eq!(data.len(), 5 * 4);
        assert!(data.iter().all(|(_, _, rps)| *rps > 0.0));
    }

    #[test]
    fn fig3_fit_quality() {
        let t = fig3(0.005, 1).render();
        assert!(t.contains("llama3-8b"));
        assert!(t.contains("0.99")); // R² ~0.99+
    }

    #[test]
    fn standalone_throughputs_ordered() {
        let cfg = DeploymentConfig::paper(
            crate::simgpu::spec::A100,
            crate::simgpu::spec::A10,
            crate::simgpu::model_desc::LLAMA3_8B,
        );
        let trace = paper_trace(&tiny_opts());
        let hi = PerfModel::new(cfg.high_gpu, cfg.model);
        let lo = PerfModel::new(cfg.low_gpu, cfg.model);
        assert!(
            standalone_prefill_rps(&hi, &trace)
                > standalone_prefill_rps(&lo, &trace)
        );
        assert!(
            standalone_decode_rps(&cfg, &hi, &trace)
                > standalone_decode_rps(&cfg, &lo, &trace)
        );
    }

    #[test]
    fn cluster_sweep_scales_and_reports_utilization() {
        let opts = ExperimentOpts { n_requests: 60, seed: 7 };
        let (table, points) =
            cluster_sweep(&opts, RoutePolicy::LeastOutstandingTokens, 2, None);
        assert_eq!(points.len(), 2);
        assert!((points[0].scaling - 1.0).abs() < 1e-9);
        assert!(
            points[1].scaling > 1.4,
            "2-pair scaling {:.2}",
            points[1].scaling
        );
        assert_eq!(points[1].outcome.report.n_finished, 60);
        let s = table.render();
        assert!(s.contains("least-outstanding"));
        assert!(s.contains('%'), "utilization column missing: {s}");
    }

    #[test]
    fn cluster_sweep_with_slo_renders_shed_column() {
        let opts = ExperimentOpts { n_requests: 50, seed: 7 };
        let (table, points) =
            cluster_sweep(&opts, RoutePolicy::SloAware, 1, Some(0.5));
        assert_eq!(points.len(), 1);
        let r = &points[0].outcome.report;
        // Everything the cluster admitted finished; an all-at-once burst
        // against a 0.5s TTFT SLO cannot admit the whole trace up front.
        assert_eq!(r.n_finished + r.n_rejected, r.n_requests);
        let s = table.render();
        assert!(s.contains("TTFT SLO"), "{s}");
        assert!(s.contains("shed"), "{s}");
    }

    #[test]
    fn session_affinity_sweep_reports_all_policies() {
        let sessions = session_workload(5, 0.5, 7);
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let (table, points) = session_affinity_sweep(&sessions, &cluster, None);
        assert_eq!(points.len(), RoutePolicy::ALL.len());
        let s = table.render();
        assert!(s.contains("kv-affinity"), "{s}");
        assert!(s.contains("hit rate"), "{s}");
        let lot = points
            .iter()
            .find(|p| p.policy == RoutePolicy::LeastOutstandingTokens)
            .unwrap();
        let aff = points
            .iter()
            .find(|p| p.policy == RoutePolicy::KvAffinity)
            .unwrap();
        // Same completed turns, strictly fewer executed prefill tokens.
        assert_eq!(lot.stats.n_finished_turns, aff.stats.n_finished_turns);
        assert!(aff.prefill_tokens_executed < lot.prefill_tokens_executed);
        assert!(aff.outcome.report.kv_hit_rate > 0.0);
    }

    #[test]
    fn cluster_hotpath_sweep_serves_every_point() {
        let (table, points) = cluster_hotpath_sweep(&[1, 2], 24, 16.0, 7);
        assert_eq!(points.len(), 2);
        for p in &points {
            assert_eq!(p.outcome.report.n_finished, 24);
            assert!(p.wall_s > 0.0 && p.ns_per_arrival > 0.0);
            // 24 finishes + at least one token each.
            assert!(p.n_events > 48, "{}", p.n_events);
            assert!(p.events_per_s > 0.0);
        }
        let s = table.render();
        assert!(s.contains("ns/arrival"), "{s}");
        assert!(s.contains("least-outstanding"), "{s}");
    }

    #[test]
    fn cluster_latency_at_rate_serves_all() {
        let cfg = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let trace = paper_trace(&tiny_opts());
        let out =
            cluster_latency_at_rate(&cfg, RoutePolicy::SloAware, &trace, 4.0);
        assert_eq!(out.report.n_finished, trace.len());
    }

    #[test]
    fn autoscale_demo_scales_up_under_burst() {
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let autoscale = AutoscaleConfig { scale_up_backlog: 1024.0, ..Default::default() };
        let (table, out) = autoscale_demo(
            &tiny_opts(),
            &cluster,
            RoutePolicy::LeastOutstandingTokens,
            &autoscale,
        );
        assert!(out.report.n_scale_ups >= 1, "burst never forced a scale-up");
        assert_eq!(out.report.n_finished, 20);
        assert!(table.render().contains("scale-up"));
    }

    #[test]
    fn qos_classes_demo_reports_both_runs_per_class() {
        let opts = ExperimentOpts { n_requests: 40, seed: 7 };
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let (table, points) = qos_classes_demo(
            &opts,
            &cluster,
            RoutePolicy::LeastOutstandingTokens,
            8.0,
            1.0,
        );
        assert_eq!(points.len(), 2);
        for p in &points {
            // default + premium + batch, in registry order.
            let names: Vec<&str> =
                p.outcome.report.classes.iter().map(|c| c.name.as_str()).collect();
            assert_eq!(names, ["default", "premium", "batch"]);
            // Every request is accounted to exactly one class.
            let total: usize =
                p.outcome.report.classes.iter().map(|c| c.n_requests).sum();
            assert_eq!(total, 40);
            let premium = &p.outcome.report.classes[1];
            assert_eq!(premium.n_requests, 12, "3 premium per 10 arrivals");
        }
        let s = table.render();
        assert!(s.contains("baseline") && s.contains("classed"), "{s}");
        assert!(s.contains("premium") && s.contains("batch"), "{s}");
    }

    #[test]
    fn faults_demo_reports_both_runs_and_counts_faults() {
        let opts = ExperimentOpts { n_requests: 30, seed: 7 };
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let cfg = FaultConfig {
            schedule: vec![crate::faults::parse_schedule_entry("0@0.4+1").unwrap()],
            ..FaultConfig::default()
        };
        let (table, points) = faults_demo(
            &opts,
            &cluster,
            RoutePolicy::LeastOutstandingTokens,
            8.0,
            &cfg,
        )
        .expect("demo runs");
        assert_eq!(points.len(), 2);
        let free = &points[0].outcome.report;
        let faulted = &points[1].outcome.report;
        assert_eq!(free.n_pair_failures, 0);
        assert_eq!(faulted.n_pair_failures, 1);
        assert_eq!(faulted.n_recovered, 1);
        // Conservation under the fault on both runs.
        assert_eq!(free.n_finished + free.n_rejected, 30);
        assert_eq!(faulted.n_finished + faulted.n_rejected, 30);
        let s = table.render();
        assert!(s.contains("fault-free") && s.contains("faulted"), "{s}");
    }

    #[test]
    fn migration_demo_same_turns_strictly_fewer_prefill_tokens() {
        // The tentpole's acceptance criterion: forced drains on a
        // closed-loop session workload, same seed with and without the
        // link — identical turns served, strictly fewer prefill tokens
        // executed, and migration chosen only where the transfer beats
        // the recompute (a fast link makes that unambiguous).
        let opts = ExperimentOpts { n_requests: 8, seed: 7 };
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let link = LinkSpec::parse("400G").unwrap();
        let (table, points) = migration_demo(&opts, &cluster, link);
        assert_eq!(points.len(), 2);
        let base = &points[0];
        let mig = &points[1];
        assert_eq!(base.label, "no-link");
        assert_eq!(mig.label, "migrate");
        // The controller actually drained pairs in both runs.
        assert!(base.outcome.report.n_scale_downs >= 1, "no drain forced");
        assert!(mig.outcome.report.n_scale_downs >= 1, "no drain forced");
        // No link, no migration.
        assert_eq!(base.outcome.report.n_migrations, 0);
        assert_eq!(base.outcome.report.migrated_tokens, 0);
        // The linked run shipped at least one warm prefix and paid wire
        // time for it.
        assert!(mig.outcome.report.n_migrations >= 1, "{}", table.render());
        assert!(mig.outcome.report.migrated_tokens > 0);
        assert!(mig.outcome.report.migration_time_s > 0.0);
        // Same turns completed, strictly fewer prefill tokens executed.
        assert_eq!(base.stats.n_finished_turns, mig.stats.n_finished_turns);
        assert_eq!(base.stats.n_shed_turns, 0);
        assert_eq!(mig.stats.n_shed_turns, 0);
        assert!(
            mig.prefill_tokens_executed < base.prefill_tokens_executed,
            "migrate {} !< no-link {}",
            mig.prefill_tokens_executed,
            base.prefill_tokens_executed
        );
        let s = table.render();
        assert!(s.contains("no-link") && s.contains("migrate"), "{s}");
    }

    #[test]
    fn faults_demo_rejects_empty_plan() {
        let opts = tiny_opts();
        let cluster = ClusterConfig::mixed(2, model_desc::LLAMA3_8B);
        let err = faults_demo(
            &opts,
            &cluster,
            RoutePolicy::LeastOutstandingTokens,
            8.0,
            &FaultConfig::default(),
        )
        .unwrap_err();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn bursty_trace_has_two_arrival_phases() {
        let t = bursty_trace(20, 7, 40.0);
        assert_eq!(t.len(), 20);
        let gap = |i: usize| t[i + 1].arrival_ns - t[i].arrival_ns;
        assert_eq!(gap(0), 25_000_000); // 40 rps
        assert_eq!(gap(15), 250_000_000); // 10x slower trickle
        assert!(t.windows(2).all(|w| w[0].arrival_ns < w[1].arrival_ns));
    }

    #[test]
    fn latency_at_rate_spaces_arrivals() {
        let cfg = DeploymentConfig::paper(
            crate::simgpu::spec::A100,
            crate::simgpu::spec::A10,
            crate::simgpu::model_desc::LLAMA3_8B,
        );
        let trace = paper_trace(&tiny_opts());
        let out = latency_at_rate(SystemKind::Cronus, &cfg, &trace, 2.0);
        assert_eq!(out.report.n_finished, trace.len());
        // At 2 rps the makespan must exceed the injection window.
        assert!(out.report.makespan_s >= (trace.len() - 1) as f64 / 2.0);
    }
}
