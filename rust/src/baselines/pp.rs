//! Pipeline parallelism + chunked prefill (§3.3).
//!
//! The model's layers are split across the two GPUs proportionally to
//! their BF16 FLOPS (§5.1: LLaMA3-8B 23+9 on A100+A10, 21+11 on
//! A100+A30; Qwen2-7B 20+8 / 18+10).  Requests are partitioned into two
//! microbatch groups whose iterations flow through the two stages as a
//! real pipeline: stage 0 (high-end GPU, first layer block) → activation
//! transfer over the link → stage 1 (low-end GPU, remaining layers).
//! Each group has at most one iteration in flight (iteration *n+1* needs
//! iteration *n*'s results), so bubbles appear whenever the stages are
//! imbalanced for the batch at hand.
//!
//! This surfaces both effects the paper blames for PP's weakness:
//!
//! * the FLOPS-proportional split balances *compute*-bound prefill, but
//!   decode is *bandwidth*-bound and the low-end card's bandwidth deficit
//!   (A10: 600 vs 2039 GB/s) makes stage 1 the decode bottleneck;
//! * every chunk/iteration pays an activation transfer + link latency,
//!   which accumulates over a prompt's chunks into TTFT.
//!
//! Memory: each GPU holds its layer fraction of the KV cache for *all*
//! requests, so per-group capacity is bounded by the tighter stage — the
//! reduced-batch-size effect of §3.3.
//!
//! The pipeline is online state (see [`crate::systems::ServingSystem`]):
//! arrivals join a microbatch group at `submit` time and the two stages
//! are stepped by `advance`.
//!
//! Like DP (see [`crate::baselines::dp`]), the group dispatcher honours
//! [`Request::kv_credit`] (ROADMAP DP/PP prefix-credit item, PP half):
//! a follow-up turn routed back to the pair holding its session's
//! prefix KV skips that prefix outright — both stages hold their layer
//! share of the resident KV, so the prefix is neither recomputed nor
//! transferred and KV-affinity clusters save prefill on PP pairs
//! exactly as they do on DP and Cronus pairs.

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::{Collector, ReqId};
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::link::LinkSpec;
use crate::simgpu::model_desc::ModelDesc;
use crate::simgpu::perfmodel::{IterationShape, PerfModel};
use crate::systems::{
    drain_pending_into, earliest_instant, past_deadline, record_engine_event,
    Admission, InstanceStat, RunOutcome, ServingSystem, SystemEvent,
};
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Stage 0 (high-end) finished group `g`'s forward part + transfer.
    Stage0Done(usize),
    /// Stage 1 (low-end) finished group `g`'s iteration.
    Stage1Done(usize),
}

/// Long-lived pipeline state: the two microbatch groups, stage occupancy
/// and the in-flight iteration plans.
struct PpState {
    hi_pm: PerfModel,
    lo_pm: PerfModel,
    link: LinkSpec,
    model: ModelDesc,
    sync_barrier: bool,
    groups: [EngineInstance; 2],
    q: EventQueue<Ev>,
    metrics: Collector,
    next_group: usize,
    /// A group's in-flight plan while it traverses the stages.
    plans: [Option<IterationPlan>; 2],
    /// Recycled plan buffers + shared event buffer (zero-allocation
    /// steady state), and the stage-1 iteration time computed once at
    /// stage-0 launch (the shape is immutable while in flight, so this
    /// replaces a per-pass `shape.clone()`).
    spares: [IterationPlan; 2],
    ev_buf: Vec<EngineEvent>,
    stage1_t: [f64; 2],
    stage0_busy: bool,
    stage1_busy: bool,
    /// Plans waiting for stage 1, by group index.
    stage1_queue: VecDeque<usize>,
    busy: [f64; 2],
    n_slots: u64,
    pending: Vec<SystemEvent>,
}

impl PpState {
    fn build(cfg: &DeploymentConfig, sync_barrier: bool) -> PpState {
        let (hi_pm, lo_pm) = stage_models_of(cfg);
        let group_capacity = group_kv_capacity_of(cfg);

        // Two microbatch groups.  The engines are used as scheduler +
        // allocator state machines; stage timings come from the stage
        // performance models.
        let groups = [
            EngineInstance::new(
                "PP-group0",
                hi_pm,
                cfg.link,
                cfg.engine.max_batched_tokens,
                cfg.engine.max_running,
                cfg.engine.block_size,
                group_capacity,
            ),
            EngineInstance::new(
                "PP-group1",
                hi_pm,
                cfg.link,
                cfg.engine.max_batched_tokens,
                cfg.engine.max_running,
                cfg.engine.block_size,
                group_capacity,
            ),
        ];
        PpState {
            hi_pm,
            lo_pm,
            link: cfg.link,
            model: cfg.model,
            sync_barrier,
            groups,
            q: EventQueue::new(),
            metrics: Collector::new(),
            next_group: 0,
            plans: [None, None],
            spares: [IterationPlan::default(), IterationPlan::default()],
            ev_buf: Vec::new(),
            stage1_t: [0.0; 2],
            stage0_busy: false,
            stage1_busy: false,
            stage1_queue: VecDeque::new(),
            busy: [0.0; 2],
            n_slots: 0,
            pending: Vec::new(),
        }
    }

    /// Activation transfer between stages for a batch.
    fn comm_time(&self, shape: &IterationShape) -> f64 {
        self.link
            .transfer_time(self.model.activation_bytes(shape.total_new_tokens()))
            + self.link.latency_s // small return hop (token ids)
    }

    fn run_until(&mut self, until: SimTime, inclusive: bool) {
        while let Some(t) = self.q.peek_time() {
            if past_deadline(t, until, inclusive) {
                break;
            }
            let (now, ev) = self.q.pop().unwrap();
            self.handle(now, ev);
        }
    }

    fn handle(&mut self, now: SimTime, ev: Ev) {
        match ev {
            Ev::Stage0Done(g) => {
                self.stage0_busy = false;
                self.stage1_queue.push_back(g);
            }
            Ev::Stage1Done(g) => {
                self.stage1_busy = false;
                let plan = self.plans[g].take().expect("stage1 without plan");
                let mut events = std::mem::take(&mut self.ev_buf);
                self.groups[g].complete_iteration_into(&plan, &mut events);
                for &ev in &events {
                    record_engine_event(&mut self.metrics, &mut self.pending, now, ev);
                }
                self.ev_buf = events;
                self.spares[g] = plan;
            }
        }
        self.pump();
    }

    /// Start stage passes wherever the pipeline has capacity: stage 1
    /// first (drain), then stage 0 (fill).
    fn pump(&mut self) {
        if !self.stage1_busy {
            if let Some(g) = self.stage1_queue.pop_front() {
                debug_assert!(self.plans[g].is_some(), "stage1 without plan");
                let t = self.stage1_t[g];
                self.busy[1] += t;
                self.stage1_busy = true;
                self.q.push_after(t, Ev::Stage1Done(g));
            }
        }
        let pipe_drained = self.plans[0].is_none() && self.plans[1].is_none();
        if !self.stage0_busy && (!self.sync_barrier || pipe_drained) {
            // Prefer the group that has waited longest: alternate.
            for attempt in 0..2 {
                let g = (self.next_group + attempt) % 2;
                if self.plans[g].is_some() {
                    continue; // iteration already in flight
                }
                let mut plan = std::mem::take(&mut self.spares[g]);
                if self.groups[g].plan_iteration_into(&mut plan) {
                    let compute = self.hi_pm.iteration_time(&plan.shape);
                    let t = compute + self.comm_time(&plan.shape);
                    // The stage-1 pass reuses the same immutable shape.
                    self.stage1_t[g] = self.lo_pm.iteration_time(&plan.shape);
                    self.busy[0] += compute;
                    self.n_slots += 1;
                    self.plans[g] = Some(plan);
                    self.stage0_busy = true;
                    self.next_group = 1 - g;
                    self.q.push_after(t, Ev::Stage0Done(g));
                    break;
                } else {
                    self.spares[g] = plan;
                }
            }
        }
    }
}

pub struct PpSystem {
    cfg: DeploymentConfig,
    /// Scheduler synchronization barrier between pipeline iterations, as
    /// in the vLLM version the paper evaluates (0.6.1): the next
    /// microbatch's stage-0 pass does not launch until the previous
    /// iteration fully drains, so stages never actually overlap.  This is
    /// the behaviour behind the paper's flat ~4 req/s PP throughput
    /// across hardware.  Set `false` for an idealized bubble-free
    /// pipeline (see the `ablation_balancer` bench).
    sync_barrier: bool,
    st: Option<PpState>,
}

/// Stage performance models under the FLOPS-proportional layer split.
fn stage_models_of(cfg: &DeploymentConfig) -> (PerfModel, PerfModel) {
    let (hi_layers, lo_layers) = cfg.pp_layer_split();
    let n = cfg.model.n_layers as f64;
    (
        PerfModel::with_layer_fraction(cfg.high_gpu, cfg.model, hi_layers as f64 / n),
        PerfModel::with_layer_fraction(cfg.low_gpu, cfg.model, lo_layers as f64 / n),
    )
}

/// Per-group KV capacity in tokens (half of the tighter stage) — the
/// single source both the simulator state and the public accessor use.
fn group_kv_capacity_of(cfg: &DeploymentConfig) -> usize {
    let (hi, lo) = stage_models_of(cfg);
    let reserve = cfg.engine.activation_reserve_frac;
    hi.kv_capacity_tokens(reserve).min(lo.kv_capacity_tokens(reserve)) / 2
}

impl PpSystem {
    pub fn new(cfg: DeploymentConfig) -> Self {
        PpSystem { cfg, sync_barrier: true, st: None }
    }

    /// Idealized pipeline without the vLLM scheduler barrier (ablation).
    pub fn without_sync_barrier(cfg: DeploymentConfig) -> Self {
        PpSystem { cfg, sync_barrier: false, st: None }
    }

    /// Stage performance models under the FLOPS-proportional layer split.
    pub fn stage_models(&self) -> (PerfModel, PerfModel) {
        stage_models_of(&self.cfg)
    }

    /// Per-group KV capacity in tokens (half of the tighter stage).
    pub fn group_kv_capacity(&self) -> usize {
        group_kv_capacity_of(&self.cfg)
    }

    fn state(&mut self) -> &mut PpState {
        if self.st.is_none() {
            self.st = Some(PpState::build(&self.cfg, self.sync_barrier));
        }
        self.st.as_mut().unwrap()
    }
}

impl ServingSystem for PpSystem {
    fn label(&self) -> String {
        "PP+Chunked".to_string()
    }

    fn submit(&mut self, t: SimTime, req: Request) -> Admission {
        let st = self.state();
        st.run_until(t, false);
        st.q.advance_now(t);
        st.metrics.on_arrival(req.id, t);
        // Dispatch to the emptier group (ties alternate with stage-0
        // scheduling, as in the batch loop).
        let g = match st.groups[0].n_in_instance().cmp(&st.groups[1].n_in_instance()) {
            std::cmp::Ordering::Equal => st.next_group,
            std::cmp::Ordering::Less => 0,
            std::cmp::Ordering::Greater => 1,
        };
        // A resident session prefix (granted by the cluster router via
        // `Request::kv_credit`) is skipped outright: each stage already
        // holds its layer share of that KV, so nothing is recomputed or
        // transferred.  Sessionless requests carry a zero credit and
        // take the exact `whole`-request path.
        let mut req = req;
        req.clamp_kv_credit();
        st.groups[g].submit(EngineRequest::with_prefix_credit(
            req.id,
            req.input_len,
            req.output_len,
            req.kv_credit,
            req.kv_credit,
        ));
        st.pump();
        Admission::Accepted
    }

    fn next_event_at(&self) -> Option<SimTime> {
        let st = self.st.as_ref()?;
        earliest_instant(&st.pending, st.q.peek_time())
    }

    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent> {
        let mut out = Vec::new();
        self.advance_into(until, &mut out);
        out
    }

    fn advance_into(&mut self, until: SimTime, out: &mut Vec<SystemEvent>) {
        if let Some(st) = self.st.as_mut() {
            st.run_until(until, true);
            drain_pending_into(&mut st.pending, until, out);
        }
    }

    fn abort_inflight(&mut self) -> Vec<ReqId> {
        let Some(old) = self.st.take() else {
            return Vec::new();
        };
        // Rebuild the pipeline from scratch: in-flight microbatch
        // iterations and all KV state die with the fault.  PP never
        // sheds, so the in-flight set is exactly the unfinished metrics
        // records; stage busy time and iteration counters carry over.
        let mut st = PpState::build(&self.cfg, self.sync_barrier);
        st.metrics = old.metrics;
        st.pending = old.pending;
        st.busy = old.busy;
        st.n_slots = old.n_slots;
        for g in 0..2 {
            st.groups[g].n_preemptions = old.groups[g].n_preemptions;
            st.groups[g].tokens_prefilled = old.groups[g].tokens_prefilled;
            st.groups[g].tokens_decoded = old.groups[g].tokens_decoded;
            st.groups[g].tokens_kv_received = old.groups[g].tokens_kv_received;
        }
        let ids = st.metrics.drop_unfinished();
        self.st = Some(st);
        ids
    }

    fn drain(&mut self) -> RunOutcome {
        let mut st = match self.st.take() {
            Some(st) => st,
            None => PpState::build(&self.cfg, self.sync_barrier),
        };
        st.run_until(SimTime(u64::MAX), true);
        let report = st.metrics.report(self.label());
        let (hi_layers, lo_layers) = self.cfg.pp_layer_split();
        let instances = vec![
            InstanceStat {
                name: format!(
                    "PP-stage0({}, {hi_layers} layers)",
                    self.cfg.high_gpu.name
                ),
                busy_time_s: st.busy[0],
                n_iterations: st.n_slots,
                n_preemptions: st.groups[0].n_preemptions + st.groups[1].n_preemptions,
                tokens_prefilled: st.groups[0].tokens_prefilled
                    + st.groups[1].tokens_prefilled,
                tokens_decoded: st.groups[0].tokens_decoded
                    + st.groups[1].tokens_decoded,
                tokens_kv_received: st.groups[0].tokens_kv_received
                    + st.groups[1].tokens_kv_received,
            },
            InstanceStat {
                name: format!(
                    "PP-stage1({}, {lo_layers} layers)",
                    self.cfg.low_gpu.name
                ),
                busy_time_s: st.busy[1],
                n_iterations: st.n_slots,
                n_preemptions: 0,
                tokens_prefilled: 0,
                tokens_decoded: 0,
                tokens_kv_received: 0,
            },
        ];
        RunOutcome { report, instances }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::driver::replay_trace;
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn pp_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(40, &AzureTraceConfig::default(), 9);
        let out = replay_trace(&mut PpSystem::new(cfg), &trace);
        assert_eq!(out.report.n_finished, 40);
        assert!(out.report.throughput_rps > 0.0);
    }

    #[test]
    fn stage_models_use_layer_split() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg);
        let (hi, lo) = sys.stage_models();
        assert!((hi.layer_fraction - 23.0 / 32.0).abs() < 1e-12);
        assert!((lo.layer_fraction - 9.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn group_capacity_bounded_by_low_end() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg.clone());
        let (_, lo) = sys.stage_models();
        let cap = sys.group_kv_capacity();
        assert_eq!(
            cap,
            lo.kv_capacity_tokens(cfg.engine.activation_reserve_frac) / 2,
            "the 24 GB card must be the binding constraint"
        );
    }

    #[test]
    fn decode_stage1_is_bottleneck() {
        // The FLOPS-proportional split leaves the low-bandwidth card with
        // a disproportionate share of memory-bound decode time.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg);
        let (hi, lo) = sys.stage_models();
        let shape = IterationShape {
            prefill: vec![],
            n_decode: 64,
            decode_ctx_sum: 64 * 1200,
        };
        assert!(
            lo.iteration_time(&shape) > hi.iteration_time(&shape),
            "low-end decode stage should dominate"
        );
    }

    #[test]
    fn pp_kv_credit_skips_resident_prefix_prefill() {
        use crate::systems::prefill_tokens_executed;
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        // Same follow-up turn, cold (no credit) vs warm (600 of the 1000
        // prompt tokens resident from the previous turn).
        let mut cold_req = crate::workload::Request::new(1, 0, 1000, 16);
        cold_req.session_id = 1;
        cold_req.prefix_len = 600;
        let mut warm_req = cold_req;
        warm_req.kv_credit = 600;

        let run = |req| replay_trace(&mut PpSystem::new(cfg.clone()), &[req]);
        let cold = run(cold_req);
        let warm = run(warm_req);
        assert_eq!(cold.report.n_finished, 1);
        assert_eq!(warm.report.n_finished, 1);
        // Executed prefill = prompt minus the resident credit, exactly —
        // and nothing moved over the link (the prefix was resident, not
        // transferred).
        assert_eq!(prefill_tokens_executed(&cold), 1000);
        assert_eq!(prefill_tokens_executed(&warm), 400);
        let received: u64 =
            warm.instances.iter().map(|i| i.tokens_kv_received).sum();
        assert_eq!(received, 0);
        // Skipping 600 prefill tokens can only help the finish time.
        assert!(warm.report.makespan_s <= cold.report.makespan_s);
    }

    #[test]
    fn pp_clamps_oversized_credit() {
        // A credit exceeding the declared prefix (or the whole prompt)
        // must be clamped, not panic the engine's invariants.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut req = crate::workload::Request::new(1, 0, 500, 8);
        req.session_id = 3;
        req.prefix_len = 499;
        req.kv_credit = 10_000;
        let out = replay_trace(&mut PpSystem::new(cfg), &[req]);
        assert_eq!(out.report.n_finished, 1);
        use crate::systems::prefill_tokens_executed;
        // Clamped to prefix_len (499): exactly one prompt token computed.
        assert_eq!(prefill_tokens_executed(&out), 1);
    }

    #[test]
    fn pp_is_deterministic() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(25, &AzureTraceConfig::default(), 12);
        let a = replay_trace(&mut PpSystem::new(cfg.clone()), &trace);
        let b = replay_trace(&mut PpSystem::new(cfg), &trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
    }
}
