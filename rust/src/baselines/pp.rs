//! Pipeline parallelism + chunked prefill (§3.3).
//!
//! The model's layers are split across the two GPUs proportionally to
//! their BF16 FLOPS (§5.1: LLaMA3-8B 23+9 on A100+A10, 21+11 on
//! A100+A30; Qwen2-7B 20+8 / 18+10).  Requests are partitioned into two
//! microbatch groups whose iterations flow through the two stages as a
//! real pipeline: stage 0 (high-end GPU, first layer block) → activation
//! transfer over the link → stage 1 (low-end GPU, remaining layers).
//! Each group has at most one iteration in flight (iteration *n+1* needs
//! iteration *n*'s results), so bubbles appear whenever the stages are
//! imbalanced for the batch at hand.
//!
//! This surfaces both effects the paper blames for PP's weakness:
//!
//! * the FLOPS-proportional split balances *compute*-bound prefill, but
//!   decode is *bandwidth*-bound and the low-end card's bandwidth deficit
//!   (A10: 600 vs 2039 GB/s) makes stage 1 the decode bottleneck;
//! * every chunk/iteration pays an activation transfer + link latency,
//!   which accumulates over a prompt's chunks into TTFT.
//!
//! Memory: each GPU holds its layer fraction of the KV cache for *all*
//! requests, so per-group capacity is bounded by the tighter stage — the
//! reduced-batch-size effect of §3.3.

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::Collector;
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::perfmodel::{IterationShape, PerfModel};
use crate::systems::{InstanceStat, RunOutcome, ServingSystem};
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(usize),
    /// Stage 0 (high-end) finished group `g`'s forward part + transfer.
    Stage0Done(usize),
    /// Stage 1 (low-end) finished group `g`'s iteration.
    Stage1Done(usize),
}

pub struct PpSystem {
    cfg: DeploymentConfig,
    /// Scheduler synchronization barrier between pipeline iterations, as
    /// in the vLLM version the paper evaluates (0.6.1): the next
    /// microbatch's stage-0 pass does not launch until the previous
    /// iteration fully drains, so stages never actually overlap.  This is
    /// the behaviour behind the paper's flat ~4 req/s PP throughput
    /// across hardware.  Set `false` for an idealized bubble-free
    /// pipeline (see the `ablation_balancer` bench).
    sync_barrier: bool,
}

impl PpSystem {
    pub fn new(cfg: DeploymentConfig) -> Self {
        PpSystem { cfg, sync_barrier: true }
    }

    /// Idealized pipeline without the vLLM scheduler barrier (ablation).
    pub fn without_sync_barrier(cfg: DeploymentConfig) -> Self {
        PpSystem { cfg, sync_barrier: false }
    }

    /// Stage performance models under the FLOPS-proportional layer split.
    pub fn stage_models(&self) -> (PerfModel, PerfModel) {
        let (hi_layers, lo_layers) = self.cfg.pp_layer_split();
        let n = self.cfg.model.n_layers as f64;
        (
            PerfModel::with_layer_fraction(
                self.cfg.high_gpu,
                self.cfg.model,
                hi_layers as f64 / n,
            ),
            PerfModel::with_layer_fraction(
                self.cfg.low_gpu,
                self.cfg.model,
                lo_layers as f64 / n,
            ),
        )
    }

    /// Per-group KV capacity in tokens (half of the tighter stage).
    fn group_kv_capacity(&self) -> usize {
        let (hi, lo) = self.stage_models();
        let reserve = self.cfg.engine.activation_reserve_frac;
        hi.kv_capacity_tokens(reserve).min(lo.kv_capacity_tokens(reserve)) / 2
    }

    /// Activation transfer between stages for a batch.
    fn comm_time(&self, shape: &IterationShape) -> f64 {
        self.cfg
            .link
            .transfer_time(self.cfg.model.activation_bytes(shape.total_new_tokens()))
            + self.cfg.link.latency_s // small return hop (token ids)
    }
}

impl ServingSystem for PpSystem {
    fn label(&self) -> String {
        "PP+Chunked".to_string()
    }

    fn run(&mut self, trace: &[Request]) -> RunOutcome {
        let cfg = &self.cfg;
        let (hi_pm, lo_pm) = self.stage_models();
        let group_capacity = self.group_kv_capacity();

        // Two microbatch groups.  The engines are used as scheduler +
        // allocator state machines; stage timings come from the stage
        // performance models.
        let mut groups = [
            EngineInstance::new(
                "PP-group0",
                hi_pm,
                cfg.link,
                cfg.engine.max_batched_tokens,
                cfg.engine.max_running,
                cfg.engine.block_size,
                group_capacity,
            ),
            EngineInstance::new(
                "PP-group1",
                hi_pm,
                cfg.link,
                cfg.engine.max_batched_tokens,
                cfg.engine.max_running,
                cfg.engine.block_size,
                group_capacity,
            ),
        ];

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut metrics = Collector::new();
        for (i, r) in trace.iter().enumerate() {
            q.push(SimTime(r.arrival_ns), Ev::Arrival(i));
        }
        let mut frontend: VecDeque<usize> = VecDeque::new();
        let mut next_group = 0usize;
        // Pipeline state: a group's in-flight plan while it traverses the
        // stages; stage occupancy; queue of plans waiting for stage 1.
        let mut plans: [Option<IterationPlan>; 2] = [None, None];
        let mut stage0_busy = false;
        let mut stage1_busy = false;
        let mut stage1_queue: VecDeque<usize> = VecDeque::new();
        let mut busy = [0.0f64; 2];
        let mut n_slots = 0u64;

        // Try to start a stage-0 pass for any group with no iteration in
        // flight.  Returns scheduled events via the queue.
        macro_rules! pump {
            ($q:expr) => {{
                // Stage 1 first (drain), then stage 0 (fill).
                if !stage1_busy {
                    if let Some(g) = stage1_queue.pop_front() {
                        let shape =
                            plans[g].as_ref().map(|p| p.shape.clone()).unwrap();
                        let t = lo_pm.iteration_time(&shape);
                        busy[1] += t;
                        stage1_busy = true;
                        $q.push_after(t, Ev::Stage1Done(g));
                    }
                }
                let pipe_drained =
                    plans[0].is_none() && plans[1].is_none();
                if !stage0_busy && (!self.sync_barrier || pipe_drained) {
                    // Prefer the group that has waited longest: alternate.
                    for attempt in 0..2 {
                        let g = (next_group + attempt) % 2;
                        if plans[g].is_some() {
                            continue; // iteration already in flight
                        }
                        if let Some(plan) = groups[g].plan_iteration() {
                            let t = hi_pm.iteration_time(&plan.shape)
                                + self.comm_time(&plan.shape);
                            busy[0] += hi_pm.iteration_time(&plan.shape);
                            n_slots += 1;
                            plans[g] = Some(plan);
                            stage0_busy = true;
                            next_group = 1 - g;
                            $q.push_after(t, Ev::Stage0Done(g));
                            break;
                        }
                    }
                }
            }};
        }

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrival(i) => {
                    metrics.on_arrival(trace[i].id, now);
                    frontend.push_back(i);
                }
                Ev::Stage0Done(g) => {
                    stage0_busy = false;
                    stage1_queue.push_back(g);
                }
                Ev::Stage1Done(g) => {
                    stage1_busy = false;
                    let plan = plans[g].take().expect("stage1 without plan");
                    for ev in groups[g].complete_iteration(&plan) {
                        match ev {
                            EngineEvent::FirstToken(id) | EngineEvent::Token(id) => {
                                metrics.on_token(id, now)
                            }
                            EngineEvent::Finished(id) => metrics.on_finish(id, now),
                            _ => {}
                        }
                    }
                }
            }

            // Dispatch arrivals to the emptier group (ties alternate).
            while let Some(&i) = frontend.front() {
                let r = &trace[i];
                let g = match groups[0]
                    .n_in_instance()
                    .cmp(&groups[1].n_in_instance())
                {
                    std::cmp::Ordering::Equal => next_group,
                    std::cmp::Ordering::Less => 0,
                    std::cmp::Ordering::Greater => 1,
                };
                groups[g].submit(EngineRequest::whole(r.id, r.input_len, r.output_len));
                frontend.pop_front();
            }

            pump!(q);
        }

        let report = metrics.report(self.label());
        let (hi_layers, lo_layers) = cfg.pp_layer_split();
        let instances = vec![
            InstanceStat {
                name: format!("PP-stage0({}, {hi_layers} layers)", cfg.high_gpu.name),
                busy_time_s: busy[0],
                n_iterations: n_slots,
                n_preemptions: groups[0].n_preemptions + groups[1].n_preemptions,
                tokens_prefilled: groups[0].tokens_prefilled + groups[1].tokens_prefilled,
                tokens_decoded: groups[0].tokens_decoded + groups[1].tokens_decoded,
            },
            InstanceStat {
                name: format!("PP-stage1({}, {lo_layers} layers)", cfg.low_gpu.name),
                busy_time_s: busy[1],
                n_iterations: n_slots,
                n_preemptions: 0,
                tokens_prefilled: 0,
                tokens_decoded: 0,
            },
        ];
        RunOutcome { report, instances }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn pp_serves_all_requests() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(40, &AzureTraceConfig::default(), 9);
        let out = PpSystem::new(cfg).run(&trace);
        assert_eq!(out.report.n_finished, 40);
        assert!(out.report.throughput_rps > 0.0);
    }

    #[test]
    fn stage_models_use_layer_split() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg);
        let (hi, lo) = sys.stage_models();
        assert!((hi.layer_fraction - 23.0 / 32.0).abs() < 1e-12);
        assert!((lo.layer_fraction - 9.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn group_capacity_bounded_by_low_end() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg.clone());
        let (_, lo) = sys.stage_models();
        let cap = sys.group_kv_capacity();
        assert_eq!(
            cap,
            lo.kv_capacity_tokens(cfg.engine.activation_reserve_frac) / 2,
            "the 24 GB card must be the binding constraint"
        );
    }

    #[test]
    fn decode_stage1_is_bottleneck() {
        // The FLOPS-proportional split leaves the low-bandwidth card with
        // a disproportionate share of memory-bound decode time.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sys = PpSystem::new(cfg);
        let (hi, lo) = sys.stage_models();
        let shape = IterationShape {
            prefill: vec![],
            n_decode: 64,
            decode_ctx_sum: 64 * 1200,
        };
        assert!(
            lo.iteration_time(&shape) > hi.iteration_time(&shape),
            "low-end decode stage should dominate"
        );
    }

    #[test]
    fn pp_is_deterministic() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(25, &AzureTraceConfig::default(), 12);
        let a = PpSystem::new(cfg.clone()).run(&trace);
        let b = PpSystem::new(cfg).run(&trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
    }
}
