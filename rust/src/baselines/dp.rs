//! Data parallelism + chunked prefill (§3.2).
//!
//! Each GPU runs an independent engine; a frontend dispatcher distributes
//! requests.  Per the paper's setup (§5.1): the high-end GPU gets weight
//! 3 and the low-end weight 1, the high-end waiting queue is capped at 3
//! requests and the low-end at 1, and the low-end engine uses a smaller
//! chunk (256 vs 512) to soften its TBT.  No inter-engine communication.
//!
//! The frontend holds requests when both queues are at their caps and
//! refills as capacity frees — the weighted-queue form of the paper's
//! "weights round-robin" router.

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::Collector;
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::perfmodel::PerfModel;
use crate::systems::{InstanceStat, RunOutcome, ServingSystem};
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(usize),
    /// Iteration completed on engine 0 (high) or 1 (low).
    EngineDone(usize),
}

pub struct DpSystem {
    cfg: DeploymentConfig,
}

impl DpSystem {
    pub fn new(cfg: DeploymentConfig) -> Self {
        DpSystem { cfg }
    }
}

impl ServingSystem for DpSystem {
    fn label(&self) -> String {
        "DP+Chunked".to_string()
    }

    fn run(&mut self, trace: &[Request]) -> RunOutcome {
        let cfg = &self.cfg;
        let hi_pm = PerfModel::new(cfg.high_gpu, cfg.model);
        let lo_pm = PerfModel::new(cfg.low_gpu, cfg.model);
        let mut engines = [
            EngineInstance::from_params(
                format!("DP-high({})", cfg.high_gpu.name),
                hi_pm,
                cfg.link,
                &cfg.engine,
                cfg.engine.max_batched_tokens,
            ),
            EngineInstance::from_params(
                format!("DP-low({})", cfg.low_gpu.name),
                lo_pm,
                cfg.link,
                &cfg.engine,
                cfg.dp_low_chunk,
            ),
        ];
        let caps = [cfg.dp_queue_caps.0, cfg.dp_queue_caps.1];
        let weights = [cfg.dp_weights.0 as f64, cfg.dp_weights.1 as f64];
        let mut dispatched = [0u64; 2];

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut metrics = Collector::new();
        for (i, r) in trace.iter().enumerate() {
            q.push(SimTime(r.arrival_ns), Ev::Arrival(i));
        }
        let mut frontend: VecDeque<usize> = VecDeque::new();
        let mut plans: [Option<IterationPlan>; 2] = [None, None];

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrival(i) => {
                    metrics.on_arrival(trace[i].id, now);
                    frontend.push_back(i);
                }
                Ev::EngineDone(which) => {
                    let plan = plans[which].take().expect("done without plan");
                    for ev in engines[which].complete_iteration(&plan) {
                        match ev {
                            EngineEvent::FirstToken(id) | EngineEvent::Token(id) => {
                                metrics.on_token(id, now)
                            }
                            EngineEvent::Finished(id) => metrics.on_finish(id, now),
                            _ => {}
                        }
                    }
                }
            }

            // Weighted dispatch into engines with queue headroom: among
            // engines below their cap, pick the most under-served
            // relative to its weight.
            loop {
                if frontend.is_empty() {
                    break;
                }
                let candidate = (0..2)
                    .filter(|&e| engines[e].stats().waiting < caps[e])
                    .min_by(|&a, &b| {
                        let ka = dispatched[a] as f64 / weights[a];
                        let kb = dispatched[b] as f64 / weights[b];
                        ka.partial_cmp(&kb).unwrap()
                    });
                let Some(e) = candidate else { break };
                let i = frontend.pop_front().unwrap();
                let r = &trace[i];
                engines[e].submit(EngineRequest::whole(
                    r.id,
                    r.input_len,
                    r.output_len,
                ));
                dispatched[e] += 1;
            }

            // Keep both engines busy.
            for e in 0..2 {
                if plans[e].is_none() {
                    if let Some(plan) = engines[e].plan_iteration() {
                        q.push_after(plan.duration_s, Ev::EngineDone(e));
                        plans[e] = Some(plan);
                    }
                }
            }
        }

        let report = metrics.report(self.label());
        let instances = engines
            .iter()
            .map(|e| InstanceStat {
                name: e.name.clone(),
                busy_time_s: e.busy_time_s,
                n_iterations: e.n_iterations,
                n_preemptions: e.n_preemptions,
                tokens_prefilled: e.tokens_prefilled,
                tokens_decoded: e.tokens_decoded,
            })
            .collect();
        RunOutcome { report, instances }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn dp_serves_all_and_respects_weights() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(80, &AzureTraceConfig::default(), 3);
        let out = DpSystem::new(cfg).run(&trace);
        assert_eq!(out.report.n_finished, 80);
        // High-end engine should have served roughly 3x the requests;
        // token counts are a proxy.
        let hi = &out.instances[0];
        let lo = &out.instances[1];
        let ratio = hi.tokens_decoded as f64 / lo.tokens_decoded.max(1) as f64;
        assert!(
            (1.5..6.0).contains(&ratio),
            "hi/lo decode ratio {ratio} (hi={}, lo={})",
            hi.tokens_decoded,
            lo.tokens_decoded
        );
    }

    #[test]
    fn dp_uses_no_kv_transfers() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(20, &AzureTraceConfig::default(), 5);
        let out = DpSystem::new(cfg).run(&trace);
        // total prefilled tokens == total input tokens (nothing shipped).
        let total_input: u64 = trace.iter().map(|r| r.input_len as u64).sum();
        let prefilled: u64 =
            out.instances.iter().map(|i| i.tokens_prefilled).sum();
        assert_eq!(prefilled, total_input);
    }

    #[test]
    fn dp_is_deterministic() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(30, &AzureTraceConfig::default(), 6);
        let a = DpSystem::new(cfg.clone()).run(&trace);
        let b = DpSystem::new(cfg).run(&trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
    }
}
