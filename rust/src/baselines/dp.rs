//! Data parallelism + chunked prefill (§3.2).
//!
//! Each GPU runs an independent engine; a frontend dispatcher distributes
//! requests.  Per the paper's setup (§5.1): the high-end GPU gets weight
//! 3 and the low-end weight 1, the high-end waiting queue is capped at 3
//! requests and the low-end at 1, and the low-end engine uses a smaller
//! chunk (256 vs 512) to soften its TBT.  No inter-engine communication.
//!
//! The frontend holds requests when both queues are at their caps and
//! refills as capacity frees — the weighted-queue form of the paper's
//! "weights round-robin" router.  The whole dispatcher is online state
//! (see [`crate::systems::ServingSystem`]): requests enter one at a time
//! via `submit` and the engines are stepped by `advance`.
//!
//! The dispatcher honours [`Request::kv_credit`] (ROADMAP DP/PP
//! prefix-credit item, DP half): a follow-up turn routed back to the
//! pair holding its session's prefix KV skips that prefix outright —
//! the engine neither recomputes nor transfers it — so KV-affinity
//! clusters save prefill on DP pairs exactly as they do on Cronus
//! pairs.

use std::collections::VecDeque;

use crate::config::DeploymentConfig;
use crate::engine::{EngineEvent, EngineInstance, EngineRequest, IterationPlan};
use crate::metrics::{Collector, ReqId};
use crate::simclock::{EventQueue, SimTime};
use crate::simgpu::perfmodel::PerfModel;
use crate::systems::{
    drain_pending_into, earliest_instant, past_deadline, record_engine_event,
    Admission, InstanceStat, RunOutcome, ServingSystem, SystemEvent,
};
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Iteration completed on engine 0 (high) or 1 (low).
    EngineDone(usize),
}

/// Long-lived dispatcher + engine state.
struct DpState {
    engines: [EngineInstance; 2],
    caps: [usize; 2],
    weights: [f64; 2],
    dispatched: [u64; 2],
    q: EventQueue<Ev>,
    metrics: Collector,
    frontend: VecDeque<Request>,
    plans: [Option<IterationPlan>; 2],
    /// Recycled plan buffers (one per engine) + shared event buffer:
    /// the steady-state step loop allocates nothing.
    spares: [IterationPlan; 2],
    ev_buf: Vec<EngineEvent>,
    pending: Vec<SystemEvent>,
}

impl DpState {
    fn build(cfg: &DeploymentConfig) -> DpState {
        let hi_pm = PerfModel::new(cfg.high_gpu, cfg.model);
        let lo_pm = PerfModel::new(cfg.low_gpu, cfg.model);
        let engines = [
            EngineInstance::from_params(
                format!("DP-high({})", cfg.high_gpu.name),
                hi_pm,
                cfg.link,
                &cfg.engine,
                cfg.engine.max_batched_tokens,
            ),
            EngineInstance::from_params(
                format!("DP-low({})", cfg.low_gpu.name),
                lo_pm,
                cfg.link,
                &cfg.engine,
                cfg.dp_low_chunk,
            ),
        ];
        DpState {
            engines,
            caps: [cfg.dp_queue_caps.0, cfg.dp_queue_caps.1],
            weights: [cfg.dp_weights.0 as f64, cfg.dp_weights.1 as f64],
            dispatched: [0; 2],
            q: EventQueue::new(),
            metrics: Collector::new(),
            frontend: VecDeque::new(),
            plans: [None, None],
            spares: [IterationPlan::default(), IterationPlan::default()],
            ev_buf: Vec::new(),
            pending: Vec::new(),
        }
    }

    fn run_until(&mut self, until: SimTime, inclusive: bool) {
        while let Some(t) = self.q.peek_time() {
            if past_deadline(t, until, inclusive) {
                break;
            }
            let (now, ev) = self.q.pop().unwrap();
            self.handle(now, ev);
        }
    }

    fn handle(&mut self, now: SimTime, ev: Ev) {
        let Ev::EngineDone(which) = ev;
        let plan = self.plans[which].take().expect("done without plan");
        let mut events = std::mem::take(&mut self.ev_buf);
        self.engines[which].complete_iteration_into(&plan, &mut events);
        for &ev in &events {
            record_engine_event(&mut self.metrics, &mut self.pending, now, ev);
        }
        self.ev_buf = events;
        self.spares[which] = plan;
        self.pump();
    }

    /// Weighted dispatch into engines with queue headroom, then keep both
    /// engines busy.
    fn pump(&mut self) {
        loop {
            if self.frontend.is_empty() {
                break;
            }
            // Among engines below their cap, pick the most under-served
            // relative to its weight.
            let candidate = (0..2)
                .filter(|&e| self.engines[e].stats().waiting < self.caps[e])
                .min_by(|&a, &b| {
                    let ka = self.dispatched[a] as f64 / self.weights[a];
                    let kb = self.dispatched[b] as f64 / self.weights[b];
                    ka.partial_cmp(&kb).unwrap()
                });
            let Some(e) = candidate else { break };
            let r = self.frontend.pop_front().unwrap();
            // A resident session prefix (granted by the cluster router
            // via `Request::kv_credit`) is skipped outright: its KV
            // already lives in this engine's pool, so it is neither
            // recomputed nor transferred.  Sessionless requests carry a
            // zero credit and take the exact `whole`-request path.
            self.engines[e].submit(EngineRequest::with_prefix_credit(
                r.id,
                r.input_len,
                r.output_len,
                r.kv_credit,
                r.kv_credit,
            ));
            self.dispatched[e] += 1;
        }

        for e in 0..2 {
            if self.plans[e].is_none() {
                let mut plan = std::mem::take(&mut self.spares[e]);
                if self.engines[e].plan_iteration_into(&mut plan) {
                    self.q.push_after(plan.duration_s, Ev::EngineDone(e));
                    self.plans[e] = Some(plan);
                } else {
                    self.spares[e] = plan;
                }
            }
        }
    }
}

pub struct DpSystem {
    cfg: DeploymentConfig,
    st: Option<DpState>,
}

impl DpSystem {
    pub fn new(cfg: DeploymentConfig) -> Self {
        DpSystem { cfg, st: None }
    }

    fn state(&mut self) -> &mut DpState {
        if self.st.is_none() {
            self.st = Some(DpState::build(&self.cfg));
        }
        self.st.as_mut().unwrap()
    }
}

impl ServingSystem for DpSystem {
    fn label(&self) -> String {
        "DP+Chunked".to_string()
    }

    fn submit(&mut self, t: SimTime, req: Request) -> Admission {
        let st = self.state();
        st.run_until(t, false);
        st.q.advance_now(t);
        st.metrics.on_arrival(req.id, t);
        let mut req = req;
        req.clamp_kv_credit();
        st.frontend.push_back(req);
        st.pump();
        Admission::Accepted
    }

    fn next_event_at(&self) -> Option<SimTime> {
        let st = self.st.as_ref()?;
        earliest_instant(&st.pending, st.q.peek_time())
    }

    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent> {
        let mut out = Vec::new();
        self.advance_into(until, &mut out);
        out
    }

    fn advance_into(&mut self, until: SimTime, out: &mut Vec<SystemEvent>) {
        if let Some(st) = self.st.as_mut() {
            st.run_until(until, true);
            drain_pending_into(&mut st.pending, until, out);
        }
    }

    fn abort_inflight(&mut self) -> Vec<ReqId> {
        let Some(old) = self.st.take() else {
            return Vec::new();
        };
        // Rebuild the dispatcher + engines from scratch: queued and
        // running work and all KV state die with the fault.  DP never
        // sheds, so the in-flight set is exactly the unfinished metrics
        // records; utilization counters and dispatch history carry over.
        let mut st = DpState::build(&self.cfg);
        st.metrics = old.metrics;
        st.pending = old.pending;
        st.dispatched = old.dispatched;
        for e in 0..2 {
            st.engines[e].busy_time_s = old.engines[e].busy_time_s;
            st.engines[e].n_iterations = old.engines[e].n_iterations;
            st.engines[e].n_preemptions = old.engines[e].n_preemptions;
            st.engines[e].tokens_prefilled = old.engines[e].tokens_prefilled;
            st.engines[e].tokens_decoded = old.engines[e].tokens_decoded;
            st.engines[e].tokens_kv_received = old.engines[e].tokens_kv_received;
        }
        let ids = st.metrics.drop_unfinished();
        self.st = Some(st);
        ids
    }

    fn drain(&mut self) -> RunOutcome {
        let mut st = match self.st.take() {
            Some(st) => st,
            None => DpState::build(&self.cfg),
        };
        st.run_until(SimTime(u64::MAX), true);
        let report = st.metrics.report(self.label());
        let instances = st
            .engines
            .iter()
            .map(|e| InstanceStat {
                name: e.name.clone(),
                busy_time_s: e.busy_time_s,
                n_iterations: e.n_iterations,
                n_preemptions: e.n_preemptions,
                tokens_prefilled: e.tokens_prefilled,
                tokens_decoded: e.tokens_decoded,
                tokens_kv_received: e.tokens_kv_received,
            })
            .collect();
        RunOutcome { report, instances }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::driver::replay_trace;
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn dp_serves_all_and_respects_weights() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(80, &AzureTraceConfig::default(), 3);
        let out = replay_trace(&mut DpSystem::new(cfg), &trace);
        assert_eq!(out.report.n_finished, 80);
        // High-end engine should have served roughly 3x the requests;
        // token counts are a proxy.
        let hi = &out.instances[0];
        let lo = &out.instances[1];
        let ratio = hi.tokens_decoded as f64 / lo.tokens_decoded.max(1) as f64;
        assert!(
            (1.5..6.0).contains(&ratio),
            "hi/lo decode ratio {ratio} (hi={}, lo={})",
            hi.tokens_decoded,
            lo.tokens_decoded
        );
    }

    #[test]
    fn dp_uses_no_kv_transfers() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(20, &AzureTraceConfig::default(), 5);
        let out = replay_trace(&mut DpSystem::new(cfg), &trace);
        // total prefilled tokens == total input tokens (nothing shipped).
        let total_input: u64 = trace.iter().map(|r| r.input_len as u64).sum();
        let prefilled: u64 =
            out.instances.iter().map(|i| i.tokens_prefilled).sum();
        assert_eq!(prefilled, total_input);
    }

    #[test]
    fn dp_kv_credit_skips_resident_prefix_prefill() {
        use crate::systems::prefill_tokens_executed;
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        // Same follow-up turn, cold (no credit) vs warm (600 of the 1000
        // prompt tokens resident from the previous turn).
        let mut cold_req = crate::workload::Request::new(1, 0, 1000, 16);
        cold_req.session_id = 1;
        cold_req.prefix_len = 600;
        let mut warm_req = cold_req;
        warm_req.kv_credit = 600;

        let run = |req| replay_trace(&mut DpSystem::new(cfg.clone()), &[req]);
        let cold = run(cold_req);
        let warm = run(warm_req);
        assert_eq!(cold.report.n_finished, 1);
        assert_eq!(warm.report.n_finished, 1);
        // Executed prefill = prompt minus the resident credit, exactly —
        // and nothing moved over the link (the prefix was resident, not
        // transferred).
        assert_eq!(prefill_tokens_executed(&cold), 1000);
        assert_eq!(prefill_tokens_executed(&warm), 400);
        let received: u64 =
            warm.instances.iter().map(|i| i.tokens_kv_received).sum();
        assert_eq!(received, 0);
        // Skipping 600 prefill tokens can only help the finish time.
        assert!(warm.report.makespan_s <= cold.report.makespan_s);
    }

    #[test]
    fn dp_clamps_oversized_credit() {
        // A credit exceeding the declared prefix (or the whole prompt)
        // must be clamped, not panic the engine's invariants.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut req = crate::workload::Request::new(1, 0, 500, 8);
        req.session_id = 3;
        req.prefix_len = 499;
        req.kv_credit = 10_000;
        let out = replay_trace(&mut DpSystem::new(cfg), &[req]);
        assert_eq!(out.report.n_finished, 1);
        use crate::systems::prefill_tokens_executed;
        // Clamped to prefix_len (499): exactly one prompt token computed.
        assert_eq!(prefill_tokens_executed(&out), 1);
    }

    #[test]
    fn dp_is_deterministic() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(30, &AzureTraceConfig::default(), 6);
        let a = replay_trace(&mut DpSystem::new(cfg.clone()), &trace);
        let b = replay_trace(&mut DpSystem::new(cfg), &trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
    }
}
