//! The paper's four baselines (§3, Table 1).
//!
//! * [`dp`] — data parallelism + chunked prefill: independent engines per
//!   GPU behind a weighted round-robin dispatcher with queue caps.
//! * [`pp`] — pipeline parallelism + chunked prefill: the model's layers
//!   split across both GPUs proportionally to BF16 FLOPS, microbatches
//!   alternating through the two stages with per-boundary communication.
//! * Disaggregated prefill (both directions) is implemented by the Cronus
//!   machinery itself with the split forced to the full prompt — see
//!   [`crate::cronus`].

pub mod dp;
pub mod pp;
