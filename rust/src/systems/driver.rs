//! Open-loop trace replay over the online [`ServingSystem`] lifecycle.
//!
//! [`replay_trace`] is the migration bridge from the old batch
//! `run(trace)` API: it feeds every recorded arrival to
//! [`ServingSystem::submit`] at its arrival instant (the system drains
//! its internal events up to each instant itself, so event processing
//! order is identical to the old single-queue loop), honours
//! [`Admission::Deferred`] with bounded retries, and finishes with
//! [`ServingSystem::drain`].  Every launcher, bench, example and CLI
//! path serves traces through this harness.
//!
//! Replay throughput is bounded by the engines' iteration loop, which
//! is allocation-free in steady state (every system steps its engines
//! through reusable plan/event scratch buffers — see EXPERIMENTS.md
//! §Perf); the driver itself keeps peak memory at one horizon's events
//! by discarding slices incrementally when nobody collects them.

use crate::simclock::SimTime;
use crate::systems::{Admission, RunOutcome, ServingSystem, SystemEvent};
use crate::workload::Request;

/// How often a single request may be deferred by SLO admission control
/// before the open-loop driver gives up and drops it.
pub const MAX_DEFERRALS: usize = 32;

/// Bookkeeping of one open-loop replay.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Distinct trace requests offered at least once.
    pub n_submitted: usize,
    pub n_accepted: usize,
    /// Requests the system rejected outright.
    pub n_rejected: usize,
    /// Deferral events (a request retried N times counts N).
    pub n_deferred: usize,
    /// Requests dropped after [`MAX_DEFERRALS`] retries.
    pub n_dropped: usize,
}

/// Serve a whole recorded trace through the online API, reproducing the
/// pre-redesign batch semantics, and return the final outcome.
///
/// Requests the driver drops after [`MAX_DEFERRALS`] retries are folded
/// into the outcome (`n_requests` and `n_rejected`) and surfaced as
/// synthetic [`SystemEvent::Shed`]s by [`replay_trace_collect`], so no
/// request ever vanishes silently.
pub fn replay_trace(system: &mut dyn ServingSystem, trace: &[Request]) -> RunOutcome {
    replay_trace_impl(system, trace, false).0
}

/// [`replay_trace`], additionally returning every [`SystemEvent`] the
/// run produced (in simulation-time order per system) and the replay's
/// admission bookkeeping.
pub fn replay_trace_collect(
    system: &mut dyn ServingSystem,
    trace: &[Request],
) -> (RunOutcome, Vec<SystemEvent>, ReplayStats) {
    replay_trace_impl(system, trace, true)
}

fn replay_trace_impl(
    system: &mut dyn ServingSystem,
    trace: &[Request],
    collect: bool,
) -> (RunOutcome, Vec<SystemEvent>, ReplayStats) {
    // Arrival order; the sort is stable so ties keep trace order, which
    // matches how the old batch loop enqueued arrivals.
    let mut arrivals: Vec<Request> = trace.to_vec();
    arrivals.sort_by_key(|r| r.arrival_ns);

    let mut stats = ReplayStats {
        n_submitted: arrivals.len(),
        ..ReplayStats::default()
    };
    // Deferred retries: (retry_at, request, attempts so far).  Rare (SLO
    // admission only), so a linear-scan priority list is fine.
    let mut deferred: Vec<(SimTime, Request, usize)> = Vec::new();
    // Synthetic Shed events for requests dropped at the retry cap — the
    // system never accepted them, so the driver records the loss.
    let mut dropped: Vec<SystemEvent> = Vec::new();
    let mut next_arrival = 0usize;

    loop {
        let arr_t = arrivals.get(next_arrival).map(|r| SimTime(r.arrival_ns));
        let def = deferred
            .iter()
            .enumerate()
            .min_by_key(|(i, (t, _, _))| (t.0, *i))
            .map(|(i, (t, _, _))| (i, *t));
        // Earliest submission instant; trace arrivals win ties so a
        // retried request queues behind fresh load at the same instant.
        let (t, req, attempts) = match (arr_t, def) {
            (None, None) => break,
            (Some(a), Some((i, d))) if d < a => {
                let (t, r, n) = deferred.remove(i);
                (t, r, n)
            }
            (None, Some((i, _))) => {
                let (t, r, n) = deferred.remove(i);
                (t, r, n)
            }
            (Some(a), _) => {
                let r = arrivals[next_arrival];
                next_arrival += 1;
                (a, r, 0)
            }
        };
        if !collect {
            // Nobody will read the event stream: discard everything up
            // to (but excluding) the submission instant so the system's
            // pending buffer stays bounded instead of accumulating one
            // event per token for the whole run.
            let _ = system.advance(SimTime(t.0.saturating_sub(1)));
        }
        match system.submit(t, req) {
            Admission::Accepted => stats.n_accepted += 1,
            Admission::Rejected { .. } => stats.n_rejected += 1,
            Admission::Deferred { retry_at } => {
                stats.n_deferred += 1;
                if attempts + 1 >= MAX_DEFERRALS {
                    stats.n_dropped += 1;
                    dropped.push(SystemEvent::Shed {
                        id: req.id,
                        t,
                        reason: format!(
                            "dropped by the replay driver after {MAX_DEFERRALS} \
                             deferrals"
                        ),
                    });
                } else {
                    // Always strictly later than `t` so the loop makes
                    // progress even on a degenerate retry hint.
                    let retry = retry_at.max(SimTime(t.0 + 1));
                    deferred.push((retry, req, attempts + 1));
                }
            }
        }
    }

    let mut events = if collect {
        system.advance(SimTime(u64::MAX))
    } else {
        // Drain the tail horizon-by-horizon, dropping each slice, so
        // peak memory is one timestamp's events rather than the run's.
        while let Some(t) = system.next_event_at() {
            let _ = system.advance(t);
        }
        Vec::new()
    };
    let mut outcome = system.drain();
    if stats.n_dropped > 0 {
        // Driver-dropped requests never reached the system's metrics;
        // account for them here so the conservation law ("every request
        // ends Finished or Shed") holds for the outcome too.
        outcome.report.n_requests += stats.n_dropped;
        outcome.report.n_rejected += stats.n_dropped;
        events.extend(dropped);
        events.sort_by_key(|e| e.time()); // stable: ties keep system order
    }
    (outcome, events, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::config::SystemKind;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::build_system;
    use crate::workload::arrival::{at_rate, stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn replay_serves_whole_trace_and_collects_events() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(30, &AzureTraceConfig::default(), 21);
        let trace = at_rate(&trace, 4.0);
        let mut sys = build_system(SystemKind::Cronus, &cfg);
        let (out, events, stats) = replay_trace_collect(sys.as_mut(), &trace);
        assert_eq!(out.report.n_finished, 30);
        assert_eq!(stats.n_submitted, 30);
        assert_eq!(stats.n_accepted, 30);
        assert_eq!(stats.n_rejected, 0);
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SystemEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, 30);
        // Events are timestamped in non-decreasing simulation order.
        for w in events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
    }

    #[test]
    fn replay_matches_unsorted_trace_order() {
        // replay_trace sorts by arrival; a shuffled trace with the same
        // arrivals produces the same report.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(25, &AzureTraceConfig::default(), 22);
        let trace = at_rate(&trace, 3.0);
        let mut shuffled = trace.clone();
        shuffled.reverse();
        let mut a = build_system(SystemKind::Cronus, &cfg);
        let mut b = build_system(SystemKind::Cronus, &cfg);
        let ra = replay_trace(a.as_mut(), &trace);
        let rb = replay_trace(b.as_mut(), &shuffled);
        assert_eq!(ra.report.makespan_s, rb.report.makespan_s);
        assert_eq!(ra.report.ttft_p99_s, rb.report.ttft_p99_s);
    }

    #[test]
    fn replay_empty_trace_is_empty_outcome() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = build_system(SystemKind::DpChunked, &cfg);
        let out = replay_trace(sys.as_mut(), &[]);
        assert_eq!(out.report.n_requests, 0);
        assert_eq!(out.report.n_finished, 0);
    }

    #[test]
    fn all_at_once_replay_matches_batch_shape() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(40, &AzureTraceConfig::default(), 23);
        let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
        let mut sys = build_system(SystemKind::PpChunked, &cfg);
        let out = replay_trace(sys.as_mut(), &trace);
        assert_eq!(out.report.n_finished, 40);
        assert!(out.report.throughput_rps > 0.0);
    }
}
