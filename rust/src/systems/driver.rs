//! Open- and closed-loop drivers over the online [`ServingSystem`]
//! lifecycle.
//!
//! [`replay_trace`] is the migration bridge from the old batch
//! `run(trace)` API: it feeds every recorded arrival to
//! [`ServingSystem::submit`] at its arrival instant (the system drains
//! its internal events up to each instant itself, so event processing
//! order is identical to the old single-queue loop), honours
//! [`Admission::Deferred`] with bounded retries, and finishes with
//! [`ServingSystem::drain`].  Every launcher, bench, example and CLI
//! path serves traces through this harness.
//!
//! [`closed_loop`] drives multi-turn [`Session`]s the way real users do:
//! turn *k+1* is submitted only after turn *k*'s `Finished` event plus
//! the user's think time, so arrival times are an *output* of the
//! simulation.  Built purely on `submit` / `next_event_at` / `advance`,
//! it works against any serving system — a bare pair or the N-pair
//! cluster — and is fully deterministic for a given session workload.
//!
//! Replay throughput is bounded by the engines' iteration loop, which
//! is allocation-free in steady state (every system steps its engines
//! through reusable plan/event scratch buffers — see EXPERIMENTS.md
//! §Perf); both drivers step systems through the zero-alloc
//! [`ServingSystem::advance_into`] with recycled event buffers, keep
//! peak memory at one horizon's events by discarding slices
//! incrementally when nobody collects them, and the closed-loop driver
//! keys pending turn submissions in a min-heap (`ReadyQueue`) instead
//! of rescanning every session per loop iteration.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::faults::RetryBackoff;
use crate::simclock::SimTime;
use crate::systems::{Admission, RunOutcome, ServingSystem, SystemEvent};
use crate::util::fxhash::FxHashMap;
use crate::workload::session::Session;
use crate::workload::Request;

/// How often a single request may be deferred by SLO admission control
/// before the open-loop driver gives up and drops it.  Both drivers now
/// express this through [`RetryBackoff::default`], whose flat (zero
/// base-delay) schedule reproduces the historical behaviour exactly.
pub const MAX_DEFERRALS: usize = crate::faults::DEFAULT_MAX_ATTEMPTS;

/// Bookkeeping of one open-loop replay.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Distinct trace requests offered at least once.
    pub n_submitted: usize,
    pub n_accepted: usize,
    /// Requests the system rejected outright.
    pub n_rejected: usize,
    /// Deferral events (a request retried N times counts N).
    pub n_deferred: usize,
    /// Requests dropped after [`MAX_DEFERRALS`] retries.
    pub n_dropped: usize,
}

/// Serve a whole recorded trace through the online API, reproducing the
/// pre-redesign batch semantics, and return the final outcome.
///
/// Requests the driver drops after [`MAX_DEFERRALS`] retries are folded
/// into the outcome (`n_requests` and `n_rejected`) and surfaced as
/// synthetic [`SystemEvent::Shed`]s by [`replay_trace_collect`], so no
/// request ever vanishes silently.
pub fn replay_trace(system: &mut dyn ServingSystem, trace: &[Request]) -> RunOutcome {
    replay_trace_impl(system, trace, Sink::Discard).0
}

/// [`replay_trace`], additionally returning every [`SystemEvent`] the
/// run produced (in simulation-time order per system) and the replay's
/// admission bookkeeping.
pub fn replay_trace_collect(
    system: &mut dyn ServingSystem,
    trace: &[Request],
) -> (RunOutcome, Vec<SystemEvent>, ReplayStats) {
    replay_trace_impl(system, trace, Sink::Collect)
}

/// [`replay_trace`], streaming every [`SystemEvent`] through `observe`
/// as it is drained instead of materializing the run's event vector —
/// peak memory stays at one horizon's events, so an online consumer
/// (e.g. the invariant oracle behind `bench-cluster --check`) can ride
/// along on production-scale replays for free.  Synthetic driver-drop
/// sheds are observed at their drop instant, which never precedes an
/// already-observed event.
pub fn replay_trace_observed(
    system: &mut dyn ServingSystem,
    trace: &[Request],
    observe: &mut dyn FnMut(&SystemEvent),
) -> (RunOutcome, ReplayStats) {
    let (out, _events, stats) = replay_trace_impl(system, trace, Sink::Observe(observe));
    (out, stats)
}

/// Where a replay's event stream goes: dropped on the floor, collected
/// into a `Vec`, or streamed through a callback.
enum Sink<'a> {
    Discard,
    Collect,
    Observe(&'a mut dyn FnMut(&SystemEvent)),
}

fn replay_trace_impl(
    system: &mut dyn ServingSystem,
    trace: &[Request],
    mut sink: Sink<'_>,
) -> (RunOutcome, Vec<SystemEvent>, ReplayStats) {
    // Arrival order; the sort is stable so ties keep trace order, which
    // matches how the old batch loop enqueued arrivals.
    let mut arrivals: Vec<Request> = trace.to_vec();
    arrivals.sort_by_key(|r| r.arrival_ns);

    let mut stats = ReplayStats {
        n_submitted: arrivals.len(),
        ..ReplayStats::default()
    };
    // Deferred retries: (retry_at, request, attempts so far).  Rare (SLO
    // admission only), so a linear-scan priority list is fine.
    let mut deferred: Vec<(SimTime, Request, usize)> = Vec::new();
    let backoff = RetryBackoff::default();
    // Synthetic Shed events for requests dropped at the retry cap — the
    // system never accepted them, so the driver records the loss.
    let mut dropped: Vec<SystemEvent> = Vec::new();
    // Recycled event buffer for the non-collecting discard path: the
    // steady-state loop allocates no `Vec` per step.
    let mut scratch: Vec<SystemEvent> = Vec::new();
    let mut next_arrival = 0usize;

    loop {
        let arr_t = arrivals.get(next_arrival).map(|r| SimTime(r.arrival_ns));
        let def = deferred
            .iter()
            .enumerate()
            .min_by_key(|(i, (t, _, _))| (t.0, *i))
            .map(|(i, (t, _, _))| (i, *t));
        // Earliest submission instant; trace arrivals win ties so a
        // retried request queues behind fresh load at the same instant.
        let (t, req, attempts) = match (arr_t, def) {
            (None, None) => break,
            (Some(a), Some((i, d))) if d < a => {
                let (t, r, n) = deferred.remove(i);
                (t, r, n)
            }
            (None, Some((i, _))) => {
                let (t, r, n) = deferred.remove(i);
                (t, r, n)
            }
            (Some(a), _) => {
                let r = arrivals[next_arrival];
                next_arrival += 1;
                (a, r, 0)
            }
        };
        match &mut sink {
            Sink::Collect => {}
            // Nobody keeps the event stream: drain everything up to (but
            // excluding) the submission instant so the system's pending
            // buffer stays bounded instead of accumulating one event per
            // token for the whole run.  An observer sees each slice
            // before it is recycled.
            Sink::Discard => {
                system.advance_into(SimTime(t.0.saturating_sub(1)), &mut scratch);
                scratch.clear();
            }
            Sink::Observe(f) => {
                system.advance_into(SimTime(t.0.saturating_sub(1)), &mut scratch);
                for ev in scratch.drain(..) {
                    f(&ev);
                }
            }
        }
        match system.submit(t, req) {
            Admission::Accepted => stats.n_accepted += 1,
            Admission::Rejected { .. } => stats.n_rejected += 1,
            Admission::Deferred { retry_at } => {
                stats.n_deferred += 1;
                if backoff.gives_up(attempts) {
                    stats.n_dropped += 1;
                    let shed = SystemEvent::Shed {
                        id: req.id,
                        t,
                        reason: format!(
                            "dropped by the replay driver after {MAX_DEFERRALS} \
                             deferrals"
                        ),
                    };
                    // The drop happens *now*: prior drains stopped at
                    // t−1, so observing it here keeps the stream ordered.
                    if let Sink::Observe(f) = &mut sink {
                        f(&shed);
                    }
                    dropped.push(shed);
                } else {
                    // Always strictly later than `t` so the loop makes
                    // progress even on a degenerate retry hint.
                    let retry = backoff.retry_at(t, retry_at, attempts);
                    deferred.push((retry, req, attempts + 1));
                }
            }
        }
    }

    let mut events = Vec::new();
    match &mut sink {
        Sink::Collect => system.advance_into(SimTime(u64::MAX), &mut events),
        // Drain the tail horizon-by-horizon, recycling each slice, so
        // peak memory is one timestamp's events rather than the run's.
        Sink::Discard => {
            while let Some(t) = system.next_event_at() {
                system.advance_into(t, &mut scratch);
                scratch.clear();
            }
        }
        Sink::Observe(f) => {
            while let Some(t) = system.next_event_at() {
                system.advance_into(t, &mut scratch);
                for ev in scratch.drain(..) {
                    f(&ev);
                }
            }
        }
    }
    let mut outcome = system.drain();
    if stats.n_dropped > 0 {
        // Driver-dropped requests never reached the system's metrics;
        // account for them here so the conservation law ("every request
        // ends Finished or Shed") holds for the outcome too.
        outcome.report.n_requests += stats.n_dropped;
        outcome.report.n_rejected += stats.n_dropped;
        events.extend(dropped);
        events.sort_by_key(|e| e.time()); // stable: ties keep system order
    }
    (outcome, events, stats)
}

// ---------------------------------------------------------------------------
// Closed-loop multi-turn session driving
// ---------------------------------------------------------------------------

/// Bookkeeping of one closed-loop session run.
///
/// `submissions` records every *accepted* turn as `(request id,
/// submission instant)` in submission order — filled by both the
/// collecting and non-collecting drivers, so the two are comparable and
/// tests can assert the closed-loop causality (turn *k+1* is never
/// submitted before turn *k*'s finish plus think time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClosedLoopStats {
    pub n_sessions: usize,
    /// Turns across all generated sessions (an aborted session's later
    /// turns are never submitted).
    pub n_turns_total: usize,
    /// Distinct turns offered to the system at least once.
    pub n_submitted: usize,
    /// Turns that produced a `Finished` event.
    pub n_finished_turns: usize,
    /// Turns rejected outright at admission.
    pub n_rejected_turns: usize,
    /// Turns shed by the system after acceptance.
    pub n_shed_turns: usize,
    /// Deferral events (a turn retried N times counts N).
    pub n_deferred: usize,
    /// Turns dropped after [`MAX_DEFERRALS`] retries.
    pub n_dropped_turns: usize,
    /// Sessions cut short by a rejected / shed / dropped turn.
    pub n_aborted_sessions: usize,
    /// Sessions whose final turn finished.
    pub n_completed_sessions: usize,
    /// `(request id, submission instant)` per accepted turn.
    pub submissions: Vec<(u64, SimTime)>,
}

/// Per-session driver state.
#[derive(Clone, Copy, Debug)]
enum SessState {
    /// The next turn may be submitted at `at` (`attempts` deferrals so
    /// far for this turn).
    Ready { at: SimTime, attempts: usize },
    /// A turn is in flight; waiting for its terminal event.
    Waiting { req_id: u64 },
    /// All turns finished, or the session aborted.
    Done,
}

/// Pending turn submissions keyed by submission instant: a lazily-
/// invalidated min-heap replaces the per-iteration scan over every
/// session the closed-loop driver used to do — O(log S) per state
/// transition instead of O(S) per loop turn.  Entries are
/// `(at, session index, generation)`; ties break toward the lowest
/// session index, exactly the scan's deterministic order, and an entry
/// is live only while its generation matches the session's current one.
struct ReadyQueue {
    heap: BinaryHeap<Reverse<(SimTime, usize, u64)>>,
    gens: Vec<u64>,
}

impl ReadyQueue {
    fn new(n: usize) -> ReadyQueue {
        ReadyQueue {
            heap: BinaryHeap::with_capacity(n + 1),
            gens: vec![0; n],
        }
    }

    /// Session `i` became ready at `at`.
    fn push(&mut self, i: usize, at: SimTime) {
        self.gens[i] += 1;
        self.heap.push(Reverse((at, i, self.gens[i])));
    }

    /// Earliest live entry, discarding superseded ones.
    fn peek(&mut self) -> Option<(SimTime, usize)> {
        while let Some(&Reverse((at, i, g))) = self.heap.peek() {
            if self.gens[i] == g {
                return Some((at, i));
            }
            self.heap.pop();
        }
        None
    }

    /// Consume the live top entry (the one `peek` just returned).  Each
    /// generation is issued exactly once, so no buried entry for the
    /// same session can come alive again.
    fn pop(&mut self) {
        self.heap.pop();
    }
}

/// Serve a session workload closed-loop: each session's turn *k+1* is
/// submitted only once turn *k* finished and the think time elapsed.
/// Rejected / dropped turns abort their session (the user left).
/// Deterministic: identical sessions and system produce identical
/// submission and event sequences.
pub fn closed_loop(
    system: &mut dyn ServingSystem,
    sessions: &[Session],
) -> (RunOutcome, ClosedLoopStats) {
    let (out, _events, stats) = closed_loop_impl(system, sessions, false);
    (out, stats)
}

/// [`closed_loop`], additionally returning every [`SystemEvent`] the run
/// produced (in simulation-time order).
pub fn closed_loop_collect(
    system: &mut dyn ServingSystem,
    sessions: &[Session],
) -> (RunOutcome, Vec<SystemEvent>, ClosedLoopStats) {
    closed_loop_impl(system, sessions, true)
}

fn closed_loop_impl(
    system: &mut dyn ServingSystem,
    sessions: &[Session],
    collect: bool,
) -> (RunOutcome, Vec<SystemEvent>, ClosedLoopStats) {
    let mut stats = ClosedLoopStats {
        n_sessions: sessions.len(),
        n_turns_total: sessions.iter().map(|s| s.turns.len()).sum(),
        ..ClosedLoopStats::default()
    };
    let mut states: Vec<SessState> = sessions
        .iter()
        .map(|s| SessState::Ready { at: SimTime(s.start_ns), attempts: 0 })
        .collect();
    // Pending submissions, keyed by submit instant (satellite perf fix:
    // the driver used to rescan every session per loop iteration).
    let mut ready_q = ReadyQueue::new(sessions.len());
    for (i, s) in sessions.iter().enumerate() {
        ready_q.push(i, SimTime(s.start_ns));
    }
    // Sessions currently in flight (their next state change is an event).
    let mut n_waiting = 0usize;
    let mut next_turn: Vec<usize> = vec![0; sessions.len()];
    // Session id -> index, to resolve terminal events back to sessions.
    let mut by_session: FxHashMap<u64, usize> = FxHashMap::default();
    for (i, s) in sessions.iter().enumerate() {
        by_session.insert(s.id, i);
    }
    let mut events: Vec<SystemEvent> = Vec::new();
    // Recycled per-step event buffer (moved into `events` when
    // collecting, cleared otherwise — either way capacity survives).
    let mut batch: Vec<SystemEvent> = Vec::new();
    // Synthetic Shed events for turns dropped at the retry cap.
    let mut dropped: Vec<SystemEvent> = Vec::new();
    let backoff = RetryBackoff::default();

    loop {
        // Earliest ready submission (ties break toward the lowest session
        // index — deterministic, same order as the scan this replaced).
        let ready = ready_q.peek().map(|(at, i)| {
            let attempts = match states[i] {
                SessState::Ready { at: a, attempts } => {
                    debug_assert_eq!(a, at);
                    attempts
                }
                st => unreachable!("live ready entry for {st:?}"),
            };
            (at, i, attempts)
        });
        let next_ev = system.next_event_at();

        let submit_now = match (ready, next_ev) {
            (None, None) => break,
            // All sessions done or in flight with nothing pending —
            // remaining events are the tail of the final turns; the
            // post-loop drain handles them.
            (None, Some(_)) if n_waiting == 0 => break,
            (None, Some(_)) => false,
            // Events at or before the submission instant run first, so a
            // finish at the same instant schedules before fresh load.
            (Some((at, _, _)), Some(te)) => te > at,
            (Some(_), None) => true,
        };

        if submit_now {
            let (at, i, attempts) = ready.expect("submit_now implies ready");
            ready_q.pop();
            let k = next_turn[i];
            let req = sessions[i].request(k, at.0);
            if attempts == 0 {
                stats.n_submitted += 1;
            }
            match system.submit(at, req) {
                Admission::Accepted => {
                    stats.submissions.push((req.id, at));
                    states[i] = SessState::Waiting { req_id: req.id };
                    n_waiting += 1;
                }
                Admission::Rejected { .. } => {
                    // The system recorded the shed; the user gives up.
                    stats.n_rejected_turns += 1;
                    stats.n_aborted_sessions += 1;
                    states[i] = SessState::Done;
                }
                Admission::Deferred { retry_at } => {
                    stats.n_deferred += 1;
                    if backoff.gives_up(attempts) {
                        stats.n_dropped_turns += 1;
                        stats.n_aborted_sessions += 1;
                        dropped.push(SystemEvent::Shed {
                            id: req.id,
                            t: at,
                            reason: format!(
                                "dropped by the closed-loop driver after \
                                 {MAX_DEFERRALS} deferrals"
                            ),
                        });
                        states[i] = SessState::Done;
                    } else {
                        // Strictly later than `at` so the loop always
                        // makes progress, even on a degenerate hint.
                        let retry = backoff.retry_at(at, retry_at, attempts);
                        states[i] =
                            SessState::Ready { at: retry, attempts: attempts + 1 };
                        ready_q.push(i, retry);
                    }
                }
            }
            continue;
        }

        let te = next_ev.expect("not submitting implies a pending event");
        debug_assert!(batch.is_empty());
        system.advance_into(te, &mut batch);
        for ev in &batch {
            let (id, t, finished) = match ev {
                SystemEvent::Finished { id, t } => (*id, *t, true),
                SystemEvent::Shed { id, t, .. } => (*id, *t, false),
                _ => continue,
            };
            let sid = crate::workload::session::session_of_request(id);
            let i = match by_session.get(&sid) {
                Some(&i) => i,
                None => continue,
            };
            let req_id = match states[i] {
                SessState::Waiting { req_id } => req_id,
                _ => continue,
            };
            if req_id != id {
                continue;
            }
            n_waiting -= 1;
            if finished {
                stats.n_finished_turns += 1;
                next_turn[i] += 1;
                if next_turn[i] == sessions[i].turns.len() {
                    stats.n_completed_sessions += 1;
                    states[i] = SessState::Done;
                } else {
                    // Think, then come back with the follow-up turn.
                    let think = sessions[i].turns[next_turn[i]].think_s;
                    let at = t.after_secs(think);
                    states[i] = SessState::Ready { at, attempts: 0 };
                    ready_q.push(i, at);
                }
            } else {
                stats.n_shed_turns += 1;
                stats.n_aborted_sessions += 1;
                states[i] = SessState::Done;
            }
        }
        if collect {
            events.append(&mut batch);
        } else {
            batch.clear();
        }
    }

    // Tail: everything left is token traffic of already-resolved turns.
    if collect {
        system.advance_into(SimTime(u64::MAX), &mut events);
    } else {
        while let Some(t) = system.next_event_at() {
            system.advance_into(t, &mut batch);
            batch.clear();
        }
    }
    let mut outcome = system.drain();
    if stats.n_dropped_turns > 0 {
        // Driver-dropped turns never reached the system's metrics;
        // account for them so "every submitted turn ends Finished xor
        // Shed" holds for the outcome too.
        outcome.report.n_requests += stats.n_dropped_turns;
        outcome.report.n_rejected += stats.n_dropped_turns;
        events.extend(dropped);
        events.sort_by_key(|e| e.time()); // stable: ties keep stream order
    }
    (outcome, events, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::config::SystemKind;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::build_system;
    use crate::workload::arrival::{at_rate, stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    #[test]
    fn replay_serves_whole_trace_and_collects_events() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(30, &AzureTraceConfig::default(), 21);
        let trace = at_rate(&trace, 4.0);
        let mut sys = build_system(SystemKind::Cronus, &cfg);
        let (out, events, stats) = replay_trace_collect(sys.as_mut(), &trace);
        assert_eq!(out.report.n_finished, 30);
        assert_eq!(stats.n_submitted, 30);
        assert_eq!(stats.n_accepted, 30);
        assert_eq!(stats.n_rejected, 0);
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SystemEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, 30);
        // Events are timestamped in non-decreasing simulation order.
        for w in events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
    }

    #[test]
    fn observed_replay_streams_the_collected_event_sequence() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(30, &AzureTraceConfig::default(), 21);
        let trace = at_rate(&trace, 4.0);
        let mut a = build_system(SystemKind::Cronus, &cfg);
        let (out_c, collected, _) = replay_trace_collect(a.as_mut(), &trace);
        let mut b = build_system(SystemKind::Cronus, &cfg);
        let mut observed = Vec::new();
        let (out_o, stats) =
            replay_trace_observed(b.as_mut(), &trace, &mut |ev| observed.push(ev.clone()));
        assert_eq!(stats.n_submitted, 30);
        assert_eq!(out_o.report.n_finished, out_c.report.n_finished);
        assert_eq!(observed, collected, "observer sees the collected stream");
    }

    #[test]
    fn replay_matches_unsorted_trace_order() {
        // replay_trace sorts by arrival; a shuffled trace with the same
        // arrivals produces the same report.
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(25, &AzureTraceConfig::default(), 22);
        let trace = at_rate(&trace, 3.0);
        let mut shuffled = trace.clone();
        shuffled.reverse();
        let mut a = build_system(SystemKind::Cronus, &cfg);
        let mut b = build_system(SystemKind::Cronus, &cfg);
        let ra = replay_trace(a.as_mut(), &trace);
        let rb = replay_trace(b.as_mut(), &shuffled);
        assert_eq!(ra.report.makespan_s, rb.report.makespan_s);
        assert_eq!(ra.report.ttft_p99_s, rb.report.ttft_p99_s);
    }

    #[test]
    fn replay_empty_trace_is_empty_outcome() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = build_system(SystemKind::DpChunked, &cfg);
        let out = replay_trace(sys.as_mut(), &[]);
        assert_eq!(out.report.n_requests, 0);
        assert_eq!(out.report.n_finished, 0);
    }

    #[test]
    fn all_at_once_replay_matches_batch_shape() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let trace = generate(40, &AzureTraceConfig::default(), 23);
        let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
        let mut sys = build_system(SystemKind::PpChunked, &cfg);
        let out = replay_trace(sys.as_mut(), &trace);
        assert_eq!(out.report.n_finished, 40);
        assert!(out.report.throughput_rps > 0.0);
    }

    // --- closed-loop sessions ---

    use crate::workload::session::{
        generate_sessions, turn_request_id, SessionConfig,
    };

    fn small_sessions(n: usize, seed: u64) -> Vec<crate::workload::session::Session> {
        generate_sessions(&SessionConfig {
            n_sessions: n,
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 0.5,
            start_window_s: 2.0,
            mean_new_input: 256.0,
            max_new_input: 1024,
            seed,
            ..SessionConfig::default()
        })
    }

    #[test]
    fn closed_loop_finishes_every_turn_on_a_bare_pair() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sessions = small_sessions(5, 31);
        let n_turns: usize = sessions.iter().map(|s| s.turns.len()).sum();
        let mut sys = build_system(SystemKind::Cronus, &cfg);
        let (out, events, stats) = closed_loop_collect(sys.as_mut(), &sessions);
        assert_eq!(stats.n_sessions, 5);
        assert_eq!(stats.n_turns_total, n_turns);
        assert_eq!(stats.n_submitted, n_turns);
        assert_eq!(stats.n_finished_turns, n_turns);
        assert_eq!(stats.n_completed_sessions, 5);
        assert_eq!(stats.n_aborted_sessions, 0);
        assert_eq!(out.report.n_finished, n_turns);
        assert_eq!(out.report.n_requests, n_turns);
        // Event stream is monotone in time.
        for w in events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SystemEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, n_turns);
    }

    #[test]
    fn closed_loop_respects_finish_plus_think_causality() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let sessions = small_sessions(4, 33);
        let mut sys = build_system(SystemKind::Cronus, &cfg);
        let (_out, events, stats) = closed_loop_collect(sys.as_mut(), &sessions);
        // Finish time per request id.
        let mut finish: std::collections::HashMap<u64, SimTime> =
            std::collections::HashMap::new();
        for ev in &events {
            if let SystemEvent::Finished { id, t } = ev {
                finish.insert(*id, *t);
            }
        }
        let submit_at: std::collections::HashMap<u64, SimTime> =
            stats.submissions.iter().copied().collect();
        for s in &sessions {
            // Turn 0 is submitted at the session start, never earlier.
            let t0 = submit_at[&turn_request_id(s.id, 0)];
            assert_eq!(t0, SimTime(s.start_ns));
            for k in 1..s.turns.len() {
                let prev_finish = finish[&turn_request_id(s.id, k - 1)];
                let earliest = prev_finish.after_secs(s.turns[k].think_s);
                let t = submit_at[&turn_request_id(s.id, k)];
                assert!(
                    t >= earliest,
                    "session {} turn {k} submitted at {t} before finish {prev_finish} + think",
                    s.id
                );
            }
        }
    }

    #[test]
    fn closed_loop_empty_sessions_is_empty_outcome() {
        let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let mut sys = build_system(SystemKind::Cronus, &cfg);
        let (out, stats) = closed_loop(sys.as_mut(), &[]);
        assert_eq!(out.report.n_requests, 0);
        assert_eq!(stats.n_submitted, 0);
        assert!(stats.submissions.is_empty());
    }
}
