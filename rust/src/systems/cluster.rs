//! The N-pair cluster serving system: a cluster-level [`Router`] in
//! front of N independent (high-end, low-end) pair deployments.
//!
//! Each pair is a full serving system of its own (Cronus by default —
//! any [`SystemKind`](crate::config::SystemKind) per pair).  Requests
//! are dispatched *at their arrival instant*: `submit` first steps the
//! pairs with due events up to the arrival (so the router sees the
//! completions that actually happened), routes against the live
//! per-pair backlog, and hands the request to the chosen pair's own
//! `submit`.  All pairs share the experiment's t = 0 clock; `drain`
//! merges the per-pair reports into exact cluster-wide TTFT/TBT
//! percentiles via [`Report::merge`].  Per-pair [`InstanceStat`]s are
//! kept, prefixed `p<i>:`, so utilization imbalance across a
//! mixed-capability fleet stays visible.
//!
//! Stepping is driven by an `EventCalendar` — a lazily-invalidated
//! min-heap of per-pair `next_event_at` keys — so `submit` / `advance` /
//! `next_event_at` touch only pairs that actually have due events:
//! O(due + log N) per arrival instead of the O(N) scan the first cluster
//! implementation did, which is what lets a single router front hundreds
//! of pairs (see `benches/cluster_hotpath.rs`).  The merged event stream
//! is byte-identical to the scan-everything stepper's — pinned across
//! every policy, driver and SLO mode by `tests/cluster_calendar_oracle.rs`.
//!
//! With a TTFT SLO configured ([`ClusterSystem::with_slo_ttft`]), the
//! router's [`slo_admission`](Router::slo_admission) policy runs before
//! routing: requests the cluster cannot serve in time are `Rejected`
//! (surfaced as [`SystemEvent::Shed`] and `Report::n_rejected`) or
//! `Deferred` with a retry hint for the open-loop driver.
//!
//! Session requests participate in KV-affinity routing: the cluster
//! stamps the router's granted `kv_credit` into the request handed to
//! the resident pair, releases residency when a session's final turn
//! completes (or a turn sheds and the conversation aborts), and reports
//! `Report::{n_kv_hits, kv_hit_rate, prefill_tokens_saved}` on drain.
//!
//! With a [`FleetController`] attached ([`ClusterSystem::with_autoscale`])
//! the active pair set becomes elastic: each arrival first feeds the
//! router's live backlog to the controller, which may *activate* a
//! standby pair (it rejoins the router's load index and starts taking
//! work at that instant) or *drain* an active one.  A draining pair
//! stops receiving new requests immediately but is retired only when its
//! last in-flight request finishes — its resident sessions are evicted
//! at that point, never mid-flight — so scaling actions can never lose
//! or duplicate a request (`tests/autoscale.rs` pins conservation and
//! determinism).  Scale actions surface in the event stream as
//! [`SystemEvent::ScaleUp`] / [`SystemEvent::ScaleDown`] and are counted
//! in `Report::{n_scale_ups, n_scale_downs}`.  Without a controller the
//! cluster behaves — byte for byte — as before.
//!
//! With a QoS [`ClassRegistry`] attached ([`ClusterSystem::with_classes`])
//! the cluster is multi-tenant: each submit passes (1) a *model
//! compatibility* shed — a class pinned to a model no active pair serves
//! is rejected with a distinct reason, (2) the weighted-fair
//! [`FairShareLedger`] — a class running more than a quantum ahead of a
//! contending class is deferred, unless it is over its own TTFT SLO and
//! of strictly higher tier (priority preemption; queued work only,
//! in-flight requests and engines are never touched), (3) the router's
//! TBT-aware admission — arrivals that would blow in-flight classes'
//! TBT-P99 headroom on every compatible pair are deferred, and (4) SLO
//! admission under the class's own TTFT SLO (falling back to the
//! cluster-wide one).  `drain` attaches a per-class breakdown
//! ([`Report::classes`]) with exact per-class TTFT/TBT percentiles.
//! Without a registry every gate is inert and the cluster behaves —
//! byte for byte — as before.
//!
//! With a fault plan attached ([`ClusterSystem::with_faults`]) pair
//! outages are injected mid-run at their exact scheduled instants:
//! the failed pair is masked out of routing, its resident KV evicted,
//! and its in-flight requests aborted and re-submitted through the full
//! admission path under a deterministic [`RetryBackoff`] (re-prefilling
//! from scratch — the KV died with the pair).  Failures and repairs
//! surface as [`SystemEvent::PairFailed`] / [`SystemEvent::PairRecovered`]
//! spliced into the merged stream, retries that exhaust the backoff (or
//! their remaining TTFT budget) shed with a distinct reason, and a
//! [`FleetController`] treats a failure as an implicit scale-up (a
//! standby flips active immediately).  `drain` reports
//! `Report::{n_pair_failures, n_retries, n_recovered, recovery_latency_s}`.
//! An empty plan is inert: every fault hook sits behind one `is_some()`
//! branch, so non-fault runs stay byte-identical (pinned by
//! `tests/faults_chaos.rs`).
//!
//! With an inter-pair link configured ([`ClusterConfig::link`] or
//! per-pair overrides) warm sessions survive displacement: the router
//! prices shipping a session's resident prefix over the link against
//! recomputing it ([`Router::handoff_pair_residency`] on drain, the
//! migration-aware affinity target on SLO-infeasible residents), and an
//! admitted request whose KV is still on the wire is *delivered* to its
//! destination pair only once the transfer lands — the link delay is
//! part of the measured TTFT.  A *failed* pair's KV is dead and is still
//! evicted, never migrated, and transfers still in flight toward a pair
//! that fails are aborted into the fault retry path.  `drain` reports
//! `Report::{n_migrations, migrated_tokens, migration_time_s}`.
//! Without a link every migration hook sits behind one `is_some()`
//! branch and runs are byte-identical to the pre-migration cluster.
//!
//! # Example
//!
//! ```
//! use cronus::config::topology::ClusterConfig;
//! use cronus::cronus::router::RoutePolicy;
//! use cronus::simgpu::model_desc::LLAMA3_8B;
//! use cronus::systems::cluster::ClusterSystem;
//! use cronus::systems::driver::replay_trace;
//! use cronus::systems::AutoscaleConfig;
//! use cronus::workload::arrival::{stamp, ArrivalProcess};
//! use cronus::workload::azure::{generate, AzureTraceConfig};
//!
//! let trace = stamp(&generate(20, &AzureTraceConfig::default(), 7), ArrivalProcess::AllAtOnce);
//! let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
//!
//! // A fixed two-pair fleet...
//! let mut fixed = ClusterSystem::new(cfg.clone(), RoutePolicy::LeastOutstandingTokens);
//! let out = replay_trace(&mut fixed, &trace);
//! assert_eq!(out.report.n_finished, 20);
//! assert_eq!(out.report.n_scale_ups, 0);
//!
//! // ...and the same fleet under queue-driven autoscaling: the burst
//! // forces the second pair to spin up.
//! let autoscale = AutoscaleConfig { scale_up_backlog: 512.0, ..Default::default() };
//! let mut elastic = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
//!     .with_autoscale(autoscale);
//! let out = replay_trace(&mut elastic, &trace);
//! assert_eq!(out.report.n_finished, 20);
//! assert!(out.report.n_scale_ups >= 1);
//! ```

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::topology::ClusterConfig;
use crate::cronus::router::{RouteDecision, RoutePolicy, Router};
use crate::faults::{FaultEvent, FaultPlan, RetryBackoff};
use crate::metrics::{ClassBreakdown, Report};
use crate::qos::{ClassId, ClassRegistry, FairShareLedger};
use crate::simclock::SimTime;
use crate::systems::{
    build_system, drain_pending_into, earliest_instant, Admission, AutoscaleConfig,
    FleetController, InstanceStat, RunOutcome, ScaleDecision, ServingSystem, SystemEvent,
};
use crate::util::fxhash::FxHashMap;
use crate::workload::{Request, NO_SESSION};

/// Cluster-side record of one in-flight request.
struct AssignedReq {
    pair: usize,
    /// Backlog tokens to release via [`Router::on_completed`].
    tokens: u64,
    session_id: u64,
    final_turn: bool,
    /// Service class (always the default class outside QoS runs).
    class: ClassId,
    /// Full context tokens — retires the request's decode stream from
    /// the router's TBT estimator when it leaves the system.
    ctx: u64,
    /// True arrival instant (per-class TTFT measures from here, so
    /// admission queueing — the thing the fair-share ledger shapes —
    /// shows up in the per-class tail).
    arrival: SimTime,
    /// Last observed token instant (per-class TBT gaps).
    last_token: Option<SimTime>,
    /// The request as the cluster admitted it — a fault abort re-submits
    /// it (with its KV claim stripped) through the retry queue.
    req: Request,
}

/// Per-service-class accumulator for one run (QoS runs only).
#[derive(Default)]
struct ClassStat {
    /// Terminal-outcome denominator: admitted or shed at the cluster
    /// gate (driver-side deferral drops never reach the cluster and are
    /// invisible here).
    n_requests: usize,
    n_finished: usize,
    n_shed: usize,
    /// Requests of this class aborted by a pair failure and re-queued
    /// for admission (fault runs only).
    n_retries: usize,
    ttft: Vec<f64>,
    tbt: Vec<f64>,
}

/// Live fault-injection state (present iff a [`FaultPlan`] is attached;
/// without one every fault hook is a single dead `is_some()` branch).
struct FaultState {
    plan: FaultPlan,
    /// Backoff schedule for re-submitting failure-aborted requests.
    backoff: RetryBackoff,
    /// Cursor into `plan.events()`: next outage not yet injected.
    next_fault: usize,
    /// Scheduled repairs: `(instant, pair)`.
    recoveries: BinaryHeap<Reverse<(SimTime, usize)>>,
    /// Aborted requests awaiting re-admission:
    /// `(retry_at, request, attempts so far)`.  Rare, so a linear-scan
    /// priority list is fine (same shape the drivers use).
    retry_q: Vec<(SimTime, Request, usize)>,
    /// Which pairs are currently failed.
    down: Vec<bool>,
    /// Outage start per failed pair (recovery-latency sample on repair).
    fail_at: Vec<Option<SimTime>>,
    n_pair_failures: usize,
    n_retries: usize,
    n_recovered: usize,
    /// Observed outage durations, seconds (unsorted until drain).
    recovery_latency: Vec<f64>,
}

/// Live KV-migration state (present iff the topology configures an
/// inter-pair link; without one every migration hook is a single dead
/// `is_some()` branch).
struct MigrationState {
    /// Admitted requests whose prefix KV is still on the wire, sorted by
    /// delivery instant (FIFO on ties): the destination pair sees the
    /// `submit` only once the transfer lands, so the link delay shows up
    /// in the measured TTFT, not just the estimate.
    deliveries: Vec<(SimTime, Request, RouteDecision)>,
}

/// The cluster's event calendar: a lazily-invalidated min-heap over the
/// pairs' `next_event_at` instants, so stepping the fleet touches only
/// the pairs with *due* events — O(due + log N) per operation instead of
/// the O(N) scan-everything stepping it replaced.
///
/// Entries are `(instant, generation, pair)`.  A pair's key is re-issued
/// with a bumped generation whenever the pair is submitted to or
/// advanced; superseded entries stay buried in the heap and are
/// discarded when they surface ([`clean_top`](Self::clean_top) runs
/// after every mutation, so the top entry is always live and
/// [`peek`](Self::peek) is O(1) and `&self`).
struct EventCalendar {
    heap: BinaryHeap<Reverse<(SimTime, u64, usize)>>,
    /// Current generation per pair; entries carrying an older generation
    /// are stale.
    gens: Vec<u64>,
}

impl EventCalendar {
    fn new(n: usize) -> EventCalendar {
        EventCalendar {
            heap: BinaryHeap::with_capacity(n + 1),
            gens: vec![0; n],
        }
    }

    /// Re-key `pair` to `at` (its fresh `next_event_at`), superseding
    /// every entry previously issued for it.  O(log N) amortized.
    fn set(&mut self, pair: usize, at: Option<SimTime>) {
        self.gens[pair] += 1;
        if let Some(t) = at {
            self.heap.push(Reverse((t, self.gens[pair], pair)));
        }
        self.clean_top();
    }

    /// Earliest pair event across the cluster.
    fn peek(&self) -> Option<SimTime> {
        self.heap.peek().map(|&Reverse((t, _, _))| t)
    }

    /// Pop one pair with an event at or before `until`.  The pair's key
    /// is consumed — the caller advances the pair and re-`set`s it.
    fn pop_due(&mut self, until: SimTime) -> Option<usize> {
        match self.heap.peek() {
            Some(&Reverse((t, _, _))) if t <= until => {}
            _ => return None,
        }
        let Reverse((_, _, pair)) = self.heap.pop().expect("peeked entry");
        self.gens[pair] += 1; // buried duplicates die with the key
        self.clean_top();
        Some(pair)
    }

    /// Discard superseded entries until the top is live (or the heap is
    /// empty), restoring the `peek` invariant.
    fn clean_top(&mut self) {
        while let Some(&Reverse((_, g, pair))) = self.heap.peek() {
            if self.gens[pair] == g {
                break;
            }
            self.heap.pop();
        }
    }
}

pub struct ClusterSystem {
    cfg: ClusterConfig,
    label: String,
    /// TTFT SLO in seconds; `None` disables admission control.
    slo_ttft_s: Option<f64>,
    router: Router,
    /// One online serving system per pair, same index order as `cfg`.
    systems: Vec<Box<dyn ServingSystem>>,
    /// In-flight requests by id.
    assigned: FxHashMap<u64, AssignedReq>,
    /// Elastic fleet controller; `None` keeps the pair set fixed (and
    /// the whole autoscale path inert — behavior is byte-identical to a
    /// controller-less cluster).
    autoscale: Option<FleetController>,
    /// Fault-injection state; `None` keeps every fault hook inert
    /// (behavior is byte-identical to a plan-less cluster).
    faults: Option<FaultState>,
    /// KV-migration state; `None` (no link configured) keeps every
    /// migration hook inert (behavior is byte-identical to a link-less
    /// cluster).
    migration: Option<MigrationState>,
    /// QoS class registry; `None` keeps every QoS gate inert (behavior
    /// is byte-identical to a registry-less cluster).
    classes: Option<ClassRegistry>,
    /// Weighted-fair admission ledger (present iff `classes` is).
    ledger: Option<FairShareLedger>,
    /// Per-class outcome + latency accumulators (empty without QoS).
    class_stats: Vec<ClassStat>,
    /// In-flight request count per pair (drain-before-retire tracking).
    inflight: Vec<usize>,
    n_scale_ups: usize,
    n_scale_downs: usize,
    routed_counts: Vec<u64>,
    /// Requests shed by the router itself (SLO admission), not by pairs.
    n_router_rejected: usize,
    /// Merged events not yet collected via `advance` (time-sorted).
    pending: Vec<SystemEvent>,
    /// Per-pair next-event calendar — the O(log N) stepping structure.
    calendar: EventCalendar,
    /// Recycled per-pair event streams for one `collect_until` batch.
    scratch: Vec<Vec<SystemEvent>>,
    /// Recycled list of pairs due in the current batch.
    due: Vec<usize>,
    /// Recycled merge cursors (one per pair; only due pairs are used).
    cursors: Vec<usize>,
    /// Recycled k-way-merge head heap: `(next event time, pair)`.
    merge: BinaryHeap<Reverse<(SimTime, usize)>>,
}

impl ClusterSystem {
    pub fn new(cfg: ClusterConfig, policy: RoutePolicy) -> ClusterSystem {
        let label = format!("{} {}", cfg.label(), policy.name());
        let router = Router::new(policy, &cfg);
        let systems = cfg
            .pairs
            .iter()
            .map(|pair| build_system(pair.system, &pair.deployment))
            .collect();
        let n = cfg.n_pairs();
        let migration = if cfg.link.is_some()
            || cfg.pairs.iter().any(|p| p.link.is_some())
        {
            Some(MigrationState { deliveries: Vec::new() })
        } else {
            None
        };
        ClusterSystem {
            cfg,
            label,
            slo_ttft_s: None,
            router,
            systems,
            assigned: FxHashMap::default(),
            autoscale: None,
            faults: None,
            migration,
            classes: None,
            ledger: None,
            class_stats: Vec::new(),
            inflight: vec![0; n],
            n_scale_ups: 0,
            n_scale_downs: 0,
            routed_counts: vec![0; n],
            n_router_rejected: 0,
            pending: Vec::new(),
            calendar: EventCalendar::new(n),
            scratch: (0..n).map(|_| Vec::new()).collect(),
            due: Vec::new(),
            cursors: vec![0; n],
            merge: BinaryHeap::new(),
        }
    }

    /// Enable TTFT SLO admission control at the router (seconds).
    pub fn with_slo_ttft(mut self, slo_ttft_s: Option<f64>) -> ClusterSystem {
        self.slo_ttft_s = slo_ttft_s;
        self
    }

    /// Attach a multi-tenant QoS class registry: submits pass the
    /// weighted-fair [`FairShareLedger`] and the router's TBT-aware
    /// admission gate, per-class TTFT SLOs override the cluster-wide
    /// SLO, model-pinned classes are shed when no active pair serves
    /// their model, and `drain` attaches a per-class breakdown to the
    /// report.  Default-class traffic is unaffected byte-for-byte.
    pub fn with_classes(mut self, registry: ClassRegistry) -> ClusterSystem {
        self.router.set_class_registry(registry.clone());
        self.ledger = Some(FairShareLedger::from_registry(&registry));
        self.class_stats =
            (0..registry.len()).map(|_| ClassStat::default()).collect();
        self.classes = Some(registry);
        self
    }

    /// The class-stat slot for `class` (`None` outside QoS runs; stale
    /// ids clamp to the default class like everywhere else).
    fn class_stat_mut(&mut self, class: ClassId) -> Option<&mut ClassStat> {
        if self.class_stats.is_empty() {
            return None;
        }
        let i = (class.0 as usize).min(self.class_stats.len() - 1);
        self.class_stats.get_mut(i)
    }

    /// Attach a queue-driven [`FleetController`]: pairs beyond its
    /// `initial_pairs` start standby (masked out of routing) and the
    /// active set grows and shrinks with the router's backlog.
    pub fn with_autoscale(mut self, cfg: AutoscaleConfig) -> ClusterSystem {
        let ctl = FleetController::new(self.cfg.n_pairs(), cfg);
        for i in 0..self.cfg.n_pairs() {
            self.router.set_pair_active(i, ctl.is_active(i));
        }
        self.autoscale = Some(ctl);
        self
    }

    /// Attach a deterministic fault plan: the scheduled pair outages are
    /// injected at their exact instants, failed pairs are masked out of
    /// routing (KV residency evicted, in-flight work aborted and
    /// re-submitted under `backoff`), and repairs bring pairs back —
    /// as standby under a [`FleetController`], directly active
    /// otherwise.  An empty plan leaves the cluster byte-identical to
    /// one with no plan attached.
    pub fn with_faults(mut self, plan: FaultPlan, backoff: RetryBackoff) -> ClusterSystem {
        let n = self.cfg.n_pairs();
        self.faults = Some(FaultState {
            plan,
            backoff,
            next_fault: 0,
            recoveries: BinaryHeap::new(),
            retry_q: Vec::new(),
            down: vec![false; n],
            fail_at: vec![None; n],
            n_pair_failures: 0,
            n_retries: 0,
            n_recovered: 0,
            recovery_latency: Vec::new(),
        });
        self
    }

    /// Feed the router's live backlog to the fleet controller at arrival
    /// instant `t` and execute at most one scaling action.
    ///
    /// Activation takes effect immediately (the pair rejoins the load
    /// index before this arrival is routed).  A drain masks the pair out
    /// of routing now; if it is already empty it retires on the spot,
    /// otherwise [`collect_until`](Self::collect_until) retires it when
    /// its last in-flight request completes.
    fn autoscale_tick(&mut self, t: SimTime) {
        let Some(ctl) = self.autoscale.as_mut() else { return };
        let outstanding = self.router.outstanding_tokens();
        // Beyond-backlog signal: when the controller's `headroom` knob is
        // set and the cluster has a TTFT SLO, feed it the best remaining
        // SLO headroom from the router's estimator.
        let headroom = match (self.slo_ttft_s, ctl.headroom_enabled()) {
            (Some(slo), true) => self.router.best_ttft_headroom(slo),
            _ => None,
        };
        // Per-pair utilization (in-flight request counts), fed only when
        // the controller's `util` knob is on so the default path stays
        // allocation-free and byte-identical.
        let util: Option<Vec<f64>> = if ctl.util_enabled() {
            Some(self.inflight.iter().map(|&c| c as f64).collect())
        } else {
            None
        };
        match ctl.decide_full(t, &outstanding, headroom, util.as_deref()) {
            Some(ScaleDecision::Activate(i)) => {
                self.router.set_pair_active(i, true);
                self.n_scale_ups += 1;
                self.pending.push(SystemEvent::ScaleUp { pair: i, t });
            }
            Some(ScaleDecision::Drain(i)) => {
                self.router.set_pair_active(i, false);
                if self.inflight[i] == 0 {
                    ctl.on_pair_drained(i);
                    // The pair's KV is alive: hand its warm sessions over
                    // the link where that beats re-prefilling (a plain
                    // eviction without a configured link).
                    self.router.handoff_pair_residency(i, t);
                    self.n_scale_downs += 1;
                    self.pending.push(SystemEvent::ScaleDown { pair: i, t });
                }
            }
            None => {}
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Step the cluster to `until`, injecting any fault-plan work
    /// (failures, repairs, failure-retries) due on the way, each at its
    /// exact instant: pairs are first stepped *to* the fault instant so
    /// the injection sees exactly the completions that beat it.  Without
    /// a fault plan this is one dead `is_some()` branch in front of
    /// [`collect_pairs_until`](Self::collect_pairs_until), so non-fault
    /// runs are byte-identical to the pre-fault cluster.
    fn collect_until(&mut self, until: SimTime) {
        if self.faults.is_some() || self.migration.is_some() {
            loop {
                let next = match
                    (self.next_fault_instant(), self.next_migration_instant())
                {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                let Some(it) = next.filter(|it| *it <= until) else { break };
                self.collect_pairs_until(it);
                if self.next_fault_instant().is_some_and(|ft| ft <= it) {
                    self.process_faults_at(it);
                }
                self.deliver_migrations_at(it);
            }
        }
        self.collect_pairs_until(until);
    }

    /// Earliest pending KV-migration delivery, if any.
    fn next_migration_instant(&self) -> Option<SimTime> {
        self.migration
            .as_ref()
            .and_then(|ms| ms.deliveries.first().map(|(at, _, _)| *at))
    }

    /// Hand every admitted request whose KV transfer has landed by `t`
    /// to its destination pair.  A pair-side deferral re-queues the
    /// delivery strictly later, so the loop terminates; a pair-side
    /// rejection buffered a `Shed` the next collect batch unwinds like
    /// any other in-flight shed.
    fn deliver_migrations_at(&mut self, t: SimTime) {
        while let Some((_, req, decision)) = {
            match self.migration.as_mut() {
                Some(ms) => match ms.deliveries.first() {
                    Some((at, _, _)) if *at <= t => Some(ms.deliveries.remove(0)),
                    _ => None,
                },
                None => None,
            }
        } {
            let pair = decision.pair;
            match self.systems[pair].submit(t, req) {
                Admission::Accepted | Admission::Rejected { .. } => {}
                Admission::Deferred { retry_at } => {
                    let deliver = retry_at.max(SimTime(t.0.saturating_add(1)));
                    let ms = self.migration.as_mut().expect("migration state");
                    let pos =
                        ms.deliveries.partition_point(|(a, _, _)| *a <= deliver);
                    ms.deliveries.insert(pos, (deliver, req, decision));
                }
            }
            self.calendar.set(pair, self.systems[pair].next_event_at());
        }
    }

    /// Earliest pending fault-plan instant: the next scheduled outage,
    /// repair, or queued failure-retry.
    fn next_fault_instant(&self) -> Option<SimTime> {
        let fs = self.faults.as_ref()?;
        let mut next = fs.plan.events().get(fs.next_fault).map(|e| e.fail_at);
        if let Some(&Reverse((rt, _))) = fs.recoveries.peek() {
            next = Some(next.map_or(rt, |n| n.min(rt)));
        }
        if let Some(rt) = fs.retry_q.iter().map(|(rt, _, _)| *rt).min() {
            next = Some(next.map_or(rt, |n| n.min(rt)));
        }
        next
    }

    /// Earliest scheduled repair — the deferral hint when the whole
    /// fleet is down.
    fn next_recovery_instant(&self) -> Option<SimTime> {
        let fs = self.faults.as_ref()?;
        fs.recoveries.peek().map(|&Reverse((rt, _))| rt)
    }

    /// Execute every fault-plan item due at `t`: repairs first (a pair
    /// repaired at `t` is routable again for the retries of the same
    /// instant), then outages, then failure-retries in
    /// `(retry_at, enqueue order)`.  Re-deferred retries land strictly
    /// after `t` (the backoff guarantees it), so each loop terminates.
    fn process_faults_at(&mut self, t: SimTime) {
        while let Some(pair) = {
            let fs = self.faults.as_mut().expect("fault state");
            match fs.recoveries.peek() {
                Some(&Reverse((rt, _))) if rt <= t => {
                    fs.recoveries.pop().map(|Reverse((_, p))| p)
                }
                _ => None,
            }
        } {
            self.recover_pair(pair, t);
        }
        while let Some(ev) = {
            let fs = self.faults.as_mut().expect("fault state");
            match fs.plan.events().get(fs.next_fault) {
                Some(e) if e.fail_at <= t => {
                    fs.next_fault += 1;
                    Some(*e)
                }
                _ => None,
            }
        } {
            self.fail_pair(ev, t);
        }
        while let Some((req, attempts)) = {
            let fs = self.faults.as_mut().expect("fault state");
            let due = fs
                .retry_q
                .iter()
                .enumerate()
                .filter(|(_, (rt, _, _))| *rt <= t)
                .min_by_key(|(i, (rt, _, _))| (rt.0, *i))
                .map(|(i, _)| i);
            due.map(|i| {
                let (_, req, attempts) = fs.retry_q.remove(i);
                (req, attempts)
            })
        } {
            self.resubmit(t, req, attempts);
        }
    }

    /// Inject one scheduled outage: mask the pair out of routing, evict
    /// its KV residency, abort and re-queue its in-flight work, and let
    /// the fleet controller flip a standby active in its place.
    fn fail_pair(&mut self, ev: FaultEvent, t: SimTime) {
        let pair = ev.pair;
        {
            let fs = self.faults.as_mut().expect("fault state");
            if fs.down[pair] {
                // Overlapping outage on a pair already down: extend the
                // repair schedule (the latest repair instant wins —
                // `recover_pair` skips entries that a later one covers).
                if let Some(r) = ev.recover_at {
                    fs.recoveries.push(Reverse((r, pair)));
                }
                return;
            }
            fs.down[pair] = true;
            fs.fail_at[pair] = Some(t);
            fs.n_pair_failures += 1;
            if let Some(r) = ev.recover_at {
                fs.recoveries.push(Reverse((r, pair)));
            }
        }
        // The pair leaves the routable set, and its resident KV — the
        // sessions' warm prefixes — dies with it.
        self.router.set_pair_active(pair, false);
        self.router.evict_pair_residency(pair);
        self.pending.push(SystemEvent::PairFailed { pair, t });

        // Abort everything in flight on the pair, unwinding the cluster
        // bookkeeping exactly as if each request had left the system,
        // and queue each for re-admission with its KV claim stripped:
        // the retry re-prefills from scratch and earns no warm-turn
        // credit.
        let qos = self.classes.is_some();
        for id in self.systems[pair].abort_inflight() {
            let Some(a) = self.assigned.remove(&id) else { continue };
            debug_assert_eq!(a.pair, pair);
            self.router.on_completed(pair, a.tokens);
            if qos {
                self.router.on_stream_completed(pair, a.class, a.ctx);
                if let Some(l) = self.ledger.as_mut() {
                    l.on_done(a.class);
                }
            }
            // Re-admission recounts the request, so the per-class
            // terminal ledger sees it exactly once.
            if let Some(cs) = self.class_stat_mut(a.class) {
                cs.n_requests -= 1;
                cs.n_retries += 1;
            }
            self.inflight[pair] -= 1;
            let mut req = a.req;
            req.strip_kv_claim();
            let fs = self.faults.as_mut().expect("fault state");
            fs.n_retries += 1;
            let retry = fs.backoff.retry_at(t, t, 0);
            fs.retry_q.push((retry, req, 0));
        }
        // Admitted-but-undelivered migrations destined to the failed
        // pair abort the same way: their KV on the wire has nowhere to
        // land, so the retry re-prefills from scratch.  (Transfers
        // *sourced* from the failed pair already left its memory before
        // the outage and are unaffected.)
        let doomed = match self.migration.as_mut() {
            Some(ms) => {
                let (doomed, keep): (Vec<_>, Vec<_>) = ms
                    .deliveries
                    .drain(..)
                    .partition(|(_, _, d)| d.pair == pair);
                ms.deliveries = keep;
                doomed
            }
            None => Vec::new(),
        };
        for (_, dreq, _) in doomed {
            let Some(a) = self.assigned.remove(&dreq.id) else { continue };
            self.router.on_completed(pair, a.tokens);
            if qos {
                self.router.on_stream_completed(pair, a.class, a.ctx);
                if let Some(l) = self.ledger.as_mut() {
                    l.on_done(a.class);
                }
            }
            if let Some(cs) = self.class_stat_mut(a.class) {
                cs.n_requests -= 1;
                cs.n_retries += 1;
            }
            self.inflight[pair] -= 1;
            let mut req = a.req;
            req.strip_kv_claim();
            let fs = self.faults.as_mut().expect("fault state");
            fs.n_retries += 1;
            let retry = fs.backoff.retry_at(t, t, 0);
            fs.retry_q.push((retry, req, 0));
        }
        // The pair's engines were rebuilt empty; refresh its calendar
        // key (it goes quiet until repair).
        self.calendar.set(pair, self.systems[pair].next_event_at());

        // A failure is an implicit scale-up signal: flip a standby
        // active right away instead of waiting for backlog pressure.
        if let Some(ctl) = self.autoscale.as_mut() {
            ctl.on_pair_failed(pair);
            if let Some(j) = ctl.force_activate() {
                self.router.set_pair_active(j, true);
                self.n_scale_ups += 1;
                self.pending.push(SystemEvent::ScaleUp { pair: j, t });
            }
        }
    }

    /// Repair a failed pair: it rejoins as standby under a fleet
    /// controller (the failure already flipped a standby active) or is
    /// unmasked directly on a fixed fleet.
    fn recover_pair(&mut self, pair: usize, t: SimTime) {
        {
            let fs = self.faults.as_mut().expect("fault state");
            if !fs.down[pair] {
                // Stale entry from a merged outage.
                return;
            }
            if fs
                .recoveries
                .iter()
                .any(|&Reverse((rt, p))| p == pair && rt > t)
            {
                // An overlapping outage extended the downtime; the later
                // repair entry wins.
                return;
            }
            fs.down[pair] = false;
            fs.n_recovered += 1;
            if let Some(f) = fs.fail_at[pair].take() {
                let lat = t.saturating_sub(f).as_secs_f64();
                // Non-finite samples would poison the report's sorted
                // percentile arrays; reject them at insertion.
                if lat.is_finite() {
                    fs.recovery_latency.push(lat);
                }
            }
        }
        if let Some(ctl) = self.autoscale.as_mut() {
            ctl.on_pair_recovered(pair);
        } else {
            self.router.set_pair_active(pair, true);
        }
        self.pending.push(SystemEvent::PairRecovered { pair, t });
    }

    /// Re-submit a failure-aborted request through the full admission
    /// path.  A deferral re-queues it under the failure backoff;
    /// exhausting the backoff sheds it with a distinct reason.
    fn resubmit(&mut self, t: SimTime, req: Request, attempts: usize) {
        match self.admit(t, req, Some(attempts)) {
            Admission::Accepted | Admission::Rejected { .. } => {}
            Admission::Deferred { retry_at } => {
                let backoff =
                    self.faults.as_ref().expect("fault state").backoff;
                if backoff.gives_up(attempts) {
                    let reason = format!(
                        "pair failure: dropped after {} retry attempts",
                        backoff.max_attempts
                    );
                    self.n_router_rejected += 1;
                    if let Some(cs) = self.class_stat_mut(req.class) {
                        cs.n_requests += 1;
                        cs.n_shed += 1;
                    }
                    if req.session_id != NO_SESSION {
                        self.router.release_session(req.session_id);
                    }
                    self.pending.push(SystemEvent::Shed { id: req.id, t, reason });
                } else {
                    let retry = backoff.retry_at(t, retry_at, attempts);
                    let fs = self.faults.as_mut().expect("fault state");
                    fs.retry_q.push((retry, req, attempts + 1));
                }
            }
        }
    }

    /// Step every pair with a *due* event to `until`, feed completions
    /// back into the router's live backlog (and session-residency
    /// lifecycle), and buffer the merged events.
    ///
    /// The calendar hands over only the due pairs — O(due · log N), not
    /// O(N) — and the per-pair streams (each already time-ordered) are
    /// k-way merged into `pending` with ties toward the lower pair
    /// index: exactly the order the old scan-everything stepper's
    /// per-batch stable sort produced, byte for byte (pinned by
    /// `tests/cluster_calendar_oracle.rs`).
    fn collect_pairs_until(&mut self, until: SimTime) {
        // The due list is recycled: taken out so iterating it never
        // borrows `self` while pairs/router/scratch are touched.
        let mut due = std::mem::take(&mut self.due);
        debug_assert!(due.is_empty());
        while let Some(pair) = self.calendar.pop_due(until) {
            due.push(pair);
        }
        if due.is_empty() {
            self.due = due;
            return;
        }
        // Ascending pair index keeps the router bookkeeping and the
        // merge tie-break in the old per-pair iteration order.
        due.sort_unstable();

        // Draining pairs that empty in this batch, with the instant of
        // the terminal event that emptied them.  Never pushed to when
        // autoscaling is off, so the fixed-fleet hot path stays
        // allocation-free.
        let mut retired: Vec<(usize, SimTime)> = Vec::new();

        for &i in &due {
            let mut buf = std::mem::take(&mut self.scratch[i]);
            debug_assert!(buf.is_empty());
            self.systems[i].advance_into(until, &mut buf);
            let qos = self.classes.is_some();
            for ev in &buf {
                match ev {
                    // Per-class latency sampling (QoS runs only; the
                    // match arms below fall through untouched otherwise,
                    // keeping the non-QoS hot path allocation-free).
                    SystemEvent::FirstToken { id, t } if qos => {
                        if let Some(a) = self.assigned.get_mut(id) {
                            let c = (a.class.0 as usize)
                                .min(self.class_stats.len() - 1);
                            self.class_stats[c]
                                .ttft
                                .push(t.saturating_sub(a.arrival).as_secs_f64());
                            a.last_token = Some(*t);
                        }
                    }
                    SystemEvent::Token { id, t } if qos => {
                        if let Some(a) = self.assigned.get_mut(id) {
                            let c = (a.class.0 as usize)
                                .min(self.class_stats.len() - 1);
                            if let Some(prev) = a.last_token {
                                self.class_stats[c]
                                    .tbt
                                    .push(t.saturating_sub(prev).as_secs_f64());
                            }
                            a.last_token = Some(*t);
                        }
                    }
                    SystemEvent::Finished { id, .. }
                    | SystemEvent::Shed { id, .. } => {
                        if let Some(a) = self.assigned.remove(id) {
                            debug_assert_eq!(a.pair, i);
                            self.router.on_completed(a.pair, a.tokens);
                            // A finished final turn releases the session's
                            // prefix KV; a shed turn aborts the conversation,
                            // so its residency is dead weight either way.
                            let shed = matches!(ev, SystemEvent::Shed { .. });
                            if a.session_id != NO_SESSION && (a.final_turn || shed) {
                                self.router.release_session(a.session_id);
                            }
                            if qos {
                                // Retire the decode stream from the TBT
                                // estimator and settle the fair ledger.
                                self.router
                                    .on_stream_completed(a.pair, a.class, a.ctx);
                                if let Some(l) = self.ledger.as_mut() {
                                    l.on_done(a.class);
                                }
                                let c = (a.class.0 as usize)
                                    .min(self.class_stats.len() - 1);
                                if shed {
                                    self.class_stats[c].n_shed += 1;
                                } else {
                                    self.class_stats[c].n_finished += 1;
                                }
                            }
                            self.inflight[i] -= 1;
                            if self.inflight[i] == 0
                                && self
                                    .autoscale
                                    .as_ref()
                                    .is_some_and(|c| c.is_draining(i))
                            {
                                // Drain-before-retire: the pair's last
                                // in-flight request just left the system.
                                retired.push((i, ev.time()));
                            }
                        }
                    }
                    _ => {}
                }
            }
            self.scratch[i] = buf;
            // Re-key the pair: everything at or before `until` was just
            // consumed, so its next event (if any) is strictly later.
            self.calendar.set(i, self.systems[i].next_event_at());
        }

        if let [i] = due[..] {
            // Single due pair (the common case once fleets are large and
            // event times spread out): move its stream over wholesale.
            let mut buf = std::mem::take(&mut self.scratch[i]);
            self.pending.append(&mut buf);
            self.scratch[i] = buf;
        } else {
            // K-way merge of the due pairs' streams.  The head heap
            // orders by (time, pair); cloning is allocation-free for
            // every token-bearing event (only a rare `Shed` carries a
            // heap-owned reason string).
            debug_assert!(self.merge.is_empty());
            for &i in &due {
                self.cursors[i] = 0;
                if let Some(ev) = self.scratch[i].first() {
                    self.merge.push(Reverse((ev.time(), i)));
                }
            }
            while let Some(Reverse((_, i))) = self.merge.pop() {
                let c = self.cursors[i];
                self.pending.push(self.scratch[i][c].clone());
                self.cursors[i] = c + 1;
                if let Some(next) = self.scratch[i].get(c + 1) {
                    self.merge.push(Reverse((next.time(), i)));
                }
            }
            for &i in &due {
                self.scratch[i].clear();
            }
        }
        due.clear();
        self.due = due;

        // Retire the pairs that drained empty: back to standby, resident
        // sessions evicted, and a ScaleDown stitched into the merged
        // stream at the retirement instant (a rare O(n) insert that
        // keeps `pending` time-sorted).
        for (pair, retire_t) in retired {
            let ctl = self.autoscale.as_mut().expect("retired pairs imply a controller");
            ctl.on_pair_drained(pair);
            // Drained, not failed: the KV is alive, so warm sessions ship
            // over the link where that beats re-prefilling (plain
            // eviction without a configured link).
            self.router.handoff_pair_residency(pair, retire_t);
            self.n_scale_downs += 1;
            let pos = self.pending.partition_point(|e| e.time() <= retire_t);
            self.pending.insert(pos, SystemEvent::ScaleDown { pair, t: retire_t });
        }
    }

    /// Bookkeeping for one accepted admission: commit the route, settle
    /// the fair ledger, and register the in-flight record.  Shared by
    /// the immediate-submit path and the delayed KV-migration path.
    fn record_accept(&mut self, req: Request, decision: &RouteDecision) {
        self.router.commit_route(&req, decision);
        if let Some(ledger) = self.ledger.as_mut() {
            ledger.on_admit(req.class, decision.charged_tokens);
        }
        if let Some(cs) = self.class_stat_mut(req.class) {
            cs.n_requests += 1;
        }
        self.assigned.insert(
            req.id,
            AssignedReq {
                pair: decision.pair,
                tokens: decision.charged_tokens,
                session_id: req.session_id,
                final_turn: req.final_turn,
                class: req.class,
                ctx: req.total_context() as u64,
                arrival: SimTime(req.arrival_ns),
                last_token: None,
                req,
            },
        );
        self.routed_counts[decision.pair] += 1;
        self.inflight[decision.pair] += 1;
    }

    /// The admission core shared by fresh arrivals (`retry = None`) and
    /// fault-driven re-submissions (`retry = Some(attempts)`): QoS
    /// gates, SLO admission, routing, and the pair submit.  Shed
    /// reasons for re-submissions carry a distinct prefix; for fresh
    /// arrivals the path (and every reason string) is unchanged.
    fn admit(&mut self, t: SimTime, req: Request, retry: Option<usize>) -> Admission {
        let fail_prefix = if retry.is_some() {
            "resubmitted after pair failure: "
        } else {
            ""
        };

        // Whole fleet down (fault runs only): hold the request for the
        // next scheduled repair, or shed it when none is coming.
        if self.faults.is_some() && self.router.n_active_pairs() == 0 {
            if let Some(rt) = self.next_recovery_instant() {
                return Admission::Deferred {
                    retry_at: rt.max(SimTime(t.0.saturating_add(1))),
                };
            }
            let reason =
                format!("{fail_prefix}all pairs failed with no repair scheduled");
            self.n_router_rejected += 1;
            if let Some(cs) = self.class_stat_mut(req.class) {
                cs.n_requests += 1;
                cs.n_shed += 1;
            }
            if req.session_id != NO_SESSION {
                self.router.release_session(req.session_id);
            }
            self.pending.push(SystemEvent::Shed {
                id: req.id,
                t,
                reason: reason.clone(),
            });
            return Admission::Rejected { reason };
        }

        // QoS gates (all inert without a class registry).
        let mut class_slo = None;
        if self.classes.is_some() {
            // Model-aware shed: a class pinned to a model no active pair
            // serves can never be dispatched — shed with a distinct
            // reason rather than mis-routing it.
            if !self.router.has_active_compatible_pair(&req) {
                let reg = self.classes.as_ref().expect("checked above");
                let reason = format!(
                    "{fail_prefix}no active pair serves model '{}'",
                    reg.get(req.class).model.map_or("<any>", |m| m.name)
                );
                self.n_router_rejected += 1;
                if let Some(cs) = self.class_stat_mut(req.class) {
                    cs.n_requests += 1;
                    cs.n_shed += 1;
                }
                if req.session_id != NO_SESSION {
                    self.router.release_session(req.session_id);
                }
                self.pending.push(SystemEvent::Shed {
                    id: req.id,
                    t,
                    reason: reason.clone(),
                });
                return Admission::Rejected { reason };
            }
            let reg = self.classes.as_ref().expect("checked above");
            let full_slo = reg.get(req.class).slo_ttft_s;
            let waited = t.saturating_sub(SimTime(req.arrival_ns)).as_secs_f64();
            // A request that has already burned half its TTFT budget in
            // deferrals is *over SLO*: if its tier is strictly higher it
            // may preempt (bypass) the fairness deferral below.
            let over_slo = full_slo.is_some_and(|slo| waited >= 0.5 * slo);
            // Per-class SLOs are end-to-end from true arrival (that is
            // what `Report.classes` measures): admission sees only the
            // *remaining* budget, so a request that burned its budget in
            // deferrals is shed rather than admitted into a guaranteed
            // violation.
            class_slo = full_slo.map(|slo| (slo - waited).max(1e-3));
            let ledger = self.ledger.as_mut().expect("ledger exists with classes");
            ledger.note_arrival(req.class, t);
            if let Some(retry_at) = ledger.check(t, req.class, over_slo) {
                return Admission::Deferred { retry_at };
            }
            // TBT-aware admission: defer when every compatible pair's
            // projected decode iteration would blow the strictest TBT
            // SLO among its in-flight classes.
            if let Some(retry_at) = self.router.tbt_admission(t, &req) {
                return Admission::Deferred { retry_at };
            }
        }

        // Per-class TTFT SLO overrides the cluster-wide one.
        let eff_slo = class_slo.or(self.slo_ttft_s);
        if let Some(slo) = eff_slo {
            match self.router.slo_admission(t, &req, slo) {
                Admission::Accepted => {}
                Admission::Rejected { reason } => {
                    let reason = format!("{fail_prefix}{reason}");
                    self.n_router_rejected += 1;
                    if let Some(cs) = self.class_stat_mut(req.class) {
                        cs.n_requests += 1;
                        cs.n_shed += 1;
                    }
                    if req.session_id != NO_SESSION {
                        // The conversation ends here; free its residency.
                        self.router.release_session(req.session_id);
                    }
                    self.pending.push(SystemEvent::Shed {
                        id: req.id,
                        t,
                        reason: reason.clone(),
                    });
                    return Admission::Rejected { reason };
                }
                deferred @ Admission::Deferred { .. } => return deferred,
            }
        }

        // With an SLO, dispatch only to pairs the admission check deemed
        // able to serve in time, whatever the base policy prefers.
        let Some(decision) = (match eff_slo {
            Some(slo) => self.router.route_within_slo(&req, slo),
            None => self.router.route(&req),
        }) else {
            // No active model-compatible pair survives (e.g. the whole
            // fleet failed with no fault plan bookkeeping to defer on):
            // shed deterministically instead of routing to a masked pair.
            let reason = format!("{fail_prefix}no active compatible pair");
            self.n_router_rejected += 1;
            if let Some(cs) = self.class_stat_mut(req.class) {
                cs.n_requests += 1;
                cs.n_shed += 1;
            }
            if req.session_id != NO_SESSION {
                self.router.release_session(req.session_id);
            }
            self.pending.push(SystemEvent::Shed {
                id: req.id,
                t,
                reason: reason.clone(),
            });
            return Admission::Rejected { reason };
        };
        let pair = decision.pair;
        // The chosen pair may skip the resident prefix: stamp the granted
        // credit into the request it sees.
        let mut pair_req = req;
        pair_req.kv_credit = decision.kv_credit;
        // A migrated prefix is still on the wire: commit the admission
        // now, but deliver the request to the destination pair only once
        // the transfer lands, so the link delay is part of the measured
        // TTFT, not just the estimate.
        let delay_ns = decision.transfer.map_or(0, |x| x.delay_ns);
        if delay_ns > 0 {
            let deliver = SimTime(t.0.saturating_add(delay_ns));
            self.record_accept(req, &decision);
            let ms = self
                .migration
                .as_mut()
                .expect("a transfer implies a configured link");
            let pos = ms.deliveries.partition_point(|(a, _, _)| *a <= deliver);
            ms.deliveries.insert(pos, (deliver, pair_req, decision));
            return Admission::Accepted;
        }
        let admission = self.systems[pair].submit(t, pair_req);
        // The pair's timeline changed (new work scheduled, or a Shed
        // buffered on rejection): refresh its calendar key.
        self.calendar.set(pair, self.systems[pair].next_event_at());
        match admission {
            Admission::Accepted => {
                // Commit only on acceptance, so residency and hit
                // accounting never reflect requests the pair turned away.
                self.record_accept(req, &decision);
                Admission::Accepted
            }
            Admission::Rejected { reason } => {
                // The pair recorded the shed itself; release the backlog
                // the router just charged.  The conversation aborts with
                // it, so its residency goes too.
                // (The decision was never committed, so the router's
                // stream counters need no rollback.)
                self.router.on_completed(pair, decision.charged_tokens);
                if let Some(cs) = self.class_stat_mut(req.class) {
                    cs.n_requests += 1;
                    cs.n_shed += 1;
                }
                if req.session_id != NO_SESSION {
                    self.router.release_session(req.session_id);
                }
                self.routed_counts[pair] += 1;
                Admission::Rejected {
                    reason: format!("{fail_prefix}{reason}"),
                }
            }
            deferred @ Admission::Deferred { .. } => {
                self.router.on_completed(pair, decision.charged_tokens);
                deferred
            }
        }
    }
}

impl ServingSystem for ClusterSystem {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn submit(&mut self, t: SimTime, req: Request) -> Admission {
        // Bring every pair up to just before the arrival so the router
        // routes on what has actually completed by now.
        self.collect_until(SimTime(t.0.saturating_sub(1)));
        // Let the fleet controller react to the live backlog before this
        // arrival is admitted or routed.
        self.autoscale_tick(t);
        self.admit(t, req, None)
    }

    fn next_event_at(&self) -> Option<SimTime> {
        // O(1): the first buffered event and the calendar top (always
        // live) — no per-pair scan.
        let base = earliest_instant(&self.pending, self.calendar.peek());
        if self.faults.is_none() && self.migration.is_none() {
            return base;
        }
        // Fault runs: scheduled outages, repairs and failure-retries are
        // events a driver must step to even when every pair is quiet.
        // Migration runs: likewise pending KV deliveries.
        let extra = match (self.next_fault_instant(), self.next_migration_instant())
        {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        match (base, extra) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent> {
        let mut out = Vec::new();
        self.advance_into(until, &mut out);
        out
    }

    fn advance_into(&mut self, until: SimTime, out: &mut Vec<SystemEvent>) {
        self.collect_until(until);
        drain_pending_into(&mut self.pending, until, out);
    }

    fn drain(&mut self) -> RunOutcome {
        // Deliver all remaining completions into the router bookkeeping.
        self.collect_until(SimTime(u64::MAX));
        self.pending.clear();

        let mut reports: Vec<Report> = Vec::new();
        let mut instances: Vec<InstanceStat> = Vec::new();
        for (i, (pair, sys)) in
            self.cfg.pairs.iter().zip(self.systems.iter_mut()).enumerate()
        {
            if self.routed_counts[i] == 0 {
                // An idle pair never got a submit (its state was never
                // built); it still shows up in the utilization table.
                instances.push(InstanceStat {
                    name: format!("p{i}:{} (idle)", pair.name),
                    busy_time_s: 0.0,
                    n_iterations: 0,
                    n_preemptions: 0,
                    tokens_prefilled: 0,
                    tokens_decoded: 0,
                    tokens_kv_received: 0,
                });
                continue;
            }
            let out = sys.drain();
            reports.push(out.report);
            for inst in out.instances {
                instances.push(InstanceStat {
                    name: format!("p{i}:{}", inst.name),
                    ..inst
                });
            }
        }
        let mut report = Report::merge(self.label.clone(), &reports);
        // Router-level sheds never reached a pair; account for them at
        // the cluster level.
        report.n_requests += self.n_router_rejected;
        report.n_rejected += self.n_router_rejected;
        // KV-affinity accounting lives in the router, not the pairs.
        report.n_kv_hits = self.router.kv_hits() as usize;
        report.prefill_tokens_saved = self.router.prefill_tokens_saved();
        report.n_prefix_routed = self.router.n_prefix_routed() as usize;
        report.kv_hit_rate = if report.n_prefix_routed > 0 {
            self.router.kv_hits() as f64 / report.n_prefix_routed as f64
        } else {
            0.0
        };
        report.n_scale_ups = self.n_scale_ups;
        report.n_scale_downs = self.n_scale_downs;
        // Fault-injection accounting (fault runs only).
        if let Some(fs) = self.faults.as_mut() {
            report.n_pair_failures = fs.n_pair_failures;
            report.n_retries = fs.n_retries;
            report.n_recovered = fs.n_recovered;
            fs.recovery_latency.sort_unstable_by(f64::total_cmp);
            report.recovery_latency_s = std::mem::take(&mut fs.recovery_latency);
        }
        // KV-migration accounting lives in the router (always zero
        // without a configured link).
        report.n_migrations = self.router.n_migrations() as usize;
        report.migrated_tokens = self.router.migrated_tokens();
        report.migration_time_s = self.router.migration_time_s();
        // Per-class breakdown (QoS runs): the accumulators drain into
        // the report; throughput shares the run's makespan clock.
        if let Some(reg) = &self.classes {
            let makespan_s = report.makespan_s;
            report.classes = reg
                .iter()
                .zip(self.class_stats.iter_mut())
                .map(|(sc, cs)| {
                    let mut cb = ClassBreakdown::from_samples(
                        sc.name.clone(),
                        cs.n_requests,
                        cs.n_finished,
                        cs.n_shed,
                        makespan_s,
                        std::mem::take(&mut cs.ttft),
                        std::mem::take(&mut cs.tbt),
                    );
                    cb.n_retries = cs.n_retries;
                    cb
                })
                .collect();
        }

        // Reset for a fresh run (each drained pair reset itself, so
        // every calendar key is gone).  `Router::reset` keeps the
        // calibrated predictors, so drain stays O(N) bookkeeping
        // instead of O(N) re-profiling.
        self.router.reset();
        self.assigned.clear();
        self.routed_counts.iter_mut().for_each(|c| *c = 0);
        self.n_router_rejected = 0;
        self.calendar = EventCalendar::new(self.cfg.n_pairs());
        self.inflight.iter_mut().for_each(|c| *c = 0);
        self.n_scale_ups = 0;
        self.n_scale_downs = 0;
        if let Some(ledger) = self.ledger.as_mut() {
            ledger.reset();
        }
        for cs in &mut self.class_stats {
            *cs = ClassStat::default();
        }
        // `Router::reset` re-activated every pair; restore the
        // controller's t=0 standby mask for the next run.
        if let Some(ctl) = self.autoscale.as_mut() {
            ctl.reset();
            for i in 0..self.cfg.n_pairs() {
                self.router.set_pair_active(i, ctl.is_active(i));
            }
        }
        // No KV transfer outlives its run (drain delivered everything).
        if let Some(ms) = self.migration.as_mut() {
            ms.deliveries.clear();
        }
        // Rewind the fault plan for the next run.
        if let Some(fs) = self.faults.as_mut() {
            fs.next_fault = 0;
            fs.recoveries.clear();
            fs.retry_q.clear();
            fs.down.iter_mut().for_each(|d| *d = false);
            fs.fail_at.iter_mut().for_each(|f| *f = None);
            fs.n_pair_failures = 0;
            fs.n_retries = 0;
            fs.n_recovered = 0;
            fs.recovery_latency.clear();
        }

        RunOutcome { report, instances }
    }
}

/// Instantiate an N-pair cluster behind `policy` (the cluster analogue
/// of [`build_system`]).
pub fn build_cluster_system(
    cfg: &ClusterConfig,
    policy: RoutePolicy,
) -> Box<dyn ServingSystem> {
    Box::new(ClusterSystem::new(cfg.clone(), policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::cronus::balancer::SplitPolicy;
    use crate::cronus::frontend::CronusSystem;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::systems::driver::{replay_trace, replay_trace_collect};
    use crate::workload::arrival::{stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn all_at_once(n: usize, seed: u64) -> Vec<Request> {
        let t = generate(n, &AzureTraceConfig::default(), seed);
        stamp(&t, ArrivalProcess::AllAtOnce)
    }

    #[test]
    fn one_pair_cluster_matches_bare_cronus() {
        let trace = all_at_once(40, 1);
        let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let cfg = ClusterConfig::homogeneous(1, deployment.clone());
        let mut cluster_sys = ClusterSystem::new(cfg, RoutePolicy::RoundRobin);
        let cluster = replay_trace(&mut cluster_sys, &trace);
        let mut bare_sys = CronusSystem::new(deployment, SplitPolicy::Balanced, false, "x");
        let bare = replay_trace(&mut bare_sys, &trace);
        assert_eq!(cluster.report.n_finished, bare.report.n_finished);
        assert_eq!(cluster.report.makespan_s, bare.report.makespan_s);
        assert_eq!(cluster.report.ttft_p99_s, bare.report.ttft_p99_s);
    }

    #[test]
    fn mixed_cluster_serves_everything() {
        let trace = all_at_once(80, 2);
        for policy in RoutePolicy::ALL {
            let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
            let mut sys = build_cluster_system(&cfg, policy);
            let out = replay_trace(sys.as_mut(), &trace);
            assert_eq!(out.report.n_finished, 80, "{}", policy.name());
            assert_eq!(out.report.n_requests, 80);
            // Two instances (PPI + CPI) per pair.
            assert_eq!(out.instances.len(), 8, "{}", policy.name());
            assert!(out.instances.iter().all(|i| i.name.starts_with('p')));
            assert!(out.report.ttft_p99_s > 0.0);
        }
    }

    #[test]
    fn scaling_out_multiplies_throughput() {
        let trace = all_at_once(160, 3);
        let run = |n_pairs| {
            let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
            let mut sys =
                build_cluster_system(&cfg, RoutePolicy::LeastOutstandingTokens);
            replay_trace(sys.as_mut(), &trace).report.throughput_rps
        };
        let one = run(1);
        let four = run(4);
        assert!(four > 2.5 * one, "scaling 1→4 pairs only {one:.2} → {four:.2} req/s");
    }

    #[test]
    fn empty_pair_reported_idle() {
        // Round-robin over 4 pairs with fewer requests than pairs leaves
        // tail pairs idle but visible.
        let trace = all_at_once(2, 4);
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let mut sys = build_cluster_system(&cfg, RoutePolicy::RoundRobin);
        let out = replay_trace(sys.as_mut(), &trace);
        assert_eq!(out.report.n_finished, 2);
        let idle = out
            .instances
            .iter()
            .filter(|i| i.name.contains("(idle)"))
            .count();
        assert_eq!(idle, 2);
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let trace = all_at_once(50, 5);
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let mut sa = build_cluster_system(&cfg, RoutePolicy::SloAware);
        let mut sb = build_cluster_system(&cfg, RoutePolicy::SloAware);
        let a = replay_trace(sa.as_mut(), &trace);
        let b = replay_trace(sb.as_mut(), &trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
        assert_eq!(a.report.tbt_p99_s, b.report.tbt_p99_s);
    }

    #[test]
    fn cluster_events_cover_all_requests() {
        let trace = all_at_once(30, 6);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens);
        let (out, events, stats) = replay_trace_collect(&mut sys, &trace);
        assert_eq!(out.report.n_finished, 30);
        assert_eq!(stats.n_accepted, 30);
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SystemEvent::Finished { .. }))
            .count();
        assert_eq!(finishes, 30);
        // Live backlog fully released at the end of the run.
        assert!(sys.assigned.is_empty());
    }

    #[test]
    fn cluster_drain_resets_for_reuse() {
        // Back-to-back runs on one ClusterSystem (calendar, router and
        // assignment state all reset by drain) match exactly — and the
        // reset keeps the calibrated predictors instead of re-profiling.
        let trace = all_at_once(30, 8);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::KvAffinity);
        let a = replay_trace(&mut sys, &trace);
        let b = replay_trace(&mut sys, &trace);
        assert_eq!(a.report.n_finished, 30);
        assert_eq!(b.report.n_finished, 30);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
        assert_eq!(a.report.tbt_p99_s, b.report.tbt_p99_s);
    }

    #[test]
    fn closed_loop_affinity_reports_kv_hits_and_saves_prefill() {
        use crate::systems::driver::closed_loop;
        use crate::systems::prefill_tokens_executed;
        use crate::workload::session::{generate_sessions, SessionConfig};
        let sessions = generate_sessions(&SessionConfig {
            n_sessions: 6,
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 0.5,
            start_window_s: 2.0,
            mean_new_input: 256.0,
            max_new_input: 1024,
            seed: 9,
            ..SessionConfig::default()
        });
        let n_turns: usize = sessions.iter().map(|s| s.turns.len()).sum();
        let total_input: u64 = sessions
            .iter()
            .map(|s| s.total_input_tokens() as u64)
            .sum();

        let run = |policy: RoutePolicy| {
            let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
            let mut sys = ClusterSystem::new(cfg, policy);
            let (out, stats) = closed_loop(&mut sys, &sessions);
            assert!(sys.assigned.is_empty(), "{}", policy.name());
            (out, stats)
        };

        let (lot, lot_stats) = run(RoutePolicy::LeastOutstandingTokens);
        let (aff, aff_stats) = run(RoutePolicy::KvAffinity);
        assert_eq!(lot_stats.n_finished_turns, n_turns);
        assert_eq!(aff_stats.n_finished_turns, n_turns);

        // KV-oblivious routing recomputes every prompt token; affinity
        // skips exactly the resident prefixes it reports as saved.
        assert_eq!(prefill_tokens_executed(&lot), total_input);
        assert_eq!(lot.report.n_kv_hits, 0);
        assert!(aff.report.n_kv_hits > 0);
        assert!(aff.report.kv_hit_rate > 0.0);
        assert!(aff.report.prefill_tokens_saved > 0);
        assert_eq!(
            prefill_tokens_executed(&aff),
            total_input - aff.report.prefill_tokens_saved
        );
    }

    #[test]
    fn slo_admission_sheds_or_defers_under_overload() {
        // A harsh TTFT SLO on a single pair under an all-at-once burst:
        // the first requests fit, the rest defer until the backlog
        // drains (or drop at the driver's retry cap).  Everything that
        // was accepted must still finish.
        let trace = all_at_once(60, 7);
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let mut sys =
            ClusterSystem::new(cfg, RoutePolicy::SloAware).with_slo_ttft(Some(0.5));
        let (out, _events, stats) = replay_trace_collect(&mut sys, &trace);
        assert_eq!(stats.n_submitted, 60);
        assert!(
            stats.n_deferred > 0 || stats.n_rejected > 0,
            "harsh SLO should defer or reject something: {stats:?}"
        );
        // Conservation under admission control: every trace request was
        // accepted (and finished), rejected, or dropped at the retry cap.
        assert_eq!(out.report.n_finished, stats.n_accepted);
        assert_eq!(
            stats.n_accepted + stats.n_rejected + stats.n_dropped,
            60,
            "{stats:?}"
        );
        // Driver-dropped deferrals are folded into the outcome, so the
        // report conserves the full trace.
        assert_eq!(out.report.n_requests, 60);
        assert_eq!(out.report.n_finished + out.report.n_rejected, 60);
        // No SLO: everything is served.
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let mut open = ClusterSystem::new(cfg, RoutePolicy::SloAware);
        let out = replay_trace(&mut open, &trace);
        assert_eq!(out.report.n_finished, 60);
    }

    // --- QoS: service classes, fair sharing, per-class reporting ---

    #[test]
    fn qos_cluster_reports_per_class_breakdown_and_conserves() {
        use crate::qos::{ClassRegistry, ServiceClass};
        let trace = all_at_once(60, 11);
        let mut reg = ClassRegistry::new();
        let premium = reg.register(ServiceClass {
            tier: 1,
            weight: 2.0,
            ..ServiceClass::named("premium")
        });
        let batch = reg.register(ServiceClass::named("batch"));
        let classed: Vec<Request> = trace
            .iter()
            .enumerate()
            .map(|(i, r)| r.with_class(if i % 3 == 0 { premium } else { batch }))
            .collect();
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
            .with_classes(reg);
        let (out, _events, stats) = replay_trace_collect(&mut sys, &classed);
        let classes = &out.report.classes;
        assert_eq!(classes.len(), 3);
        assert_eq!(classes[0].name, "default");
        assert_eq!(classes[1].name, "premium");
        assert_eq!(classes[2].name, "batch");
        assert_eq!(classes[0].n_requests, 0, "nothing ran in the default class");
        // Per-class conservation: every terminal outcome lands in its
        // class's ledger, and the slices sum to the run totals.
        for c in classes {
            assert_eq!(c.n_finished + c.n_shed, c.n_requests, "{}", c.name);
        }
        assert_eq!(
            classes.iter().map(|c| c.n_requests).sum::<usize>(),
            stats.n_accepted + stats.n_rejected
        );
        assert_eq!(
            classes.iter().map(|c| c.n_finished).sum::<usize>(),
            out.report.n_finished
        );
        assert_eq!(classes[1].ttft_samples.len(), classes[1].n_finished);
        assert!(classes[1].n_finished > 0 && classes[2].n_finished > 0);
        assert!(classes[1].ttft_p99_s > 0.0 && classes[2].tbt_p99_s > 0.0);
        let s = out.report.summary();
        assert!(s.contains("class premium") && s.contains("class batch"), "{s}");
    }

    #[test]
    fn default_class_run_is_byte_identical_with_registry_attached() {
        use crate::qos::{ClassRegistry, ServiceClass};
        let trace = all_at_once(40, 12);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut plain = ClusterSystem::new(cfg.clone(), RoutePolicy::KvAffinity);
        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass {
            slo_tbt_p99_s: Some(0.5),
            ..ServiceClass::named("premium")
        });
        let mut qos = ClusterSystem::new(cfg, RoutePolicy::KvAffinity)
            .with_classes(reg);
        let (a_out, a_events, _) = replay_trace_collect(&mut plain, &trace);
        let (b_out, b_events, _) = replay_trace_collect(&mut qos, &trace);
        assert_eq!(a_events, b_events, "event streams must match exactly");
        assert_eq!(a_out.report.ttft_p99_s, b_out.report.ttft_p99_s);
        assert_eq!(a_out.report.tbt_p99_s, b_out.report.tbt_p99_s);
        assert_eq!(a_out.report.makespan_s, b_out.report.makespan_s);
        // Only the QoS run carries the (all-default) class breakdown.
        assert!(a_out.report.classes.is_empty());
        assert_eq!(b_out.report.classes.len(), 2);
        assert_eq!(b_out.report.classes[0].n_finished, 40);
        assert_eq!(b_out.report.classes[1].n_requests, 0);
    }

    #[test]
    fn model_pinned_class_sheds_when_no_compatible_pair() {
        use crate::qos::{ClassRegistry, ServiceClass};
        use crate::simgpu::model_desc::QWEN2_7B;
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B); // llama-only fleet
        let mut reg = ClassRegistry::new();
        let mut sc = ServiceClass::named("qwen-tenant");
        sc.model = Some(QWEN2_7B);
        let qwen = reg.register(sc);
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
            .with_classes(reg);
        let trace: Vec<Request> =
            all_at_once(10, 13).iter().map(|r| r.with_class(qwen)).collect();
        let (out, events, stats) = replay_trace_collect(&mut sys, &trace);
        assert_eq!(stats.n_rejected, 10);
        assert_eq!(out.report.n_finished, 0);
        assert_eq!(out.report.n_rejected, 10);
        let c = &out.report.classes[1];
        assert_eq!((c.n_requests, c.n_shed), (10, 10));
        assert!(events.iter().all(|e| matches!(
            e,
            SystemEvent::Shed { reason, .. } if reason.contains(QWEN2_7B.name)
        )));
    }

    // --- Fault injection: outages, retries, recovery ---

    #[test]
    fn scheduled_pair_failure_recovers_and_conserves() {
        let trace = all_at_once(40, 21);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let plan = FaultPlan::new(vec![FaultEvent {
            pair: 0,
            fail_at: SimTime::from_secs_f64(0.5),
            recover_at: Some(SimTime::from_secs_f64(2.0)),
        }])
        .expect("valid plan");
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
            .with_faults(plan, RetryBackoff::default());
        let (out, events, stats) = replay_trace_collect(&mut sys, &trace);
        assert_eq!(out.report.n_pair_failures, 1);
        assert_eq!(out.report.n_recovered, 1);
        assert!(out.report.n_retries > 0, "the burst keeps pair 0 busy at 0.5s");
        assert_eq!(out.report.recovery_latency_s.len(), 1);
        assert!((out.report.recovery_latency_s[0] - 1.5).abs() < 1e-6);
        assert!(events
            .iter()
            .any(|e| matches!(e, SystemEvent::PairFailed { pair: 0, .. })));
        assert!(events
            .iter()
            .any(|e| matches!(e, SystemEvent::PairRecovered { pair: 0, .. })));
        // Conservation: every trace request reaches exactly one terminal
        // outcome, failure or not.
        assert_eq!(stats.n_accepted + stats.n_rejected + stats.n_dropped, 40);
        assert_eq!(out.report.n_finished + out.report.n_rejected, 40);
        assert!(sys.assigned.is_empty());
        assert!(out.report.summary().contains("faults 1"));
    }

    #[test]
    fn fail_stop_on_single_pair_sheds_survivors_distinctly() {
        // The only pair fail-stops mid-burst with no repair scheduled:
        // aborted and not-yet-arrived requests shed with fault reasons
        // instead of hanging or panicking.
        let trace = all_at_once(20, 22);
        let cfg = ClusterConfig::mixed(1, LLAMA3_8B);
        let plan = FaultPlan::new(vec![FaultEvent {
            pair: 0,
            fail_at: SimTime::from_secs_f64(0.2),
            recover_at: None,
        }])
        .expect("valid plan");
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
            .with_faults(plan, RetryBackoff::default());
        let (out, events, stats) = replay_trace_collect(&mut sys, &trace);
        assert_eq!(out.report.n_pair_failures, 1);
        assert_eq!(out.report.n_recovered, 0);
        assert!(out.report.n_finished < 20, "the outage must cost something");
        assert_eq!(stats.n_accepted + stats.n_rejected + stats.n_dropped, 20);
        assert_eq!(out.report.n_finished + out.report.n_rejected, 20);
        assert!(events.iter().any(|e| matches!(
            e,
            SystemEvent::Shed { reason, .. }
                if reason.contains("pair failure") || reason.contains("all pairs failed")
        )));
    }

    #[test]
    fn empty_fault_plan_is_byte_identical_to_no_plan() {
        let trace = all_at_once(40, 23);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let mut plain = ClusterSystem::new(cfg.clone(), RoutePolicy::KvAffinity);
        let mut inert = ClusterSystem::new(cfg, RoutePolicy::KvAffinity)
            .with_faults(FaultPlan::empty(), RetryBackoff::default());
        let (a_out, a_events, _) = replay_trace_collect(&mut plain, &trace);
        let (b_out, b_events, _) = replay_trace_collect(&mut inert, &trace);
        assert_eq!(a_events, b_events, "inert plan must not perturb the stream");
        assert_eq!(a_out.report.makespan_s, b_out.report.makespan_s);
        assert_eq!(a_out.report.ttft_p99_s, b_out.report.ttft_p99_s);
        assert_eq!(a_out.report.tbt_p99_s, b_out.report.tbt_p99_s);
        assert_eq!(b_out.report.n_pair_failures, 0);
        assert_eq!(b_out.report.n_retries, 0);
    }

    #[test]
    fn faulted_runs_reset_cleanly_for_reuse() {
        let trace = all_at_once(30, 24);
        let cfg = ClusterConfig::mixed(2, LLAMA3_8B);
        let plan = FaultPlan::new(vec![FaultEvent {
            pair: 1,
            fail_at: SimTime::from_secs_f64(0.3),
            recover_at: Some(SimTime::from_secs_f64(1.0)),
        }])
        .expect("valid plan");
        let mut sys = ClusterSystem::new(cfg, RoutePolicy::LeastOutstandingTokens)
            .with_faults(plan, RetryBackoff::default());
        let a = replay_trace(&mut sys, &trace);
        let b = replay_trace(&mut sys, &trace);
        assert_eq!(a.report.n_pair_failures, b.report.n_pair_failures);
        assert_eq!(a.report.n_retries, b.report.n_retries);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
    }
}
