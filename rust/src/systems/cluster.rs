//! The N-pair cluster serving system: a cluster-level [`Router`] in
//! front of N independent (high-end, low-end) pair deployments.
//!
//! Each pair is a full serving system of its own (Cronus by default —
//! any [`SystemKind`] per pair); the router partitions the arriving
//! trace across pairs online, each pair serves its share on the shared
//! simulated clock (all pairs start at the experiment's t = 0), and the
//! per-pair reports merge into exact cluster-wide TTFT/TBT percentiles
//! via [`Report::merge`].  Per-pair [`InstanceStat`]s are kept, prefixed
//! `p<i>:`, so utilization imbalance across a mixed-capability fleet
//! stays visible.

use crate::config::topology::ClusterConfig;
use crate::cronus::router::{RoutePolicy, Router};
use crate::metrics::Report;
use crate::systems::{build_system, InstanceStat, RunOutcome, ServingSystem};
use crate::workload::Request;

pub struct ClusterSystem {
    cfg: ClusterConfig,
    policy: RoutePolicy,
    label: String,
}

impl ClusterSystem {
    pub fn new(cfg: ClusterConfig, policy: RoutePolicy) -> ClusterSystem {
        let label = format!("{} {}", cfg.label(), policy.name());
        ClusterSystem { cfg, policy, label }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Partition `trace` across the pairs with this system's policy
    /// (exposed for tests; [`run`](ServingSystem::run) uses it).
    pub fn route(&self, trace: &[Request]) -> Vec<usize> {
        Router::new(self.policy, &self.cfg).route_trace(trace)
    }
}

impl ServingSystem for ClusterSystem {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn run(&mut self, trace: &[Request]) -> RunOutcome {
        let assignments = self.route(trace);
        let n_pairs = self.cfg.n_pairs();
        let mut sub_traces: Vec<Vec<Request>> = vec![Vec::new(); n_pairs];
        for (req, &pair) in trace.iter().zip(&assignments) {
            sub_traces[pair].push(*req);
        }

        let mut reports: Vec<Report> = Vec::with_capacity(n_pairs);
        let mut instances: Vec<InstanceStat> = Vec::new();
        for (i, (pair, sub)) in self.cfg.pairs.iter().zip(&sub_traces).enumerate() {
            if sub.is_empty() {
                // An idle pair still shows up in the utilization table.
                instances.push(InstanceStat {
                    name: format!("p{i}:{} (idle)", pair.name),
                    busy_time_s: 0.0,
                    n_iterations: 0,
                    n_preemptions: 0,
                    tokens_prefilled: 0,
                    tokens_decoded: 0,
                });
                continue;
            }
            let out = build_system(pair.system, &pair.deployment).run(sub);
            reports.push(out.report);
            for inst in out.instances {
                instances.push(InstanceStat {
                    name: format!("p{i}:{}", inst.name),
                    ..inst
                });
            }
        }

        RunOutcome {
            report: Report::merge(self.label.clone(), &reports),
            instances,
        }
    }
}

/// Instantiate an N-pair cluster behind `policy` (the cluster analogue
/// of [`build_system`]).
pub fn build_cluster_system(
    cfg: &ClusterConfig,
    policy: RoutePolicy,
) -> Box<dyn ServingSystem> {
    Box::new(ClusterSystem::new(cfg.clone(), policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::cronus::balancer::SplitPolicy;
    use crate::cronus::frontend::CronusSystem;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A10, A100};
    use crate::workload::arrival::{stamp, ArrivalProcess};
    use crate::workload::azure::{generate, AzureTraceConfig};

    fn all_at_once(n: usize, seed: u64) -> Vec<Request> {
        let t = generate(n, &AzureTraceConfig::default(), seed);
        stamp(&t, ArrivalProcess::AllAtOnce)
    }

    #[test]
    fn one_pair_cluster_matches_bare_cronus() {
        let trace = all_at_once(40, 1);
        let deployment = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
        let cfg = ClusterConfig::homogeneous(1, deployment.clone());
        let cluster = ClusterSystem::new(cfg, RoutePolicy::RoundRobin).run(&trace);
        let bare = CronusSystem::new(deployment, SplitPolicy::Balanced, false, "x").run(&trace);
        assert_eq!(cluster.report.n_finished, bare.report.n_finished);
        assert_eq!(cluster.report.makespan_s, bare.report.makespan_s);
        assert_eq!(cluster.report.ttft_p99_s, bare.report.ttft_p99_s);
    }

    #[test]
    fn mixed_cluster_serves_everything() {
        let trace = all_at_once(80, 2);
        for policy in RoutePolicy::ALL {
            let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
            let out = build_cluster_system(&cfg, policy).run(&trace);
            assert_eq!(out.report.n_finished, 80, "{}", policy.name());
            assert_eq!(out.report.n_requests, 80);
            // Two instances (PPI + CPI) per pair.
            assert_eq!(out.instances.len(), 8, "{}", policy.name());
            assert!(out.instances.iter().all(|i| i.name.starts_with('p')));
            assert!(out.report.ttft_p99_s > 0.0);
        }
    }

    #[test]
    fn scaling_out_multiplies_throughput() {
        let trace = all_at_once(160, 3);
        let run = |n_pairs| {
            let cfg = ClusterConfig::mixed(n_pairs, LLAMA3_8B);
            build_cluster_system(&cfg, RoutePolicy::LeastOutstandingTokens)
                .run(&trace)
                .report
                .throughput_rps
        };
        let one = run(1);
        let four = run(4);
        assert!(four > 2.5 * one, "scaling 1→4 pairs only {one:.2} → {four:.2} req/s");
    }

    #[test]
    fn empty_pair_reported_idle() {
        // Round-robin over 4 pairs with fewer requests than pairs leaves
        // tail pairs idle but visible.
        let trace = all_at_once(2, 4);
        let cfg = ClusterConfig::mixed(4, LLAMA3_8B);
        let out = build_cluster_system(&cfg, RoutePolicy::RoundRobin).run(&trace);
        assert_eq!(out.report.n_finished, 2);
        let idle = out
            .instances
            .iter()
            .filter(|i| i.name.contains("(idle)"))
            .count();
        assert_eq!(idle, 2);
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let trace = all_at_once(50, 5);
        let cfg = ClusterConfig::mixed(3, LLAMA3_8B);
        let a = build_cluster_system(&cfg, RoutePolicy::SloAware).run(&trace);
        let b = build_cluster_system(&cfg, RoutePolicy::SloAware).run(&trace);
        assert_eq!(a.report.makespan_s, b.report.makespan_s);
        assert_eq!(a.report.ttft_p99_s, b.report.ttft_p99_s);
        assert_eq!(a.report.tbt_p99_s, b.report.tbt_p99_s);
    }
}
