//! The `ServingSystem` abstraction: every approach the paper evaluates —
//! Cronus and the four baselines — implements this trait, so benches and
//! examples can sweep them uniformly.  [`cluster`] lifts any of them to
//! an N-pair deployment behind the cluster-level router.

pub mod cluster;

use crate::baselines::{dp::DpSystem, pp::PpSystem};
use crate::config::{DeploymentConfig, SystemKind};
use crate::cronus::frontend::CronusSystem;
use crate::cronus::balancer::SplitPolicy;
use crate::metrics::Report;
use crate::workload::Request;

pub use cluster::{build_cluster_system, ClusterSystem};

/// Per-instance accounting attached to a run (feeds Table 3).
#[derive(Clone, Debug)]
pub struct InstanceStat {
    pub name: String,
    pub busy_time_s: f64,
    pub n_iterations: u64,
    pub n_preemptions: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
}

/// Result of serving one trace.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub report: Report,
    pub instances: Vec<InstanceStat>,
}

/// A deployable serving system (one experiment subject).
pub trait ServingSystem {
    fn label(&self) -> String;

    /// Serve the trace to completion on the simulated cluster.
    fn run(&mut self, trace: &[Request]) -> RunOutcome;
}

/// Instantiate the system the paper calls `kind` on deployment `cfg`.
pub fn build_system(
    kind: SystemKind,
    cfg: &DeploymentConfig,
) -> Box<dyn ServingSystem> {
    match kind {
        SystemKind::Cronus => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Balanced,
            false,
            "Cronus",
        )),
        SystemKind::DisaggLowHigh => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Full,
            false,
            "Disagg. L-H",
        )),
        SystemKind::DisaggHighLow => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Full,
            true,
            "Disagg. H-L",
        )),
        SystemKind::DpChunked => Box::new(DpSystem::new(cfg.clone())),
        SystemKind::PpChunked => Box::new(PpSystem::new(cfg.clone())),
    }
}
