//! The `ServingSystem` abstraction: every approach the paper evaluates —
//! Cronus and the four baselines — implements this trait, so benches and
//! examples can sweep them uniformly.  [`cluster`] lifts any of them to
//! an N-pair deployment behind the cluster-level router.
//!
//! # Lifecycle: submit → advance → drain
//!
//! The API is *online and event-driven* (the shape Cronus's §4.3 dynamic
//! balancing — and everything on the roadmap: SLO admission control,
//! autoscaling, KV-aware routing — actually needs):
//!
//! 1. [`ServingSystem::submit`] hands the system one request at its
//!    arrival instant and returns an [`Admission`] decision immediately
//!    (systems may reject oversized prompts, or defer under an SLO
//!    admission policy);
//! 2. [`ServingSystem::advance`] steps the simulation up to a deadline
//!    and returns the timestamped [`SystemEvent`]s (first tokens, decode
//!    tokens, finishes, sheds) that became visible;
//! 3. [`ServingSystem::next_event_at`] peeks the next internal event so
//!    open-loop drivers can interleave arrivals with progress;
//! 4. [`ServingSystem::drain`] runs the system to completion and yields
//!    the final [`RunOutcome`] (report + per-instance accounting).
//!
//! The batch experiments of the paper are a special case:
//! [`driver::replay_trace`] replays a recorded trace through this
//! lifecycle and reproduces the old whole-trace semantics exactly.

pub mod autoscale;
pub mod cluster;
pub mod driver;

use crate::baselines::{dp::DpSystem, pp::PpSystem};
use crate::config::{DeploymentConfig, SystemKind};
use crate::cronus::balancer::SplitPolicy;
use crate::cronus::frontend::CronusSystem;
use crate::engine::EngineEvent;
use crate::metrics::{Collector, Report, ReqId};
use crate::simclock::SimTime;
use crate::workload::Request;

pub use autoscale::{AutoscaleConfig, FleetController, PairState, ScaleDecision};
pub use cluster::{build_cluster_system, ClusterSystem};
pub use driver::{
    closed_loop, closed_loop_collect, replay_trace, replay_trace_collect,
    replay_trace_observed, ClosedLoopStats, ReplayStats,
};

/// Per-instance accounting attached to a run (feeds Table 3).
#[derive(Clone, Debug)]
pub struct InstanceStat {
    pub name: String,
    pub busy_time_s: f64,
    pub n_iterations: u64,
    pub n_preemptions: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
    /// Of `tokens_prefilled`, context made present by KV transfers rather
    /// than local compute; `tokens_prefilled - tokens_kv_received` is the
    /// prefill this instance actually executed (what KV-affinity routing
    /// saves — see [`prefill_tokens_executed`]).
    pub tokens_kv_received: u64,
}

/// Prefill tokens a run actually *computed*, across all instances:
/// `tokens_prefilled` minus the context that arrived as KV transfers.
/// Session-prefix KV resident from a previous turn counts in neither, so
/// KV-affinity savings show up directly in this number.
pub fn prefill_tokens_executed(outcome: &RunOutcome) -> u64 {
    outcome
        .instances
        .iter()
        .map(|i| i.tokens_prefilled.saturating_sub(i.tokens_kv_received))
        .sum()
}

/// Result of serving a workload to completion.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub report: Report,
    pub instances: Vec<InstanceStat>,
}

/// Admission decision returned by [`ServingSystem::submit`].
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    /// The request entered the system and will end in a
    /// [`SystemEvent::Finished`] or [`SystemEvent::Shed`].
    Accepted,
    /// The request can never be served (e.g. the prompt exceeds every
    /// KV pool, or no pair can meet the SLO even when idle).  The system
    /// has recorded it as shed.
    Rejected { reason: String },
    /// The system is too loaded right now (SLO admission control); the
    /// caller may retry at `retry_at`.  Nothing was recorded.
    Deferred { retry_at: SimTime },
}

/// A timestamped, externally visible event returned by
/// [`ServingSystem::advance`].
#[derive(Clone, Debug, PartialEq)]
pub enum SystemEvent {
    /// Prefill finished; the request's first output token exists.
    FirstToken { id: ReqId, t: SimTime },
    /// One more decode token.
    Token { id: ReqId, t: SimTime },
    /// EOS reached; the request left the system.
    Finished { id: ReqId, t: SimTime },
    /// The request was dropped without being served.
    Shed { id: ReqId, t: SimTime, reason: String },
    /// Autoscaling activated standby pair `pair` (cluster systems only).
    ScaleUp { pair: usize, t: SimTime },
    /// Autoscaling finished draining pair `pair` and retired it to
    /// standby (cluster systems only).  Emitted at the instant the last
    /// in-flight request on the pair completed.
    ScaleDown { pair: usize, t: SimTime },
    /// A fault plan took pair `pair` down (cluster systems only): its
    /// in-flight work is aborted and re-submitted elsewhere, its KV
    /// residency is lost, and the router masks it out.
    PairFailed { pair: usize, t: SimTime },
    /// Pair `pair` was repaired and rejoined the fleet (cluster systems
    /// only): standby under a fleet controller, immediately active
    /// otherwise.  It rejoins cold — all KV state died with the fault.
    PairRecovered { pair: usize, t: SimTime },
}

impl SystemEvent {
    pub fn time(&self) -> SimTime {
        match self {
            SystemEvent::FirstToken { t, .. }
            | SystemEvent::Token { t, .. }
            | SystemEvent::Finished { t, .. }
            | SystemEvent::Shed { t, .. }
            | SystemEvent::ScaleUp { t, .. }
            | SystemEvent::ScaleDown { t, .. }
            | SystemEvent::PairFailed { t, .. }
            | SystemEvent::PairRecovered { t, .. } => *t,
        }
    }

    /// The request the event belongs to.  Scale and fault events carry
    /// no request; they report the affected pair index instead.
    pub fn id(&self) -> ReqId {
        match self {
            SystemEvent::FirstToken { id, .. }
            | SystemEvent::Token { id, .. }
            | SystemEvent::Finished { id, .. }
            | SystemEvent::Shed { id, .. } => *id,
            SystemEvent::ScaleUp { pair, .. }
            | SystemEvent::ScaleDown { pair, .. }
            | SystemEvent::PairFailed { pair, .. }
            | SystemEvent::PairRecovered { pair, .. } => *pair as ReqId,
        }
    }
}

/// A deployable serving system (one experiment subject), driven online.
///
/// Time never flows backwards: calls must use non-decreasing timestamps
/// (`submit(t, ..)` requires every event before `t` to have been
/// consumed, which `submit` enforces by draining them internally and
/// handing them to the next [`advance`](Self::advance) call).
pub trait ServingSystem {
    fn label(&self) -> String;

    /// Offer one request to the system at its arrival instant `t`.
    fn submit(&mut self, t: SimTime, req: Request) -> Admission;

    /// Time of the earliest event the system will produce, or `None`
    /// when it is fully idle (no queued work, no in-flight iteration).
    fn next_event_at(&self) -> Option<SimTime>;

    /// Step the simulation up to and including `until`; returns every
    /// uncollected [`SystemEvent`] with `time() <= until` (including
    /// events produced while `submit` advanced the clock internally).
    /// Later buffered events stay queued, so the stream a caller
    /// assembles from successive calls is monotone in time.
    fn advance(&mut self, until: SimTime) -> Vec<SystemEvent>;

    /// Zero-allocation form of [`advance`](Self::advance): append every
    /// uncollected event with `time() <= until` to `out` (which is *not*
    /// cleared first), so a driver can recycle one buffer across the
    /// whole run instead of receiving a fresh `Vec` per step.  The
    /// default implementation wraps `advance`; every system in this
    /// crate overrides it with an allocation-free drain of its internal
    /// pending buffer (see `drain_pending_into`).
    fn advance_into(&mut self, until: SimTime, out: &mut Vec<SystemEvent>) {
        out.append(&mut self.advance(until));
    }

    /// Run to completion and produce the final outcome.  Uncollected
    /// events are discarded (call `advance(SimTime(u64::MAX))` first to
    /// keep them).  The system resets and may serve a fresh run after.
    fn drain(&mut self) -> RunOutcome;

    /// Fault abort: drop every in-flight request — queued and running
    /// work vanishes, engine/KV state resets, and the aborted requests'
    /// metrics records are forgotten (they contribute to no count and no
    /// sample; the cluster re-submits them elsewhere).  Banked state —
    /// finished/shed records and utilization counters — survives.
    /// Returns the aborted request ids, ascending.  Default: nothing to
    /// abort (systems without online state).
    fn abort_inflight(&mut self) -> Vec<ReqId> {
        Vec::new()
    }
}

/// Shared deadline predicate for the systems' event loops: `inclusive`
/// pops events *at* the deadline (advance); exclusive leaves them
/// queued (submit's pre-drain, so same-instant arrivals keep the old
/// batch loop's arrival-first tie order).
pub(crate) fn past_deadline(t: SimTime, until: SimTime, inclusive: bool) -> bool {
    if inclusive {
        t > until
    } else {
        t >= until
    }
}

/// Record a token-bearing engine event into a collector + pending event
/// stream — the translation every system shares.  Returns `false` when
/// the event needs system-specific handling (KV transfers, preemptions).
pub(crate) fn record_engine_event(
    metrics: &mut Collector,
    pending: &mut Vec<SystemEvent>,
    now: SimTime,
    ev: EngineEvent,
) -> bool {
    match ev {
        EngineEvent::FirstToken(id) => {
            metrics.on_token(id, now);
            pending.push(SystemEvent::FirstToken { id, t: now });
            true
        }
        EngineEvent::Token(id) => {
            metrics.on_token(id, now);
            pending.push(SystemEvent::Token { id, t: now });
            true
        }
        EngineEvent::Finished(id) => {
            metrics.on_finish(id, now);
            pending.push(SystemEvent::Finished { id, t: now });
            true
        }
        EngineEvent::KvReceived(_) | EngineEvent::Preempted(_) => false,
    }
}

/// Earliest visible instant of a system: the first buffered (pending)
/// event or the next queued one — the shared `next_event_at` shape.
pub(crate) fn earliest_instant(
    pending: &[SystemEvent],
    queue_next: Option<SimTime>,
) -> Option<SimTime> {
    match (pending.first().map(|e| e.time()), queue_next) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    }
}

/// Drain the prefix of `pending` with events at or before `until` into
/// `out`, preserving order; later events (buffered by submit-time
/// processing) stay queued for a future `advance` call, keeping the
/// assembled stream monotone in time.  `pending` is always time-sorted:
/// pushes happen in event-pop order, and submit-time pushes are never
/// earlier than previously buffered events.  Both vectors keep their
/// capacity, so a steady-state advance loop allocates nothing — the
/// shared implementation behind every [`ServingSystem::advance_into`].
pub(crate) fn drain_pending_into(
    pending: &mut Vec<SystemEvent>,
    until: SimTime,
    out: &mut Vec<SystemEvent>,
) {
    // Common case: the whole buffer drains (open-loop replay advances to
    // the next event instant) — hand it over without the binary search.
    if pending.last().map_or(true, |e| e.time() <= until) {
        out.append(pending);
    } else {
        let idx = pending.partition_point(|e| e.time() <= until);
        out.extend(pending.drain(..idx));
    }
}

/// Instantiate the system the paper calls `kind` on deployment `cfg`.
pub fn build_system(
    kind: SystemKind,
    cfg: &DeploymentConfig,
) -> Box<dyn ServingSystem> {
    match kind {
        SystemKind::Cronus => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Balanced,
            false,
            "Cronus",
        )),
        SystemKind::DisaggLowHigh => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Full,
            false,
            "Disagg. L-H",
        )),
        SystemKind::DisaggHighLow => Box::new(CronusSystem::new(
            cfg.clone(),
            SplitPolicy::Full,
            true,
            "Disagg. H-L",
        )),
        SystemKind::DpChunked => Box::new(DpSystem::new(cfg.clone())),
        SystemKind::PpChunked => Box::new(PpSystem::new(cfg.clone())),
    }
}
