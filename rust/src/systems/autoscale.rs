//! Queue-driven elastic fleet control.
//!
//! A [`FleetController`] watches the cluster router's per-pair
//! outstanding-token backlog over a sliding time window and decides when
//! to *activate* a standby pair (scale up) or *drain* an active one
//! (scale down).  The controller only makes decisions — the cluster
//! executes them: activation re-registers the pair with the router's
//! load index, while a drained pair first stops receiving new work and
//! is retired only once its last in-flight request finishes, so no
//! request is ever lost or duplicated by a scaling action (see the
//! conservation test in `tests/autoscale.rs`).
//!
//! Thresholds are expressed in **backlog tokens per active pair**: the
//! mean over the window of `total outstanding tokens / active pairs`.
//! Normalizing by the active count makes one pair of thresholds work
//! across fleet sizes — a four-pair fleet at 4 × 6 k tokens is exactly
//! as loaded as a one-pair fleet at 6 k.
//!
//! # Example
//!
//! ```
//! use cronus::simclock::SimTime;
//! use cronus::systems::{AutoscaleConfig, FleetController, ScaleDecision};
//!
//! let cfg = AutoscaleConfig { window_s: 0.1, cooldown_s: 0.0, ..Default::default() };
//! let mut ctl = FleetController::new(3, cfg);
//! assert_eq!(ctl.n_active(), 1); // starts at `initial_pairs`
//!
//! // Sustained backlog above the scale-up threshold activates pair 1.
//! let mut t = SimTime::ZERO;
//! loop {
//!     t = t.after_secs(0.05);
//!     if let Some(d) = ctl.decide(t, &[10_000.0, 0.0, 0.0]) {
//!         assert_eq!(d, ScaleDecision::Activate(1));
//!         break;
//!     }
//! }
//! assert_eq!(ctl.n_active(), 2);
//! ```

use std::collections::VecDeque;

use crate::config::toml::TomlDoc;
use crate::simclock::SimTime;

/// Knobs for the [`FleetController`].  Loadable from an `[autoscale]`
/// TOML section via [`AutoscaleConfig::apply_toml`]; see `CONFIG.md` for
/// the key-by-key reference.
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Never drain below this many active pairs.
    pub min_pairs: usize,
    /// Pairs active at t=0 (clamped to `[min_pairs, n_pairs]`).
    pub initial_pairs: usize,
    /// Sliding window (seconds) over which backlog samples are averaged.
    pub window_s: f64,
    /// Mean backlog tokens *per active pair* above which a standby pair
    /// is activated.
    pub scale_up_backlog: f64,
    /// Mean backlog tokens *per active pair* below which an active pair
    /// is drained.
    pub scale_down_backlog: f64,
    /// Minimum time between scaling decisions, so one burst cannot
    /// thrash the fleet up and down.
    pub cooldown_s: f64,
    /// TTFT-SLO headroom (seconds) below which a standby pair is
    /// activated even when the backlog threshold is quiet — the
    /// beyond-backlog signal fed from the router's TTFT estimator
    /// ([`Router::best_ttft_headroom`](crate::cronus::router::Router::best_ttft_headroom)).
    /// `0.0` (the default) disables the signal, keeping decisions a
    /// pure function of the backlog alone.
    pub headroom: f64,
    /// Mean in-flight requests *per active pair* above which a standby
    /// pair is activated even when the token backlog is quiet — the
    /// per-pair utilization signal, catching batch-slot pressure from
    /// many small requests that token counts miss.  `0.0` (the default)
    /// disables the signal.
    pub util: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            min_pairs: 1,
            initial_pairs: 1,
            window_s: 2.0,
            scale_up_backlog: 6144.0,
            scale_down_backlog: 768.0,
            cooldown_s: 1.0,
            headroom: 0.0,
            util: 0.0,
        }
    }
}

impl AutoscaleConfig {
    /// Overlay `[autoscale]` keys from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &TomlDoc) {
        if let Some(x) = doc.get_i64("autoscale.min_pairs") {
            self.min_pairs = x as usize;
        }
        if let Some(x) = doc.get_i64("autoscale.initial_pairs") {
            self.initial_pairs = x as usize;
        }
        if let Some(x) = doc.get_f64("autoscale.window_s") {
            self.window_s = x;
        }
        if let Some(x) = doc.get_f64("autoscale.scale_up_backlog") {
            self.scale_up_backlog = x;
        }
        if let Some(x) = doc.get_f64("autoscale.scale_down_backlog") {
            self.scale_down_backlog = x;
        }
        if let Some(x) = doc.get_f64("autoscale.cooldown_s") {
            self.cooldown_s = x;
        }
        if let Some(x) = doc.get_f64("autoscale.headroom") {
            self.headroom = x;
        }
        if let Some(x) = doc.get_f64("autoscale.util") {
            self.util = x;
        }
    }

    /// Emit this config as a canonical `[autoscale]` section.  Inverse
    /// of [`AutoscaleConfig::apply_toml`]: the output parses back to an
    /// equal config and re-emits byte-identically, so planner output and
    /// scenario capsules carry the full scaling policy.
    pub fn to_toml(&self) -> String {
        format!(
            "[autoscale]\n\
             min_pairs = {}\n\
             initial_pairs = {}\n\
             window_s = {}\n\
             scale_up_backlog = {}\n\
             scale_down_backlog = {}\n\
             cooldown_s = {}\n\
             headroom = {}\n\
             util = {}\n",
            self.min_pairs,
            self.initial_pairs,
            self.window_s,
            self.scale_up_backlog,
            self.scale_down_backlog,
            self.cooldown_s,
            self.headroom,
            self.util,
        )
    }
}

/// Lifecycle state of one pair under fleet control.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairState {
    /// Receiving new work.
    Active,
    /// No new work routed to it; retires when its backlog empties.
    Draining,
    /// Retired (or never started) — eligible for the next scale-up.
    Standby,
    /// Down due to an injected fault; invisible to scaling decisions
    /// until repaired, then rejoins as standby.
    Failed,
}

/// A scaling action the cluster should execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Start routing to this standby pair.
    Activate(usize),
    /// Stop routing to this active pair and retire it once empty.
    Drain(usize),
}

/// The scaling policy: a windowed mean of per-active-pair backlog with
/// hysteresis (distinct up/down thresholds) and a decision cooldown.
/// Deterministic — decisions depend only on the observed `(time,
/// backlog)` sequence, never on wall-clock or randomness, so a run with
/// autoscaling is exactly as reproducible as one without.
pub struct FleetController {
    cfg: AutoscaleConfig,
    states: Vec<PairState>,
    /// `(sample time, backlog per active pair)`, oldest first.
    samples: VecDeque<(SimTime, f64)>,
    /// Running sum of the sample values (O(1) windowed mean).
    sum: f64,
    last_scale_at: Option<SimTime>,
}

impl FleetController {
    /// A controller for `n_pairs` pairs; the first
    /// `initial_pairs.clamp(min_pairs, n_pairs)` start active, the rest
    /// standby.
    pub fn new(n_pairs: usize, cfg: AutoscaleConfig) -> FleetController {
        assert!(n_pairs > 0, "fleet controller needs at least one pair");
        let initial = cfg.initial_pairs.clamp(cfg.min_pairs.max(1), n_pairs);
        let states = (0..n_pairs)
            .map(|i| if i < initial { PairState::Active } else { PairState::Standby })
            .collect();
        FleetController { cfg, states, samples: VecDeque::new(), sum: 0.0, last_scale_at: None }
    }

    /// Pair `i` currently receives new work.
    pub fn is_active(&self, i: usize) -> bool {
        self.states[i] == PairState::Active
    }

    /// Pair `i` is draining toward retirement.
    pub fn is_draining(&self, i: usize) -> bool {
        self.states[i] == PairState::Draining
    }

    /// Pairs currently receiving new work.
    pub fn n_active(&self) -> usize {
        self.states.iter().filter(|s| **s == PairState::Active).count()
    }

    /// The `headroom` signal is configured (`cfg.headroom > 0`), so the
    /// cluster should feed an observed TTFT-SLO headroom into
    /// [`FleetController::decide_with_headroom`].
    pub fn headroom_enabled(&self) -> bool {
        self.cfg.headroom > 0.0
    }

    /// The `util` signal is configured (`cfg.util > 0`), so the cluster
    /// should feed per-pair in-flight counts into
    /// [`FleetController::decide_full`].
    pub fn util_enabled(&self) -> bool {
        self.cfg.util > 0.0
    }

    /// Observe the router's per-pair outstanding-token backlog at `t`
    /// and return at most one scaling action.
    ///
    /// The cluster calls this once per arrival; between arrivals the
    /// fleet has no reason to grow (no queue pressure) and shrinking can
    /// wait for the next call, so no separate timer is needed.
    pub fn decide(&mut self, t: SimTime, outstanding: &[f64]) -> Option<ScaleDecision> {
        self.decide_with_headroom(t, outstanding, None)
    }

    /// [`FleetController::decide`] plus a beyond-backlog scale-up signal:
    /// `ttft_headroom_s` is the best (largest) `SLO − estimated TTFT`
    /// across active pairs, as reported by the router's estimator at `t`.
    /// When `cfg.headroom > 0` and the observed headroom has shrunk below
    /// it, a standby pair is activated even though the backlog mean is
    /// still under `scale_up_backlog` — catching SLO pressure from long
    /// contexts or slow pairs that plain token counts miss.  A low
    /// headroom also vetoes draining (shrinking while TTFT is already
    /// near the SLO would be self-defeating).  Deterministic: decisions
    /// remain a pure function of the observed `(time, backlog, headroom)`
    /// sequence.
    pub fn decide_with_headroom(
        &mut self,
        t: SimTime,
        outstanding: &[f64],
        ttft_headroom_s: Option<f64>,
    ) -> Option<ScaleDecision> {
        self.decide_full(t, outstanding, ttft_headroom_s, None)
    }

    /// [`FleetController::decide_with_headroom`] plus the per-pair
    /// utilization signal: `utilization[i]` is pair `i`'s in-flight
    /// request count as observed by the cluster at `t`.  When
    /// `cfg.util > 0` and the mean over active pairs exceeds it, a
    /// standby pair is activated even though the token backlog is quiet
    /// — and, like a low TTFT headroom, high utilization vetoes
    /// draining.  `None` (or `cfg.util = 0`) keeps decisions identical
    /// to [`FleetController::decide_with_headroom`].
    pub fn decide_full(
        &mut self,
        t: SimTime,
        outstanding: &[f64],
        ttft_headroom_s: Option<f64>,
        utilization: Option<&[f64]>,
    ) -> Option<ScaleDecision> {
        let n_active = self.n_active().max(1);
        let total: f64 = self
            .states
            .iter()
            .zip(outstanding)
            .filter(|(s, _)| **s == PairState::Active)
            .map(|(_, o)| *o)
            .sum();
        let horizon = SimTime::from_secs_f64(self.cfg.window_s);
        while let Some(&(ts, v)) = self.samples.front() {
            if ts.0 + horizon.0 < t.0 {
                self.sum -= v;
                self.samples.pop_front();
            } else {
                break;
            }
        }
        let per_active = total / n_active as f64;
        self.samples.push_back((t, per_active));
        self.sum += per_active;
        let mean = self.sum / self.samples.len() as f64;

        if let Some(last) = self.last_scale_at {
            if t.0 < last.after_secs(self.cfg.cooldown_s).0 {
                return None;
            }
        }
        let headroom_low = self.cfg.headroom > 0.0
            && ttft_headroom_s.is_some_and(|h| h < self.cfg.headroom);
        let util_high = self.cfg.util > 0.0
            && utilization.is_some_and(|u| {
                let total: f64 = self
                    .states
                    .iter()
                    .zip(u)
                    .filter(|(s, _)| **s == PairState::Active)
                    .map(|(_, v)| *v)
                    .sum();
                total / n_active as f64 > self.cfg.util
            });
        if mean > self.cfg.scale_up_backlog || headroom_low || util_high {
            // Lowest-index standby first: retired pairs are reused in a
            // fixed order, keeping runs deterministic.
            let target = self.states.iter().position(|s| *s == PairState::Standby)?;
            self.states[target] = PairState::Active;
            self.last_scale_at = Some(t);
            return Some(ScaleDecision::Activate(target));
        }
        if mean < self.cfg.scale_down_backlog
            && self.n_active() > self.cfg.min_pairs.max(1)
            && !self.states.contains(&PairState::Draining)
        {
            // Drain the emptiest active pair (ties to the highest index,
            // so pair 0 stays the fleet's stable core).
            let mut victim: Option<(usize, f64)> = None;
            for (i, s) in self.states.iter().enumerate() {
                if *s == PairState::Active
                    && victim.map_or(true, |(_, b)| outstanding[i] <= b)
                {
                    victim = Some((i, outstanding[i]));
                }
            }
            let (target, _) = victim?;
            self.states[target] = PairState::Draining;
            self.last_scale_at = Some(t);
            return Some(ScaleDecision::Drain(target));
        }
        None
    }

    /// A draining pair's last in-flight request finished: it is now
    /// standby and may be re-activated by a later scale-up.
    ///
    /// The pair's resident KV is *alive* at this point — the cluster
    /// hands its sessions to surviving pairs over the inter-pair link
    /// ([`Router::handoff_pair_residency`]) instead of evicting them
    /// blindly; only when no link is configured (or no destination
    /// qualifies) does retirement fall back to eviction.
    ///
    /// [`Router::handoff_pair_residency`]: crate::cronus::router::Router::handoff_pair_residency
    pub fn on_pair_drained(&mut self, i: usize) {
        debug_assert_eq!(self.states[i], PairState::Draining);
        self.states[i] = PairState::Standby;
    }

    /// Pair `i` is down due to an injected fault.
    pub fn is_failed(&self, i: usize) -> bool {
        self.states[i] == PairState::Failed
    }

    /// The cluster injected a failure on pair `i`: it leaves the
    /// routable set immediately, whatever its lifecycle state was, and
    /// stays invisible to scaling decisions until repaired.
    pub fn on_pair_failed(&mut self, i: usize) {
        self.states[i] = PairState::Failed;
    }

    /// Pair `i` was repaired: it rejoins as *standby* — the failure
    /// already flipped a standby active in its place
    /// ([`FleetController::force_activate`]), so re-activation waits for
    /// real backlog pressure.
    pub fn on_pair_recovered(&mut self, i: usize) {
        debug_assert_eq!(self.states[i], PairState::Failed);
        self.states[i] = PairState::Standby;
    }

    /// Immediately activate the lowest-index standby pair, bypassing the
    /// windowed thresholds and the cooldown — the implicit scale-up the
    /// cluster executes when a pair fails.  Leaves the decision clock
    /// untouched so ordinary scaling is not delayed by the emergency
    /// action.  `None` when no standby is left.
    pub fn force_activate(&mut self) -> Option<usize> {
        let target = self.states.iter().position(|s| *s == PairState::Standby)?;
        self.states[target] = PairState::Active;
        Some(target)
    }

    /// Restore the t=0 state (initial actives, empty window).
    pub fn reset(&mut self) {
        let initial = self.cfg.initial_pairs.clamp(self.cfg.min_pairs.max(1), self.states.len());
        for (i, s) in self.states.iter_mut().enumerate() {
            *s = if i < initial { PairState::Active } else { PairState::Standby };
        }
        self.samples.clear();
        self.sum = 0.0;
        self.last_scale_at = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_pairs: 1,
            initial_pairs: 1,
            window_s: 1.0,
            scale_up_backlog: 1000.0,
            scale_down_backlog: 100.0,
            cooldown_s: 0.5,
            headroom: 0.0,
            util: 0.0,
        }
    }

    fn at(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn autoscale_toml_round_trips_byte_for_byte() {
        let c = AutoscaleConfig {
            min_pairs: 2,
            initial_pairs: 3,
            window_s: 1.25,
            scale_up_backlog: 4096.0,
            scale_down_backlog: 512.0,
            cooldown_s: 0.75,
            headroom: 0.2,
            util: 48.0,
        };
        let text = c.to_toml();
        let doc = toml::parse(&text).expect("emitted TOML parses");
        let mut back = AutoscaleConfig::default();
        back.apply_toml(&doc);
        assert_eq!(back.to_toml(), text, "re-emission is byte-identical");
        assert_eq!(back.min_pairs, 2);
        assert_eq!(back.window_s, 1.25);
        assert_eq!(back.util, 48.0);
    }

    #[test]
    fn scales_up_on_sustained_backlog_and_respects_cooldown() {
        let mut ctl = FleetController::new(3, cfg());
        assert_eq!(ctl.n_active(), 1);
        // One hot sample pushes the windowed mean over the threshold.
        let d = ctl.decide(at(0.1), &[5000.0, 0.0, 0.0]);
        assert_eq!(d, Some(ScaleDecision::Activate(1)));
        assert!(ctl.is_active(1));
        // Still hot, but inside the cooldown: no second action.
        assert_eq!(ctl.decide(at(0.2), &[5000.0, 5000.0, 0.0]), None);
        // Past the cooldown the next standby pair activates.
        let d = ctl.decide(at(0.7), &[5000.0, 5000.0, 0.0]);
        assert_eq!(d, Some(ScaleDecision::Activate(2)));
        assert_eq!(ctl.n_active(), 3);
    }

    #[test]
    fn drains_emptiest_pair_and_reuses_it_after_retirement() {
        let mut c = cfg();
        c.initial_pairs = 3;
        let mut ctl = FleetController::new(3, c);
        assert_eq!(ctl.n_active(), 3);
        // Idle fleet: drain the emptiest (ties → highest index).
        let d = ctl.decide(at(0.1), &[50.0, 10.0, 10.0]);
        assert_eq!(d, Some(ScaleDecision::Drain(2)));
        assert!(ctl.is_draining(2));
        // Only one pair drains at a time, even past the cooldown.
        assert_eq!(ctl.decide(at(1.0), &[10.0, 10.0, 5.0]), None);
        ctl.on_pair_drained(2);
        assert_eq!(ctl.n_active(), 2);
        // The retired pair is the next scale-up target.
        let d = ctl.decide(at(2.0), &[9000.0, 9000.0, 0.0]);
        assert_eq!(d, Some(ScaleDecision::Activate(2)));
    }

    #[test]
    fn never_drains_below_min_pairs() {
        let mut c = cfg();
        c.min_pairs = 2;
        c.initial_pairs = 2;
        c.cooldown_s = 0.0;
        let mut ctl = FleetController::new(3, c);
        for k in 1..20 {
            assert_eq!(ctl.decide(at(k as f64), &[0.0, 0.0, 0.0]), None);
        }
        assert_eq!(ctl.n_active(), 2);
    }

    #[test]
    fn window_forgets_old_samples() {
        let mut c = cfg();
        c.cooldown_s = 0.0;
        let mut ctl = FleetController::new(2, c);
        // A burst inflates the mean and activates pair 1...
        let d = ctl.decide(at(0.1), &[8000.0, 0.0]);
        assert_eq!(d, Some(ScaleDecision::Activate(1)));
        // ...but once the window slides past the burst sample, only the
        // idle observation remains and the emptier pair drains.
        let d = ctl.decide(at(3.0), &[10.0, 0.0]);
        assert_eq!(d, Some(ScaleDecision::Drain(1)));
        ctl.on_pair_drained(1);
        // At the fleet minimum nothing further happens.
        assert_eq!(ctl.decide(at(4.0), &[10.0, 0.0]), None);
        assert_eq!(ctl.n_active(), 1);
    }

    #[test]
    fn reset_restores_initial_states() {
        let mut ctl = FleetController::new(3, cfg());
        ctl.decide(at(0.1), &[5000.0, 0.0, 0.0]);
        assert_eq!(ctl.n_active(), 2);
        ctl.reset();
        assert_eq!(ctl.n_active(), 1);
        assert!(ctl.is_active(0));
        assert!(!ctl.is_active(1));
    }

    #[test]
    fn apply_toml_overlays_every_key() {
        let doc = toml::parse(
            "[autoscale]\nmin_pairs = 2\ninitial_pairs = 3\nwindow_s = 4.0\n\
             scale_up_backlog = 5000\nscale_down_backlog = 500\ncooldown_s = 2.5\n\
             headroom = 0.4\nutil = 0.9\n",
        )
        .expect("parse");
        let mut c = AutoscaleConfig::default();
        assert!(!FleetController::new(1, c.clone()).headroom_enabled());
        c.apply_toml(&doc);
        assert_eq!(c.min_pairs, 2);
        assert_eq!(c.initial_pairs, 3);
        assert_eq!(c.window_s, 4.0);
        assert_eq!(c.scale_up_backlog, 5000.0);
        assert_eq!(c.scale_down_backlog, 500.0);
        assert_eq!(c.cooldown_s, 2.5);
        assert_eq!(c.headroom, 0.4);
        assert_eq!(c.util, 0.9);
        assert!(FleetController::new(1, c).headroom_enabled());
    }

    #[test]
    fn low_ttft_headroom_scales_up_below_backlog_threshold() {
        let mut c = cfg();
        c.headroom = 0.5;
        c.cooldown_s = 0.0;
        let mut ctl = FleetController::new(3, c);
        // Backlog far under scale_up_backlog (1000), but the router says
        // the best pair's TTFT is within 0.2 s of the SLO: activate.
        let d = ctl.decide_with_headroom(at(0.1), &[50.0, 0.0, 0.0], Some(0.2));
        assert_eq!(d, Some(ScaleDecision::Activate(1)));
        // Comfortable headroom: the same quiet backlog drains instead.
        let d = ctl.decide_with_headroom(at(5.0), &[10.0, 0.0, 0.0], Some(3.0));
        assert_eq!(d, Some(ScaleDecision::Drain(1)));
        ctl.on_pair_drained(1);
        // Low headroom with no signal wired (None) never fires, and a
        // disabled knob (headroom = 0) ignores the signal entirely.
        assert_eq!(ctl.decide_with_headroom(at(9.0), &[0.0, 0.0, 0.0], None), None);
        let mut off = FleetController::new(2, cfg());
        assert_eq!(off.decide_with_headroom(at(0.1), &[0.0, 0.0], Some(0.001)), None);
    }

    #[test]
    fn low_headroom_vetoes_draining_an_idle_fleet() {
        let mut c = cfg();
        c.headroom = 0.5;
        c.cooldown_s = 0.0;
        c.initial_pairs = 3;
        let mut ctl = FleetController::new(3, c);
        // Quiet backlog would normally drain, but every pair is out of
        // standby and TTFT is already near the SLO: hold steady.
        assert_eq!(ctl.decide_with_headroom(at(0.1), &[10.0, 10.0, 10.0], Some(0.1)), None);
        assert_eq!(ctl.n_active(), 3);
        // With headroom restored the drain proceeds as usual.
        let d = ctl.decide_with_headroom(at(0.2), &[10.0, 10.0, 10.0], Some(4.0));
        assert_eq!(d, Some(ScaleDecision::Drain(2)));
    }

    #[test]
    fn high_utilization_scales_up_below_backlog_threshold() {
        let mut c = cfg();
        c.util = 4.0;
        c.cooldown_s = 0.0;
        let mut ctl = FleetController::new(2, c);
        // Token backlog far under scale_up_backlog (1000), but six
        // in-flight requests on the one active pair exceed the util
        // threshold: activate the standby.
        let d = ctl.decide_full(at(0.1), &[50.0, 0.0], None, Some(&[6.0, 0.0]));
        assert_eq!(d, Some(ScaleDecision::Activate(1)));
        // The same signal with the knob off (util = 0) is ignored...
        let mut off = FleetController::new(2, cfg());
        assert_eq!(
            off.decide_full(at(0.1), &[50.0, 0.0], None, Some(&[6.0, 0.0])),
            None
        );
        // ...and the knob without a wired signal (None) never fires.
        let mut c2 = cfg();
        c2.util = 4.0;
        let mut unwired = FleetController::new(2, c2);
        assert_eq!(unwired.decide_full(at(0.1), &[50.0, 0.0], None, None), None);
    }

    #[test]
    fn failure_hooks_flip_standby_and_repair_to_standby() {
        let mut ctl = FleetController::new(3, cfg());
        assert_eq!(ctl.n_active(), 1);
        ctl.on_pair_failed(0);
        assert!(ctl.is_failed(0));
        assert_eq!(ctl.n_active(), 0);
        // The implicit scale-up bypasses the window and the cooldown.
        assert_eq!(ctl.force_activate(), Some(1));
        assert_eq!(ctl.n_active(), 1);
        // Repair returns the pair as standby, not active.
        ctl.on_pair_recovered(0);
        assert!(!ctl.is_active(0) && !ctl.is_failed(0));
        // A fully failed fleet has nothing left to force-activate.
        ctl.on_pair_failed(0);
        ctl.on_pair_failed(1);
        ctl.on_pair_failed(2);
        assert_eq!(ctl.force_activate(), None);
        assert_eq!(ctl.n_active(), 0);
        // Reset clears failures with everything else.
        ctl.reset();
        assert_eq!(ctl.n_active(), 1);
        assert!(!ctl.is_failed(1) && !ctl.is_failed(2));
    }
}
