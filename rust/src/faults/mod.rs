//! Deterministic fault injection: pair failures, repairs, and retry
//! backoff.
//!
//! A [`FaultPlan`] is a time-ordered list of pair outages fixed *before*
//! the run — either spelled out in a TOML `[faults]` section
//! ([`FaultConfig`]) or drawn from a seeded generator — so every chaos
//! run is exactly reproducible: same plan + same trace + same seed ⇒
//! byte-identical event streams, failures included.  The cluster splices
//! the plan into its merged event stream as
//! [`PairFailed`](crate::systems::SystemEvent::PairFailed) /
//! [`PairRecovered`](crate::systems::SystemEvent::PairRecovered) events
//! and recovers by masking the pair, evicting its KV residency, and
//! re-submitting aborted in-flight work through admission under a
//! [`RetryBackoff`] schedule.
//!
//! An empty plan is inert by construction: the cluster's fault hooks sit
//! behind a single `is_some()` branch and an empty plan never reaches
//! them, so every non-fault run stays byte-identical (pinned by the
//! chaos suite).

use crate::config::toml::{TomlDoc, TomlValue};
use crate::simclock::SimTime;
use crate::util::rng::Rng;

/// Retry attempts allowed by default — the drivers' historical
/// `MAX_DEFERRALS` cap, preserved so [`RetryBackoff::default`] replays
/// old deferral behaviour byte-for-byte.
pub const DEFAULT_MAX_ATTEMPTS: usize = 32;

/// One scheduled pair outage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Index of the pair that fails.
    pub pair: usize,
    /// Instant the pair goes down.
    pub fail_at: SimTime,
    /// `None` = fail-stop (the pair never rejoins this run); `Some(t)` =
    /// transient stall repaired at `t` (strictly after `fail_at`).
    pub recover_at: Option<SimTime>,
}

/// A deterministic, time-ordered fault schedule for one run.
///
/// Build one from explicit events ([`FaultPlan::new`]) or from a
/// `[faults]` TOML section ([`FaultConfig::build_plan`]).  The plan is
/// immutable once built; the cluster walks it with a cursor.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Validate and time-sort a set of fault events into a plan.
    /// Rejects outages whose repair does not come strictly after the
    /// failure.
    pub fn new(mut events: Vec<FaultEvent>) -> Result<FaultPlan, String> {
        for e in &events {
            if let Some(r) = e.recover_at {
                if r <= e.fail_at {
                    return Err(format!(
                        "fault on pair {}: recover_at {:.3}s must come after \
                         fail_at {:.3}s",
                        e.pair,
                        r.as_secs_f64(),
                        e.fail_at.as_secs_f64()
                    ));
                }
            }
        }
        events.sort_by_key(|e| (e.fail_at, e.pair));
        Ok(FaultPlan { events })
    }

    /// The inert plan: injects nothing, leaves every run byte-identical.
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The scheduled outages, sorted by `(fail_at, pair)`.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }
}

impl FaultEvent {
    /// Render this outage in the `faults.schedule` / `--fail` grammar,
    /// `"<pair>@<fail_s>[+<down_s>]"` — the exact inverse of
    /// [`parse_schedule_entry`], so an emitted entry parses back to an
    /// equal `FaultEvent`.
    pub fn spec(&self) -> String {
        let fail = self.fail_at.as_secs_f64();
        match self.recover_at {
            Some(r) => format!("{}@{}+{}", self.pair, fail, r.as_secs_f64() - fail),
            None => format!("{}@{}", self.pair, fail),
        }
    }
}

/// Deterministic capped exponential backoff for re-submitting deferred
/// or failure-aborted requests.
///
/// Attempt `k` (0-based) retries after `min(base_s · multiplier^k,
/// cap_s)` seconds, never earlier than the admission layer's own
/// `retry_at` hint and never at the same nanosecond it was deferred.
/// The default (`base_s = 0`) degenerates to "retry at the hint, at
/// least 1 ns later, give up after [`DEFAULT_MAX_ATTEMPTS`]" — exactly
/// the drivers' historical `MAX_DEFERRALS` behaviour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryBackoff {
    /// Give up (shed) once this many attempts have been made.
    pub max_attempts: usize,
    /// Delay before the first retry, seconds; `0` disables the delay.
    pub base_s: f64,
    /// Geometric growth factor per attempt.
    pub multiplier: f64,
    /// Ceiling on the delay, seconds.
    pub cap_s: f64,
}

impl Default for RetryBackoff {
    fn default() -> RetryBackoff {
        RetryBackoff {
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            base_s: 0.0,
            multiplier: 2.0,
            cap_s: 1.0,
        }
    }
}

impl RetryBackoff {
    /// Whether an attempt numbered `attempts` (0-based count of attempts
    /// already made) would exceed the cap — shed instead of retrying.
    pub fn gives_up(&self, attempts: usize) -> bool {
        attempts + 1 >= self.max_attempts
    }

    /// Next submission instant for a request deferred (or aborted) at
    /// `now` after `attempts` prior attempts.  `hint` is the admission
    /// layer's own earliest-retry estimate; the result honours whichever
    /// of hint / backoff delay is later, and always lands strictly after
    /// `now`.
    pub fn retry_at(&self, now: SimTime, hint: SimTime, attempts: usize) -> SimTime {
        let backed_off = if self.base_s > 0.0 {
            let growth = self.multiplier.powi(attempts.min(63) as i32);
            now.after_secs((self.base_s * growth).min(self.cap_s))
        } else {
            now
        };
        hint.max(backed_off).max(SimTime(now.0.saturating_add(1)))
    }
}

/// The TOML `[faults]` section: an explicit schedule, a seeded outage
/// generator, and the failure-retry backoff knobs.  See CONFIG.md
/// §`[faults]`.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Seed of the outage generator (`faults.seed`).
    pub seed: u64,
    /// Outages to draw from the generator (`faults.n_failures`); `0`
    /// means only the explicit schedule runs.
    pub n_failures: usize,
    /// Mean time between generated failures, fleet-wide, seconds
    /// (`faults.mtbf_s`).
    pub mtbf_s: f64,
    /// Mean time to repair a generated transient failure, seconds
    /// (`faults.mttr_s`).
    pub mttr_s: f64,
    /// Fraction of generated failures that are fail-stop — never
    /// repaired (`faults.fail_stop_frac`).
    pub fail_stop_frac: f64,
    /// Explicit outages (`faults.schedule`), grammar
    /// `"<pair>@<fail_s>[+<down_s>]"`; composed with the generated ones.
    pub schedule: Vec<FaultEvent>,
    /// Failure-retry attempt cap (`faults.max_retries`).
    pub max_retries: usize,
    /// First failure-retry delay, seconds (`faults.retry_base_s`).
    pub retry_base_s: f64,
    /// Geometric backoff growth (`faults.retry_multiplier`).
    pub retry_multiplier: f64,
    /// Backoff delay ceiling, seconds (`faults.retry_cap_s`).
    pub retry_cap_s: f64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            seed: 7,
            n_failures: 0,
            mtbf_s: 5.0,
            mttr_s: 2.0,
            fail_stop_frac: 0.0,
            schedule: Vec::new(),
            max_retries: 8,
            retry_base_s: 0.05,
            retry_multiplier: 2.0,
            retry_cap_s: 1.0,
        }
    }
}

/// Parse one `faults.schedule` entry: `"<pair>@<fail_s>[+<down_s>]"`
/// (e.g. `"1@2.5+3"` = pair 1 down at 2.5 s, repaired 3 s later;
/// `"0@10"` = pair 0 fail-stop at 10 s).  Also the grammar of the CLI's
/// repeatable `--fail` flag.
pub fn parse_schedule_entry(spec: &str) -> Result<FaultEvent, String> {
    let bad = |what: &str| format!("fault spec '{spec}': {what} (grammar: <pair>@<fail_s>[+<down_s>])");
    let (pair_s, rest) = spec.split_once('@').ok_or_else(|| bad("missing '@'"))?;
    let pair: usize = pair_s
        .trim()
        .parse()
        .map_err(|_| bad("pair index must be a non-negative integer"))?;
    let (fail_s, down_s) = match rest.split_once('+') {
        Some((f, d)) => (f, Some(d)),
        None => (rest, None),
    };
    let fail: f64 = fail_s
        .trim()
        .parse()
        .map_err(|_| bad("failure time must be a number of seconds"))?;
    if !fail.is_finite() || fail < 0.0 {
        return Err(bad("failure time must be finite and non-negative"));
    }
    let recover_at = match down_s {
        Some(d) => {
            let down: f64 = d
                .trim()
                .parse()
                .map_err(|_| bad("downtime must be a number of seconds"))?;
            if !down.is_finite() || down <= 0.0 {
                return Err(bad("downtime must be finite and positive"));
            }
            Some(SimTime::from_secs_f64(fail + down))
        }
        None => None,
    };
    Ok(FaultEvent {
        pair,
        fail_at: SimTime::from_secs_f64(fail),
        recover_at,
    })
}

impl FaultConfig {
    /// Overlay `faults.*` keys from a parsed TOML document.  Absent keys
    /// keep their current value; a malformed `schedule` entry is an
    /// error.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        if let Some(x) = doc.get_i64("faults.seed") {
            self.seed = x as u64;
        }
        if let Some(x) = doc.get_i64("faults.n_failures") {
            self.n_failures = x.max(0) as usize;
        }
        if let Some(x) = doc.get_f64("faults.mtbf_s") {
            self.mtbf_s = x;
        }
        if let Some(x) = doc.get_f64("faults.mttr_s") {
            self.mttr_s = x;
        }
        if let Some(x) = doc.get_f64("faults.fail_stop_frac") {
            self.fail_stop_frac = x.clamp(0.0, 1.0);
        }
        if let Some(TomlValue::Array(items)) = doc.get("faults.schedule") {
            let mut schedule = Vec::with_capacity(items.len());
            for item in items {
                let text = item
                    .as_str()
                    .ok_or("faults.schedule entries must be strings")?;
                schedule.push(parse_schedule_entry(text)?);
            }
            self.schedule = schedule;
        }
        if let Some(x) = doc.get_i64("faults.max_retries") {
            self.max_retries = x.max(1) as usize;
        }
        if let Some(x) = doc.get_f64("faults.retry_base_s") {
            self.retry_base_s = x.max(0.0);
        }
        if let Some(x) = doc.get_f64("faults.retry_multiplier") {
            self.retry_multiplier = x.max(1.0);
        }
        if let Some(x) = doc.get_f64("faults.retry_cap_s") {
            self.retry_cap_s = x.max(0.0);
        }
        Ok(())
    }

    /// Emit this config as a canonical `[faults]` section.  The output
    /// parses back ([`FaultConfig::apply_toml`]) to an equal config, and
    /// re-emission is byte-identical — the `[topology]` round-trip
    /// contract, extended to faults so a captured scenario capsule is a
    /// complete run description.
    pub fn to_toml(&self) -> String {
        let mut out = String::from("[faults]\n");
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str(&format!("n_failures = {}\n", self.n_failures));
        out.push_str(&format!("mtbf_s = {}\n", self.mtbf_s));
        out.push_str(&format!("mttr_s = {}\n", self.mttr_s));
        out.push_str(&format!("fail_stop_frac = {}\n", self.fail_stop_frac));
        let entries: Vec<String> = self
            .schedule
            .iter()
            .map(|e| format!("\"{}\"", e.spec()))
            .collect();
        out.push_str(&format!("schedule = [{}]\n", entries.join(", ")));
        out.push_str(&format!("max_retries = {}\n", self.max_retries));
        out.push_str(&format!("retry_base_s = {}\n", self.retry_base_s));
        out.push_str(&format!("retry_multiplier = {}\n", self.retry_multiplier));
        out.push_str(&format!("retry_cap_s = {}\n", self.retry_cap_s));
        out
    }

    /// The failure-retry backoff these knobs describe.
    pub fn backoff(&self) -> RetryBackoff {
        RetryBackoff {
            max_attempts: self.max_retries,
            base_s: self.retry_base_s,
            multiplier: self.retry_multiplier,
            cap_s: self.retry_cap_s,
        }
    }

    /// Materialize the plan for an `n_pairs` fleet: the explicit
    /// schedule plus `n_failures` outages drawn from the seeded
    /// generator (exponential inter-failure gaps at rate `1/mtbf_s`,
    /// uniform victim pair, exponential repair at rate `1/mttr_s`, and a
    /// `fail_stop_frac` chance of never repairing).  Same seed ⇒ same
    /// plan.
    pub fn build_plan(&self, n_pairs: usize) -> Result<FaultPlan, String> {
        if n_pairs == 0 {
            return Err("fault plan needs at least one pair".to_string());
        }
        let mut events = self.schedule.clone();
        if self.n_failures > 0 {
            let mut rng = Rng::new(self.seed);
            let mut t = 0.0;
            for _ in 0..self.n_failures {
                t += rng.exponential(1.0 / self.mtbf_s.max(1e-9));
                let pair = rng.range_usize(0, n_pairs);
                let fail_stop = rng.f64() < self.fail_stop_frac;
                let down = rng.exponential(1.0 / self.mttr_s.max(1e-9)).max(1e-3);
                events.push(FaultEvent {
                    pair,
                    fail_at: SimTime::from_secs_f64(t),
                    recover_at: if fail_stop {
                        None
                    } else {
                        Some(SimTime::from_secs_f64(t + down))
                    },
                });
            }
        }
        for e in &events {
            if e.pair >= n_pairs {
                return Err(format!(
                    "fault on pair {} but the fleet has only {n_pairs} pairs",
                    e.pair
                ));
            }
        }
        FaultPlan::new(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backoff_replays_flat_deferral_semantics() {
        let b = RetryBackoff::default();
        assert_eq!(b.max_attempts, DEFAULT_MAX_ATTEMPTS);
        // Old driver rule: retry = hint.max(t + 1ns), give up at 32.
        let now = SimTime(1_000);
        assert_eq!(b.retry_at(now, SimTime(5_000), 0), SimTime(5_000));
        assert_eq!(b.retry_at(now, SimTime::ZERO, 7), SimTime(1_001));
        assert!(!b.gives_up(30));
        assert!(b.gives_up(31));
    }

    #[test]
    fn backoff_grows_geometrically_and_caps() {
        let b = RetryBackoff {
            max_attempts: 4,
            base_s: 0.1,
            multiplier: 2.0,
            cap_s: 0.3,
        };
        let now = SimTime::ZERO;
        let hint = SimTime::ZERO;
        assert_eq!(b.retry_at(now, hint, 0), SimTime::from_secs_f64(0.1));
        assert_eq!(b.retry_at(now, hint, 1), SimTime::from_secs_f64(0.2));
        // 0.4 would exceed the cap.
        assert_eq!(b.retry_at(now, hint, 2), SimTime::from_secs_f64(0.3));
        assert_eq!(b.retry_at(now, hint, 60), SimTime::from_secs_f64(0.3));
        // A later hint wins over the backoff delay.
        let late = SimTime::from_secs_f64(9.0);
        assert_eq!(b.retry_at(now, late, 0), late);
        assert!(b.gives_up(3));
        assert!(!b.gives_up(2));
    }

    #[test]
    fn plan_sorts_and_validates() {
        let plan = FaultPlan::new(vec![
            FaultEvent {
                pair: 1,
                fail_at: SimTime::from_secs_f64(5.0),
                recover_at: None,
            },
            FaultEvent {
                pair: 0,
                fail_at: SimTime::from_secs_f64(2.0),
                recover_at: Some(SimTime::from_secs_f64(3.0)),
            },
        ])
        .expect("valid plan");
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.events()[0].pair, 0);
        assert_eq!(plan.events()[1].pair, 1);
        assert!(FaultPlan::empty().is_empty());

        let bad = FaultPlan::new(vec![FaultEvent {
            pair: 0,
            fail_at: SimTime::from_secs_f64(2.0),
            recover_at: Some(SimTime::from_secs_f64(2.0)),
        }]);
        assert!(bad.is_err());
    }

    #[test]
    fn schedule_grammar_round_trips() {
        let e = parse_schedule_entry("1@2.5+3").expect("transient spec");
        assert_eq!(e.pair, 1);
        assert_eq!(e.fail_at, SimTime::from_secs_f64(2.5));
        assert_eq!(e.recover_at, Some(SimTime::from_secs_f64(5.5)));

        let e = parse_schedule_entry("0@10").expect("fail-stop spec");
        assert_eq!(e.pair, 0);
        assert_eq!(e.recover_at, None);

        assert!(parse_schedule_entry("nope").is_err());
        assert!(parse_schedule_entry("x@1").is_err());
        assert!(parse_schedule_entry("0@-1").is_err());
        assert!(parse_schedule_entry("0@1+0").is_err());
    }

    #[test]
    fn toml_section_overlays_every_key() {
        let doc = crate::config::toml::parse(
            "[faults]\n\
             seed = 99\n\
             n_failures = 3\n\
             mtbf_s = 1.5\n\
             mttr_s = 0.5\n\
             fail_stop_frac = 0.25\n\
             schedule = [\"0@1.0+2\", \"1@4\"]\n\
             max_retries = 5\n\
             retry_base_s = 0.02\n\
             retry_multiplier = 3.0\n\
             retry_cap_s = 0.5\n",
        )
        .expect("parses");
        let mut cfg = FaultConfig::default();
        cfg.apply_toml(&doc).expect("valid section");
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.n_failures, 3);
        assert_eq!(cfg.mtbf_s, 1.5);
        assert_eq!(cfg.mttr_s, 0.5);
        assert_eq!(cfg.fail_stop_frac, 0.25);
        assert_eq!(cfg.schedule.len(), 2);
        assert_eq!(cfg.max_retries, 5);
        let b = cfg.backoff();
        assert_eq!(b.max_attempts, 5);
        assert_eq!(b.base_s, 0.02);
        assert_eq!(b.multiplier, 3.0);
        assert_eq!(b.cap_s, 0.5);
    }

    #[test]
    fn generator_is_seed_deterministic_and_in_range() {
        let cfg = FaultConfig {
            n_failures: 16,
            fail_stop_frac: 0.3,
            ..FaultConfig::default()
        };
        let a = cfg.build_plan(4).expect("plan");
        let b = cfg.build_plan(4).expect("plan");
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        let mut last = SimTime::ZERO;
        for e in a.events() {
            assert!(e.pair < 4);
            assert!(e.fail_at >= last, "plan must be time-sorted");
            if let Some(r) = e.recover_at {
                assert!(r > e.fail_at);
            }
            last = e.fail_at;
        }
        let other = FaultConfig { seed: 8, ..cfg }.build_plan(4).expect("plan");
        assert_ne!(a, other, "different seeds draw different outages");
    }

    #[test]
    fn event_spec_inverts_parse() {
        for spec in ["1@2.5+3", "0@10", "3@0.125+0.25", "2@100.5"] {
            let e = parse_schedule_entry(spec).expect("parses");
            assert_eq!(e.spec(), spec, "spec should re-render canonically");
            assert_eq!(parse_schedule_entry(&e.spec()).unwrap(), e);
        }
        // Non-canonical input still round-trips by value.
        let e = parse_schedule_entry(" 1 @ 2.50 + 3.0 ").expect("parses");
        assert_eq!(parse_schedule_entry(&e.spec()).unwrap(), e);
    }

    #[test]
    fn faults_toml_round_trips_byte_for_byte() {
        let cfg = FaultConfig {
            seed: 99,
            n_failures: 3,
            mtbf_s: 1.5,
            mttr_s: 0.5,
            fail_stop_frac: 0.25,
            schedule: vec![
                parse_schedule_entry("0@1+2").unwrap(),
                parse_schedule_entry("1@4").unwrap(),
            ],
            max_retries: 5,
            retry_base_s: 0.02,
            retry_multiplier: 3.0,
            retry_cap_s: 0.5,
        };
        let text = cfg.to_toml();
        let doc = crate::config::toml::parse(&text).expect("emitted TOML parses");
        let mut back = FaultConfig::default();
        back.apply_toml(&doc).expect("applies");
        assert_eq!(back, cfg, "parse(emit(cfg)) == cfg");
        assert_eq!(back.to_toml(), text, "re-emission is byte-identical");

        // Defaults (empty schedule) round-trip too.
        let d = FaultConfig::default();
        let doc = crate::config::toml::parse(&d.to_toml()).expect("parses");
        let mut back = FaultConfig {
            seed: 1,
            n_failures: 9,
            schedule: vec![parse_schedule_entry("0@1").unwrap()],
            ..FaultConfig::default()
        };
        back.apply_toml(&doc).expect("applies");
        assert_eq!(back, d);
    }

    #[test]
    fn out_of_range_pair_is_rejected() {
        let cfg = FaultConfig {
            schedule: vec![FaultEvent {
                pair: 7,
                fail_at: SimTime::from_secs_f64(1.0),
                recover_at: None,
            }],
            ..FaultConfig::default()
        };
        assert!(cfg.build_plan(2).is_err());
        assert!(cfg.build_plan(8).is_ok());
    }
}
