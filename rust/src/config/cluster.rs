//! Typed deployment configuration — the knobs of §5.1 of the paper, with
//! the paper's defaults baked in:
//!
//! * max token batch 512 for chunked prefill (256 for DP's low-end GPU),
//! * DP weighted round-robin 3:1 with waiting-queue caps 3 / 1,
//! * PP layer split proportional to BF16 FLOPS,
//! * 100 Gbps InfiniBand between nodes.

use crate::simgpu::link::LinkSpec;
use crate::simgpu::model_desc::{self, ModelDesc};
use crate::simgpu::spec::{self, GpuSpec};

use crate::config::toml::TomlDoc;

/// Which serving system to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    Cronus,
    DpChunked,
    PpChunked,
    DisaggHighLow,
    DisaggLowHigh,
}

impl SystemKind {
    pub const ALL: [SystemKind; 5] = [
        SystemKind::DpChunked,
        SystemKind::PpChunked,
        SystemKind::DisaggHighLow,
        SystemKind::DisaggLowHigh,
        SystemKind::Cronus,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Cronus => "Cronus",
            SystemKind::DpChunked => "DP+Chunked",
            SystemKind::PpChunked => "PP+Chunked",
            SystemKind::DisaggHighLow => "Disagg. H-L",
            SystemKind::DisaggLowHigh => "Disagg. L-H",
        }
    }

    pub fn from_name(name: &str) -> Option<SystemKind> {
        match name.to_ascii_lowercase().replace(['-', '_', ' ', '+', '.'], "").as_str() {
            "cronus" => Some(SystemKind::Cronus),
            "dp" | "dpchunked" => Some(SystemKind::DpChunked),
            "pp" | "ppchunked" => Some(SystemKind::PpChunked),
            "disagghl" | "disagghighlow" => Some(SystemKind::DisaggHighLow),
            "disagglh" | "disagglowhigh" => Some(SystemKind::DisaggLowHigh),
            _ => None,
        }
    }
}

/// Per-engine scheduler parameters.
#[derive(Clone, Copy, Debug)]
pub struct EngineParams {
    /// Max batched tokens per iteration (chunked-prefill budget).
    pub max_batched_tokens: usize,
    /// Cap on concurrently running requests.
    pub max_running: usize,
    /// KV block size in tokens.
    pub block_size: usize,
    /// Fraction of device memory reserved for activations / workspace /
    /// allocator slack (mirrors vLLM's `gpu_memory_utilization=0.9` plus
    /// activation workspace).
    pub activation_reserve_frac: f64,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            max_batched_tokens: 512,
            max_running: 256,
            block_size: 16,
            activation_reserve_frac: 0.12,
        }
    }
}

/// Full deployment description (one experiment cell).
#[derive(Clone, Debug)]
pub struct DeploymentConfig {
    pub high_gpu: GpuSpec,
    pub low_gpu: GpuSpec,
    pub model: ModelDesc,
    pub link: LinkSpec,
    /// Chunked-prefill engine on the high-end GPU (Cronus CPI, DP high,
    /// PP stages, disagg decode side).
    pub engine: EngineParams,
    /// DP only: the low-end GPU uses a smaller chunk (paper: 256).
    pub dp_low_chunk: usize,
    /// DP dispatch weights (high : low), paper: 3 : 1.
    pub dp_weights: (u32, u32),
    /// DP waiting-queue caps (high, low), paper: (3, 1).
    pub dp_queue_caps: (usize, usize),
    /// Relative measurement noise used when calibrating the Balancer's
    /// predictors (profiling is not noise-free on real hardware either).
    pub calibration_noise: f64,
    pub calibration_seed: u64,
}

impl DeploymentConfig {
    /// Paper testbed: A100 + A10 or A100 + A30, 100 Gbps IB.
    pub fn paper(high: GpuSpec, low: GpuSpec, model: ModelDesc) -> Self {
        DeploymentConfig {
            high_gpu: high,
            low_gpu: low,
            model,
            link: LinkSpec::INFINIBAND_100G,
            engine: EngineParams::default(),
            dp_low_chunk: 256,
            dp_weights: (3, 1),
            dp_queue_caps: (3, 1),
            calibration_noise: 0.01,
            calibration_seed: 0xC0FFEE,
        }
    }

    /// The four evaluation cells of Table 2 / Fig. 4:
    /// (A100+A10, A100+A30) × (LLaMA3-8B, Qwen2-7B).
    pub fn paper_matrix() -> Vec<(String, DeploymentConfig)> {
        let mut out = Vec::new();
        for (low, low_name) in [(spec::A10, "A10"), (spec::A30, "A30")] {
            for model in [model_desc::LLAMA3_8B, model_desc::QWEN2_7B] {
                let label = format!("A100+{low_name} {}", model.name);
                out.push((label, DeploymentConfig::paper(spec::A100, low, model)));
            }
        }
        out
    }

    /// PP layer split (high-end layers, low-end layers), proportional to
    /// BF16 FLOPS as in §5.1.
    pub fn pp_layer_split(&self) -> (usize, usize) {
        let f = self.high_gpu.bf16_tflops
            / (self.high_gpu.bf16_tflops + self.low_gpu.bf16_tflops);
        let hi = ((self.model.n_layers as f64) * f).round() as usize;
        let hi = hi.clamp(1, self.model.n_layers - 1);
        (hi, self.model.n_layers - hi)
    }

    /// Load overrides from a parsed TOML document (missing keys keep the
    /// paper defaults).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        if let Some(name) = doc.get_str("cluster.high_gpu") {
            self.high_gpu =
                spec::by_name(name).ok_or_else(|| format!("unknown gpu '{name}'"))?;
        }
        if let Some(name) = doc.get_str("cluster.low_gpu") {
            self.low_gpu =
                spec::by_name(name).ok_or_else(|| format!("unknown gpu '{name}'"))?;
        }
        if let Some(name) = doc.get_str("cluster.model") {
            self.model = model_desc::by_name(name)
                .ok_or_else(|| format!("unknown model '{name}'"))?;
        }
        if let Some(g) = doc.get_f64("cluster.link_gbps") {
            self.link.gbps = g;
        }
        if let Some(x) = doc.get_i64("engine.max_batched_tokens") {
            self.engine.max_batched_tokens = x as usize;
        }
        if let Some(x) = doc.get_i64("engine.max_running") {
            self.engine.max_running = x as usize;
        }
        if let Some(x) = doc.get_i64("engine.block_size") {
            self.engine.block_size = x as usize;
        }
        if let Some(x) = doc.get_f64("engine.activation_reserve_frac") {
            self.engine.activation_reserve_frac = x;
        }
        if let Some(x) = doc.get_i64("dp.low_chunk") {
            self.dp_low_chunk = x as usize;
        }
        if let Some(x) = doc.get_i64("dp.weight_high") {
            self.dp_weights.0 = x as u32;
        }
        if let Some(x) = doc.get_i64("dp.weight_low") {
            self.dp_weights.1 = x as u32;
        }
        if let Some(x) = doc.get_i64("dp.queue_cap_high") {
            self.dp_queue_caps.0 = x as usize;
        }
        if let Some(x) = doc.get_i64("dp.queue_cap_low") {
            self.dp_queue_caps.1 = x as usize;
        }
        if let Some(x) = doc.get_f64("balancer.calibration_noise") {
            self.calibration_noise = x;
        }
        if let Some(x) = doc.get_i64("balancer.calibration_seed") {
            self.calibration_seed = x as u64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn paper_defaults() {
        let c = DeploymentConfig::paper(spec::A100, spec::A10, model_desc::LLAMA3_8B);
        assert_eq!(c.engine.max_batched_tokens, 512);
        assert_eq!(c.dp_low_chunk, 256);
        assert_eq!(c.dp_weights, (3, 1));
        assert_eq!(c.dp_queue_caps, (3, 1));
        assert_eq!(c.link.gbps, 100.0);
    }

    #[test]
    fn paper_matrix_has_four_cells() {
        let m = DeploymentConfig::paper_matrix();
        assert_eq!(m.len(), 4);
        let labels: Vec<&str> = m.iter().map(|(l, _)| l.as_str()).collect();
        assert!(labels.contains(&"A100+A10 llama3-8b"));
        assert!(labels.contains(&"A100+A30 qwen2-7b"));
    }

    #[test]
    fn pp_split_matches_paper() {
        let c = DeploymentConfig::paper(spec::A100, spec::A10, model_desc::LLAMA3_8B);
        assert_eq!(c.pp_layer_split(), (23, 9));
        let c = DeploymentConfig::paper(spec::A100, spec::A30, model_desc::LLAMA3_8B);
        assert_eq!(c.pp_layer_split(), (21, 11));
        let c = DeploymentConfig::paper(spec::A100, spec::A10, model_desc::QWEN2_7B);
        assert_eq!(c.pp_layer_split(), (20, 8));
        let c = DeploymentConfig::paper(spec::A100, spec::A30, model_desc::QWEN2_7B);
        assert_eq!(c.pp_layer_split(), (18, 10));
    }

    #[test]
    fn toml_overrides() {
        let mut c =
            DeploymentConfig::paper(spec::A100, spec::A10, model_desc::LLAMA3_8B);
        let doc = toml::parse(
            "[cluster]\nlow_gpu = \"a30\"\nmodel = \"qwen2-7b\"\nlink_gbps = 200\n\
             [engine]\nmax_batched_tokens = 1024\n[dp]\nlow_chunk = 128\n",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.low_gpu.name, "A30");
        assert_eq!(c.model.name, "qwen2-7b");
        assert_eq!(c.link.gbps, 200.0);
        assert_eq!(c.engine.max_batched_tokens, 1024);
        assert_eq!(c.dp_low_chunk, 128);
    }

    #[test]
    fn toml_unknown_gpu_errors() {
        let mut c =
            DeploymentConfig::paper(spec::A100, spec::A10, model_desc::LLAMA3_8B);
        let doc = toml::parse("[cluster]\nhigh_gpu = \"tpuv9\"\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn system_kind_names_roundtrip() {
        for kind in SystemKind::ALL {
            assert_eq!(SystemKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(SystemKind::from_name("dp"), Some(SystemKind::DpChunked));
        assert!(SystemKind::from_name("magic").is_none());
    }
}
