//! Tiny CLI flag parser (`clap` substitute).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and generates usage text from registered options.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec used for parsing + usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => {
                write!(f, "option --{name} requires a value")
            }
        }
    }
}

impl std::error::Error for CliError {}

pub struct Parser {
    pub command: &'static str,
    pub about: &'static str,
    specs: Vec<OptSpec>,
}

impl Parser {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Parser { command, about, specs: Vec::new() }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.command, self.about);
        let _ = writeln!(s, "Options:");
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <value>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let default = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {arg:<28} {}{default}", spec.help);
        }
        s
    }

    /// Parse raw args (without argv[0]).
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let token = &raw[i];
            if let Some(body) = token.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    out.values.insert(name, value);
                } else {
                    out.flags.push(name);
                }
            } else {
                out.positional.push(token.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new("cronus", "test")
            .opt("model", "model name", Some("llama3-8b"))
            .opt("rate", "request rate", None)
            .flag("verbose", "chatty")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse(&args(&[])).unwrap();
        assert_eq!(a.get("model"), Some("llama3-8b"));
        assert_eq!(a.get("rate"), None);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parser().parse(&args(&["--model", "qwen", "--rate=7.5"])).unwrap();
        assert_eq!(a.get("model"), Some("qwen"));
        assert_eq!(a.get_f64("rate"), Some(7.5));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parser().parse(&args(&["serve", "--verbose", "extra"])).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            parser().parse(&args(&["--bogus"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            parser().parse(&args(&["--rate"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn usage_mentions_options() {
        let u = parser().usage();
        assert!(u.contains("--model"));
        assert!(u.contains("default: llama3-8b"));
        assert!(u.contains("--verbose"));
    }
}
