//! Minimal TOML-subset parser for deployment config files.
//!
//! Supported grammar (everything our configs use):
//!   * `[section]` and `[section.subsection]` headers
//!   * `key = value` with string (`"..."`), integer, float, boolean
//!     values, and flat arrays of those
//!   * `#` comments, blank lines
//!
//! Keys are flattened to `section.subsection.key` paths.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Flattened key-path -> value table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(|v| v.as_str())
    }

    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(|v| v.as_i64())
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(|v| v.as_bool())
    }

    /// Keys under a section prefix (e.g. "cluster.").
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if doc.entries.insert(path.clone(), value).is_some() {
            return Err(err(lineno, &format!("duplicate key '{path}'")));
        }
    }
    Ok(doc)
}

fn err(line: usize, msg: &str) -> TomlError {
    TomlError { line, msg: msg.to_string() }
}

/// Remove a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            return Err(err(lineno, "trailing data after string"));
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value '{text}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let doc = parse(
            "top = 1\n[cluster]\nhigh = \"a100\"\nlow = \"a10\"\n\
             [engine.cpi]\nmax_tokens = 512\n",
        )
        .unwrap();
        assert_eq!(doc.get_i64("top"), Some(1));
        assert_eq!(doc.get_str("cluster.high"), Some("a100"));
        assert_eq!(doc.get_i64("engine.cpi.max_tokens"), Some(512));
    }

    #[test]
    fn parses_types() {
        let doc = parse(
            "s = \"x\"\ni = -3\nf = 2.5\nb = true\narr = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("s"), Some("x"));
        assert_eq!(doc.get_i64("i"), Some(-3));
        assert_eq!(doc.get_f64("f"), Some(2.5));
        assert_eq!(doc.get_bool("b"), Some(true));
        match doc.get("arr").unwrap() {
            TomlValue::Array(xs) => assert_eq!(xs.len(), 3),
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 5\n").unwrap();
        assert_eq!(doc.get_f64("x"), Some(5.0));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse("# header\n\na = 1 # trailing\nb = \"#not a comment\"\n").unwrap();
        assert_eq!(doc.get_i64("a"), Some(1));
        assert_eq!(doc.get_str("b"), Some("#not a comment"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("good = 1\nbad\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("x = \n").is_err());
        assert!(parse("x = zzz\n").is_err());
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn section_keys_listing() {
        let doc = parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        assert_eq!(doc.section_keys("a."), vec!["a.x", "a.y"]);
    }
}
