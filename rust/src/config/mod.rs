//! Configuration system: a TOML-subset parser, a CLI flag parser, and the
//! typed deployment configuration every binary consumes.
//!
//! (The offline build ships no `serde`/`toml`/`clap`; these are small
//! from-scratch replacements — DESIGN.md §1.)

pub mod cli;
pub mod cluster;
pub mod toml;
pub mod topology;

pub use cluster::{DeploymentConfig, EngineParams, SystemKind};
pub use topology::{ClusterConfig, PairConfig};
