//! Configuration system: a TOML-subset parser, a CLI flag parser, and the
//! typed deployment configuration every binary consumes.
//!
//! (The offline build ships no `serde`/`toml`/`clap`; these are small
//! from-scratch replacements — DESIGN.md §1.)
//!
//! One parsed [`toml::TomlDoc`] feeds every typed config through its
//! `apply_toml` method: `[topology]` → [`ClusterConfig`], `[autoscale]`
//! → `systems::AutoscaleConfig`, `[classes]` →
//! `qos::ClassRegistry` (multi-tenant service classes), and
//! `[cluster]`/`[engine]`/`[dp]`/`[balancer]` → [`DeploymentConfig`].
//! The repo-root `CONFIG.md` is the key-by-key reference; the pair-spec
//! grammar is `<high>+<low>[:<rate_share>][@<system>][=<model>]`.
//!
//! # Example
//!
//! ```
//! use cronus::config::{toml, ClusterConfig, SystemKind};
//! use cronus::systems::AutoscaleConfig;
//!
//! let doc = toml::parse(
//!     "[topology]\n\
//!      model = \"llama3-8b\"\n\
//!      pairs = [\"a100+a10\", \"a100+a30:1.5@dp\"]\n\
//!      [autoscale]\n\
//!      initial_pairs = 2\n",
//! )
//! .unwrap();
//!
//! let mut fleet = ClusterConfig::default();
//! fleet.apply_toml(&doc).unwrap();
//! assert_eq!(fleet.n_pairs(), 2);
//! assert_eq!(fleet.pairs[1].rate_share, 1.5);
//! assert_eq!(fleet.pairs[1].system, SystemKind::DpChunked);
//!
//! let mut auto = AutoscaleConfig::default();
//! auto.apply_toml(&doc);
//! assert_eq!(auto.initial_pairs, 2);
//! ```

pub mod cli;
pub mod cluster;
pub mod toml;
pub mod topology;

pub use cluster::{DeploymentConfig, EngineParams, SystemKind};
pub use topology::{ClusterConfig, PairConfig};
