//! Cluster topology: N heterogeneous (high-end, low-end) GPU pairs
//! behind one cluster-level router.
//!
//! The paper deploys Cronus on a single pair; organizational clusters
//! (the paper's target setting, and what HexGen-2 / "High-Throughput LLM
//! inference on Heterogeneous Clusters" schedule across) have many such
//! pairs with different capability mixes.  A [`ClusterConfig`] is an
//! ordered list of [`PairConfig`]s — each pair carries its own
//! [`DeploymentConfig`] (GPU combo, link, engine knobs), the serving
//! system it runs (Cronus by default), and a relative `rate_share` used
//! by the weighted round-robin routing policy.
//!
//! TOML form (parsed by [`crate::config::toml`]):
//!
//! ```toml
//! [topology]
//! model = "llama3-8b"
//! pairs = ["a100+a10", "a100+a30:1.5", "a100+v100"]
//! ```
//!
//! Each pair spec is `<high_gpu>+<low_gpu>` with an optional
//! `:<rate_share>` suffix.

use crate::config::cluster::{DeploymentConfig, SystemKind};
use crate::config::toml::{TomlDoc, TomlValue};
use crate::simgpu::model_desc::{self, ModelDesc};
use crate::simgpu::spec::{self, GpuSpec};

/// One (high-end, low-end) GPU pair in the cluster.
#[derive(Clone, Debug)]
pub struct PairConfig {
    /// Display name, e.g. `A100-80G+A10`.
    pub name: String,
    pub deployment: DeploymentConfig,
    /// Which serving system this pair runs (Cronus unless overridden).
    pub system: SystemKind,
    /// Relative share of offered load for weighted routing policies.
    pub rate_share: f64,
}

impl PairConfig {
    /// A Cronus pair with unit rate share.
    pub fn cronus(deployment: DeploymentConfig) -> PairConfig {
        let name =
            format!("{}+{}", deployment.high_gpu.name, deployment.low_gpu.name);
        PairConfig {
            name,
            deployment,
            system: SystemKind::Cronus,
            rate_share: 1.0,
        }
    }

    /// Parse `"a100+a10"` or `"a100+a10:2.0"` (rate share suffix).
    pub fn from_spec(text: &str, model: ModelDesc) -> Result<PairConfig, String> {
        let (gpus, share) = match text.split_once(':') {
            Some((g, s)) => {
                let share: f64 = s
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad rate share in '{text}'"))?;
                if share <= 0.0 {
                    return Err(format!("rate share must be > 0 in '{text}'"));
                }
                (g, share)
            }
            None => (text, 1.0),
        };
        let (hi, lo) = gpus
            .split_once('+')
            .ok_or_else(|| format!("pair spec '{text}' is not '<high>+<low>'"))?;
        let high = spec::by_name(hi.trim())
            .ok_or_else(|| format!("unknown gpu '{}'", hi.trim()))?;
        let low = spec::by_name(lo.trim())
            .ok_or_else(|| format!("unknown gpu '{}'", lo.trim()))?;
        let mut pair = PairConfig::cronus(DeploymentConfig::paper(high, low, model));
        pair.rate_share = share;
        Ok(pair)
    }
}

/// An N-pair heterogeneous cluster behind one router.
#[derive(Clone, Debug, Default)]
pub struct ClusterConfig {
    pub pairs: Vec<PairConfig>,
}

impl ClusterConfig {
    pub fn new(pairs: Vec<PairConfig>) -> ClusterConfig {
        ClusterConfig { pairs }
    }

    /// `n` identical Cronus pairs.
    pub fn homogeneous(n: usize, deployment: DeploymentConfig) -> ClusterConfig {
        ClusterConfig {
            pairs: (0..n).map(|_| PairConfig::cronus(deployment.clone())).collect(),
        }
    }

    /// The standard mixed-capability scale-out fleet: A100 high-end cards
    /// paired with low-end cards of decreasing capability.  The first
    /// pair (A100+A10) is the scale-out baseline; pairs 5–8 add V100 and
    /// T4 partners to exercise the capability-mismatch paths.
    pub fn mixed(n_pairs: usize, model: ModelDesc) -> ClusterConfig {
        const LOWS: [GpuSpec; 8] = [
            spec::A10,
            spec::A30,
            spec::A10,
            spec::A30,
            spec::V100,
            spec::T4,
            spec::V100,
            spec::T4,
        ];
        ClusterConfig {
            pairs: (0..n_pairs)
                .map(|i| {
                    let low = LOWS[i % LOWS.len()];
                    PairConfig::cronus(DeploymentConfig::paper(spec::A100, low, model))
                })
                .collect(),
        }
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    pub fn total_rate_share(&self) -> f64 {
        self.pairs.iter().map(|p| p.rate_share).sum()
    }

    /// Short display label, e.g. `cluster[A10|A30|A10]`.
    pub fn label(&self) -> String {
        let lows: Vec<&str> = self.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        format!("cluster[{}]", lows.join("|"))
    }

    /// Load a topology from a parsed TOML document.  `topology.pairs`
    /// replaces the pair list; `topology.model` sets the served model
    /// (defaulting to the current first pair's model).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        let model = match doc.get_str("topology.model") {
            Some(name) => model_desc::by_name(name)
                .ok_or_else(|| format!("unknown model '{name}'"))?,
            None => self
                .pairs
                .first()
                .map(|p| p.deployment.model)
                .unwrap_or(model_desc::LLAMA3_8B),
        };
        if let Some(TomlValue::Array(items)) = doc.get("topology.pairs") {
            let mut pairs = Vec::with_capacity(items.len());
            for item in items {
                let text = item
                    .as_str()
                    .ok_or("topology.pairs entries must be strings")?;
                pairs.push(PairConfig::from_spec(text, model)?);
            }
            if pairs.is_empty() {
                return Err("topology.pairs must not be empty".into());
            }
            self.pairs = pairs;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;
    use crate::simgpu::model_desc::LLAMA3_8B;

    #[test]
    fn mixed_fleet_shape() {
        let c = ClusterConfig::mixed(4, LLAMA3_8B);
        assert_eq!(c.n_pairs(), 4);
        let lows: Vec<&str> = c.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        assert_eq!(lows, vec!["A10", "A30", "A10", "A30"]);
        assert!(c.pairs.iter().all(|p| p.deployment.high_gpu.name == "A100-80G"));
        assert!(c.pairs.iter().all(|p| p.system == SystemKind::Cronus));
        assert_eq!(c.total_rate_share(), 4.0);
        assert_eq!(c.label(), "cluster[A10|A30|A10|A30]");
    }

    #[test]
    fn mixed_fleet_extends_to_v100_t4() {
        let c = ClusterConfig::mixed(8, LLAMA3_8B);
        let lows: Vec<&str> = c.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        assert_eq!(lows[4], "V100-32G");
        assert_eq!(lows[5], "T4");
    }

    #[test]
    fn pair_spec_parses_share() {
        let p = PairConfig::from_spec("a100+a30:2.5", LLAMA3_8B).unwrap();
        assert_eq!(p.deployment.low_gpu.name, "A30");
        assert_eq!(p.rate_share, 2.5);
        let p = PairConfig::from_spec("a100+v100", LLAMA3_8B).unwrap();
        assert_eq!(p.rate_share, 1.0);
        assert!(PairConfig::from_spec("a100", LLAMA3_8B).is_err());
        assert!(PairConfig::from_spec("a100+tpu", LLAMA3_8B).is_err());
        assert!(PairConfig::from_spec("a100+a10:-1", LLAMA3_8B).is_err());
    }

    #[test]
    fn toml_topology_roundtrip() {
        let doc = toml::parse(
            "[topology]\nmodel = \"qwen2-7b\"\n\
             pairs = [\"a100+a10\", \"a100+a30:1.5\", \"a100+t4\"]\n",
        )
        .unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_pairs(), 3);
        assert_eq!(c.pairs[0].deployment.model.name, "qwen2-7b");
        assert_eq!(c.pairs[1].rate_share, 1.5);
        assert_eq!(c.pairs[2].deployment.low_gpu.name, "T4");
    }

    #[test]
    fn toml_bad_entries_error() {
        let mut c = ClusterConfig::mixed(1, LLAMA3_8B);
        let doc = toml::parse("[topology]\npairs = [\"a100+h100\"]\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
        let doc = toml::parse("[topology]\nmodel = \"gpt5\"\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
        // No topology section: config unchanged.
        let doc = toml::parse("[cluster]\nhigh_gpu = \"a100\"\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_pairs(), 1);
    }
}
