//! Cluster topology: N heterogeneous (high-end, low-end) GPU pairs
//! behind one cluster-level router.
//!
//! The paper deploys Cronus on a single pair; organizational clusters
//! (the paper's target setting, and what HexGen-2 / "High-Throughput LLM
//! inference on Heterogeneous Clusters" schedule across) have many such
//! pairs with different capability mixes.  A [`ClusterConfig`] is an
//! ordered list of [`PairConfig`]s — each pair carries its own
//! [`DeploymentConfig`] (GPU combo, link, engine knobs), the serving
//! system it runs (Cronus by default), and a relative `rate_share` used
//! by the weighted round-robin routing policy.
//!
//! TOML form (parsed by [`crate::config::toml`]):
//!
//! ```toml
//! [topology]
//! model = "llama3-8b"
//! pairs = ["a100+a10", "a100+a30:1.5", "a100+v100@dp"]
//!
//! [cluster]
//! link = "100G@5us:0.9"      # inter-pair interconnect (KV migration)
//! links = ["2:25G@20us:0.8"] # per-pair override: pair 2 sits on 25G
//! ```
//!
//! The `[cluster] link` key enables cross-pair KV migration: warm
//! session prefixes ship over the modeled interconnect (priced by
//! [`LinkSpec::kv_transfer_time`]) instead of being recomputed when
//! their resident pair drains or blows the TTFT SLO.  Omitting it (the
//! default everywhere) keeps migration off and the cluster
//! byte-identical to the pre-migration code.
//!
//! Each pair spec is `<high_gpu>+<low_gpu>` with an optional
//! `:<rate_share>` suffix, an optional `@<system>` suffix (`cronus`,
//! `dp`, `pp`, `disagg-hl`, `disagg-lh`; Cronus when omitted), and an
//! optional `=<model>` suffix overriding `topology.model` for that pair
//! alone — a multi-model fleet for the QoS router's model-aware
//! placement (`"a100+a30=qwen2-7b"` serves Qwen2-7B while the rest of
//! the fleet serves the topology model).
//! [`ClusterConfig::to_toml`] emits this exact grammar back out — the
//! topology planner writes its winning fleet through it, and the CI docs
//! job round-trips the emitted file through [`crate::config::toml`].
//! See `CONFIG.md` at the repository root for the full key reference.

use crate::config::cluster::{DeploymentConfig, SystemKind};
use crate::config::toml::{TomlDoc, TomlValue};
use crate::simgpu::link::LinkSpec;
use crate::simgpu::model_desc::{self, ModelDesc};
use crate::simgpu::spec::{self, GpuSpec};

/// One (high-end, low-end) GPU pair in the cluster.
#[derive(Clone, Debug)]
pub struct PairConfig {
    /// Display name, e.g. `A100-80G+A10`.
    pub name: String,
    pub deployment: DeploymentConfig,
    /// Which serving system this pair runs (Cronus unless overridden).
    pub system: SystemKind,
    /// Relative share of offered load for weighted routing policies.
    pub rate_share: f64,
    /// Inter-pair link override for this pair's node (KV migration
    /// prices a transfer at the slower endpoint).  `None` falls back to
    /// [`ClusterConfig::link`]; both `None` disables migration for
    /// transfers touching this pair.
    pub link: Option<LinkSpec>,
}

impl PairConfig {
    /// A Cronus pair with unit rate share.
    pub fn cronus(deployment: DeploymentConfig) -> PairConfig {
        let name =
            format!("{}+{}", deployment.high_gpu.name, deployment.low_gpu.name);
        PairConfig {
            name,
            deployment,
            system: SystemKind::Cronus,
            rate_share: 1.0,
            link: None,
        }
    }

    /// Parse `"a100+a10"`, `"a100+a10:2.0"` (rate share suffix),
    /// `"a100+a10:2.0@dp"` (serving-system suffix) or
    /// `"a100+a10=qwen2-7b"` (per-pair served-model override).
    pub fn from_spec(text: &str, model: ModelDesc) -> Result<PairConfig, String> {
        // The model override is the outermost suffix: strip it first so
        // the remaining grammar is exactly the pre-override one.
        let (text2, model) = match text.rsplit_once('=') {
            Some((r, m)) => {
                let desc = model_desc::by_name(m.trim())
                    .ok_or_else(|| format!("unknown model '{}' in '{text}'", m.trim()))?;
                (r, desc)
            }
            None => (text, model),
        };
        let text = text2;
        let (rest, system) = match text.rsplit_once('@') {
            Some((r, s)) => {
                let kind = SystemKind::from_name(s.trim())
                    .ok_or_else(|| format!("unknown system '{}' in '{text}'", s.trim()))?;
                (r, kind)
            }
            None => (text, SystemKind::Cronus),
        };
        let (gpus, share) = match rest.split_once(':') {
            Some((g, s)) => {
                let share: f64 = s
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad rate share in '{text}'"))?;
                if share <= 0.0 {
                    return Err(format!("rate share must be > 0 in '{text}'"));
                }
                (g, share)
            }
            None => (rest, 1.0),
        };
        let (hi, lo) = gpus
            .split_once('+')
            .ok_or_else(|| format!("pair spec '{text}' is not '<high>+<low>'"))?;
        let high = spec::by_name(hi.trim())
            .ok_or_else(|| format!("unknown gpu '{}'", hi.trim()))?;
        let low = spec::by_name(lo.trim())
            .ok_or_else(|| format!("unknown gpu '{}'", lo.trim()))?;
        let mut pair = PairConfig::cronus(DeploymentConfig::paper(high, low, model));
        pair.rate_share = share;
        pair.system = system;
        Ok(pair)
    }

    /// Render this pair back into the spec grammar `from_spec` accepts:
    /// `<high>+<low>[:<share>][@<system>]`, with the unit share and the
    /// default Cronus system elided.
    pub fn spec(&self) -> String {
        let mut s = format!(
            "{}+{}",
            self.deployment.high_gpu.name.to_ascii_lowercase(),
            self.deployment.low_gpu.name.to_ascii_lowercase()
        );
        if self.rate_share != 1.0 {
            s.push(':');
            s.push_str(&self.rate_share.to_string());
        }
        if self.system != SystemKind::Cronus {
            s.push('@');
            s.push_str(system_spec_token(self.system));
        }
        s
    }

    /// [`PairConfig::spec`] plus the `=<model>` suffix whenever this
    /// pair's served model differs from `default_model` (the fleet's
    /// `topology.model`) — what [`ClusterConfig::to_toml`] emits so
    /// multi-model fleets round-trip.
    pub fn spec_with_default(&self, default_model: ModelDesc) -> String {
        let mut s = self.spec();
        if self.deployment.model != default_model {
            s.push('=');
            s.push_str(self.deployment.model.name);
        }
        s
    }

    /// Rental cost of the pair's two cards, USD/hour.
    pub fn cost_per_hour(&self) -> f64 {
        self.deployment.high_gpu.cost_per_hour + self.deployment.low_gpu.cost_per_hour
    }

    /// Combined board power of the pair's two cards, watts.
    pub fn power_w(&self) -> f64 {
        self.deployment.high_gpu.power_w + self.deployment.low_gpu.power_w
    }
}

/// The canonical lowercase token `SystemKind::from_name` maps back to
/// each kind — used when emitting pair specs.
fn system_spec_token(kind: SystemKind) -> &'static str {
    match kind {
        SystemKind::Cronus => "cronus",
        SystemKind::DpChunked => "dp",
        SystemKind::PpChunked => "pp",
        SystemKind::DisaggHighLow => "disagg-hl",
        SystemKind::DisaggLowHigh => "disagg-lh",
    }
}

/// An N-pair heterogeneous cluster behind one router.
#[derive(Clone, Debug, Default)]
pub struct ClusterConfig {
    pub pairs: Vec<PairConfig>,
    /// Default inter-pair interconnect.  `Some` enables cross-pair KV
    /// migration (warm prefixes ship instead of being recomputed);
    /// `None` (the default) keeps every migration path a dead branch —
    /// routing is byte-identical to the pre-migration cluster.
    pub link: Option<LinkSpec>,
}

impl ClusterConfig {
    pub fn new(pairs: Vec<PairConfig>) -> ClusterConfig {
        ClusterConfig { pairs, link: None }
    }

    /// `n` identical Cronus pairs.
    pub fn homogeneous(n: usize, deployment: DeploymentConfig) -> ClusterConfig {
        ClusterConfig {
            pairs: (0..n).map(|_| PairConfig::cronus(deployment.clone())).collect(),
            link: None,
        }
    }

    /// The standard mixed-capability scale-out fleet: A100 high-end cards
    /// paired with low-end cards of decreasing capability.  The first
    /// pair (A100+A10) is the scale-out baseline; pairs 5–8 add V100 and
    /// T4 partners to exercise the capability-mismatch paths.
    pub fn mixed(n_pairs: usize, model: ModelDesc) -> ClusterConfig {
        const LOWS: [GpuSpec; 8] = [
            spec::A10,
            spec::A30,
            spec::A10,
            spec::A30,
            spec::V100,
            spec::T4,
            spec::V100,
            spec::T4,
        ];
        ClusterConfig {
            pairs: (0..n_pairs)
                .map(|i| {
                    let low = LOWS[i % LOWS.len()];
                    PairConfig::cronus(DeploymentConfig::paper(spec::A100, low, model))
                })
                .collect(),
            link: None,
        }
    }

    /// Enable cross-pair KV migration over `link` (builder form).
    pub fn with_link(mut self, link: LinkSpec) -> ClusterConfig {
        self.link = Some(link);
        self
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    pub fn total_rate_share(&self) -> f64 {
        self.pairs.iter().map(|p| p.rate_share).sum()
    }

    /// Total fleet rental cost, USD/hour (the planner's cost budget
    /// counts both cards of every pair).
    pub fn cost_per_hour(&self) -> f64 {
        self.pairs.iter().map(|p| p.cost_per_hour()).sum()
    }

    /// Total fleet board power, watts.
    pub fn power_w(&self) -> f64 {
        self.pairs.iter().map(|p| p.power_w()).sum()
    }

    /// Short display label, e.g. `cluster[A10|A30|A10]`.
    pub fn label(&self) -> String {
        let lows: Vec<&str> = self.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        format!("cluster[{}]", lows.join("|"))
    }

    /// Load a topology from a parsed TOML document.  `topology.pairs`
    /// replaces the pair list; `topology.model` sets the served model
    /// (defaulting to the current first pair's model).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        let model = match doc.get_str("topology.model") {
            Some(name) => model_desc::by_name(name)
                .ok_or_else(|| format!("unknown model '{name}'"))?,
            None => self
                .pairs
                .first()
                .map(|p| p.deployment.model)
                .unwrap_or(model_desc::LLAMA3_8B),
        };
        if let Some(TomlValue::Array(items)) = doc.get("topology.pairs") {
            let mut pairs = Vec::with_capacity(items.len());
            for item in items {
                let text = item
                    .as_str()
                    .ok_or("topology.pairs entries must be strings")?;
                pairs.push(PairConfig::from_spec(text, model)?);
            }
            if pairs.is_empty() {
                return Err("topology.pairs must not be empty".into());
            }
            self.pairs = pairs;
        }
        // Interconnect: `[cluster] link = "<gbps>G[@<lat>us][:<eff>]"`
        // turns cross-pair KV migration on; `links = ["<pair>:<spec>"]`
        // overrides individual pairs (asymmetric fabrics — the
        // multi-vendor setting where link speeds differ per node).
        if let Some(text) = doc.get_str("cluster.link") {
            self.link = Some(LinkSpec::parse(text)?);
        }
        if let Some(TomlValue::Array(items)) = doc.get("cluster.links") {
            for item in items {
                let text = item
                    .as_str()
                    .ok_or("cluster.links entries must be strings")?;
                let (idx, spec) = text
                    .split_once(':')
                    .ok_or_else(|| format!("link override '{text}' is not '<pair>:<spec>'"))?;
                let idx: usize = idx
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad pair index in link override '{text}'"))?;
                let n = self.pairs.len();
                let pair = self.pairs.get_mut(idx).ok_or_else(|| {
                    format!("link override '{text}' names pair {idx} of a {n}-pair fleet")
                })?;
                pair.link = Some(LinkSpec::parse(spec.trim())?);
            }
        }
        Ok(())
    }

    /// Emit this topology as a `[topology]` TOML section in exactly the
    /// grammar [`ClusterConfig::apply_toml`] reads back (single-line
    /// `pairs` array — the in-tree parser's requirement).  The default
    /// model is taken from the first pair; pairs serving a different
    /// model carry an explicit `=<model>` suffix, so multi-model fleets
    /// round-trip too.  A configured interconnect (and any per-pair
    /// overrides) is emitted as a `[cluster]` section after it.
    pub fn to_toml(&self) -> String {
        let model = self
            .pairs
            .first()
            .map(|p| p.deployment.model)
            .unwrap_or(model_desc::LLAMA3_8B);
        let specs: Vec<String> = self
            .pairs
            .iter()
            .map(|p| format!("\"{}\"", p.spec_with_default(model)))
            .collect();
        let mut out = format!(
            "[topology]\nmodel = \"{}\"\npairs = [{}]\n",
            model.name,
            specs.join(", ")
        );
        let overrides: Vec<String> = self
            .pairs
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.link.map(|l| format!("\"{i}:{}\"", l.spec())))
            .collect();
        if self.link.is_some() || !overrides.is_empty() {
            out.push_str("\n[cluster]\n");
            if let Some(l) = self.link {
                out.push_str(&format!("link = \"{}\"\n", l.spec()));
            }
            if !overrides.is_empty() {
                out.push_str(&format!("links = [{}]\n", overrides.join(", ")));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;
    use crate::simgpu::model_desc::LLAMA3_8B;

    #[test]
    fn mixed_fleet_shape() {
        let c = ClusterConfig::mixed(4, LLAMA3_8B);
        assert_eq!(c.n_pairs(), 4);
        let lows: Vec<&str> = c.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        assert_eq!(lows, vec!["A10", "A30", "A10", "A30"]);
        assert!(c.pairs.iter().all(|p| p.deployment.high_gpu.name == "A100-80G"));
        assert!(c.pairs.iter().all(|p| p.system == SystemKind::Cronus));
        assert_eq!(c.total_rate_share(), 4.0);
        assert_eq!(c.label(), "cluster[A10|A30|A10|A30]");
    }

    #[test]
    fn mixed_fleet_extends_to_v100_t4() {
        let c = ClusterConfig::mixed(8, LLAMA3_8B);
        let lows: Vec<&str> = c.pairs.iter().map(|p| p.deployment.low_gpu.name).collect();
        assert_eq!(lows[4], "V100-32G");
        assert_eq!(lows[5], "T4");
    }

    #[test]
    fn pair_spec_parses_share() {
        let p = PairConfig::from_spec("a100+a30:2.5", LLAMA3_8B).unwrap();
        assert_eq!(p.deployment.low_gpu.name, "A30");
        assert_eq!(p.rate_share, 2.5);
        let p = PairConfig::from_spec("a100+v100", LLAMA3_8B).unwrap();
        assert_eq!(p.rate_share, 1.0);
        assert!(PairConfig::from_spec("a100", LLAMA3_8B).is_err());
        assert!(PairConfig::from_spec("a100+tpu", LLAMA3_8B).is_err());
        assert!(PairConfig::from_spec("a100+a10:-1", LLAMA3_8B).is_err());
    }

    #[test]
    fn toml_topology_roundtrip() {
        let doc = toml::parse(
            "[topology]\nmodel = \"qwen2-7b\"\n\
             pairs = [\"a100+a10\", \"a100+a30:1.5\", \"a100+t4\"]\n",
        )
        .unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_pairs(), 3);
        assert_eq!(c.pairs[0].deployment.model.name, "qwen2-7b");
        assert_eq!(c.pairs[1].rate_share, 1.5);
        assert_eq!(c.pairs[2].deployment.low_gpu.name, "T4");
    }

    #[test]
    fn pair_spec_parses_system_suffix() {
        let p = PairConfig::from_spec("a100+a30@dp", LLAMA3_8B).unwrap();
        assert_eq!(p.system, SystemKind::DpChunked);
        assert_eq!(p.rate_share, 1.0);
        let p = PairConfig::from_spec("a100+t4:2.5@disagg-hl", LLAMA3_8B).unwrap();
        assert_eq!(p.system, SystemKind::DisaggHighLow);
        assert_eq!(p.rate_share, 2.5);
        assert!(PairConfig::from_spec("a100+a30@warp", LLAMA3_8B).is_err());
    }

    #[test]
    fn pair_spec_parses_model_override() {
        use crate::simgpu::model_desc::QWEN2_7B;
        let p = PairConfig::from_spec("a100+a30=qwen2-7b", LLAMA3_8B).unwrap();
        assert_eq!(p.deployment.model, QWEN2_7B);
        assert_eq!(p.rate_share, 1.0);
        assert_eq!(p.system, SystemKind::Cronus);
        // Composes with both earlier suffixes (model is outermost).
        let p = PairConfig::from_spec("a100+t4:2.5@dp=qwen2-7b", LLAMA3_8B).unwrap();
        assert_eq!(p.deployment.model, QWEN2_7B);
        assert_eq!(p.rate_share, 2.5);
        assert_eq!(p.system, SystemKind::DpChunked);
        // Omitted: inherits the fleet model.
        let p = PairConfig::from_spec("a100+a10", LLAMA3_8B).unwrap();
        assert_eq!(p.deployment.model, LLAMA3_8B);
        assert!(PairConfig::from_spec("a100+a10=gpt5", LLAMA3_8B).is_err());
    }

    #[test]
    fn multi_model_fleet_round_trips_through_toml() {
        use crate::simgpu::model_desc::QWEN2_7B;
        let mut c = ClusterConfig::mixed(3, LLAMA3_8B);
        c.pairs[2].deployment = DeploymentConfig::paper(
            c.pairs[2].deployment.high_gpu,
            c.pairs[2].deployment.low_gpu,
            QWEN2_7B,
        );
        let text = c.to_toml();
        assert!(text.contains("=qwen2-7b"), "override suffix missing: {text}");
        let doc = toml::parse(&text).unwrap();
        let mut rt = ClusterConfig::default();
        rt.apply_toml(&doc).unwrap();
        assert_eq!(rt.pairs[0].deployment.model, LLAMA3_8B);
        assert_eq!(rt.pairs[1].deployment.model, LLAMA3_8B);
        assert_eq!(rt.pairs[2].deployment.model, QWEN2_7B);
        // A pair matching the fleet model gets no suffix; a differing
        // one carries exactly the override.
        assert_eq!(c.pairs[0].spec_with_default(LLAMA3_8B), "a100-80g+a10");
        assert_eq!(
            c.pairs[2].spec_with_default(LLAMA3_8B),
            "a100-80g+a10=qwen2-7b"
        );
    }

    #[test]
    fn pair_spec_round_trips_through_emission() {
        let specs = [
            "a100-80g+a10",
            "a100-80g+a30:1.5",
            "a100-80g+v100-32g:2@dp",
            "v100-32g+t4@pp",
        ];
        for text in specs {
            let p = PairConfig::from_spec(text, LLAMA3_8B).unwrap();
            assert_eq!(p.spec(), text, "emission changed the spec");
            let q = PairConfig::from_spec(&p.spec(), LLAMA3_8B).unwrap();
            assert_eq!(q.system, p.system);
            assert_eq!(q.rate_share, p.rate_share);
            assert_eq!(q.deployment.high_gpu, p.deployment.high_gpu);
            assert_eq!(q.deployment.low_gpu, p.deployment.low_gpu);
        }
    }

    #[test]
    fn to_toml_round_trips_through_parser() {
        let mut c = ClusterConfig::mixed(3, LLAMA3_8B);
        c.pairs[1].rate_share = 1.5;
        c.pairs[2].system = SystemKind::DpChunked;
        let text = c.to_toml();
        let doc = toml::parse(&text).unwrap();
        let mut rt = ClusterConfig::default();
        rt.apply_toml(&doc).unwrap();
        assert_eq!(rt.n_pairs(), c.n_pairs());
        for (a, b) in rt.pairs.iter().zip(&c.pairs) {
            assert_eq!(a.deployment.high_gpu, b.deployment.high_gpu);
            assert_eq!(a.deployment.low_gpu, b.deployment.low_gpu);
            assert_eq!(a.deployment.model, b.deployment.model);
            assert_eq!(a.system, b.system);
            assert_eq!(a.rate_share, b.rate_share);
        }
    }

    #[test]
    fn fleet_cost_and_power_sum_both_cards() {
        use crate::simgpu::spec::{A10, A100, A30};
        let c = ClusterConfig::mixed(2, LLAMA3_8B); // A100+A10, A100+A30
        let want_cost = 2.0 * A100.cost_per_hour + A10.cost_per_hour + A30.cost_per_hour;
        assert!((c.cost_per_hour() - want_cost).abs() < 1e-12);
        let want_w = 2.0 * A100.power_w + A10.power_w + A30.power_w;
        assert!((c.power_w() - want_w).abs() < 1e-12);
    }

    #[test]
    fn cluster_link_round_trips_through_toml() {
        let mut c = ClusterConfig::mixed(3, LLAMA3_8B)
            .with_link(LinkSpec::INFINIBAND_100G);
        c.pairs[2].link = Some(LinkSpec::parse("25G@20us:0.8").unwrap());
        let text = c.to_toml();
        assert!(text.contains("link = \"100G\""), "{text}");
        assert!(text.contains("links = [\"2:25G@"), "{text}");
        let doc = toml::parse(&text).unwrap();
        let mut rt = ClusterConfig::default();
        rt.apply_toml(&doc).unwrap();
        assert_eq!(rt.link, Some(LinkSpec::INFINIBAND_100G));
        assert_eq!(rt.pairs[0].link, None);
        assert_eq!(rt.pairs[2].link, c.pairs[2].link);
        // No link configured: no [cluster] section at all, so planner
        // emissions and older configs are unchanged byte-for-byte.
        let plain = ClusterConfig::mixed(2, LLAMA3_8B).to_toml();
        assert!(!plain.contains("[cluster]"), "{plain}");
        // Bad overrides error out.
        let doc = toml::parse("[cluster]\nlinks = [\"9:100G\"]\n").unwrap();
        assert!(ClusterConfig::mixed(2, LLAMA3_8B).apply_toml(&doc).is_err());
        let doc = toml::parse("[cluster]\nlink = \"fast\"\n").unwrap();
        assert!(ClusterConfig::mixed(2, LLAMA3_8B).apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_bad_entries_error() {
        let mut c = ClusterConfig::mixed(1, LLAMA3_8B);
        let doc = toml::parse("[topology]\npairs = [\"a100+h100\"]\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
        let doc = toml::parse("[topology]\nmodel = \"gpt5\"\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
        // No topology section: config unchanged.
        let doc = toml::parse("[cluster]\nhigh_gpu = \"a100\"\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_pairs(), 1);
    }
}
