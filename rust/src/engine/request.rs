//! Per-request state machine inside an engine instance.

pub type ReqId = u64;

/// Lifecycle phase of a request on one engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// In the waiting queue; no KV allocated.
    Queued,
    /// Prefill in progress; `done` local prompt tokens computed so far
    /// (on top of `prefill_offset` computed elsewhere).
    Prefilling { done: usize },
    /// Decode in progress; `generated` output tokens emitted so far
    /// (the first was produced by the final prefill iteration).
    Decoding { generated: usize },
    Finished,
}

/// A request as tracked by an engine instance.
#[derive(Clone, Debug)]
pub struct EngineRequest {
    pub id: ReqId,
    pub input_len: usize,
    pub output_len: usize,
    /// Prompt tokens whose KV was computed elsewhere — on another
    /// instance (Cronus partial prefill) or in a previous turn of the
    /// same conversation (resident session prefix).
    /// `prefill_offset == input_len` is full disaggregation: this engine
    /// only decodes.
    pub prefill_offset: usize,
    /// Leading `prefill_offset` tokens whose KV is *already resident* in
    /// this engine's pool (session prefix reuse): neither recomputed nor
    /// transferred.  Only `[resident_len, prefill_offset)` moves over the
    /// link.
    pub resident_len: usize,
    /// KV for `[resident_len, prefill_offset)` must still be fetched over
    /// the link; cleared once the transfer iteration completes.
    pub needs_kv_recv: bool,
    pub phase: Phase,
}

impl EngineRequest {
    /// A request served end-to-end by this engine (DP / PP / standalone).
    pub fn whole(id: ReqId, input_len: usize, output_len: usize) -> Self {
        EngineRequest {
            id,
            input_len,
            output_len,
            prefill_offset: 0,
            resident_len: 0,
            needs_kv_recv: false,
            phase: Phase::Queued,
        }
    }

    /// A request whose first `prefill_offset` prompt tokens were prefilled
    /// on another instance (arrives with a pending KV transfer).
    pub fn with_offset(
        id: ReqId,
        input_len: usize,
        output_len: usize,
        prefill_offset: usize,
    ) -> Self {
        Self::with_prefix_credit(id, input_len, output_len, prefill_offset, 0)
    }

    /// A request whose first `prefill_offset` prompt tokens carry KV from
    /// elsewhere, of which the leading `resident_len` are already in this
    /// engine's pool (session prefix reuse — no transfer, no compute);
    /// only `[resident_len, prefill_offset)` is pulled over the link.
    pub fn with_prefix_credit(
        id: ReqId,
        input_len: usize,
        output_len: usize,
        prefill_offset: usize,
        resident_len: usize,
    ) -> Self {
        assert!(prefill_offset <= input_len);
        assert!(resident_len <= prefill_offset);
        // A fully resident whole prompt would leave the engine nothing
        // to do and nothing to transfer — at least one prompt token must
        // be computed or received (callers cap credit at input_len - 1).
        assert!(resident_len == 0 || resident_len < input_len);
        EngineRequest {
            id,
            input_len,
            output_len,
            prefill_offset,
            resident_len,
            needs_kv_recv: prefill_offset > resident_len,
            phase: Phase::Queued,
        }
    }

    /// KV tokens that must move over the link before this engine can
    /// continue the prefill (the non-resident part of the offset).
    #[inline]
    pub fn transfer_len(&self) -> usize {
        self.prefill_offset - self.resident_len
    }

    /// Prompt tokens this engine still has to prefill.
    #[inline]
    pub fn local_prefill_len(&self) -> usize {
        self.input_len - self.prefill_offset
    }

    /// Prompt tokens this engine has left to prefill right now.
    #[inline]
    pub fn prefill_remaining(&self) -> usize {
        match self.phase {
            Phase::Queued => self.local_prefill_len(),
            Phase::Prefilling { done } => self.local_prefill_len() - done,
            _ => 0,
        }
    }

    /// Context length (tokens with KV present) once `generated` outputs
    /// exist: the whole prompt plus the generated tokens.
    ///
    /// Read once per decode request per planned iteration — the single
    /// hottest accessor in the crate (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn context_len(&self) -> usize {
        match self.phase {
            Phase::Queued => 0,
            Phase::Prefilling { done } => self.prefill_offset + done,
            Phase::Decoding { generated } => self.input_len + generated,
            Phase::Finished => self.input_len + self.output_len,
        }
    }

    #[inline]
    pub fn is_decoding(&self) -> bool {
        matches!(self.phase, Phase::Decoding { .. })
    }

    #[inline]
    pub fn is_prefilling(&self) -> bool {
        matches!(self.phase, Phase::Queued | Phase::Prefilling { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_request_lifecycle_counts() {
        let mut r = EngineRequest::whole(1, 100, 10);
        assert_eq!(r.local_prefill_len(), 100);
        assert_eq!(r.prefill_remaining(), 100);
        r.phase = Phase::Prefilling { done: 60 };
        assert_eq!(r.prefill_remaining(), 40);
        assert_eq!(r.context_len(), 60);
        r.phase = Phase::Decoding { generated: 3 };
        assert_eq!(r.prefill_remaining(), 0);
        assert_eq!(r.context_len(), 103);
    }

    #[test]
    fn offset_request() {
        let r = EngineRequest::with_offset(2, 100, 10, 70);
        assert!(r.needs_kv_recv);
        assert_eq!(r.local_prefill_len(), 30);
        // Full disaggregation: nothing to prefill locally.
        let r = EngineRequest::with_offset(3, 100, 10, 100);
        assert_eq!(r.local_prefill_len(), 0);
        assert!(r.needs_kv_recv);
    }

    #[test]
    fn zero_offset_needs_no_recv() {
        let r = EngineRequest::with_offset(4, 100, 10, 0);
        assert!(!r.needs_kv_recv);
    }

    #[test]
    fn resident_prefix_shrinks_the_transfer() {
        // 70 offset tokens, 30 of them already resident: 40 transfer.
        let r = EngineRequest::with_prefix_credit(6, 100, 10, 70, 30);
        assert!(r.needs_kv_recv);
        assert_eq!(r.transfer_len(), 40);
        assert_eq!(r.local_prefill_len(), 30);
        // Fully resident offset: no transfer at all.
        let r = EngineRequest::with_prefix_credit(7, 100, 10, 30, 30);
        assert!(!r.needs_kv_recv);
        assert_eq!(r.transfer_len(), 0);
        assert_eq!(r.local_prefill_len(), 70);
        // Plain with_offset keeps the old all-transferred semantics.
        let r = EngineRequest::with_offset(8, 100, 10, 70);
        assert_eq!(r.transfer_len(), 70);
    }

    #[test]
    #[should_panic]
    fn resident_larger_than_offset_panics() {
        EngineRequest::with_prefix_credit(9, 100, 10, 50, 51);
    }

    #[test]
    #[should_panic]
    fn offset_larger_than_input_panics() {
        EngineRequest::with_offset(5, 10, 1, 11);
    }
}
