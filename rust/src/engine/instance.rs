//! One GPU's inference engine: queues, KV accounting, iteration planning.
//!
//! # Hot-path design (EXPERIMENTS.md §Perf)
//!
//! The engine is the inner loop of every experiment: `plan_iteration` /
//! `complete_iteration` run millions of times per sweep, so the data
//! layout is chosen to make one steady-state iteration allocation-free
//! and hash-free:
//!
//! * **Slab storage** — live requests sit in a dense `Vec<Slot>` with a
//!   free-list; the `ReqId -> slot` hash map is touched only at `submit`
//!   and on finish, never inside the iteration loop.  Slots (and their
//!   id-map entries) are *evicted when a request finishes*, so a
//!   long-running online engine holds memory proportional to its live
//!   population, not to everything it ever served.
//! * **Phase membership lists** — `running` is split into a decode list
//!   and a prefill list, both ordered by admission sequence (the order
//!   the old single `running` vector had).  Removal is O(1): the slot's
//!   `epoch` is bumped, which invalidates its list entries; stale
//!   entries are compacted away by the next planning pass, which walks
//!   the list anyway.  This replaces the three per-plan
//!   `iter().filter().collect()` scans and both O(n) `retain` calls of
//!   the previous design.
//! * **Incremental statistics** — `n_decode`, `decode_ctx_sum` and
//!   `n_prefilling` are maintained on every phase transition, making
//!   [`EngineInstance::stats`] and the admission headroom check O(1)
//!   (the headroom check used to rescan `running` per admission, making
//!   admission bursts O(n²)).
//! * **Reusable scratch** — [`EngineInstance::plan_iteration_into`] and
//!   [`EngineInstance::complete_iteration_into`] fill caller-owned
//!   buffers whose capacity survives across iterations, so steady-state
//!   planning performs zero heap allocations (verified by the
//!   allocation-counting test in `tests/zero_alloc.rs`; the only
//!   amortized exception is paged-KV block-list doubling as contexts
//!   grow past a power-of-two block count).
//!
//! The refactor is *events-identical*: for any submission schedule the
//! engine emits byte-for-byte the same event stream (order, ids,
//! durations) as the previous implementation — pinned by the lockstep
//! oracle test in `tests/events_golden.rs`.

use std::collections::VecDeque;

use crate::util::fxhash::FxHashMap;

use crate::engine::request::{EngineRequest, Phase, ReqId};
use crate::kvcache::BlockAllocator;
use crate::simgpu::link::LinkSpec;
use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};

/// What one planned iteration contains.  The driver schedules its
/// completion `duration_s` after it starts and then feeds the plan back
/// into [`EngineInstance::complete_iteration`].
///
/// A plan doubles as a *reusable scratch buffer*: pass it to
/// [`EngineInstance::plan_iteration_into`] again after completion and
/// its vectors are refilled in place, retaining capacity — the
/// steady-state zero-allocation path every serving system uses.
///
/// Invariant: a plan handed to `complete_iteration` must have been
/// produced by `plan_iteration`/`plan_iteration_into` on the *same*
/// engine (clones included).  The public vectors are for inspection;
/// hand-editing them desynchronizes the plan's internal slot bindings
/// and completion will panic rather than mis-apply it.
#[derive(Clone, Debug, Default)]
pub struct IterationPlan {
    /// (request, chunk tokens, finishes local prefill?)
    pub prefill_parts: Vec<(ReqId, usize, bool)>,
    /// Requests contributing one decode token each.
    pub decode_ids: Vec<ReqId>,
    /// Requests whose prefix KV is fetched during this iteration
    /// (tokens transferred); replaces their compute (paper Fig. 2).
    pub kv_recv: Vec<(ReqId, usize)>,
    /// The batch shape used for timing (exposed for tests/benches).
    pub shape: IterationShape,
    /// Simulated duration of this iteration.
    pub duration_s: f64,
    // Slot bindings parallel to the public vectors: `complete_iteration`
    // resolves requests by slab index instead of re-probing the id map.
    prefill_slots: Vec<SlotRef>,
    decode_slots: Vec<SlotRef>,
    recv_slots: Vec<SlotRef>,
}

impl IterationPlan {
    /// Reset all buffers, retaining their capacity.
    fn clear(&mut self) {
        self.prefill_parts.clear();
        self.decode_ids.clear();
        self.kv_recv.clear();
        self.shape.prefill.clear();
        self.shape.n_decode = 0;
        self.shape.decode_ctx_sum = 0;
        self.duration_s = 0.0;
        self.prefill_slots.clear();
        self.decode_slots.clear();
        self.recv_slots.clear();
    }
}

/// A plan's reference to a slab slot at a specific membership epoch.
/// Slot identity is stable between plan and completion (submission is
/// the only slot-recycling path and cannot interleave), so completion
/// re-checks the slot's *phase* exactly like the pre-slab
/// implementation re-probed the request map; the recorded epoch
/// additionally guards the prefill/recv paths, where it is equivalent
/// to the phase check.
#[derive(Clone, Copy, Debug, Default)]
struct SlotRef {
    slot: u32,
    epoch: u32,
}

/// A membership entry in the decode or prefill list.  `seq` is the
/// admission sequence number, which totally orders (re-)admissions and
/// reproduces the old `running` vector's order; `epoch` validates the
/// entry against the slot (stale entries are dropped on the next pass).
#[derive(Clone, Copy, Debug)]
struct Member {
    slot: u32,
    epoch: u32,
    seq: u64,
}

/// One occupied (or recycled) slab slot.
#[derive(Clone, Debug)]
struct Slot {
    req: EngineRequest,
    /// Tokens already reported for this request (survives preemption so
    /// recovered requests don't double-report).
    emitted: usize,
    /// Membership epoch: bumped on every list insertion/removal and on
    /// slot recycling, so stale `Member`/`SlotRef` entries never match.
    epoch: u32,
    /// Admission sequence of the current admission (0 while queued
    /// before first admission).
    seq: u64,
    /// Occupied (vs sitting in the free list).
    live: bool,
}

/// Externally visible effects of a completed iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineEvent {
    /// Prefill finished; the request's first output token exists now.
    FirstToken(ReqId),
    /// One more decode token.
    Token(ReqId),
    /// EOS reached; KV freed.
    Finished(ReqId),
    /// Prefix-KV transfer completed (the sending side may free its copy).
    KvReceived(ReqId),
    /// Request was preempted (KV freed, re-queued; it will recompute).
    /// Reserved: currently *never emitted* — recompute-on-resume makes
    /// preemptions externally invisible (the engine only counts them in
    /// `n_preemptions`), and consumers treat this variant as unreachable.
    Preempted(ReqId),
}

/// Snapshot the Cronus Balancer reads (§4.3: "retrieves statistics from
/// the chunked prefill instance").  Maintained incrementally; reading it
/// is O(1).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub n_decode: usize,
    pub decode_ctx_sum: usize,
    pub n_prefilling: usize,
    pub waiting: usize,
    pub free_blocks: usize,
    pub block_size: usize,
    pub total_blocks: usize,
}

/// One GPU's engine.
pub struct EngineInstance {
    pub name: String,
    pm: PerfModel,
    link: LinkSpec,
    max_batched_tokens: usize,
    max_running: usize,
    /// Keyed by slab slot index (dense small integers), not request id.
    kv: BlockAllocator,
    /// Waiting queue of slab slot indices — preemption re-queues at the
    /// front.
    waiting: VecDeque<u32>,
    /// Running decode requests, ordered by admission sequence.
    decode_list: Vec<Member>,
    /// Running prefill requests, ordered by admission sequence.
    prefill_list: Vec<Member>,
    slots: Vec<Slot>,
    free_slots: Vec<u32>,
    /// Request id -> slot; touched only at submit/finish boundaries.
    by_id: FxHashMap<ReqId, u32>,
    // --- incremental statistics (see EngineStats) ---
    n_decode: usize,
    decode_ctx_sum: usize,
    n_prefilling: usize,
    /// Monotone admission counter feeding `Member::seq`.
    admit_counter: u64,
    // --- accounting ---
    pub busy_time_s: f64,
    pub n_iterations: u64,
    pub n_preemptions: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
    /// Of `tokens_prefilled`, context made present by KV *transfers*
    /// rather than local compute — subtract to get the prefill tokens
    /// this engine actually executed.
    pub tokens_kv_received: u64,
}

impl EngineInstance {
    pub fn new(
        name: impl Into<String>,
        pm: PerfModel,
        link: LinkSpec,
        max_batched_tokens: usize,
        max_running: usize,
        block_size: usize,
        kv_capacity_tokens: usize,
    ) -> Self {
        let n_blocks = kv_capacity_tokens / block_size;
        EngineInstance {
            name: name.into(),
            pm,
            link,
            max_batched_tokens,
            max_running,
            kv: BlockAllocator::new(n_blocks, block_size),
            waiting: VecDeque::new(),
            decode_list: Vec::new(),
            prefill_list: Vec::new(),
            slots: Vec::new(),
            free_slots: Vec::new(),
            by_id: FxHashMap::default(),
            n_decode: 0,
            decode_ctx_sum: 0,
            n_prefilling: 0,
            admit_counter: 0,
            busy_time_s: 0.0,
            n_iterations: 0,
            n_preemptions: 0,
            tokens_prefilled: 0,
            tokens_decoded: 0,
            tokens_kv_received: 0,
        }
    }

    /// Build from a deployment's engine params.
    pub fn from_params(
        name: impl Into<String>,
        pm: PerfModel,
        link: LinkSpec,
        params: &crate::config::EngineParams,
        max_batched_tokens: usize,
    ) -> Self {
        let capacity = pm.kv_capacity_tokens(params.activation_reserve_frac);
        EngineInstance::new(
            name,
            pm,
            link,
            max_batched_tokens,
            params.max_running,
            params.block_size,
            capacity,
        )
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }

    pub fn submit(&mut self, req: EngineRequest) {
        debug_assert!(
            !self.by_id.contains_key(&req.id),
            "request {} submitted while still live",
            req.id
        );
        let id = req.id;
        let slot = match self.free_slots.pop() {
            Some(s) => {
                // Recycled slot: the epoch was bumped at retirement, so
                // any stale members pointing here never match.
                let slot = &mut self.slots[s as usize];
                slot.req = req;
                slot.emitted = 0;
                slot.seq = 0;
                slot.live = true;
                s
            }
            None => {
                let s = self.slots.len() as u32;
                self.slots.push(Slot {
                    req,
                    emitted: 0,
                    epoch: 0,
                    seq: 0,
                    live: true,
                });
                s
            }
        };
        self.by_id.insert(id, slot);
        self.waiting.push_back(slot);
    }

    fn n_running(&self) -> usize {
        self.n_decode + self.n_prefilling
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.n_running() > 0
    }

    pub fn n_in_instance(&self) -> usize {
        self.waiting.len() + self.n_running()
    }

    /// Requests currently tracked by the slab (waiting + running).
    /// Finished requests are evicted, so this stays bounded by the live
    /// population on long online runs.
    pub fn n_tracked_requests(&self) -> usize {
        self.by_id.len()
    }

    /// Slab capacity (high-water mark of concurrently live requests).
    pub fn slab_size(&self) -> usize {
        self.slots.len()
    }

    /// O(1): all counters are maintained incrementally on phase
    /// transitions.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            n_decode: self.n_decode,
            decode_ctx_sum: self.decode_ctx_sum,
            n_prefilling: self.n_prefilling,
            waiting: self.waiting.len(),
            free_blocks: self.kv.free_blocks(),
            block_size: self.kv.block_size(),
            total_blocks: self.kv.total_blocks(),
        }
    }

    pub fn kv_allocator(&self) -> &BlockAllocator {
        &self.kv
    }

    /// Plan the next iteration.  Returns `None` when there is nothing to
    /// run (caller goes idle until new work arrives).  Mutates allocator
    /// state (admissions, growth, preemptions) — the plan *will* run.
    ///
    /// Convenience wrapper over [`Self::plan_iteration_into`] that
    /// allocates a fresh plan; hot loops should hold a reusable
    /// [`IterationPlan`] and call the `_into` form instead.
    pub fn plan_iteration(&mut self) -> Option<IterationPlan> {
        let mut plan = IterationPlan::default();
        if self.plan_iteration_into(&mut plan) {
            Some(plan)
        } else {
            None
        }
    }

    /// Plan the next iteration into a caller-owned buffer, retaining its
    /// capacity.  Returns `false` (with `plan` cleared) when there is
    /// nothing to run.  Like the old `plan_iteration() -> None` path,
    /// a `false` return is not a pure no-op: planning may still have
    /// compacted membership lists and — when the KV pool is exhausted —
    /// preempted victims (KV freed, request re-queued) before
    /// discovering that nothing can run.
    pub fn plan_iteration_into(&mut self, plan: &mut IterationPlan) -> bool {
        plan.clear();
        let mut budget = self.max_batched_tokens;

        // 1. Decode-first: every running decode request gets one token.
        //    The pass compacts stale members (preempted/finished since
        //    the last pass) in place while it walks the list.
        let len = self.decode_list.len();
        let mut write = 0usize;
        let mut read = 0usize;
        while read < len {
            if budget == 0 {
                break;
            }
            let m = self.decode_list[read];
            read += 1;
            // A preemption triggered by an earlier decode request in
            // this same pass (or an earlier retirement) bumped the
            // slot's epoch — the entry is stale; drop it.
            if self.slots[m.slot as usize].epoch != m.epoch {
                continue;
            }
            self.decode_list[write] = m;
            write += 1;
            let idx = m.slot as usize;
            let ctx = self.slots[idx].req.context_len();
            // Grow KV coverage for the token this iteration writes.
            let mut covered = true;
            loop {
                match self.kv.grow(m.slot as u64, ctx + 1) {
                    Ok(()) => break,
                    Err(_) => {
                        if let Some(victim) = self.pick_preemption_victim(m.slot) {
                            self.preempt(victim);
                        } else {
                            covered = false; // nothing to evict; skip
                            break;
                        }
                    }
                }
            }
            if !covered {
                continue; // could not grow; try next iteration
            }
            budget -= 1;
            plan.shape.n_decode += 1;
            plan.shape.decode_ctx_sum += ctx;
            plan.decode_ids.push(self.slots[idx].req.id);
            plan.decode_slots.push(SlotRef { slot: m.slot, epoch: m.epoch });
        }
        if read < len {
            // Budget ran out: keep the unvisited tail (stale entries in
            // it are dropped by a later pass).
            self.decode_list.copy_within(read..len, write);
            write += len - read;
        }
        self.decode_list.truncate(write);

        // 2. Fill remaining budget with prefill chunks (head-of-line),
        //    compacting stale members the same way.
        let len = self.prefill_list.len();
        let mut write = 0usize;
        let mut read = 0usize;
        while read < len {
            if budget == 0 {
                break;
            }
            let m = self.prefill_list[read];
            read += 1;
            if self.slots[m.slot as usize].epoch != m.epoch {
                continue;
            }
            self.prefill_list[write] = m;
            write += 1;
            let idx = m.slot as usize;
            let remaining = self.slots[idx].req.prefill_remaining();
            if remaining == 0 {
                continue;
            }
            let chunk = remaining.min(budget);
            let done = match self.slots[idx].req.phase {
                Phase::Prefilling { done } => done,
                _ => 0,
            };
            let ctx_end = self.slots[idx].req.prefill_offset + done + chunk;
            plan.shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end });
            plan.prefill_parts.push((self.slots[idx].req.id, chunk, chunk == remaining));
            plan.prefill_slots.push(SlotRef { slot: m.slot, epoch: m.epoch });
            budget -= chunk;
        }
        if read < len {
            self.prefill_list.copy_within(read..len, write);
            write += len - read;
        }
        self.prefill_list.truncate(write);

        // 3. Admit from the waiting queue.
        while !self.waiting.is_empty() && self.n_running() < self.max_running {
            let slot = *self.waiting.front().unwrap();
            let idx = slot as usize;
            let needs_recv = self.slots[idx].req.needs_kv_recv;
            let local_prefill = self.slots[idx].req.local_prefill_len();
            let input_len = self.slots[idx].req.input_len;
            // Recv-only admissions don't consume token budget; compute
            // admissions need budget for at least one token.
            if !needs_recv && budget == 0 {
                break;
            }
            // Admission watermark: beyond the prompt itself, keep one
            // spare block per running decode request so near-term decode
            // growth doesn't immediately preempt what we just admitted.
            // `n_decode` is maintained incrementally — this check used
            // to rescan `running` per admission.
            let need = self.kv.blocks_for(input_len) + self.n_decode;
            if need > self.kv.free_blocks() {
                break; // head-of-line blocking, as in vLLM
            }
            self.kv
                .allocate(slot as u64, input_len)
                .expect("checked can_allocate");
            self.waiting.pop_front();
            self.admit(slot);
            if needs_recv {
                // First iteration = KV transfer, replacing this request's
                // compute (it contributes nothing else this iteration).
                // Only the non-resident part of the offset crosses the
                // link — a session prefix already in this engine's pool
                // costs neither transfer nor compute.
                let transfer = self.slots[idx].req.transfer_len();
                plan.kv_recv.push((self.slots[idx].req.id, transfer));
                plan.recv_slots.push(SlotRef { slot, epoch: self.slots[idx].epoch });
                self.slots[idx].req.needs_kv_recv = false;
            } else {
                let chunk = local_prefill.min(budget);
                if chunk == 0 {
                    // Zero-length local prefill without recv cannot happen
                    // (resident_len < input => local >= 1), but guard anyway.
                    continue;
                }
                // A fully resident prefix (no transfer) is context the
                // first chunk already attends over.
                let ctx_end = self.slots[idx].req.prefill_offset + chunk;
                plan.shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end });
                plan.prefill_parts.push((
                    self.slots[idx].req.id,
                    chunk,
                    chunk == local_prefill,
                ));
                plan.prefill_slots.push(SlotRef { slot, epoch: self.slots[idx].epoch });
                budget -= chunk;
            }
        }

        if plan.shape.is_empty() && plan.kv_recv.is_empty() {
            return false;
        }

        // 4. Timing: compute time of the batch, overlapped with the
        //    longest KV transfer (Fig. 2: transfers hide behind other
        //    requests' compute; an uncovered remainder extends the
        //    iteration).
        let compute_t = self.pm.iteration_time(&plan.shape);
        let transfer_t = plan
            .kv_recv
            .iter()
            .map(|(_, tokens)| {
                self.link
                    .kv_transfer_time(*tokens, self.pm.model.kv_bytes_per_token())
            })
            .fold(0.0f64, f64::max);
        plan.duration_s = compute_t.max(transfer_t);

        self.n_iterations += 1;
        self.busy_time_s += plan.duration_s;
        true
    }

    /// Apply a completed iteration; returns the externally visible events
    /// (tokens, finishes, completed transfers).
    ///
    /// Convenience wrapper over [`Self::complete_iteration_into`]; hot
    /// loops should reuse an event buffer instead.
    pub fn complete_iteration(&mut self, plan: &IterationPlan) -> Vec<EngineEvent> {
        let mut events = Vec::new();
        self.complete_iteration_into(plan, &mut events);
        events
    }

    /// Apply a completed iteration, writing the externally visible
    /// events into a caller-owned buffer (cleared first, capacity
    /// retained).
    pub fn complete_iteration_into(
        &mut self,
        plan: &IterationPlan,
        events: &mut Vec<EngineEvent>,
    ) {
        events.clear();

        for (k, &(id, tokens)) in plan.kv_recv.iter().enumerate() {
            events.push(EngineEvent::KvReceived(id));
            self.tokens_prefilled += tokens as u64; // context made present
            self.tokens_kv_received += tokens as u64; // ... without compute
            let sr = plan.recv_slots[k];
            debug_assert_eq!(self.slots[sr.slot as usize].epoch, sr.epoch);
            // If nothing remains to prefill locally (full disaggregation),
            // the handoff iteration yields the first token.
            if self.slots[sr.slot as usize].req.local_prefill_len() == 0 {
                self.finish_prefill(sr.slot, events);
            }
        }

        for (k, &(_id, chunk, finishes)) in plan.prefill_parts.iter().enumerate() {
            let sr = plan.prefill_slots[k];
            let idx = sr.slot as usize;
            if self.slots[idx].epoch != sr.epoch || !self.slots[idx].req.is_prefilling() {
                continue; // preempted later in the same planning pass
            }
            let done = match self.slots[idx].req.phase {
                Phase::Prefilling { done } => done,
                _ => 0,
            };
            self.slots[idx].req.phase = Phase::Prefilling { done: done + chunk };
            self.tokens_prefilled += chunk as u64;
            if finishes {
                self.finish_prefill(sr.slot, events);
            }
        }

        for (k, &id) in plan.decode_ids.iter().enumerate() {
            let sr = plan.decode_slots[k];
            let idx = sr.slot as usize;
            // Gate on the slot's *phase*, not its epoch: a request
            // preempted later in the same planning pass is Queued (skip,
            // as before) — but one preempted, re-admitted *and* fully
            // re-prefilled within this very iteration is Decoding again
            // via recovery, and the original engine applies its planned
            // decode step in that case.  Slot identity is stable between
            // plan and complete (submissions are the only slot-recycling
            // path and cannot interleave), so the phase check reproduces
            // the old `reqs.get_mut(id)`-based behaviour exactly.
            if let Phase::Decoding { generated } = self.slots[idx].req.phase {
                let new_gen = generated + 1;
                self.slots[idx].req.phase = Phase::Decoding { generated: new_gen };
                self.decode_ctx_sum += 1; // this request's context grew by one
                self.tokens_decoded += 1;
                if new_gen > self.slots[idx].emitted {
                    self.slots[idx].emitted = new_gen;
                    events.push(EngineEvent::Token(id));
                }
                if new_gen >= self.slots[idx].req.output_len {
                    self.slots[idx].req.phase = Phase::Finished;
                    events.push(EngineEvent::Finished(id));
                    self.n_decode -= 1;
                    self.decode_ctx_sum -= self.slots[idx].req.input_len + new_gen;
                    self.retire(sr.slot);
                }
            }
        }
    }

    /// Transition a request from prefill to decode, emitting its first
    /// token (unless it is recovering from preemption and already did).
    /// The caller guarantees the slot currently counts as prefilling.
    fn finish_prefill(&mut self, slot: u32, events: &mut Vec<EngineEvent>) {
        let idx = slot as usize;
        let id = self.slots[idx].req.id;
        let emitted = self.slots[idx].emitted;
        // Leaving the prefill membership whatever happens next.
        self.n_prefilling -= 1;
        self.slots[idx].epoch = self.slots[idx].epoch.wrapping_add(1);
        if emitted == 0 {
            self.slots[idx].req.phase = Phase::Decoding { generated: 1 };
            events.push(EngineEvent::FirstToken(id));
            self.slots[idx].emitted = 1;
            if self.slots[idx].req.output_len <= 1 {
                self.slots[idx].req.phase = Phase::Finished;
                events.push(EngineEvent::Finished(id));
                self.retire(slot);
            } else {
                self.enter_decode(slot, 1);
            }
        } else {
            // Preemption recovery: resume where the request left off.
            self.slots[idx].req.phase = Phase::Decoding { generated: emitted };
            if emitted >= self.slots[idx].req.output_len {
                self.slots[idx].req.phase = Phase::Finished;
                events.push(EngineEvent::Finished(id));
                self.retire(slot);
            } else {
                self.enter_decode(slot, emitted);
            }
        }
    }

    /// Add a slot to the decode membership, keeping the list ordered by
    /// admission sequence (prefill→decode transitions can complete out
    /// of admission order when KV transfers are in play).
    fn enter_decode(&mut self, slot: u32, generated: usize) {
        let idx = slot as usize;
        self.n_decode += 1;
        self.decode_ctx_sum += self.slots[idx].req.input_len + generated;
        let seq = self.slots[idx].seq;
        let m = Member { slot, epoch: self.slots[idx].epoch, seq };
        let pos = self.decode_list.partition_point(|x| x.seq < seq);
        self.decode_list.insert(pos, m);
    }

    /// Mark the head-of-queue slot admitted: fresh admission sequence,
    /// fresh epoch, prefill membership.
    fn admit(&mut self, slot: u32) {
        let idx = slot as usize;
        self.admit_counter += 1;
        self.slots[idx].seq = self.admit_counter;
        self.slots[idx].epoch = self.slots[idx].epoch.wrapping_add(1);
        self.slots[idx].req.phase = Phase::Prefilling { done: 0 };
        self.n_prefilling += 1;
        self.prefill_list.push(Member {
            slot,
            epoch: self.slots[idx].epoch,
            seq: self.admit_counter,
        });
    }

    /// Drop a finished request: KV freed, id mapping evicted, slot
    /// recycled.  Phase counters are the caller's responsibility (the
    /// request may leave from decode or directly from prefill).
    fn retire(&mut self, slot: u32) {
        let idx = slot as usize;
        let _ = self.kv.release(slot as u64);
        self.slots[idx].epoch = self.slots[idx].epoch.wrapping_add(1);
        self.by_id.remove(&self.slots[idx].req.id);
        self.slots[idx].live = false;
        self.free_slots.push(slot);
    }

    /// Preemption victim: the youngest running request other than
    /// `protect` (vLLM's recompute policy evicts latest-admitted first).
    /// Rare path — only runs when the KV pool is exhausted — so the
    /// reverse scans over possibly-stale tails are fine.
    fn pick_preemption_victim(&self, protect: u32) -> Option<u32> {
        let d = self.last_valid_member(&self.decode_list, protect);
        let p = self.last_valid_member(&self.prefill_list, protect);
        match (d, p) {
            (Some((ds, dslot)), Some((ps, pslot))) => {
                if ds > ps {
                    Some(dslot)
                } else {
                    Some(pslot)
                }
            }
            (Some((_, s)), None) | (None, Some((_, s))) => Some(s),
            (None, None) => None,
        }
    }

    /// Latest-admitted valid member of a list, excluding `protect`.
    fn last_valid_member(&self, list: &[Member], protect: u32) -> Option<(u64, u32)> {
        list.iter()
            .rev()
            .find(|m| {
                m.slot != protect && self.slots[m.slot as usize].epoch == m.epoch
            })
            .map(|m| (m.seq, m.slot))
    }

    fn preempt(&mut self, slot: u32) {
        self.n_preemptions += 1;
        let idx = slot as usize;
        match self.slots[idx].req.phase {
            Phase::Decoding { generated } => {
                self.n_decode -= 1;
                self.decode_ctx_sum -= self.slots[idx].req.input_len + generated;
            }
            _ => {
                self.n_prefilling -= 1;
            }
        }
        let _ = self.kv.release(slot as u64);
        // Invalidate the membership entry (compacted away by the next
        // planning pass) instead of an O(n) `retain`.
        self.slots[idx].epoch = self.slots[idx].epoch.wrapping_add(1);
        // Recompute everything locally on resume: the engine holds the
        // full model + prompt, so a lost transferred (or resident)
        // prefix is rebuilt.
        self.slots[idx].req.prefill_offset = 0;
        self.slots[idx].req.resident_len = 0;
        self.slots[idx].req.needs_kv_recv = false;
        self.slots[idx].req.phase = Phase::Queued;
        self.waiting.push_front(slot);
    }

    /// Consistency checks for property tests: membership lists, slab
    /// occupancy, id map, KV holdings and the incremental statistics all
    /// have to agree with one another.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        let mut seen = vec![false; self.slots.len()];
        let mut n_dec = 0usize;
        let mut ctx_sum = 0usize;
        let mut n_pre = 0usize;

        let mut last_seq = 0u64;
        for m in &self.decode_list {
            if m.seq < last_seq {
                return Err("decode list out of admission order".into());
            }
            last_seq = m.seq;
            let slot = &self.slots[m.slot as usize];
            if slot.epoch != m.epoch {
                continue; // stale entry awaiting compaction
            }
            if seen[m.slot as usize] {
                return Err(format!("slot {} in two memberships", m.slot));
            }
            seen[m.slot as usize] = true;
            if !slot.live {
                return Err(format!("decode member for dead slot {}", m.slot));
            }
            match slot.req.phase {
                Phase::Decoding { generated } => {
                    n_dec += 1;
                    ctx_sum += slot.req.input_len + generated;
                }
                other => {
                    return Err(format!(
                        "decode member {} in phase {other:?}",
                        slot.req.id
                    ))
                }
            }
            if !self.kv.holds(m.slot as u64) {
                return Err(format!("running request {} without KV", slot.req.id));
            }
        }

        let mut last_seq = 0u64;
        for m in &self.prefill_list {
            if m.seq < last_seq {
                return Err("prefill list out of admission order".into());
            }
            last_seq = m.seq;
            let slot = &self.slots[m.slot as usize];
            if slot.epoch != m.epoch {
                continue;
            }
            if seen[m.slot as usize] {
                return Err(format!("slot {} in two memberships", m.slot));
            }
            seen[m.slot as usize] = true;
            if !slot.live {
                return Err(format!("prefill member for dead slot {}", m.slot));
            }
            if !matches!(slot.req.phase, Phase::Prefilling { .. }) {
                return Err(format!(
                    "prefill member {} in phase {:?}",
                    slot.req.id, slot.req.phase
                ));
            }
            n_pre += 1;
            if !self.kv.holds(m.slot as u64) {
                return Err(format!("running request {} without KV", slot.req.id));
            }
        }

        for &w in &self.waiting {
            let slot = &self.slots[w as usize];
            if !slot.live {
                return Err(format!("waiting entry for dead slot {w}"));
            }
            if seen[w as usize] {
                return Err(format!("waiting slot {w} also running"));
            }
            seen[w as usize] = true;
            if !matches!(slot.req.phase, Phase::Queued) {
                return Err(format!(
                    "waiting request {} in phase {:?}",
                    slot.req.id, slot.req.phase
                ));
            }
            if self.kv.holds(w as u64) {
                return Err(format!("waiting request {} holds KV", slot.req.id));
            }
        }

        if n_dec != self.n_decode
            || ctx_sum != self.decode_ctx_sum
            || n_pre != self.n_prefilling
        {
            return Err(format!(
                "incremental stats drift: decode {}/{} ctx {}/{} prefill {}/{}",
                self.n_decode, n_dec, self.decode_ctx_sum, ctx_sum, self.n_prefilling, n_pre
            ));
        }

        let live = self.slots.iter().filter(|s| s.live).count();
        if live != n_dec + n_pre + self.waiting.len() {
            return Err(format!(
                "live slot count {live} != members {} + waiting {}",
                n_dec + n_pre,
                self.waiting.len()
            ));
        }
        if self.by_id.len() != live {
            return Err(format!(
                "id map size {} != live slots {live}",
                self.by_id.len()
            ));
        }
        for (&id, &slot) in &self.by_id {
            let s = self
                .slots
                .get(slot as usize)
                .ok_or_else(|| format!("id {id} maps to bad slot {slot}"))?;
            if !s.live || s.req.id != id {
                return Err(format!("id {id} maps to slot {slot} holding {}", s.req.id));
            }
        }
        for &f in &self.free_slots {
            let s = self
                .slots
                .get(f as usize)
                .ok_or_else(|| format!("free slot {f} out of range"))?;
            if s.live {
                return Err(format!("free slot {f} is live"));
            }
        }
        if self.free_slots.len() + live != self.slots.len() {
            return Err("slab accounting drift (free + live != slots)".into());
        }
        Ok(())
    }

    /// Look up a *live* (waiting or running) request; finished requests
    /// are evicted and return `None`.
    pub fn request(&self, id: ReqId) -> Option<&EngineRequest> {
        self.by_id.get(&id).map(|&s| &self.slots[s as usize].req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineParams;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::A100;

    fn engine(max_tokens: usize, kv_tokens: usize) -> EngineInstance {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        EngineInstance::new(
            "test",
            pm,
            LinkSpec::INFINIBAND_100G,
            max_tokens,
            256,
            16,
            kv_tokens,
        )
    }

    /// Drive the engine to completion, returning all events in order.
    fn run_to_completion(e: &mut EngineInstance) -> Vec<EngineEvent> {
        let mut all = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 100_000, "engine did not converge");
            match e.plan_iteration() {
                Some(plan) => all.extend(e.complete_iteration(&plan)),
                None => break,
            }
            e.check_invariants().unwrap();
        }
        all
    }

    #[test]
    fn single_request_token_count() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 1000, 5));
        let events = run_to_completion(&mut e);
        let first = events.iter().filter(|e| matches!(e, EngineEvent::FirstToken(_))).count();
        let tokens = events.iter().filter(|e| matches!(e, EngineEvent::Token(_))).count();
        let fin = events.iter().filter(|e| matches!(e, EngineEvent::Finished(_))).count();
        assert_eq!(first, 1);
        assert_eq!(tokens, 4); // 5 outputs = 1 first + 4 decode
        assert_eq!(fin, 1);
        // 1000 prefill tokens at 512/iter = 2 prefill iterations + 4 decode.
        assert_eq!(e.n_iterations, 2 + 4);
        assert_eq!(e.kv_allocator().n_requests(), 0, "KV leaked");
    }

    #[test]
    fn prefill_chunking_respects_budget() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 1300, 1));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.prefill_parts, vec![(1, 512, false)]);
        e.complete_iteration(&p1);
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.prefill_parts, vec![(1, 512, false)]);
        e.complete_iteration(&p2);
        let p3 = e.plan_iteration().unwrap();
        assert_eq!(p3.prefill_parts, vec![(1, 276, true)]);
        // Context of the last chunk ends at the full prompt.
        assert_eq!(p3.shape.prefill[0].ctx_end, 1300);
    }

    #[test]
    fn decode_piggybacks_with_prefill() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 400, 10));
        let p = e.plan_iteration().unwrap();
        e.complete_iteration(&p); // request 1 now decoding
        e.submit(EngineRequest::whole(2, 600, 10));
        let p = e.plan_iteration().unwrap();
        assert_eq!(p.decode_ids, vec![1]);
        // Remaining budget 511 goes to request 2's prefill.
        assert_eq!(p.prefill_parts, vec![(2, 511, false)]);
        assert_eq!(p.shape.n_decode, 1);
    }

    #[test]
    fn offset_request_transfers_then_prefills() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_offset(1, 1000, 3, 700));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.kv_recv, vec![(1, 700)]);
        assert!(p1.prefill_parts.is_empty(), "transfer replaces compute");
        assert!(p1.duration_s > 0.0);
        let ev = e.complete_iteration(&p1);
        assert_eq!(ev, vec![EngineEvent::KvReceived(1)]);
        // Next iteration prefills the remaining 300 with full context.
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.prefill_parts, vec![(1, 300, true)]);
        assert_eq!(p2.shape.prefill[0].ctx_end, 1000);
        let ev = e.complete_iteration(&p2);
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
    }

    #[test]
    fn resident_prefix_skips_transfer_and_compute() {
        // 1000-token prompt, offset 700 of which 300 are session-resident:
        // only 400 cross the link, and executed prefill excludes both the
        // transfer and the resident prefix.
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_prefix_credit(1, 1000, 3, 700, 300));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.kv_recv, vec![(1, 400)]);
        let ev = e.complete_iteration(&p1);
        assert_eq!(ev, vec![EngineEvent::KvReceived(1)]);
        assert_eq!(e.tokens_kv_received, 400);
        // Remaining local prefill (300) with full-context attention.
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.prefill_parts, vec![(1, 300, true)]);
        assert_eq!(p2.shape.prefill[0].ctx_end, 1000);
        let ev = e.complete_iteration(&p2);
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
        // tokens_prefilled = transfer (400) + local (300); executed
        // compute = 300; the 300 resident tokens cost nothing.
        assert_eq!(e.tokens_prefilled, 700);
        assert_eq!(e.tokens_prefilled - e.tokens_kv_received, 300);
        run_to_completion(&mut e);
    }

    #[test]
    fn fully_resident_offset_needs_no_transfer_iteration() {
        // Offset entirely resident: no KvReceived, the first iteration
        // goes straight to local prefill of the fresh suffix.
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_prefix_credit(1, 800, 2, 500, 500));
        let p1 = e.plan_iteration().unwrap();
        assert!(p1.kv_recv.is_empty());
        assert_eq!(p1.prefill_parts, vec![(1, 300, true)]);
        assert_eq!(p1.shape.prefill[0].ctx_end, 800);
        let ev = e.complete_iteration(&p1);
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
        assert_eq!(e.tokens_kv_received, 0);
        assert_eq!(e.tokens_prefilled, 300);
        run_to_completion(&mut e);
        assert_eq!(e.kv_allocator().n_requests(), 0, "KV leaked");
    }

    #[test]
    fn full_disagg_offset_first_token_after_transfer() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_offset(1, 1000, 2, 1000));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.kv_recv, vec![(1, 1000)]);
        let ev = e.complete_iteration(&p1);
        assert!(ev.contains(&EngineEvent::KvReceived(1)));
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
        // Decode continues normally.
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.decode_ids, vec![1]);
        let ev = e.complete_iteration(&p2);
        assert!(ev.contains(&EngineEvent::Finished(1)));
    }

    #[test]
    fn transfer_overlaps_with_compute() {
        let mut e = engine(512, 200_000);
        // Build a big decode population first.
        for i in 0..64 {
            e.submit(EngineRequest::whole(i, 512, 50));
        }
        // Drain the waiting queue so the recv request is head-of-line.
        while e.stats().waiting > 0 || e.stats().n_prefilling > 0 {
            let p = e.plan_iteration().unwrap();
            e.complete_iteration(&p);
        }
        let stats = e.stats();
        assert!(stats.n_decode > 0);
        // Now a transfer arrives; iteration time must be the max of
        // compute and transfer, not their sum.
        e.submit(EngineRequest::with_offset(1000, 800, 5, 800));
        let p = e.plan_iteration().unwrap();
        assert!(!p.kv_recv.is_empty());
        let compute = e.perf_model().iteration_time(&p.shape);
        let transfer = LinkSpec::INFINIBAND_100G
            .kv_transfer_time(800, LLAMA3_8B.kv_bytes_per_token());
        assert!((p.duration_s - compute.max(transfer)).abs() < 1e-12);
    }

    #[test]
    fn admission_blocks_without_kv() {
        // Pool fits only ~62 tokens -> a 100-token prompt never admits.
        let mut e = engine(512, 64);
        e.submit(EngineRequest::whole(1, 100, 2));
        assert!(e.plan_iteration().is_none());
        // A small one admits fine behind it? No — head-of-line blocking.
        e.submit(EngineRequest::whole(2, 32, 2));
        assert!(e.plan_iteration().is_none());
    }

    #[test]
    fn preemption_on_decode_growth() {
        // Tiny pool: two requests fit during prefill, but decode growth
        // must preempt the younger one.
        let mut e = engine(512, 512 + 64);
        e.submit(EngineRequest::whole(1, 256, 200));
        e.submit(EngineRequest::whole(2, 256, 200));
        let mut preemptions = 0;
        let mut finished = 0;
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 10_000);
            let Some(plan) = e.plan_iteration() else { break };
            for ev in e.complete_iteration(&plan) {
                if let EngineEvent::Finished(_) = ev {
                    finished += 1;
                }
            }
            preemptions = e.n_preemptions;
            e.check_invariants().unwrap();
        }
        assert_eq!(finished, 2, "both requests must eventually finish");
        assert!(preemptions > 0, "expected decode-growth preemption");
    }

    #[test]
    fn preempted_request_does_not_double_report() {
        let mut e = engine(512, 512 + 64);
        e.submit(EngineRequest::whole(1, 256, 200));
        e.submit(EngineRequest::whole(2, 256, 200));
        let events = run_to_completion(&mut e);
        for id in [1u64, 2u64] {
            let first: usize = events
                .iter()
                .filter(|ev| **ev == EngineEvent::FirstToken(id))
                .count();
            let tokens: usize =
                events.iter().filter(|ev| **ev == EngineEvent::Token(id)).count();
            assert_eq!(first, 1, "req {id} first-token count");
            assert_eq!(tokens, 199, "req {id} token count");
        }
    }

    #[test]
    fn stats_reflect_queues() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 400, 10));
        e.submit(EngineRequest::whole(2, 10_000, 10)); // waits (budget)
        let s = e.stats();
        assert_eq!(s.waiting, 2);
        let p = e.plan_iteration().unwrap();
        e.complete_iteration(&p);
        let s = e.stats();
        assert_eq!(s.n_decode, 1);
        assert!(s.decode_ctx_sum >= 400);
        assert_eq!(s.block_size, 16);
    }

    #[test]
    fn from_params_uses_capacity() {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let e = EngineInstance::from_params(
            "cap",
            pm,
            LinkSpec::INFINIBAND_100G,
            &EngineParams::default(),
            512,
        );
        // ~500k tokens / 16 per block.
        assert!(e.kv_allocator().total_blocks() > 20_000);
    }

    #[test]
    fn many_requests_all_finish() {
        let mut e = engine(512, 300_000);
        for i in 0..100 {
            e.submit(EngineRequest::whole(i, 100 + (i as usize * 37) % 900, 1 + (i as usize % 40)));
        }
        let events = run_to_completion(&mut e);
        let fin = events.iter().filter(|e| matches!(e, EngineEvent::Finished(_))).count();
        assert_eq!(fin, 100);
        assert_eq!(e.kv_allocator().used_blocks(), 0);
    }

    #[test]
    fn finished_requests_are_evicted() {
        // The slab must not grow with the number of requests *served* —
        // only with the number concurrently live (the unbounded-memory
        // fix this PR ships: `reqs`/`emitted` used to be retained
        // forever).
        let mut e = engine(512, 300_000);
        for wave in 0..20u64 {
            for i in 0..50u64 {
                e.submit(EngineRequest::whole(wave * 50 + i, 200, 5));
            }
            assert_eq!(e.n_tracked_requests(), 50);
            run_to_completion(&mut e);
            assert_eq!(e.n_tracked_requests(), 0, "finished requests leaked");
            assert_eq!(e.kv_allocator().n_requests(), 0);
        }
        // 1000 requests served, but the slab only ever held one wave.
        assert!(
            e.slab_size() <= 50,
            "slab grew to {} slots for 50 concurrent requests",
            e.slab_size()
        );
    }

    #[test]
    fn resubmission_after_finish_is_allowed() {
        // Eviction on finish means an id can be reused once its first
        // lifetime ended (online frontends recycle nothing, but the
        // engine no longer keeps ghosts around to collide with).
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(7, 100, 2));
        run_to_completion(&mut e);
        assert!(e.request(7).is_none(), "finished request still tracked");
        e.submit(EngineRequest::whole(7, 100, 2));
        let events = run_to_completion(&mut e);
        let fin = events.iter().filter(|e| matches!(e, EngineEvent::Finished(_))).count();
        assert_eq!(fin, 1);
    }

    #[test]
    fn plan_scratch_retains_capacity() {
        // The `_into` APIs must reuse the caller's buffers: after the
        // first refill, capacities never shrink and never need to grow
        // again in steady state.
        let mut e = engine(512, 400_000);
        for i in 0..64 {
            e.submit(EngineRequest::whole(i, 512, 10_000));
        }
        let mut plan = IterationPlan::default();
        let mut events = Vec::new();
        // ~2 iterations per admission: 200 warmup iterations put all 64
        // requests into steady decode.
        for _ in 0..200 {
            assert!(e.plan_iteration_into(&mut plan));
            e.complete_iteration_into(&plan, &mut events);
        }
        let cap = plan.decode_ids.capacity();
        assert!(cap >= 64, "decode scratch never warmed: {cap}");
        for _ in 0..50 {
            assert!(e.plan_iteration_into(&mut plan));
            e.complete_iteration_into(&plan, &mut events);
        }
        assert_eq!(plan.decode_ids.capacity(), cap, "scratch was reallocated");
        assert_eq!(plan.decode_ids.len(), 64);
    }

    #[test]
    fn incremental_stats_match_recomputation() {
        // Randomized-ish mixed workload: after every step the O(1)
        // counters must equal a from-scratch recomputation (also wired
        // into check_invariants, asserted here explicitly).
        let mut e = engine(256, 8_000);
        for i in 0..24u64 {
            let input = 50 + (i as usize * 131) % 900;
            let output = 1 + (i as usize * 17) % 60;
            let offset = if i % 3 == 0 {
                (25 + (i as usize * 67) % 500).min(input)
            } else {
                0
            };
            e.submit(EngineRequest::with_offset(i, input, output, offset));
        }
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 100_000);
            let Some(plan) = e.plan_iteration() else { break };
            e.complete_iteration(&plan);
            e.check_invariants().unwrap();
            let s = e.stats();
            assert_eq!(s.n_decode + s.n_prefilling + s.waiting, e.n_in_instance());
        }
        let s = e.stats();
        assert_eq!(s.n_decode, 0);
        assert_eq!(s.decode_ctx_sum, 0);
        assert_eq!(s.n_prefilling, 0);
        assert_eq!(s.waiting, 0);
    }
}
