//! One GPU's inference engine: queues, KV accounting, iteration planning.

use std::collections::VecDeque;

use crate::util::fxhash::FxHashMap;

use crate::engine::request::{EngineRequest, Phase, ReqId};
use crate::kvcache::BlockAllocator;
use crate::simgpu::link::LinkSpec;
use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};

/// What one planned iteration contains.  The driver schedules its
/// completion `duration_s` after it starts and then feeds the plan back
/// into [`EngineInstance::complete_iteration`].
#[derive(Clone, Debug)]
pub struct IterationPlan {
    /// (request, chunk tokens, finishes local prefill?)
    pub prefill_parts: Vec<(ReqId, usize, bool)>,
    /// Requests contributing one decode token each.
    pub decode_ids: Vec<ReqId>,
    /// Requests whose prefix KV is fetched during this iteration
    /// (tokens transferred); replaces their compute (paper Fig. 2).
    pub kv_recv: Vec<(ReqId, usize)>,
    /// The batch shape used for timing (exposed for tests/benches).
    pub shape: IterationShape,
    /// Simulated duration of this iteration.
    pub duration_s: f64,
}

/// Externally visible effects of a completed iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineEvent {
    /// Prefill finished; the request's first output token exists now.
    FirstToken(ReqId),
    /// One more decode token.
    Token(ReqId),
    /// EOS reached; KV freed.
    Finished(ReqId),
    /// Prefix-KV transfer completed (the sending side may free its copy).
    KvReceived(ReqId),
    /// Request was preempted (KV freed, re-queued; it will recompute).
    Preempted(ReqId),
}

/// Snapshot the Cronus Balancer reads (§4.3: "retrieves statistics from
/// the chunked prefill instance").
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub n_decode: usize,
    pub decode_ctx_sum: usize,
    pub n_prefilling: usize,
    pub waiting: usize,
    pub free_blocks: usize,
    pub block_size: usize,
    pub total_blocks: usize,
}

/// One GPU's engine.
pub struct EngineInstance {
    pub name: String,
    pm: PerfModel,
    link: LinkSpec,
    max_batched_tokens: usize,
    max_running: usize,
    kv: BlockAllocator,
    waiting: VecDeque<ReqId>,
    /// Admission order (oldest first) — preemption evicts from the back.
    running: Vec<ReqId>,
    reqs: FxHashMap<ReqId, EngineRequest>,
    /// Tokens already reported per request (survives preemption so
    /// recovered requests don't double-report).
    emitted: FxHashMap<ReqId, usize>,
    // --- accounting ---
    pub busy_time_s: f64,
    pub n_iterations: u64,
    pub n_preemptions: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
}

impl EngineInstance {
    pub fn new(
        name: impl Into<String>,
        pm: PerfModel,
        link: LinkSpec,
        max_batched_tokens: usize,
        max_running: usize,
        block_size: usize,
        kv_capacity_tokens: usize,
    ) -> Self {
        let n_blocks = kv_capacity_tokens / block_size;
        EngineInstance {
            name: name.into(),
            pm,
            link,
            max_batched_tokens,
            max_running,
            kv: BlockAllocator::new(n_blocks, block_size),
            waiting: VecDeque::new(),
            running: Vec::new(),
            reqs: FxHashMap::default(),
            emitted: FxHashMap::default(),
            busy_time_s: 0.0,
            n_iterations: 0,
            n_preemptions: 0,
            tokens_prefilled: 0,
            tokens_decoded: 0,
        }
    }

    /// Build from a deployment's engine params.
    pub fn from_params(
        name: impl Into<String>,
        pm: PerfModel,
        link: LinkSpec,
        params: &crate::config::EngineParams,
        max_batched_tokens: usize,
    ) -> Self {
        let capacity = pm.kv_capacity_tokens(params.activation_reserve_frac);
        EngineInstance::new(
            name,
            pm,
            link,
            max_batched_tokens,
            params.max_running,
            params.block_size,
            capacity,
        )
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }

    pub fn submit(&mut self, req: EngineRequest) {
        debug_assert!(!self.reqs.contains_key(&req.id));
        self.waiting.push_back(req.id);
        self.emitted.entry(req.id).or_insert(0);
        self.reqs.insert(req.id, req);
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    pub fn n_in_instance(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn stats(&self) -> EngineStats {
        let mut n_decode = 0;
        let mut decode_ctx_sum = 0;
        let mut n_prefilling = 0;
        for id in &self.running {
            let r = &self.reqs[id];
            if r.is_decoding() {
                n_decode += 1;
                decode_ctx_sum += r.context_len();
            } else {
                n_prefilling += 1;
            }
        }
        EngineStats {
            n_decode,
            decode_ctx_sum,
            n_prefilling,
            waiting: self.waiting.len(),
            free_blocks: self.kv.free_blocks(),
            block_size: self.kv.block_size(),
            total_blocks: self.kv.total_blocks(),
        }
    }

    pub fn kv_allocator(&self) -> &BlockAllocator {
        &self.kv
    }

    /// Plan the next iteration.  Returns `None` when there is nothing to
    /// run (caller goes idle until new work arrives).  Mutates allocator
    /// state (admissions, growth, preemptions) — the plan *will* run.
    pub fn plan_iteration(&mut self) -> Option<IterationPlan> {
        let mut events_preempt: Vec<ReqId> = Vec::new();
        let mut budget = self.max_batched_tokens;
        let mut shape = IterationShape::default();
        let mut prefill_parts = Vec::new();
        let mut decode_ids = Vec::new();
        let mut kv_recv = Vec::new();

        // 1. Decode-first: every running decode request gets one token.
        let decoding: Vec<ReqId> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.reqs[id].is_decoding())
            .collect();
        for id in decoding {
            if budget == 0 {
                break;
            }
            // A preemption triggered by an earlier decode request in this
            // same pass may have evicted this one — skip it.  (Preemption
            // resets the phase to Queued, so the phase check suffices; an
            // earlier `running.contains` scan here made planning O(n²) —
            // see EXPERIMENTS.md §Perf.)
            if !self.reqs[&id].is_decoding() {
                continue;
            }
            let ctx = self.reqs[&id].context_len();
            // Grow KV coverage for the token this iteration writes.
            loop {
                match self.kv.grow(id, ctx + 1) {
                    Ok(()) => break,
                    Err(_) => {
                        if let Some(victim) = self.pick_preemption_victim(id) {
                            self.preempt(victim);
                            events_preempt.push(victim);
                        } else {
                            break; // nothing to evict; skip this decode
                        }
                    }
                }
            }
            if self.kv.tokens_of(id).map(|t| t >= ctx + 1) != Some(true) {
                continue; // could not grow; try next iteration
            }
            budget -= 1;
            shape.n_decode += 1;
            shape.decode_ctx_sum += ctx;
            decode_ids.push(id);
        }

        // 2. Fill remaining budget with prefill chunks (head-of-line).
        //    (A preempted request may appear in `running` no longer —
        //    filter against current membership.)
        let prefilling: Vec<ReqId> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.reqs[id].is_prefilling())
            .collect();
        for id in prefilling {
            if budget == 0 {
                break;
            }
            let r = &self.reqs[&id];
            let remaining = r.prefill_remaining();
            if remaining == 0 {
                continue;
            }
            let chunk = remaining.min(budget);
            let done = match r.phase {
                Phase::Prefilling { done } => done,
                _ => 0,
            };
            let ctx_end = r.prefill_offset + done + chunk;
            shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end });
            prefill_parts.push((id, chunk, chunk == remaining));
            budget -= chunk;
        }

        // 3. Admit from the waiting queue.
        while !self.waiting.is_empty() && self.running.len() < self.max_running {
            let id = *self.waiting.front().unwrap();
            let r = &self.reqs[&id];
            let needs_recv = r.needs_kv_recv;
            let local_prefill = r.local_prefill_len();
            // Recv-only admissions don't consume token budget; compute
            // admissions need budget for at least one token.
            if !needs_recv && budget == 0 {
                break;
            }
            // Admission watermark: beyond the prompt itself, keep one
            // spare block per running decode request so near-term decode
            // growth doesn't immediately preempt what we just admitted.
            let headroom_blocks = self
                .running
                .iter()
                .filter(|id| self.reqs[id].is_decoding())
                .count();
            let need = self.kv.blocks_for(r.input_len) + headroom_blocks;
            if need > self.kv.free_blocks() {
                break; // head-of-line blocking, as in vLLM
            }
            self.kv.allocate(id, r.input_len).expect("checked can_allocate");
            self.waiting.pop_front();
            self.running.push(id);
            let r = self.reqs.get_mut(&id).unwrap();
            r.phase = Phase::Prefilling { done: 0 };
            if needs_recv {
                // First iteration = KV transfer, replacing this request's
                // compute (it contributes nothing else this iteration).
                kv_recv.push((id, r.prefill_offset));
                r.needs_kv_recv = false;
            } else {
                let chunk = local_prefill.min(budget);
                if chunk == 0 {
                    // Zero-length local prefill without recv cannot happen
                    // (offset 0 => local == input >= 1), but guard anyway.
                    continue;
                }
                shape.prefill.push(PrefillSeg { q_tokens: chunk, ctx_end: chunk });
                prefill_parts.push((id, chunk, chunk == local_prefill));
                budget -= chunk;
            }
        }

        if shape.is_empty() && kv_recv.is_empty() {
            return None;
        }

        // 4. Timing: compute time of the batch, overlapped with the
        //    longest KV transfer (Fig. 2: transfers hide behind other
        //    requests' compute; an uncovered remainder extends the
        //    iteration).
        let compute_t = self.pm.iteration_time(&shape);
        let transfer_t = kv_recv
            .iter()
            .map(|(_, tokens)| {
                self.link
                    .kv_transfer_time(*tokens, self.pm.model.kv_bytes_per_token())
            })
            .fold(0.0f64, f64::max);
        let duration_s = compute_t.max(transfer_t);

        self.n_iterations += 1;
        self.busy_time_s += duration_s;

        Some(IterationPlan { prefill_parts, decode_ids, kv_recv, shape, duration_s })
    }

    /// Apply a completed iteration; returns the externally visible events
    /// (tokens, finishes, completed transfers).  Preemptions performed at
    /// planning time are reported here too via the internal queue.
    pub fn complete_iteration(&mut self, plan: &IterationPlan) -> Vec<EngineEvent> {
        let mut events = Vec::new();

        for (id, tokens) in &plan.kv_recv {
            events.push(EngineEvent::KvReceived(*id));
            self.tokens_prefilled += *tokens as u64; // context made present
            // If nothing remains to prefill locally (full disaggregation),
            // the handoff iteration yields the first token.
            let r = self.reqs.get_mut(id).unwrap();
            if r.local_prefill_len() == 0 {
                self.finish_prefill(*id, &mut events);
            }
        }

        for (id, chunk, finishes) in &plan.prefill_parts {
            let r = match self.reqs.get_mut(id) {
                Some(r) if r.is_prefilling() => r,
                _ => continue, // preempted later in the same planning pass
            };
            let done = match r.phase {
                Phase::Prefilling { done } => done,
                _ => 0,
            };
            r.phase = Phase::Prefilling { done: done + chunk };
            self.tokens_prefilled += *chunk as u64;
            if *finishes {
                self.finish_prefill(*id, &mut events);
            }
        }

        for id in &plan.decode_ids {
            let r = match self.reqs.get_mut(id) {
                Some(r) if r.is_decoding() => r,
                _ => continue,
            };
            if let Phase::Decoding { generated } = r.phase {
                let new_gen = generated + 1;
                r.phase = Phase::Decoding { generated: new_gen };
                self.tokens_decoded += 1;
                let emitted = self.emitted.get_mut(id).unwrap();
                if new_gen > *emitted {
                    *emitted = new_gen;
                    events.push(EngineEvent::Token(*id));
                }
                if new_gen >= r.output_len {
                    r.phase = Phase::Finished;
                    events.push(EngineEvent::Finished(*id));
                    self.retire(*id);
                }
            }
        }

        events
    }

    /// Transition a request from prefill to decode, emitting its first
    /// token (unless it is recovering from preemption and already did).
    fn finish_prefill(&mut self, id: ReqId, events: &mut Vec<EngineEvent>) {
        let emitted = *self.emitted.get(&id).unwrap_or(&0);
        let r = self.reqs.get_mut(&id).unwrap();
        if emitted == 0 {
            r.phase = Phase::Decoding { generated: 1 };
            events.push(EngineEvent::FirstToken(id));
            *self.emitted.get_mut(&id).unwrap() = 1;
            if r.output_len <= 1 {
                r.phase = Phase::Finished;
                events.push(EngineEvent::Finished(id));
                self.retire(id);
            }
        } else {
            // Preemption recovery: resume where the request left off.
            r.phase = Phase::Decoding { generated: emitted };
            if emitted >= r.output_len {
                r.phase = Phase::Finished;
                events.push(EngineEvent::Finished(id));
                self.retire(id);
            }
        }
    }

    fn retire(&mut self, id: ReqId) {
        self.running.retain(|x| *x != id);
        let _ = self.kv.release(id);
    }

    /// Preemption victim: the youngest running request other than
    /// `protect` (vLLM's recompute policy evicts latest-admitted first).
    fn pick_preemption_victim(&self, protect: ReqId) -> Option<ReqId> {
        self.running.iter().rev().copied().find(|id| *id != protect)
    }

    fn preempt(&mut self, id: ReqId) {
        self.n_preemptions += 1;
        let _ = self.kv.release(id);
        self.running.retain(|x| *x != id);
        let r = self.reqs.get_mut(&id).unwrap();
        // Recompute everything locally on resume: the engine holds the
        // full model + prompt, so a lost transferred prefix is rebuilt.
        r.prefill_offset = 0;
        r.needs_kv_recv = false;
        r.phase = Phase::Queued;
        self.waiting.push_front(id);
    }

    /// Consistency checks for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        for id in &self.running {
            let r = self.reqs.get(id).ok_or("running id without record")?;
            if matches!(r.phase, Phase::Queued | Phase::Finished) {
                return Err(format!("running request {id} in phase {:?}", r.phase));
            }
            if !self.kv.holds(*id) {
                return Err(format!("running request {id} without KV"));
            }
        }
        for id in &self.waiting {
            let r = self.reqs.get(id).ok_or("waiting id without record")?;
            if !matches!(r.phase, Phase::Queued) {
                return Err(format!("waiting request {id} in phase {:?}", r.phase));
            }
            if self.kv.holds(*id) {
                return Err(format!("waiting request {id} holds KV"));
            }
        }
        Ok(())
    }

    pub fn request(&self, id: ReqId) -> Option<&EngineRequest> {
        self.reqs.get(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineParams;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::A100;

    fn engine(max_tokens: usize, kv_tokens: usize) -> EngineInstance {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        EngineInstance::new(
            "test",
            pm,
            LinkSpec::INFINIBAND_100G,
            max_tokens,
            256,
            16,
            kv_tokens,
        )
    }

    /// Drive the engine to completion, returning all events in order.
    fn run_to_completion(e: &mut EngineInstance) -> Vec<EngineEvent> {
        let mut all = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 100_000, "engine did not converge");
            match e.plan_iteration() {
                Some(plan) => all.extend(e.complete_iteration(&plan)),
                None => break,
            }
            e.check_invariants().unwrap();
        }
        all
    }

    #[test]
    fn single_request_token_count() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 1000, 5));
        let events = run_to_completion(&mut e);
        let first = events.iter().filter(|e| matches!(e, EngineEvent::FirstToken(_))).count();
        let tokens = events.iter().filter(|e| matches!(e, EngineEvent::Token(_))).count();
        let fin = events.iter().filter(|e| matches!(e, EngineEvent::Finished(_))).count();
        assert_eq!(first, 1);
        assert_eq!(tokens, 4); // 5 outputs = 1 first + 4 decode
        assert_eq!(fin, 1);
        // 1000 prefill tokens at 512/iter = 2 prefill iterations + 4 decode.
        assert_eq!(e.n_iterations, 2 + 4);
        assert_eq!(e.kv_allocator().n_requests(), 0, "KV leaked");
    }

    #[test]
    fn prefill_chunking_respects_budget() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 1300, 1));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.prefill_parts, vec![(1, 512, false)]);
        e.complete_iteration(&p1);
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.prefill_parts, vec![(1, 512, false)]);
        e.complete_iteration(&p2);
        let p3 = e.plan_iteration().unwrap();
        assert_eq!(p3.prefill_parts, vec![(1, 276, true)]);
        // Context of the last chunk ends at the full prompt.
        assert_eq!(p3.shape.prefill[0].ctx_end, 1300);
    }

    #[test]
    fn decode_piggybacks_with_prefill() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 400, 10));
        let p = e.plan_iteration().unwrap();
        e.complete_iteration(&p); // request 1 now decoding
        e.submit(EngineRequest::whole(2, 600, 10));
        let p = e.plan_iteration().unwrap();
        assert_eq!(p.decode_ids, vec![1]);
        // Remaining budget 511 goes to request 2's prefill.
        assert_eq!(p.prefill_parts, vec![(2, 511, false)]);
        assert_eq!(p.shape.n_decode, 1);
    }

    #[test]
    fn offset_request_transfers_then_prefills() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_offset(1, 1000, 3, 700));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.kv_recv, vec![(1, 700)]);
        assert!(p1.prefill_parts.is_empty(), "transfer replaces compute");
        assert!(p1.duration_s > 0.0);
        let ev = e.complete_iteration(&p1);
        assert_eq!(ev, vec![EngineEvent::KvReceived(1)]);
        // Next iteration prefills the remaining 300 with full context.
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.prefill_parts, vec![(1, 300, true)]);
        assert_eq!(p2.shape.prefill[0].ctx_end, 1000);
        let ev = e.complete_iteration(&p2);
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
    }

    #[test]
    fn full_disagg_offset_first_token_after_transfer() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::with_offset(1, 1000, 2, 1000));
        let p1 = e.plan_iteration().unwrap();
        assert_eq!(p1.kv_recv, vec![(1, 1000)]);
        let ev = e.complete_iteration(&p1);
        assert!(ev.contains(&EngineEvent::KvReceived(1)));
        assert!(ev.contains(&EngineEvent::FirstToken(1)));
        // Decode continues normally.
        let p2 = e.plan_iteration().unwrap();
        assert_eq!(p2.decode_ids, vec![1]);
        let ev = e.complete_iteration(&p2);
        assert!(ev.contains(&EngineEvent::Finished(1)));
    }

    #[test]
    fn transfer_overlaps_with_compute() {
        let mut e = engine(512, 200_000);
        // Build a big decode population first.
        for i in 0..64 {
            e.submit(EngineRequest::whole(i, 512, 50));
        }
        // Drain the waiting queue so the recv request is head-of-line.
        while e.stats().waiting > 0 || e.stats().n_prefilling > 0 {
            let p = e.plan_iteration().unwrap();
            e.complete_iteration(&p);
        }
        let stats = e.stats();
        assert!(stats.n_decode > 0);
        // Now a transfer arrives; iteration time must be the max of
        // compute and transfer, not their sum.
        e.submit(EngineRequest::with_offset(1000, 800, 5, 800));
        let p = e.plan_iteration().unwrap();
        assert!(!p.kv_recv.is_empty());
        let compute = e.perf_model().iteration_time(&p.shape);
        let transfer = LinkSpec::INFINIBAND_100G
            .kv_transfer_time(800, LLAMA3_8B.kv_bytes_per_token());
        assert!((p.duration_s - compute.max(transfer)).abs() < 1e-12);
    }

    #[test]
    fn admission_blocks_without_kv() {
        // Pool fits only ~62 tokens -> a 100-token prompt never admits.
        let mut e = engine(512, 64);
        e.submit(EngineRequest::whole(1, 100, 2));
        assert!(e.plan_iteration().is_none());
        // A small one admits fine behind it? No — head-of-line blocking.
        e.submit(EngineRequest::whole(2, 32, 2));
        assert!(e.plan_iteration().is_none());
    }

    #[test]
    fn preemption_on_decode_growth() {
        // Tiny pool: two requests fit during prefill, but decode growth
        // must preempt the younger one.
        let mut e = engine(512, 512 + 64);
        e.submit(EngineRequest::whole(1, 256, 200));
        e.submit(EngineRequest::whole(2, 256, 200));
        let mut preemptions = 0;
        let mut finished = 0;
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 10_000);
            let Some(plan) = e.plan_iteration() else { break };
            for ev in e.complete_iteration(&plan) {
                if let EngineEvent::Finished(_) = ev {
                    finished += 1;
                }
            }
            preemptions = e.n_preemptions;
            e.check_invariants().unwrap();
        }
        assert_eq!(finished, 2, "both requests must eventually finish");
        assert!(preemptions > 0, "expected decode-growth preemption");
    }

    #[test]
    fn preempted_request_does_not_double_report() {
        let mut e = engine(512, 512 + 64);
        e.submit(EngineRequest::whole(1, 256, 200));
        e.submit(EngineRequest::whole(2, 256, 200));
        let events = run_to_completion(&mut e);
        for id in [1u64, 2u64] {
            let first: usize = events
                .iter()
                .filter(|ev| **ev == EngineEvent::FirstToken(id))
                .count();
            let tokens: usize =
                events.iter().filter(|ev| **ev == EngineEvent::Token(id)).count();
            assert_eq!(first, 1, "req {id} first-token count");
            assert_eq!(tokens, 199, "req {id} token count");
        }
    }

    #[test]
    fn stats_reflect_queues() {
        let mut e = engine(512, 100_000);
        e.submit(EngineRequest::whole(1, 400, 10));
        e.submit(EngineRequest::whole(2, 10_000, 10)); // waits (budget)
        let s = e.stats();
        assert_eq!(s.waiting, 2);
        let p = e.plan_iteration().unwrap();
        e.complete_iteration(&p);
        let s = e.stats();
        assert_eq!(s.n_decode, 1);
        assert!(s.decode_ctx_sum >= 400);
        assert_eq!(s.block_size, 16);
    }

    #[test]
    fn from_params_uses_capacity() {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let e = EngineInstance::from_params(
            "cap",
            pm,
            LinkSpec::INFINIBAND_100G,
            &EngineParams::default(),
            512,
        );
        // ~500k tokens / 16 per block.
        assert!(e.kv_allocator().total_blocks() > 20_000);
    }

    #[test]
    fn many_requests_all_finish() {
        let mut e = engine(512, 300_000);
        for i in 0..100 {
            e.submit(EngineRequest::whole(i, 100 + (i as usize * 37) % 900, 1 + (i as usize % 40)));
        }
        let events = run_to_completion(&mut e);
        let fin = events.iter().filter(|e| matches!(e, EngineEvent::Finished(_))).count();
        assert_eq!(fin, 100);
        assert_eq!(e.kv_allocator().used_blocks(), 0);
    }
}
