//! The continuous-batching inference engine with chunked prefill —
//! the per-GPU substrate every serving system in this crate schedules on
//! (Sarathi/vLLM-style; the paper implements Cronus on a vLLM fork).
//!
//! One [`instance::EngineInstance`] models one GPU running one model
//! (or a layer fraction of it, for pipeline parallelism).  The driver
//! loop lives in the *system* (Cronus frontend, DP router, PP pipeline);
//! the engine only answers two questions:
//!
//! 1. [`instance::EngineInstance::plan_iteration`] — given current queues
//!    and KV state, what batch runs next and how long does it take?
//! 2. [`instance::EngineInstance::complete_iteration`] — apply the
//!    iteration's effects (tokens emitted, prefills advanced, requests
//!    finished, KV freed) and report them as events.
//!
//! Hot loops should use the allocation-free forms
//! [`instance::EngineInstance::plan_iteration_into`] /
//! [`instance::EngineInstance::complete_iteration_into`], which refill
//! caller-owned scratch buffers ([`instance::IterationPlan`] and a
//! `Vec<EngineEvent>`) instead of allocating per iteration — see the
//! README "Performance" section and EXPERIMENTS.md §Perf.
//!
//! Scheduling policy (matches the paper's setup):
//! * decode-first: every running decode request contributes one token;
//! * the remaining token budget (512, or 256 on DP's low-end GPU) is
//!   filled with prefill chunks, head-of-line first;
//! * admission requires KV blocks for the full prompt; decode growth
//!   allocates block-by-block and preempts the youngest request when the
//!   pool runs dry;
//! * a request arriving with `prefill_offset > 0` (Cronus partial
//!   prefill / disaggregated prefill) spends its first iteration fetching
//!   the prefix KV over the link — the transfer *replaces* its compute
//!   and overlaps with other requests' iteration (paper Fig. 2).

pub mod instance;
pub mod request;

pub use instance::{EngineEvent, EngineInstance, EngineStats, IterationPlan};
pub use request::{EngineRequest, Phase};
