//! Paged KV-cache block allocator (vLLM-style).
//!
//! GPU memory for KV caches is divided into fixed-size blocks of
//! `block_size` tokens; each request owns an ordered list of blocks
//! covering its context.  The engine admits requests only when enough
//! free blocks exist (Algorithm 1's `N_free` check reads this structure)
//! and grows allocations one block at a time as decode extends contexts,
//! preempting when the pool runs dry.

use crate::util::fxhash::FxHashMap;

pub type ReqId = u64;

/// Errors surfaced to the scheduler (which reacts by waiting/preempting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks { need: usize, free: usize },
    UnknownRequest(ReqId),
    AlreadyAllocated(ReqId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks: need {need}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyAllocated(id) => {
                write!(f, "request {id} already has an allocation")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Fixed-pool paged block allocator.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    block_size: usize,
    n_blocks: usize,
    free: Vec<u32>,
    /// request -> (block list, tokens covered)
    table: FxHashMap<ReqId, (Vec<u32>, usize)>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        BlockAllocator {
            block_size,
            n_blocks,
            free: (0..n_blocks as u32).rev().collect(),
            table: FxHashMap::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Blocks needed to cover `tokens` context tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a *new* allocation of `tokens` be satisfied right now?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate blocks covering `tokens` for a new request.
    pub fn allocate(&mut self, req: ReqId, tokens: usize) -> Result<(), KvError> {
        if self.table.contains_key(&req) {
            return Err(KvError::AlreadyAllocated(req));
        }
        let need = self.blocks_for(tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfBlocks { need, free: self.free.len() });
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.table.insert(req, (blocks, tokens));
        Ok(())
    }

    /// Extend a request's coverage to `new_tokens` total, allocating
    /// additional blocks as needed (decode growth: +1 token per step).
    ///
    /// Runs once per decode request per iteration, so it moves blocks
    /// off the free list in place instead of splitting off a temporary
    /// vector — the steady-state path performs no heap allocation (the
    /// request's block list doubles amortizedly as its context crosses
    /// power-of-two block counts; see EXPERIMENTS.md §Perf).
    pub fn grow(&mut self, req: ReqId, new_tokens: usize) -> Result<(), KvError> {
        let (blocks, tokens) = self
            .table
            .get_mut(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        if new_tokens <= *tokens {
            *tokens = (*tokens).max(new_tokens);
            return Ok(());
        }
        let have = blocks.len();
        let need_total = new_tokens.div_ceil(self.block_size);
        let extra = need_total.saturating_sub(have);
        if extra > self.free.len() {
            return Err(KvError::OutOfBlocks { need: extra, free: self.free.len() });
        }
        for _ in 0..extra {
            blocks.push(self.free.pop().expect("checked free list length"));
        }
        *tokens = new_tokens;
        Ok(())
    }

    /// Release all blocks owned by `req`.
    pub fn release(&mut self, req: ReqId) -> Result<usize, KvError> {
        let (mut blocks, _) =
            self.table.remove(&req).ok_or(KvError::UnknownRequest(req))?;
        let n = blocks.len();
        self.free.append(&mut blocks);
        Ok(n)
    }

    #[inline]
    pub fn tokens_of(&self, req: ReqId) -> Option<usize> {
        self.table.get(&req).map(|(_, t)| *t)
    }

    #[inline]
    pub fn holds(&self, req: ReqId) -> bool {
        self.table.contains_key(&req)
    }

    pub fn n_requests(&self) -> usize {
        self.table.len()
    }

    /// Sum of context tokens across all live allocations.
    pub fn total_tokens(&self) -> usize {
        self.table.values().map(|(_, t)| *t).sum()
    }

    /// Internal consistency check (used by property tests): every block is
    /// either free or owned by exactly one request, and counts add up.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.n_blocks];
        for &b in &self.free {
            let b = b as usize;
            if b >= self.n_blocks {
                return Err(format!("free block {b} out of range"));
            }
            if seen[b] {
                return Err(format!("block {b} double-counted (free list)"));
            }
            seen[b] = true;
        }
        for (req, (blocks, tokens)) in &self.table {
            if blocks.len() < tokens.div_ceil(self.block_size) {
                return Err(format!(
                    "req {req}: {} blocks cannot cover {} tokens",
                    blocks.len(),
                    tokens
                ));
            }
            for &b in blocks {
                let b = b as usize;
                if b >= self.n_blocks {
                    return Err(format!("owned block {b} out of range"));
                }
                if seen[b] {
                    return Err(format!("block {b} double-owned"));
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked block (neither free nor owned)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut a = BlockAllocator::new(10, 16);
        a.allocate(1, 33).unwrap(); // 3 blocks
        assert_eq!(a.free_blocks(), 7);
        assert_eq!(a.tokens_of(1), Some(33));
        assert_eq!(a.release(1).unwrap(), 3);
        assert_eq!(a.free_blocks(), 10);
        a.check_invariants().unwrap();
    }

    #[test]
    fn blocks_for_rounds_up() {
        let a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for(0), 0);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
    }

    #[test]
    fn allocation_fails_when_exhausted() {
        let mut a = BlockAllocator::new(4, 16);
        a.allocate(1, 48).unwrap(); // 3 blocks
        let err = a.allocate(2, 32).unwrap_err(); // needs 2, only 1 free
        assert_eq!(err, KvError::OutOfBlocks { need: 2, free: 1 });
        // Failed allocation must not leak partial state.
        assert_eq!(a.free_blocks(), 1);
        assert!(!a.holds(2));
        a.check_invariants().unwrap();
    }

    #[test]
    fn double_allocate_rejected() {
        let mut a = BlockAllocator::new(4, 16);
        a.allocate(1, 16).unwrap();
        assert_eq!(a.allocate(1, 16).unwrap_err(), KvError::AlreadyAllocated(1));
    }

    #[test]
    fn grow_within_block_is_free() {
        let mut a = BlockAllocator::new(4, 16);
        a.allocate(1, 10).unwrap();
        a.grow(1, 16).unwrap(); // still 1 block
        assert_eq!(a.free_blocks(), 3);
        a.grow(1, 17).unwrap(); // now 2 blocks
        assert_eq!(a.free_blocks(), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn grow_fails_preserves_state() {
        let mut a = BlockAllocator::new(2, 16);
        a.allocate(1, 32).unwrap(); // both blocks
        let err = a.grow(1, 33).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(a.tokens_of(1), Some(32));
        a.check_invariants().unwrap();
    }

    #[test]
    fn grow_is_monotone() {
        let mut a = BlockAllocator::new(4, 16);
        a.allocate(1, 32).unwrap();
        a.grow(1, 20).unwrap(); // shrink request ignored
        assert_eq!(a.tokens_of(1), Some(32));
    }

    #[test]
    fn release_unknown_rejected() {
        let mut a = BlockAllocator::new(2, 16);
        assert_eq!(a.release(9).unwrap_err(), KvError::UnknownRequest(9));
    }

    #[test]
    fn total_tokens_tracks_live_contexts() {
        let mut a = BlockAllocator::new(10, 16);
        a.allocate(1, 20).unwrap();
        a.allocate(2, 30).unwrap();
        assert_eq!(a.total_tokens(), 50);
        a.release(1).unwrap();
        assert_eq!(a.total_tokens(), 30);
        assert_eq!(a.n_requests(), 1);
    }

    #[test]
    fn zero_token_allocation() {
        let mut a = BlockAllocator::new(2, 16);
        a.allocate(1, 0).unwrap();
        assert_eq!(a.free_blocks(), 2);
        a.grow(1, 5).unwrap();
        assert_eq!(a.free_blocks(), 1);
        a.check_invariants().unwrap();
    }
}
