//! GPU device specifications (public spec-sheet numbers).
//!
//! The paper's testbeds pair an A100 (80 GB) with an A10 or A30 (24 GB).
//! We carry the three first-order quantities the two inference phases
//! care about — dense BF16 throughput (prefill is compute-bound), HBM
//! bandwidth (decode is memory-bound) and capacity (KV cache) — plus two
//! derate factors that map peak numbers to achievable ones.
//!
//! Each spec also carries two fleet-economics numbers the topology
//! planner budgets against: a nominal rental cost (USD per GPU-hour,
//! on-demand list-price ballpark) and the board power limit (watts).
//! Absolute dollar figures drift with the market; what the planner's
//! conclusions rest on is the *relative* cost ladder (A100 ≫ V100 >
//! A30 > A10 > T4), which is stable.

/// A GPU device description.  All numbers are *peak* spec-sheet values;
/// `compute_efficiency` / `mem_efficiency` derate them to the sustained
/// fractions a tuned serving kernel achieves (roughly constant across
/// this GPU family, so relative comparisons — what the paper's
/// conclusions rest on — are preserved).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense BF16 tensor-core throughput, TFLOP/s (no sparsity).
    pub bf16_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// Total device memory, GiB.
    pub mem_gib: f64,
    /// Fraction of peak FLOPs sustained on large matmuls.
    pub compute_efficiency: f64,
    /// Fraction of peak bandwidth sustained on streaming reads.
    pub mem_efficiency: f64,
    /// Fixed per-iteration overhead (kernel launches, scheduler), seconds.
    pub iteration_overhead_s: f64,
    /// Nominal rental cost, USD per GPU-hour (planner cost budget).
    pub cost_per_hour: f64,
    /// Board power limit (TDP), watts (planner power budget).
    pub power_w: f64,
}

impl GpuSpec {
    /// Achievable FLOP/s.
    pub fn flops(&self) -> f64 {
        self.bf16_tflops * 1e12 * self.compute_efficiency
    }

    /// Achievable bytes/s.
    pub fn bandwidth(&self) -> f64 {
        self.hbm_gbps * 1e9 * self.mem_efficiency
    }

    /// Total memory in bytes.
    pub fn mem_bytes(&self) -> f64 {
        self.mem_gib * (1u64 << 30) as f64
    }
}

/// NVIDIA A100 SXM 80 GB: 312 TFLOPS BF16, 2039 GB/s HBM2e.
pub const A100: GpuSpec = GpuSpec {
    name: "A100-80G",
    bf16_tflops: 312.0,
    hbm_gbps: 2039.0,
    mem_gib: 80.0,
    compute_efficiency: 0.50,
    mem_efficiency: 0.75,
    iteration_overhead_s: 4.0e-3,
    cost_per_hour: 3.00,
    power_w: 400.0,
};

/// NVIDIA A30 24 GB: 165 TFLOPS BF16, 933 GB/s HBM2.  Sustained serving
/// bandwidth on the smaller HBM2 stack derates harder than A100's HBM2e.
pub const A30: GpuSpec = GpuSpec {
    name: "A30",
    bf16_tflops: 165.0,
    hbm_gbps: 933.0,
    mem_gib: 24.0,
    compute_efficiency: 0.50,
    mem_efficiency: 0.62,
    iteration_overhead_s: 4.0e-3,
    cost_per_hour: 0.80,
    power_w: 165.0,
};

/// NVIDIA A10 24 GB: 125 TFLOPS BF16, 600 GB/s GDDR6.  GDDR6 sustains a
/// markedly lower fraction of peak than HBM on the scattered reads of
/// paged KV attention.
pub const A10: GpuSpec = GpuSpec {
    name: "A10",
    bf16_tflops: 125.0,
    hbm_gbps: 600.0,
    mem_gib: 24.0,
    compute_efficiency: 0.50,
    mem_efficiency: 0.52,
    iteration_overhead_s: 4.0e-3,
    cost_per_hour: 0.60,
    power_w: 150.0,
};

/// NVIDIA V100S 32 GB: 112 TFLOPS FP16 tensor, 1134 GB/s HBM2.  No BF16
/// tensor cores — served in FP16, with a lower sustained matmul fraction
/// on the older Volta pipeline.
pub const V100: GpuSpec = GpuSpec {
    name: "V100-32G",
    bf16_tflops: 112.0,
    hbm_gbps: 1134.0,
    mem_gib: 32.0,
    compute_efficiency: 0.45,
    mem_efficiency: 0.65,
    iteration_overhead_s: 4.0e-3,
    cost_per_hour: 1.20,
    power_w: 250.0,
};

/// NVIDIA T4 16 GB: 65 TFLOPS FP16 tensor, 300 GB/s GDDR6.  Too little
/// memory to hold an 8B model's weights plus KV — in a mixed cluster a
/// T4 partial-prefill instance degrades to a zero-length prefix and the
/// pair serves everything on its high-end card.
pub const T4: GpuSpec = GpuSpec {
    name: "T4",
    bf16_tflops: 65.0,
    hbm_gbps: 300.0,
    mem_gib: 16.0,
    compute_efficiency: 0.45,
    mem_efficiency: 0.50,
    iteration_overhead_s: 4.0e-3,
    cost_per_hour: 0.35,
    power_w: 70.0,
};

/// Every GPU model the simulator knows — the topology planner's default
/// inventory, ordered high-end first.
pub const ALL_GPUS: [GpuSpec; 5] = [A100, V100, A30, A10, T4];

/// Look up a spec by (case-insensitive) name, for config files / CLI.
pub fn by_name(name: &str) -> Option<GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "a100" | "a100-80g" => Some(A100),
        "a30" => Some(A30),
        "a10" => Some(A10),
        "v100" | "v100-32g" => Some(V100),
        "t4" => Some(T4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_sheet_values() {
        assert_eq!(A100.bf16_tflops, 312.0);
        assert_eq!(A30.bf16_tflops, 165.0);
        assert_eq!(A10.bf16_tflops, 125.0);
        assert_eq!(A100.mem_gib, 80.0);
        assert_eq!(A30.mem_gib, 24.0);
        assert_eq!(A10.mem_gib, 24.0);
    }

    #[test]
    fn hierarchy_high_to_low() {
        // The paper's premise: A100 dominates both low-end GPUs in
        // compute, bandwidth and memory; A30 dominates A10.
        assert!(A100.flops() > A30.flops() && A30.flops() > A10.flops());
        assert!(A100.bandwidth() > A30.bandwidth());
        assert!(A30.bandwidth() > A10.bandwidth());
        assert!(A100.mem_bytes() > A30.mem_bytes());
    }

    #[test]
    fn derated_numbers() {
        assert!((A100.flops() - 312.0e12 * A100.compute_efficiency).abs() < 1.0);
        assert!((A10.bandwidth() - 600.0e9 * A10.mem_efficiency).abs() < 1.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("A100").unwrap().name, "A100-80G");
        assert_eq!(by_name("a30").unwrap().name, "A30");
        assert_eq!(by_name("a10").unwrap().name, "A10");
        assert_eq!(by_name("v100").unwrap().name, "V100-32G");
        assert_eq!(by_name("T4").unwrap().name, "T4");
        assert!(by_name("h100").is_none());
    }

    #[test]
    fn mixed_fleet_ordering() {
        // The scale-out fleet's capability ladder: every low-end card is
        // dominated by the A100, and the T4 is the weakest of the set.
        for low in [&A30, &A10, &V100, &T4] {
            assert!(A100.flops() > low.flops(), "{}", low.name);
            assert!(A100.bandwidth() > low.bandwidth(), "{}", low.name);
        }
        assert!(T4.flops() < V100.flops() && T4.flops() < A10.flops());
        assert!(T4.mem_bytes() < A10.mem_bytes());
    }

    #[test]
    fn cost_and_power_ladder() {
        // Fleet economics follow capability: the A100 is by far the most
        // expensive and hungriest card, the T4 the cheapest and leanest.
        for low in [&V100, &A30, &A10, &T4] {
            assert!(A100.cost_per_hour > low.cost_per_hour, "{}", low.name);
            assert!(A100.power_w > low.power_w, "{}", low.name);
        }
        assert!(V100.cost_per_hour > A30.cost_per_hour);
        assert!(A30.cost_per_hour > A10.cost_per_hour);
        assert!(A10.cost_per_hour > T4.cost_per_hour);
        for g in &ALL_GPUS {
            assert!(g.cost_per_hour > 0.0 && g.power_w > 0.0, "{}", g.name);
        }
    }

    #[test]
    fn inventory_covers_every_named_spec() {
        assert_eq!(ALL_GPUS.len(), 5);
        for g in &ALL_GPUS {
            assert_eq!(by_name(g.name).unwrap(), *g);
        }
    }

    #[test]
    fn pp_layer_split_from_flops_matches_paper() {
        // The paper splits LLaMA3-8B (32 layers) into 23+9 on A100+A10 and
        // 21+11 on A100+A30; Qwen2-7B (28) into 20+8 and 18+10.  Verify
        // the proportional-to-BF16-FLOPS rule reproduces those splits.
        let split = |layers: f64, hi: &GpuSpec, lo: &GpuSpec| -> (u32, u32) {
            let f = hi.bf16_tflops / (hi.bf16_tflops + lo.bf16_tflops);
            let hi_layers = (layers * f).round() as u32;
            (hi_layers, layers as u32 - hi_layers)
        };
        assert_eq!(split(32.0, &A100, &A10), (23, 9));
        assert_eq!(split(32.0, &A100, &A30), (21, 11));
        assert_eq!(split(28.0, &A100, &A10), (20, 8));
        assert_eq!(split(28.0, &A100, &A30), (18, 10));
    }
}
