//! Heterogeneous-GPU substrate: device specs, an analytical execution-time
//! model in the paper's own form (Eq. 2 / Eq. 3), a network-link model for
//! KV-cache transfers, and the profiling/fitting pipeline that calibrates
//! the Balancer's coefficients exactly the way the paper does (linear
//! regression on profiled iteration times — Fig. 3).
//!
//! This module is the substitution for the paper's physical
//! A100/A30/A10 + InfiniBand testbed (DESIGN.md §1): every quantity the
//! schedulers consume (iteration times, memory capacities, transfer
//! times) is produced here from public spec-sheet numbers.

pub mod fit;
pub mod link;
pub mod model_desc;
pub mod perfmodel;
pub mod spec;

pub use fit::{profile_chunked, profile_prefill, ChunkedCoeffs, PrefillCoeffs};
pub use link::LinkSpec;
pub use model_desc::ModelDesc;
pub use perfmodel::{IterationShape, PerfModel, PrefillSeg};
pub use spec::GpuSpec;
