//! Analytical execution-time model for LLM inference iterations.
//!
//! This is the timing substrate the discrete-event simulation runs on.
//! It produces iteration times in exactly the *functional form* the paper
//! validates on real hardware (§4.4, Fig. 3, R² ≥ 0.99):
//!
//! * dense (MLP + projections) time — constant for a fixed token budget,
//!   `max(compute, weight-read)` roofline otherwise;
//! * prefill-attention time — linear in `q_tokens × context` (compute-bound
//!   matrix-matrix work, the paper's `k_ctxp · L(R_i^P2)` term);
//! * decode-attention time — linear in the total decode context
//!   (bandwidth-bound matrix-vector work, the `k_ctxd · Σ L(R_l^D)` term);
//! * a constant per-iteration overhead (`b_c`).
//!
//! Because the simulator *generates* times from a linear family, the
//! Balancer's regression-based predictors (calibrated from profiled
//! samples with measurement noise, `fit.rs`) recover them with the same
//! R²/MAPE quality the paper reports — preserving the control loop's
//! behaviour end to end.

use crate::simgpu::model_desc::ModelDesc;
use crate::simgpu::spec::GpuSpec;

/// One prefill segment scheduled into an iteration: `q_tokens` new prompt
/// tokens whose attention spans `ctx_end` total context (everything up to
/// and including this chunk).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefillSeg {
    pub q_tokens: usize,
    /// Total context visible to this chunk's last token.
    pub ctx_end: usize,
}

/// The composition of one engine iteration (a batch).
#[derive(Clone, Debug, Default)]
pub struct IterationShape {
    /// Prefill chunks in this batch.
    pub prefill: Vec<PrefillSeg>,
    /// Number of decode requests (one token each).
    pub n_decode: usize,
    /// Sum of context lengths across decode requests.
    pub decode_ctx_sum: usize,
}

impl IterationShape {
    pub fn total_new_tokens(&self) -> usize {
        self.prefill.iter().map(|p| p.q_tokens).sum::<usize>() + self.n_decode
    }

    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.n_decode == 0
    }
}

/// Per-(GPU, model, layer-fraction) performance model.
#[derive(Clone, Copy, Debug)]
pub struct PerfModel {
    pub gpu: GpuSpec,
    pub model: ModelDesc,
    /// Fraction of the model's layers resident on this GPU (1.0 except in
    /// pipeline parallelism).
    pub layer_fraction: f64,
}

impl PerfModel {
    pub fn new(gpu: GpuSpec, model: ModelDesc) -> Self {
        PerfModel { gpu, model, layer_fraction: 1.0 }
    }

    pub fn with_layer_fraction(gpu: GpuSpec, model: ModelDesc, frac: f64) -> Self {
        PerfModel { gpu, model, layer_fraction: frac }
    }

    /// Time for the dense (context-independent) work of a batch with
    /// `n_tokens` new tokens: roofline of matmul compute vs a full weight
    /// sweep (one read of every resident weight per iteration).
    pub fn dense_time(&self, n_tokens: usize) -> f64 {
        if n_tokens == 0 {
            return 0.0;
        }
        let compute = self.model.dense_flops_per_token(self.layer_fraction)
            * n_tokens as f64
            / self.gpu.flops();
        let weight_read =
            self.model.weight_bytes(self.layer_fraction) / self.gpu.bandwidth();
        compute.max(weight_read)
    }

    /// Prefill-attention time for one segment (compute-bound).  The
    /// average context across the chunk's tokens is `ctx_end - q/2`.
    pub fn prefill_attn_time(&self, seg: PrefillSeg) -> f64 {
        let avg_ctx = seg.ctx_end as f64 - seg.q_tokens as f64 / 2.0;
        self.model
            .attn_flops(seg.q_tokens as f64, avg_ctx.max(0.0), self.layer_fraction)
            / self.gpu.flops()
    }

    /// Decode-attention time: one KV-cache sweep of `ctx_sum` total
    /// context tokens (bandwidth-bound).
    pub fn decode_attn_time(&self, ctx_sum: usize) -> f64 {
        self.model.kv_bytes_per_token() as f64 * self.layer_fraction
            * ctx_sum as f64
            / self.gpu.bandwidth()
    }

    /// Full iteration time — the simulator's ground truth for one engine
    /// step, and the quantity the paper's Eq. 3 approximates linearly.
    pub fn iteration_time(&self, shape: &IterationShape) -> f64 {
        if shape.is_empty() {
            return 0.0;
        }
        let mut t = self.dense_time(shape.total_new_tokens());
        for seg in &shape.prefill {
            t += self.prefill_attn_time(*seg);
        }
        t += self.decode_attn_time(shape.decode_ctx_sum);
        t + self.gpu.iteration_overhead_s
    }

    /// Whole-prompt prefill time (a single large batch of `n` tokens) —
    /// the partial-prefill instance's cost model (paper Eq. 2's ground
    /// truth; linear in `n` once dense work dominates).
    pub fn prefill_time(&self, n_tokens: usize) -> f64 {
        if n_tokens == 0 {
            return 0.0;
        }
        let shape = IterationShape {
            prefill: vec![PrefillSeg { q_tokens: n_tokens, ctx_end: n_tokens }],
            n_decode: 0,
            decode_ctx_sum: 0,
        };
        self.iteration_time(&shape)
    }

    /// KV-cache bytes this GPU holds per token of context.
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.model.kv_bytes_per_token() as f64 * self.layer_fraction
    }

    /// Tokens of KV cache that fit on this device after weights and an
    /// activation reserve are subtracted.
    pub fn kv_capacity_tokens(&self, activation_reserve_frac: f64) -> usize {
        let weights = self.model.weight_bytes(self.layer_fraction);
        let reserve = self.gpu.mem_bytes() * activation_reserve_frac;
        let free = (self.gpu.mem_bytes() - weights - reserve).max(0.0);
        (free / self.kv_bytes_per_token()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::{LLAMA3_8B, QWEN2_7B};
    use crate::simgpu::spec::{A10, A100, A30};

    fn a100_llama() -> PerfModel {
        PerfModel::new(A100, LLAMA3_8B)
    }

    #[test]
    fn dense_time_scales_then_floors() {
        let pm = a100_llama();
        // Large batches are compute-bound: 2x tokens ~ 2x time.
        let t512 = pm.dense_time(512);
        let t1024 = pm.dense_time(1024);
        assert!((t1024 / t512 - 2.0).abs() < 1e-9);
        // Tiny batches are weight-read-bound: same time for 1 and 2 tokens.
        assert_eq!(pm.dense_time(1), pm.dense_time(2));
        assert!(pm.dense_time(1) > 0.0);
    }

    #[test]
    fn weight_read_floor_matches_bandwidth() {
        let pm = a100_llama();
        let expected = LLAMA3_8B.weight_bytes(1.0) / A100.bandwidth();
        assert!((pm.dense_time(1) - expected).abs() < 1e-12);
        // ~16 GB over ~1.6 TB/s ≈ 10 ms: sanity band for decode iterations.
        assert!((0.004..0.020).contains(&pm.dense_time(1)));
    }

    #[test]
    fn iteration_time_is_linear_in_prefill_ctx() {
        // The foundation of Fig. 3 / Eq. 3: fixing the token budget and
        // decode load, iteration time is affine in prefill context.
        let pm = a100_llama();
        let t = |ctx: usize| {
            pm.iteration_time(&IterationShape {
                prefill: vec![PrefillSeg { q_tokens: 512, ctx_end: ctx }],
                n_decode: 0,
                decode_ctx_sum: 0,
            })
        };
        let d1 = t(2048) - t(1024);
        let d2 = t(3072) - t(2048);
        assert!((d1 - d2).abs() < 1e-12, "not affine: {d1} vs {d2}");
        assert!(d1 > 0.0);
    }

    #[test]
    fn iteration_time_is_linear_in_decode_ctx() {
        let pm = a100_llama();
        let t = |ctx: usize| {
            pm.iteration_time(&IterationShape {
                prefill: vec![],
                n_decode: 32,
                decode_ctx_sum: ctx,
            })
        };
        let d1 = t(64_000) - t(32_000);
        let d2 = t(96_000) - t(64_000);
        assert!((d1 - d2).abs() < 1e-12);
        assert!(d1 > 0.0);
    }

    #[test]
    fn chunked_iteration_in_realistic_band() {
        // 512-token chunk on A100/LLaMA3-8B: paper's Fig. 3 regime is
        // tens of milliseconds per iteration.
        let pm = a100_llama();
        let t = pm.iteration_time(&IterationShape {
            prefill: vec![PrefillSeg { q_tokens: 512, ctx_end: 1024 }],
            n_decode: 64,
            decode_ctx_sum: 64 * 1200,
        });
        assert!((0.01..0.25).contains(&t), "iteration {t}s out of band");
    }

    #[test]
    fn prefill_faster_on_a100_than_a10() {
        let hi = PerfModel::new(A100, LLAMA3_8B).prefill_time(1014);
        let lo = PerfModel::new(A10, LLAMA3_8B).prefill_time(1014);
        let ratio = lo / hi;
        // Spec ratio is 312/125 = 2.5; attention + overhead distort a bit.
        assert!((1.8..3.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn kv_capacity_ordering_matches_paper_premise() {
        // A100 (80G) fits several times the KV of a 24G card — the reason
        // Cronus decodes on the high-end GPU.
        let hi = PerfModel::new(A100, LLAMA3_8B).kv_capacity_tokens(0.05);
        let a30 = PerfModel::new(A30, LLAMA3_8B).kv_capacity_tokens(0.05);
        let a10 = PerfModel::new(A10, LLAMA3_8B).kv_capacity_tokens(0.05);
        assert!(hi as f64 > 5.0 * a30 as f64, "hi {hi} a30 {a30}");
        assert_eq!(a30, a10); // same capacity, same KV fit
        // Low-end cards still fit a usable batch (~tens of requests).
        assert!(a10 > 20_000, "a10 {a10}");
    }

    #[test]
    fn qwen_kv_capacity_larger_than_llama() {
        // Narrower GQA -> more tokens fit -> higher throughput (Table 2).
        let llama = PerfModel::new(A100, LLAMA3_8B).kv_capacity_tokens(0.05);
        let qwen = PerfModel::new(A100, QWEN2_7B).kv_capacity_tokens(0.05);
        assert!(qwen as f64 > 1.8 * llama as f64);
    }

    #[test]
    fn layer_fraction_splits_work() {
        let full = PerfModel::new(A100, LLAMA3_8B);
        let frac = PerfModel::with_layer_fraction(A100, LLAMA3_8B, 0.25);
        let shape = IterationShape {
            prefill: vec![PrefillSeg { q_tokens: 512, ctx_end: 4096 }],
            n_decode: 16,
            decode_ctx_sum: 16_000,
        };
        let t_full = full.iteration_time(&shape) - A100.iteration_overhead_s;
        let t_frac = frac.iteration_time(&shape) - A100.iteration_overhead_s;
        assert!(
            (t_full / t_frac - 4.0).abs() < 0.2,
            "fraction scaling {t_full} vs {t_frac}"
        );
    }

    #[test]
    fn empty_iteration_costs_nothing() {
        let pm = a100_llama();
        assert_eq!(pm.iteration_time(&IterationShape::default()), 0.0);
        assert_eq!(pm.prefill_time(0), 0.0);
    }
}
