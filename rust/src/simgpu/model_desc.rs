//! Model geometry descriptors — the Rust mirror of `python/compile/model.py`'s
//! `ModelDims`.  The performance model derives FLOPs and byte counts from
//! these; the published LLaMA3-8B / Qwen2-7B configs drive the paper's
//! experiments, and the tiny config matches the AOT-compiled artifact.

/// Geometry of a decoder-only transformer (LLaMA family).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelDesc {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    /// Bytes per weight/KV element as served (2 = bf16).
    pub dtype_bytes: usize,
}

impl ModelDesc {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total parameter count (embed + blocks + head), matching
    /// `model.ModelDims.param_count()` on the Python side.
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ff as u64;
        let per_layer = d * self.q_dim() as u64
            + 2 * d * self.kv_dim() as u64
            + self.q_dim() as u64 * d
            + 3 * d * f
            + 2 * d;
        self.vocab as u64 * d * 2 + self.n_layers as u64 * per_layer + d
    }

    /// Weight bytes resident on a device serving `layer_fraction` of the
    /// model (PP shards layers; embeddings/head counted on their stage).
    pub fn weight_bytes(&self, layer_fraction: f64) -> f64 {
        self.param_count() as f64 * self.dtype_bytes as f64 * layer_fraction
    }

    /// KV-cache bytes per token of context (2 × layers × kv_dim × dtype).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.kv_dim() * self.dtype_bytes) as u64
    }

    /// Dense (non-attention-score) FLOPs to process one token:
    /// ~2 FLOPs per parameter touched (matmul-dominated).
    pub fn dense_flops_per_token(&self, layer_fraction: f64) -> f64 {
        2.0 * self.param_count() as f64 * layer_fraction
    }

    /// Attention-score FLOPs for `q_tokens` queries against an *average*
    /// context of `ctx` tokens: QKᵀ plus PV, 2·2·d_model per (q, ctx)
    /// pair per layer (GQA shares K/V storage, not score compute).
    pub fn attn_flops(&self, q_tokens: f64, ctx: f64, layer_fraction: f64) -> f64 {
        4.0 * self.n_layers as f64 * layer_fraction
            * self.d_model as f64
            * q_tokens
            * ctx
    }

    /// Bytes of activations crossing a pipeline-stage boundary for a batch
    /// of `n_tokens` (hidden states only).
    pub fn activation_bytes(&self, n_tokens: usize) -> f64 {
        (n_tokens * self.d_model * self.dtype_bytes) as f64
    }
}

/// LLaMA3-8B (32 layers, d=4096, 32 q-heads / 8 kv-heads, ff=14336).
pub const LLAMA3_8B: ModelDesc = ModelDesc {
    name: "llama3-8b",
    vocab: 128_256,
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    n_kv_heads: 8,
    head_dim: 128,
    d_ff: 14_336,
    dtype_bytes: 2,
};

/// Qwen2-7B (28 layers, d=3584, 28 q-heads / 4 kv-heads, ff=18944).
pub const QWEN2_7B: ModelDesc = ModelDesc {
    name: "qwen2-7b",
    vocab: 152_064,
    d_model: 3584,
    n_layers: 28,
    n_heads: 28,
    n_kv_heads: 4,
    head_dim: 128,
    d_ff: 18_944,
    dtype_bytes: 2,
};

/// The tiny model actually AOT-compiled and executed (matches
/// `python/compile/model.py::TINY`; served in f32 on CPU).
pub const TINY: ModelDesc = ModelDesc {
    name: "tiny-llama",
    vocab: 2048,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    n_kv_heads: 2,
    head_dim: 32,
    d_ff: 704,
    dtype_bytes: 4,
};

pub fn by_name(name: &str) -> Option<ModelDesc> {
    match name.to_ascii_lowercase().as_str() {
        "llama3-8b" | "llama" => Some(LLAMA3_8B),
        "qwen2-7b" | "qwen" => Some(QWEN2_7B),
        "tiny-llama" | "tiny" => Some(TINY),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        let llama = LLAMA3_8B.param_count() as f64;
        assert!((7.5e9..8.5e9).contains(&llama), "llama {llama}");
        let qwen = QWEN2_7B.param_count() as f64;
        assert!((7.0e9..8.2e9).contains(&qwen), "qwen {qwen}");
    }

    #[test]
    fn kv_bytes_per_token() {
        // LLaMA3-8B: 2 * 32 layers * (8*128) * 2 bytes = 128 KiB/token.
        assert_eq!(LLAMA3_8B.kv_bytes_per_token(), 131_072);
        // Qwen2-7B's GQA is narrower: 2 * 28 * 512 * 2 = 56 KiB/token —
        // the reason its decode throughput is higher in Table 2.
        assert_eq!(QWEN2_7B.kv_bytes_per_token(), 57_344);
    }

    #[test]
    fn tiny_matches_python_manifest_values() {
        assert_eq!(TINY.param_count(), 3_868_928);
        assert_eq!(TINY.n_layers, 4);
        assert_eq!(TINY.vocab, 2048);
    }

    #[test]
    fn layer_fraction_scales_linearly() {
        let full = LLAMA3_8B.dense_flops_per_token(1.0);
        let half = LLAMA3_8B.dense_flops_per_token(0.5);
        assert!((full / half - 2.0).abs() < 1e-12);
        assert!(LLAMA3_8B.weight_bytes(0.25) * 4.0 - LLAMA3_8B.weight_bytes(1.0) < 1.0);
    }

    #[test]
    fn attn_flops_bilinear() {
        let a = LLAMA3_8B.attn_flops(512.0, 1000.0, 1.0);
        assert_eq!(a, 4.0 * 32.0 * 4096.0 * 512.0 * 1000.0);
        assert_eq!(LLAMA3_8B.attn_flops(256.0, 1000.0, 1.0) * 2.0, a);
        assert_eq!(LLAMA3_8B.attn_flops(512.0, 500.0, 1.0) * 2.0, a);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("llama3-8b").unwrap().n_layers, 32);
        assert_eq!(by_name("QWEN").unwrap().n_layers, 28);
        assert!(by_name("gpt-5").is_none());
    }
}
