//! Inter-node network link model (α–β): the paper's nodes are connected
//! by 100 Gbps InfiniBand, over which disaggregated/partial prefill ships
//! KV caches from the prefill instance to the decode instance.

/// A point-to-point link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Line rate in Gbit/s.
    pub gbps: f64,
    /// One-way latency (α term), seconds.
    pub latency_s: f64,
    /// Achievable fraction of line rate (protocol + RDMA overheads).
    pub efficiency: f64,
}

impl LinkSpec {
    /// The paper's testbed link: 100 Gbps InfiniBand between nodes.
    pub const INFINIBAND_100G: LinkSpec =
        LinkSpec { gbps: 100.0, latency_s: 5.0e-6, efficiency: 0.90 };

    /// Achievable bytes per second.
    pub fn bytes_per_sec(&self) -> f64 {
        self.gbps * 1e9 / 8.0 * self.efficiency
    }

    /// α–β transfer time for `bytes`.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.latency_s + bytes / self.bytes_per_sec()
    }

    /// Time to ship the KV cache of `tokens` context tokens for a model
    /// storing `kv_bytes_per_token` per token.
    pub fn kv_transfer_time(&self, tokens: usize, kv_bytes_per_token: u64) -> f64 {
        self.transfer_time(tokens as f64 * kv_bytes_per_token as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;

    #[test]
    fn line_rate() {
        let l = LinkSpec::INFINIBAND_100G;
        assert!((l.bytes_per_sec() - 11.25e9).abs() < 1.0);
    }

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(LinkSpec::INFINIBAND_100G.transfer_time(0.0), 0.0);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let l = LinkSpec::INFINIBAND_100G;
        let t = l.transfer_time(100.0);
        assert!((t - l.latency_s) / l.latency_s < 0.01);
    }

    #[test]
    fn kv_transfer_in_realistic_band() {
        // A 1014-token LLaMA3-8B prompt's KV is ~130 MB -> ~12 ms on
        // 100 Gbps IB.  This is the quantity Fig. 2 overlaps with compute.
        let l = LinkSpec::INFINIBAND_100G;
        let t = l.kv_transfer_time(1014, LLAMA3_8B.kv_bytes_per_token());
        assert!((0.005..0.05).contains(&t), "kv transfer {t}");
    }

    #[test]
    fn transfer_linear_in_tokens() {
        let l = LinkSpec::INFINIBAND_100G;
        let per = LLAMA3_8B.kv_bytes_per_token();
        let t1 = l.kv_transfer_time(1000, per) - l.latency_s;
        let t2 = l.kv_transfer_time(2000, per) - l.latency_s;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
