//! Inter-node network link model (α–β): the paper's nodes are connected
//! by 100 Gbps InfiniBand, over which disaggregated/partial prefill ships
//! KV caches from the prefill instance to the decode instance.

/// A point-to-point link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Line rate in Gbit/s.
    pub gbps: f64,
    /// One-way latency (α term), seconds.
    pub latency_s: f64,
    /// Achievable fraction of line rate (protocol + RDMA overheads).
    pub efficiency: f64,
}

impl LinkSpec {
    /// The paper's testbed link: 100 Gbps InfiniBand between nodes.
    pub const INFINIBAND_100G: LinkSpec =
        LinkSpec { gbps: 100.0, latency_s: 5.0e-6, efficiency: 0.90 };

    /// Achievable bytes per second.
    pub fn bytes_per_sec(&self) -> f64 {
        self.gbps * 1e9 / 8.0 * self.efficiency
    }

    /// α–β transfer time for `bytes`.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.latency_s + bytes / self.bytes_per_sec()
    }

    /// Time to ship the KV cache of `tokens` context tokens for a model
    /// storing `kv_bytes_per_token` per token.
    pub fn kv_transfer_time(&self, tokens: usize, kv_bytes_per_token: u64) -> f64 {
        self.transfer_time(tokens as f64 * kv_bytes_per_token as f64)
    }

    /// Parse the config grammar `<gbps>G[@<latency>us][:<efficiency>]`:
    /// `"100G"` is a 100 Gbps link with [`INFINIBAND_100G`]'s latency and
    /// efficiency, `"25G@20us:0.8"` overrides both.  Case-insensitive on
    /// the unit suffixes.
    pub fn parse(text: &str) -> Result<LinkSpec, String> {
        let mut spec = LinkSpec::INFINIBAND_100G;
        let (rest, eff) = match text.rsplit_once(':') {
            Some((r, e)) => {
                let eff: f64 = e
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad link efficiency in '{text}'"))?;
                if !(eff > 0.0 && eff <= 1.0) {
                    return Err(format!("link efficiency must be in (0, 1] in '{text}'"));
                }
                (r, Some(eff))
            }
            None => (text, None),
        };
        let (rate, lat) = match rest.split_once('@') {
            Some((r, l)) => {
                let l = l.trim();
                let micros = l
                    .strip_suffix("us")
                    .or_else(|| l.strip_suffix("US"))
                    .ok_or_else(|| format!("link latency must end in 'us' in '{text}'"))?;
                let us: f64 = micros
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad link latency in '{text}'"))?;
                if us < 0.0 {
                    return Err(format!("link latency must be >= 0 in '{text}'"));
                }
                (r, Some(us * 1e-6))
            }
            None => (rest, None),
        };
        let rate = rate.trim();
        let gbps_txt = rate
            .strip_suffix('G')
            .or_else(|| rate.strip_suffix('g'))
            .ok_or_else(|| format!("link rate must end in 'G' in '{text}'"))?;
        let gbps: f64 = gbps_txt
            .trim()
            .parse()
            .map_err(|_| format!("bad link rate in '{text}'"))?;
        if !(gbps > 0.0) {
            return Err(format!("link rate must be > 0 in '{text}'"));
        }
        spec.gbps = gbps;
        if let Some(l) = lat {
            spec.latency_s = l;
        }
        if let Some(e) = eff {
            spec.efficiency = e;
        }
        Ok(spec)
    }

    /// Render this link back into the grammar [`LinkSpec::parse`]
    /// accepts, eliding the suffixes that match the InfiniBand defaults.
    pub fn spec(&self) -> String {
        let mut s = format!("{}G", self.gbps);
        if self.latency_s != LinkSpec::INFINIBAND_100G.latency_s {
            s.push_str(&format!("@{}us", self.latency_s * 1e6));
        }
        if self.efficiency != LinkSpec::INFINIBAND_100G.efficiency {
            s.push(':');
            s.push_str(&self.efficiency.to_string());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;

    #[test]
    fn line_rate() {
        let l = LinkSpec::INFINIBAND_100G;
        assert!((l.bytes_per_sec() - 11.25e9).abs() < 1.0);
    }

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(LinkSpec::INFINIBAND_100G.transfer_time(0.0), 0.0);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let l = LinkSpec::INFINIBAND_100G;
        let t = l.transfer_time(100.0);
        assert!((t - l.latency_s) / l.latency_s < 0.01);
    }

    #[test]
    fn kv_transfer_in_realistic_band() {
        // A 1014-token LLaMA3-8B prompt's KV is ~130 MB -> ~12 ms on
        // 100 Gbps IB.  This is the quantity Fig. 2 overlaps with compute.
        let l = LinkSpec::INFINIBAND_100G;
        let t = l.kv_transfer_time(1014, LLAMA3_8B.kv_bytes_per_token());
        assert!((0.005..0.05).contains(&t), "kv transfer {t}");
    }

    #[test]
    fn parse_full_and_defaulted_specs() {
        let l = LinkSpec::parse("100G").unwrap();
        assert_eq!(l, LinkSpec::INFINIBAND_100G);
        let l = LinkSpec::parse("25G@20us:0.8").unwrap();
        assert_eq!(l.gbps, 25.0);
        assert!((l.latency_s - 20e-6).abs() < 1e-12);
        assert_eq!(l.efficiency, 0.8);
        let l = LinkSpec::parse("10g@5us").unwrap();
        assert_eq!(l.gbps, 10.0);
        assert_eq!(l.efficiency, LinkSpec::INFINIBAND_100G.efficiency);
        assert!(LinkSpec::parse("100").is_err(), "missing G suffix");
        assert!(LinkSpec::parse("0G").is_err(), "zero rate");
        assert!(LinkSpec::parse("100G@5ms").is_err(), "latency unit");
        assert!(LinkSpec::parse("100G:1.5").is_err(), "efficiency > 1");
    }

    #[test]
    fn spec_round_trips_through_parse() {
        for text in ["100G", "25G@20us:0.8", "10G:0.5", "40G@1us"] {
            let l = LinkSpec::parse(text).unwrap();
            let rt = LinkSpec::parse(&l.spec()).unwrap();
            assert_eq!(rt, l, "'{text}' -> '{}' changed the link", l.spec());
        }
        assert_eq!(LinkSpec::INFINIBAND_100G.spec(), "100G");
    }

    #[test]
    fn transfer_linear_in_tokens() {
        let l = LinkSpec::INFINIBAND_100G;
        let per = LLAMA3_8B.kv_bytes_per_token();
        let t1 = l.kv_transfer_time(1000, per) - l.latency_s;
        let t2 = l.kv_transfer_time(2000, per) - l.latency_s;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
